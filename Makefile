PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast determinism-gate lint analyze bench bench-dryrun bench-serve \
        bench-rounds bench-comm bench-privacy bench-agents bench-roofline \
        sweep sweep-comm sweep-privacy docs-check quickstart serve-example \
        strategies-parity

# Tier-1 gate: the full suite.  Multi-device sharding checks spawn their own
# subprocesses with --xla_force_host_platform_device_count=8.
test:
	$(PY) -m pytest -x -q

# Everything except tests carrying the `slow` marker (pytest.ini): the
# subprocess lower+compile checks.
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# Replay determinism: the seeded async straggler simulation must produce
# byte-identical event journals (and the same final-params digest, which
# is a journal field) across two runs.  cmp diffs the files raw.
determinism-gate:
	$(PY) -m repro.run.simclock --seed 7 --rounds 6 --out /tmp/det_a.jsonl
	$(PY) -m repro.run.simclock --seed 7 --rounds 6 --out /tmp/det_b.jsonl
	cmp /tmp/det_a.jsonl /tmp/det_b.jsonl
	@echo "determinism gate: journals byte-identical"

# No linter wheel ships in the container: byte-compile everything, verify
# the public entry points import (catches syntax + import drift cheaply),
# then run the repo-specific AST lint (host-sync, kernel/ref pairing,
# refusal-matrix, catalogue drift) against the committed baseline.
lint:
	$(PY) -m compileall -q src tests benchmarks examples
	$(PY) -c "import repro, repro.dist, repro.launch.steps, repro.launch.dryrun, repro.configs, repro.models, repro.core, repro.kernels, repro.serve, repro.checkpoint, repro.run, repro.run.experiments, repro.data, repro.evals, repro.comm, repro.kernels.qpack.ops, repro.kernels.qsync.ops"
	$(PY) -m repro.analysis --rules lint

# The full two-layer static-analysis pass: AST lint + jaxpr trace audit +
# the strategy x codec wire matrix (compiles every cell on an emulated
# 8-device mesh — minutes, not seconds).  Fails on any non-baseline
# finding; report lands in analysis_report.json.
analyze:
	$(PY) -m repro.analysis --rules all --out analysis_report.json

# Execute every runnable snippet in docs/*.md (the docs-drift gate).
docs-check:
	$(PY) -m pytest -q tests/test_docs_snippets.py

# Paper-figure benchmarks at reduced budgets (CSV to stdout).
bench:
	$(PY) benchmarks/run.py --fast

# One production-mesh dry-run pair (slow: compiles for 512 emulated devices).
ARCH ?= gemma3-4b
SHAPE ?= train_4k
bench-dryrun:
	$(PY) -m repro.launch.dryrun --arch $(ARCH) --shape $(SHAPE)

# Serving-path benchmark with machine-readable BENCH_serve.json artifact.
bench-serve:
	$(PY) benchmarks/run.py --only serve --fast --json

# Round-loop throughput (legacy blocking loop vs repro.run driver) with
# machine-readable BENCH_rounds.json artifact — the perf trajectory row.
bench-rounds:
	$(PY) benchmarks/run.py --only rounds --fast --json

# Wire-byte accounting per strategy/codec + qpack pack/unpack throughput,
# with machine-readable BENCH_comm.json artifact (byte-count shaped rows —
# the CI host is a 2-core container, backbone steps/s would be noise).
bench-comm:
	$(PY) benchmarks/run.py --only comm --json

# Privacy/robustness cost surface: mode coverage under a planted Byzantine
# agent (plain vs trimmed-mean/median), DP-SGD with its accountant epsilon,
# masked-sync overhead + wire accounting.  BENCH_privacy.json artifact.
bench-privacy:
	$(PY) benchmarks/run.py --only privacy --fast --json

# Per-kernel roofline rows (qpack pack/unpack, fedavg, fused qsync, fused
# adam+sync: achieved GB/s + elems/s vs a measured copy roofline) plus the
# fused-vs-composed dispatch-count row, no dry-run artifacts needed.
# BENCH_roofline.json artifact; CI gates the quantize-site counts (the
# 2-core container's wall-clock is noise — see benchmarks/ROOFLINE.md).
bench-roofline:
	$(PY) benchmarks/run.py --only roofline --fast --json

# Virtual-client fleet scaling: dense-vs-identity overhead + rounds/s
# flatness 16 -> 1024 registered clients at a 16-slot cohort, with
# machine-readable BENCH_agents.json artifact (both numbers CI-gated).
bench-agents:
	$(PY) benchmarks/run.py --only agents --fast --json

# The paper's robustness-to-reduced-communication curve in one command
# (FID stand-in vs K, FedGAN vs the per-step distributed baseline).
sweep:
	$(PY) -m repro.run.experiments --experiment toy_2d \
	    --sweep K=1,5,20,50 --compare distributed --steps 1000

# The K×codec communication surface: quality + measured bytes/round per
# (K, codec) cell on mixed_gaussian (int8/int4 + error feedback vs
# uncompressed) at the paper's full step budget — the numbers quoted in
# docs/communication.md.  ~half an hour on a 2-core CPU box.
sweep-comm:
	$(PY) -m repro.run.experiments --experiment mixed_gaussian \
	    --sweep K=5,20 --codecs none,int8,int4

# The K×codec×privacy cost surface (PR 6 acceptance sweep): quality +
# bytes/round + dp_epsilon per (K, privacy) cell on mixed_gaussian.
sweep-privacy:
	$(PY) -m repro.run.experiments --experiment mixed_gaussian \
	    --sweep K=5,20 --privacy none,dp,secure,trimmed_mean,median

quickstart:
	$(PY) examples/quickstart.py --K 20

# Continuous-batching serving example (smoke-size arch, CPU-friendly).
serve-example:
	$(PY) examples/serve_generator.py --arch gemma3-4b --requests 5 --gen 8

# SyncStrategy parity (legacy mode strings vs strategies, bit-identical)
# + launcher strategy plumbing.
strategies-parity:
	$(PY) -m pytest -q tests/test_strategies.py tests/test_launch_cli.py
