"""Agent-axis scaling: the virtual-client scheduler vs fleet size.

Two claims, both CI-gated from BENCH_agents.json:

  * **flat scaling** — rounds/s at a fixed cohort (``A_active = 16``) must
    stay flat (±15%) as the registered fleet grows 16 -> 1024: the round
    executable is compiled for the ``(P, A_active)`` slot grid only, and
    paging cost tracks the *cohort* (diff-based swaps), never ``A_total``.
    The 1024-client case doubles as the 2-core-host OOM smoke: device
    state is bounded by the 16 slots, the other 1008 clients are host rows
    (copy-on-write over the shared init template).
  * **thin when idle** — with ``A_total == A_active`` and the identity
    schedule the scheduler swaps nothing, so its rounds/s must stay
    within 15% of the dense ``RoundDriver`` stream path.

Run directly (``python benchmarks/bench_agents.py --json``) or as the
``agents`` suite of ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import os
import sys

# support `python benchmarks/bench_agents.py` directly (run.py does the
# same dance for the suite path)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from benchmarks import common


def _virtual_driver(spec):
    from repro.core.participation import ParticipationSchedule
    from repro.run.virtual import VirtualClientDriver
    fed, fleet = spec.build_fleet()
    return VirtualClientDriver(
        fed, fleet, spec.n_rounds, log_every=0,
        schedule=ParticipationSchedule(seed=spec.participation_seed))


def _median(runs, key):
    return sorted(runs, key=lambda r: r.timings[key])[len(runs) // 2]


def _interleaved(drivers, seeds, n=3):
    """Warm each driver (pays the one compile), then round-robin ``n``
    timed runs across all of them.  The CI host shares 2 cores and its
    effective clock drifts ±20% over a suite, so configs whose ratio is
    gated must sample the same time windows — a sequential sweep turns
    that drift into a fake scaling trend."""
    for d, s in zip(drivers, seeds):
        d.run(jax.random.key(s))
    runs = [[] for _ in drivers]
    for _ in range(n):
        for i, (d, s) in enumerate(zip(drivers, seeds)):
            runs[i].append(d.run(jax.random.key(s)))
    return runs


def bench_fleet_scaling(*, fast: bool = False):
    """rounds/s vs A_total at A_active = 16 on mixed_gaussian."""
    from repro.launch.train import experiment_spec
    a_active = 16
    n = 6 if fast else 20
    samples = 64 if fast else 256
    a_totals = (16, 64, 256, 1024)
    drivers, seeds = [], []
    for a_total in a_totals:
        spec, _ = experiment_spec(
            "mixed_gaussian", K=5, steps=n * 5, log_every=0,
            a_total=a_total, a_active=a_active, samples_per_agent=samples)
        drivers.append(_virtual_driver(spec))
        seeds.append(spec.seed + 1)
    all_runs = _interleaved(drivers, seeds)
    rps = {}
    for a_total, driver, runs in zip(a_totals, drivers, all_runs):
        t = _median(runs, "rounds_per_s").timings
        rps[a_total] = t["rounds_per_s"]
        assert driver.n_traces == 1, driver.n_traces  # compiled once, warm
        common.emit(
            f"agents_fleet_{a_total}", 1e6 / t["rounds_per_s"],
            f"{t['rounds_per_s']:.1f} rounds/s, {t['store_rows']} host rows, "
            f"{t['swapped_rows']} swapped",
            rounds_per_s=round(t["rounds_per_s"], 2),
            a_total=a_total, a_active=a_active,
            store_rows=t["store_rows"], swapped_rows=t["swapped_rows"],
            n_rounds=n, K=5, samples_per_agent=samples)
    flatness = rps[1024] / rps[16]
    common.emit("agents_scaling_flatness", 0.0,
                f"rounds/s(A_total=1024) / rounds/s(16) = {flatness:.3f}",
                flatness=round(flatness, 3))
    return flatness


def bench_virtual_overhead(*, fast: bool = False):
    """Identity-cohort virtual path vs the dense stream RoundDriver."""
    from repro.launch.train import experiment_spec
    from repro.run.driver import RoundDriver
    n = 8 if fast else 25
    samples = 64 if fast else 256
    kw = dict(K=5, steps=n * 5, log_every=0, samples_per_agent=samples)
    dense_spec, _ = experiment_spec("mixed_gaussian", agents=16, **kw)
    fed, _ = dense_spec.build()
    dense = RoundDriver(fed, dense_spec.build_data(), n, log_every=0)
    virt_spec, _ = experiment_spec("mixed_gaussian", a_total=16,
                                   a_active=16, **kw)
    virt = _virtual_driver(virt_spec)
    dense_runs, virt_runs = _interleaved(
        [dense, virt], [dense_spec.seed + 1, virt_spec.seed + 1])
    dense_res = _median(dense_runs, "steps_per_s")
    virt_res = _median(virt_runs, "rounds_per_s")
    assert virt_res.timings["swapped_rows"] == 0  # identity schedule pages 0

    # the dense driver reports steps/s; rounds/s = steps/s / K
    d_rps = dense_res.timings["steps_per_s"] / 5
    v_rps = virt_res.timings["rounds_per_s"]
    overhead = d_rps / v_rps - 1.0
    common.emit(
        "agents_virtual_overhead", 1e6 / v_rps,
        f"dense {d_rps:.1f} vs virtual {v_rps:.1f} rounds/s "
        f"({overhead * 100:+.1f}% overhead)",
        dense_rounds_per_s=round(d_rps, 2),
        virtual_rounds_per_s=round(v_rps, 2),
        overhead_frac=round(overhead, 4), n_rounds=n)
    return overhead


def bench_async_stragglers(*, fast: bool = False):
    """Buffered-async vs blocking-sync under injected stragglers.

    The headline number is **virtual-time makespan**: the async schedule
    (flush every ``buffer_goal`` arrivals) vs the modeled blocking
    schedule (every round waits for its slowest cohort member) under the
    *same* seeded latency model — fully deterministic, so CI gates the
    speedup tightly.  Wall-clock for the async run is emitted as its own
    row and gated only against the committed baseline with generous slack
    (the 2-core CI host's clock drifts; determinism does not)."""
    from repro.core.participation import ParticipationSchedule
    from repro.launch.train import experiment_spec
    from repro.run.async_agg import AsyncAggDriver, modeled_sync_makespan
    from repro.run.simclock import LatencyModel
    from repro.run.virtual import StragglerPolicy

    n = 6 if fast else 16
    samples = 64 if fast else 256
    a_total, a_active = 16, 8
    spec, _ = experiment_spec(
        "mixed_gaussian", K=5, steps=n * 5, log_every=0,
        a_total=a_total, a_active=a_active, samples_per_agent=samples)
    fed, fleet = spec.build_fleet()
    schedule = ParticipationSchedule(seed=spec.participation_seed)
    latency = LatencyModel(base=1.0, jitter=0.5, straggler_frac=0.25,
                           straggler_factor=8.0)
    driver = AsyncAggDriver(
        fed, fleet, n, log_every=0, schedule=schedule,
        straggler=StragglerPolicy(mode="defer", decay=0.5, max_staleness=2),
        buffer_goal=a_active // 2, latency=latency,
        timeout=6.0, max_retries=2, backoff=2.0)
    runs = _interleaved([driver], [spec.seed + 1])[0]
    res = _median(runs, "total_s")
    assert driver.n_traces == 1, driver.n_traces  # one (1,1) trace, warm

    sync_makespan = modeled_sync_makespan(schedule, latency, n,
                                          a_total, a_active)
    speedup = sync_makespan / res.timings["makespan"]
    common.emit(
        "agents_async_makespan", 0.0,
        f"async {res.timings['makespan']:.2f} vs blocking-sync "
        f"{sync_makespan:.2f} virtual s ({speedup:.2f}x), "
        f"{res.timings['timeouts']} timeouts, "
        f"{res.timings['expired_deltas']} expired",
        makespan=round(res.timings["makespan"], 4),
        sync_makespan=round(sync_makespan, 4),
        async_speedup=round(speedup, 3),
        timeouts=res.timings["timeouts"], retries=res.timings["retries"],
        gave_up=res.timings["gave_up"],
        expired_deltas=res.timings["expired_deltas"],
        merged_deltas=res.timings["merged_deltas"],
        buffer_goal=a_active // 2, n_rounds=n,
        a_total=a_total, a_active=a_active)
    common.emit(
        "agents_async_wallclock", 1e6 * res.timings["total_s"],
        f"{res.timings['total_s'] * 1e3:.0f} ms wall for {n} flushes "
        f"({res.timings['dispatches']} dispatches)",
        total_s=round(res.timings["total_s"], 4),
        dispatches=res.timings["dispatches"], n_rounds=n)
    return speedup


def main(*, fast: bool = False):
    bench_virtual_overhead(fast=fast)
    bench_fleet_scaling(fast=fast)
    bench_async_stragglers(fast=fast)


if __name__ == "__main__":
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_agents.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
    if args.json:
        with open("BENCH_agents.json", "w") as f:
            json.dump({"suite": "agents", "fast": args.fast,
                       "records": common.drain_records()}, f, indent=1)
        print("# wrote BENCH_agents.json", file=sys.stderr)
