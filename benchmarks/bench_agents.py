"""Agent-axis scaling: the virtual-client scheduler vs fleet size.

Two claims, both CI-gated from BENCH_agents.json:

  * **flat scaling** — rounds/s at a fixed cohort (``A_active = 16``) must
    stay flat (±15%) as the registered fleet grows 16 -> 1024: the round
    executable is compiled for the ``(P, A_active)`` slot grid only, and
    paging cost tracks the *cohort* (diff-based swaps), never ``A_total``.
    The 1024-client case doubles as the 2-core-host OOM smoke: device
    state is bounded by the 16 slots, the other 1008 clients are host rows
    (copy-on-write over the shared init template).
  * **thin when idle** — with ``A_total == A_active`` and the identity
    schedule the scheduler swaps nothing, so its rounds/s must stay
    within 15% of the dense ``RoundDriver`` stream path.

Run directly (``python benchmarks/bench_agents.py --json``) or as the
``agents`` suite of ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import os
import sys

# support `python benchmarks/bench_agents.py` directly (run.py does the
# same dance for the suite path)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from benchmarks import common


def _virtual_driver(spec):
    from repro.core.participation import ParticipationSchedule
    from repro.run.virtual import VirtualClientDriver
    fed, fleet = spec.build_fleet()
    return VirtualClientDriver(
        fed, fleet, spec.n_rounds, log_every=0,
        schedule=ParticipationSchedule(seed=spec.participation_seed))


def _median(runs, key):
    return sorted(runs, key=lambda r: r.timings[key])[len(runs) // 2]


def _interleaved(drivers, seeds, n=3):
    """Warm each driver (pays the one compile), then round-robin ``n``
    timed runs across all of them.  The CI host shares 2 cores and its
    effective clock drifts ±20% over a suite, so configs whose ratio is
    gated must sample the same time windows — a sequential sweep turns
    that drift into a fake scaling trend."""
    for d, s in zip(drivers, seeds):
        d.run(jax.random.key(s))
    runs = [[] for _ in drivers]
    for _ in range(n):
        for i, (d, s) in enumerate(zip(drivers, seeds)):
            runs[i].append(d.run(jax.random.key(s)))
    return runs


def bench_fleet_scaling(*, fast: bool = False):
    """rounds/s vs A_total at A_active = 16 on mixed_gaussian."""
    from repro.launch.train import experiment_spec
    a_active = 16
    n = 6 if fast else 20
    samples = 64 if fast else 256
    a_totals = (16, 64, 256, 1024)
    drivers, seeds = [], []
    for a_total in a_totals:
        spec, _ = experiment_spec(
            "mixed_gaussian", K=5, steps=n * 5, log_every=0,
            a_total=a_total, a_active=a_active, samples_per_agent=samples)
        drivers.append(_virtual_driver(spec))
        seeds.append(spec.seed + 1)
    all_runs = _interleaved(drivers, seeds)
    rps = {}
    for a_total, driver, runs in zip(a_totals, drivers, all_runs):
        t = _median(runs, "rounds_per_s").timings
        rps[a_total] = t["rounds_per_s"]
        assert driver.n_traces == 1, driver.n_traces  # compiled once, warm
        common.emit(
            f"agents_fleet_{a_total}", 1e6 / t["rounds_per_s"],
            f"{t['rounds_per_s']:.1f} rounds/s, {t['store_rows']} host rows, "
            f"{t['swapped_rows']} swapped",
            rounds_per_s=round(t["rounds_per_s"], 2),
            a_total=a_total, a_active=a_active,
            store_rows=t["store_rows"], swapped_rows=t["swapped_rows"],
            n_rounds=n, K=5, samples_per_agent=samples)
    flatness = rps[1024] / rps[16]
    common.emit("agents_scaling_flatness", 0.0,
                f"rounds/s(A_total=1024) / rounds/s(16) = {flatness:.3f}",
                flatness=round(flatness, 3))
    return flatness


def bench_virtual_overhead(*, fast: bool = False):
    """Identity-cohort virtual path vs the dense stream RoundDriver."""
    from repro.launch.train import experiment_spec
    from repro.run.driver import RoundDriver
    n = 8 if fast else 25
    samples = 64 if fast else 256
    kw = dict(K=5, steps=n * 5, log_every=0, samples_per_agent=samples)
    dense_spec, _ = experiment_spec("mixed_gaussian", agents=16, **kw)
    fed, _ = dense_spec.build()
    dense = RoundDriver(fed, dense_spec.build_data(), n, log_every=0)
    virt_spec, _ = experiment_spec("mixed_gaussian", a_total=16,
                                   a_active=16, **kw)
    virt = _virtual_driver(virt_spec)
    dense_runs, virt_runs = _interleaved(
        [dense, virt], [dense_spec.seed + 1, virt_spec.seed + 1])
    dense_res = _median(dense_runs, "steps_per_s")
    virt_res = _median(virt_runs, "rounds_per_s")
    assert virt_res.timings["swapped_rows"] == 0  # identity schedule pages 0

    # the dense driver reports steps/s; rounds/s = steps/s / K
    d_rps = dense_res.timings["steps_per_s"] / 5
    v_rps = virt_res.timings["rounds_per_s"]
    overhead = d_rps / v_rps - 1.0
    common.emit(
        "agents_virtual_overhead", 1e6 / v_rps,
        f"dense {d_rps:.1f} vs virtual {v_rps:.1f} rounds/s "
        f"({overhead * 100:+.1f}% overhead)",
        dense_rounds_per_s=round(d_rps, 2),
        virtual_rounds_per_s=round(v_rps, 2),
        overhead_frac=round(overhead, 4), n_rounds=n)
    return overhead


def main(*, fast: bool = False):
    bench_virtual_overhead(fast=fast)
    bench_fleet_scaling(fast=fast)


if __name__ == "__main__":
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_agents.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast)
    if args.json:
        with open("BENCH_agents.json", "w") as f:
            json.dump({"suite": "agents", "fast": args.fast,
                       "records": common.drain_records()}, f, indent=1)
        print("# wrote BENCH_agents.json", file=sys.stderr)
