"""Paper §3.2 — communication complexity.

Per-strategy wire-byte accounting for every assigned architecture — each
``SyncStrategy`` owns its own ``bytes_per_round`` (no more hand-coded
2·2M/K formulas here) — cross-checked against the loop-aware HLO
collective audit of the dry-run artifacts when present (agent-axis bytes
only — tensor-parallel ICI traffic within an agent is orthogonal to the
paper's claim).
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config, list_archs
from repro.core import FedGANConfig
from repro.core.strategies import (FedAvgSync, Hierarchical, PartialSharing,
                                   PerStepGradAvg)
from repro.launch.steps import make_lm_gan_task


def bench_analytic(K=20):
    strategies = {
        "fedgan": FedAvgSync(),
        "distributed": PerStepGradAvg(),
        "partial_sharing": PartialSharing(),
        "fedgan_bf16": FedAvgSync(sync_dtype=jnp.bfloat16),
        "hierarchical": Hierarchical(intra_interval=K // 4),
    }
    for arch in list_archs():
        cfg = get_config(arch).smoke()  # param ratio is scale-free; use smoke
        task = make_lm_gan_task(cfg)
        params = jax.eval_shape(task.init, jax.random.key(0))
        M = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params))
        fcfg = FedGANConfig(agent_grid=(1, 1), sync_interval=K)
        per_round = {name: s.bytes_per_round(fcfg, params)
                     for name, s in strategies.items()}
        fields = ";".join(f"{name}_B_per_step={b / K:.0f}"
                          for name, b in per_round.items())
        emit(f"comm_{arch}", 0.0,
             f"M_bytes={M};{fields};"
             f"ratio={per_round['distributed'] // per_round['fedgan']};"
             f"partial_vs_full={per_round['partial_sharing'] / per_round['fedgan']:.3f}")


def bench_hlo_audit(results_dir="results/dryrun"):
    """Agent-axis collective bytes per step from the compiled dry-runs."""
    for path in sorted(glob.glob(os.path.join(results_dir, "*train_4k*16x16.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        ax = rec["collective_by_axis"]
        steps = rec.get("steps_per_call", 1)
        emit(f"comm_hlo_{rec['arch']}_{rec.get('mode','fedgan')}", 0.0,
             f"agent_axis_B_per_step={ax.get('agent',0)/steps:.0f};"
             f"model_axis_B_per_step={ax.get('model',0)/steps:.0f}")


def main():
    bench_analytic()
    bench_hlo_audit()


if __name__ == "__main__":
    main()
