"""Paper §3.2 — communication complexity.

Analytic accounting (2·2M/K vs 2·2M per agent per step) for every assigned
architecture, cross-checked against the loop-aware HLO collective audit of
the dry-run artifacts when present (agent-axis bytes only — tensor-parallel
ICI traffic within an agent is orthogonal to the paper's claim).
"""
from __future__ import annotations

import glob
import json
import os

import jax

from benchmarks.common import emit
from repro.configs import get_config, list_archs
from repro.models.adversarial import AdversarialLM


def bench_analytic(K=20):
    for arch in list_archs():
        cfg = get_config(arch).smoke()  # param ratio is scale-free; use smoke
        model = AdversarialLM(cfg)
        params = jax.eval_shape(model.init, jax.random.key(0))
        M = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params))
        fed_per_step = 2 * M / K
        dist_per_step = 2 * M
        emit(f"comm_{arch}", 0.0,
             f"M_bytes={M};fedgan_B_per_step={fed_per_step:.0f};"
             f"distributed_B_per_step={dist_per_step:.0f};ratio={K}")


def bench_hlo_audit(results_dir="results/dryrun"):
    """Agent-axis collective bytes per step from the compiled dry-runs."""
    for path in sorted(glob.glob(os.path.join(results_dir, "*train_4k*16x16.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        ax = rec["collective_by_axis"]
        steps = rec.get("steps_per_call", 1)
        emit(f"comm_hlo_{rec['arch']}_{rec.get('mode','fedgan')}", 0.0,
             f"agent_axis_B_per_step={ax.get('agent',0)/steps:.0f};"
             f"model_axis_B_per_step={ax.get('model',0)/steps:.0f}")


def main():
    bench_analytic()
    bench_hlo_audit()


if __name__ == "__main__":
    main()
