"""Paper §3.2 — communication complexity, extended to the codec axis.

Three row families, all machine-readable through ``run.py --json``
(BENCH_comm.json — part of the committed perf trajectory):

  * ``comm_<arch>`` — per-strategy wire bytes for every assigned
    architecture; each ``SyncStrategy`` owns its own ``bytes_per_round``
    (no hand-coded 2·2M/K formulas), including the ``repro.comm`` codec
    strategies.  Structured extras carry the int8/int4 reduction ratios
    the CI gate asserts (int8 ≥ 3.5x vs float32 FedAvgSync).
  * ``comm_paper_mixed_gaussian`` — the same accounting on the paper's
    mixed-Gaussian MLP GAN (the README headline numbers), with a
    *measured* reduction cross-check: the ratio of the actually
    materialized encoded arrays (trimmed payload + scales), not just the
    analytic formula.
  * ``comm_codec_*`` — encode/decode throughput of the qpack pack/unpack
    path, kernel (interpret) vs ref, on a fixed stream.  Byte-count and
    codec-throughput shaped on purpose: the CI host is a 2-core CPU
    container, so backbone steps/s would benchmark the machine, not the
    code.

Cross-checked against the loop-aware HLO collective audit of the dry-run
artifacts when present (agent-axis bytes only — tensor-parallel ICI
traffic within an agent is orthogonal to the paper's claim).
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.comm import IntQuant, Sequential, TopK
from repro.configs import get_config, list_archs
from repro.core import FedGANConfig
from repro.core.strategies import (FedAvgSync, Hierarchical, PartialSharing,
                                   PerStepGradAvg)
from repro.launch.steps import make_lm_gan_task


def _strategies(K):
    return {
        "fedgan": FedAvgSync(),
        "distributed": PerStepGradAvg(),
        "partial_sharing": PartialSharing(),
        "fedgan_bf16": FedAvgSync(sync_dtype=jnp.bfloat16),
        "hierarchical": Hierarchical(intra_interval=K // 4),
        "fedgan_int8_ef": FedAvgSync(codec=IntQuant(bits=8)),
        "fedgan_int4_ef": FedAvgSync(codec=IntQuant(bits=4)),
        "fedgan_topk_int8": FedAvgSync(
            codec=Sequential((TopK(fraction=0.125), IntQuant(bits=8)))),
    }


def _per_round(params, K):
    fcfg = FedGANConfig(agent_grid=(1, 1), sync_interval=K)
    return {name: s.bytes_per_round(fcfg, params)
            for name, s in _strategies(K).items()}


def bench_analytic(K=20):
    for arch in list_archs():
        cfg = get_config(arch).smoke()  # param ratio is scale-free; use smoke
        task = make_lm_gan_task(cfg)
        params = jax.eval_shape(task.init, jax.random.key(0))
        M = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(params))
        per_round = _per_round(params, K)
        fields = ";".join(f"{name}_B_per_step={b / K:.0f}"
                          for name, b in per_round.items())
        full = per_round["fedgan"]
        emit(f"comm_{arch}", 0.0,
             f"M_bytes={M};{fields};"
             f"ratio={per_round['distributed'] // full};"
             f"partial_vs_full={per_round['partial_sharing'] / full:.3f}",
             bytes_per_round=full,
             int8_bytes_per_round=per_round["fedgan_int8_ef"],
             int8_reduction=round(full / per_round["fedgan_int8_ef"], 3),
             int4_reduction=round(full / per_round["fedgan_int4_ef"], 3),
             topk_int8_reduction=round(
                 full / per_round["fedgan_topk_int8"], 3))


def bench_paper_comm(K=20):
    """The README headline row: wire bytes of the mixed-Gaussian MLP GAN
    under each codec, analytic AND measured from the materialized encoded
    arrays (trimmed payload + scales/indices — the honest-accounting
    cross-check)."""
    from repro.launch.train import mlp_gan_task
    task, _ = mlp_gan_task()
    params = task.init(jax.random.key(0))
    per_round = _per_round(params, K)
    full = per_round["fedgan"]

    # measured: sum of the actual encoded array sizes for one direction
    codec = IntQuant(bits=8)
    measured = 0
    for leaf in jax.tree_util.tree_leaves(params):
        payload, meta = codec.encode(leaf)
        n = int(leaf.size)
        trim = (n * codec.bits + 7) // 8  # padding lanes never ship
        # the billed trim must bound the materialized payload (the
        # cross-check is against the real arrays, not the formula twice)
        actual = int(payload.size) * payload.dtype.itemsize
        assert trim <= actual < trim + codec.block * codec.bits // 8, \
            (leaf.shape, trim, actual)
        measured += trim + sum(int(m.size) * m.dtype.itemsize
                               for m in jax.tree_util.tree_leaves(meta))
    from repro.dist import collectives
    f32 = collectives.tree_bytes(params)
    emit("comm_paper_mixed_gaussian", 0.0,
         f"M_bytes={f32};fedgan_B={full};int8_B={per_round['fedgan_int8_ef']};"
         f"int4_B={per_round['fedgan_int4_ef']};"
         f"measured_int8_one_way_B={measured}",
         bytes_per_round=full,
         int8_bytes_per_round=per_round["fedgan_int8_ef"],
         int4_bytes_per_round=per_round["fedgan_int4_ef"],
         topk_int8_bytes_per_round=per_round["fedgan_topk_int8"],
         int8_reduction=round(full / per_round["fedgan_int8_ef"], 3),
         int4_reduction=round(full / per_round["fedgan_int4_ef"], 3),
         measured_int8_reduction=round(f32 / measured, 3))


def bench_codec_throughput(fast=False):
    """Encode/decode throughput of the qpack path, kernel (interpret mode
    off-TPU) vs vectorized ref — the codec cost a round_sync actually pays.
    Overhead-dominated on purpose: small fixed streams, MB/s derived."""
    from repro.kernels.qpack.ops import (_use_kernel_default,
                                         dequantize_blocks, quantize_blocks)
    n = 1 << 14 if fast else 1 << 16
    x = jax.random.normal(jax.random.key(0), (8, n))
    mb = x.size * 4 / 1e6
    default_kern = _use_kernel_default()
    for bits in (8, 4):
        for label, kern in (("ref", False), ("kernel", True)):
            enc = jax.jit(lambda v, b=bits, k=kern: quantize_blocks(
                v, bits=b, use_kernel=k))
            (q, s), us = timed(enc, x)
            dec = jax.jit(lambda qq, ss, b=bits, k=kern: dequantize_blocks(
                qq, ss, n=n, bits=b, use_kernel=k))
            _, us_d = timed(dec, q, s)
            # record which path this row actually exercised: `path` is the
            # implementation forced here, `is_default_path` whether a
            # round_sync with use_kernel=None would have run the same one
            # on this backend (on the CPU CI host the kernel rows time
            # interpret mode, which the default never picks)
            emit(f"comm_codec_int{bits}_{label}", us,
                 f"encode_MBps={mb / (us / 1e6):.0f};"
                 f"decode_MBps={mb / (us_d / 1e6):.0f};path={label}",
                 encode_mb_per_s=round(mb / (us / 1e6), 1),
                 decode_mb_per_s=round(mb / (us_d / 1e6), 1),
                 path=label,
                 backend=jax.default_backend(),
                 is_default_path=(kern == default_kern))


def bench_hlo_audit(results_dir="results/dryrun"):
    """Agent-axis collective bytes per step from the compiled dry-runs."""
    for path in sorted(glob.glob(os.path.join(results_dir, "*train_4k*16x16.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        ax = rec["collective_by_axis"]
        steps = rec.get("steps_per_call", 1)
        emit(f"comm_hlo_{rec['arch']}_{rec.get('mode','fedgan')}", 0.0,
             f"agent_axis_B_per_step={ax.get('agent',0)/steps:.0f};"
             f"model_axis_B_per_step={ax.get('model',0)/steps:.0f}")


def main(fast=False):
    bench_analytic()
    bench_paper_comm()
    bench_codec_throughput(fast=fast)
    bench_hlo_audit()


if __name__ == "__main__":
    main()
