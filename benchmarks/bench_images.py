"""Paper Fig 1b / Fig 2b — FD score (FID stand-in) vs synchronization
interval K, FedGAN vs the distributed-GAN baseline, on synthetic
class-conditional images (MNIST/CIFAR-10 gate) and attribute-class images
(CelebA gate).  The paper's claim: FedGAN's score stays close to the
per-step-communication distributed GAN even at large K.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import FedGAN, FedGANConfig, PerStepGradAvg
from repro.data import synthetic
from repro.evals import fd_score
from repro.launch.train import acgan_task
from repro.optim import Adam, constant, equal_timescale

HW = 16


def _train_acgan(K, steps, strategy=None, num_classes=10, B=5, n=32, seed=0):
    task, (G, D) = acgan_task(hw=HW, num_classes=num_classes)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy),
                 opt_g=Adam(b1=0.5), opt_d=Adam(b1=0.5),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(seed))
    rng = jax.random.key(seed + 1)
    round_fn = jax.jit(fed.round)
    per = max(num_classes // B, 1)
    t0 = time.perf_counter()
    for r in range(max(steps // K, 1)):
        rng, r1, r2, r3, r4 = jax.random.split(rng, 5)
        labs, imgs = [], []
        for i in range(B):
            lab = jax.random.randint(jax.random.fold_in(r1, r * B + i),
                                     (K * n,), i * per,
                                     min((i + 1) * per, num_classes))
            img = synthetic.sample_class_images(
                jax.random.fold_in(r2, r * B + i), K * n, lab, hw=HW,
                num_classes=num_classes)
            labs.append(lab.reshape(K, n))
            imgs.append(img.reshape(K, n, HW, HW, 3))
        batch = {
            "x": jnp.stack(imgs, axis=1).reshape(K, 1, B, n, HW, HW, 3),
            "y": jnp.stack(labs, axis=1).reshape(K, 1, B, n),
            "z": jax.random.normal(r3, (K, 1, B, n, 62)),
        }
        seeds = jax.random.randint(r4, (K, 1, B), 0, 2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, batch, seeds)
    us = (time.perf_counter() - t0) / steps * 1e6
    return fed, state, (G, D), us


def _fd_of(fed, state, G, num_classes, n_eval=512, seed=9):
    gp = fed.averaged_params(state)["gen"]
    rng = jax.random.key(seed)
    lab = jax.random.randint(rng, (n_eval,), 0, num_classes)
    z = jax.random.normal(jax.random.fold_in(rng, 1), (n_eval, 62))
    fake = G.apply(gp, z, lab)
    real = synthetic.sample_class_images(jax.random.fold_in(rng, 2), n_eval,
                                         lab, hw=HW, num_classes=num_classes)
    return fd_score(jax.random.key(123), real, fake)


def bench_fd_vs_k(steps=400):
    """Fig 1b analog: K sweep + distributed baseline (same step budget)."""
    fed, state, (G, D), us = _train_acgan(1, steps, PerStepGradAvg())
    fd_base = _fd_of(fed, state, G, 10)
    emit("fig1b_distributed_gan", us, f"fd={fd_base:.2f}")
    for K in (10, 20, 100):
        fed, state, (G, D), us = _train_acgan(K, steps)
        fd = _fd_of(fed, state, G, 10)
        emit(f"fig1b_fedgan_K{K}", us, f"fd={fd:.2f};vs_distributed={fd/max(fd_base,1e-9):.2f}x")


def bench_celeba_attributes(steps=300):
    """Fig 2b analog: 16 attribute classes split over 5 agents."""
    for K in (10, 50):
        fed, state, (G, D), us = _train_acgan(K, steps, num_classes=16)
        fd = _fd_of(fed, state, G, 16)
        emit(f"fig2b_celeba_K{K}", us, f"fd={fd:.2f}")


def main():
    bench_fd_vs_k()
    bench_celeba_attributes()


if __name__ == "__main__":
    main()
