"""Kernel microbenchmarks: interpret-mode Pallas vs pure-jnp oracle.

NOTE: on this CPU container ``us_per_call`` measures the interpret-mode
Python execution, NOT TPU performance — the derived column carries the
max-abs error vs the oracle, which is the portable signal.  The XLA-path
timings (oracle under jit) are the meaningful CPU numbers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.kernels.fedavg.ops import fedavg_tree
from repro.kernels.fedavg.ref import fedavg_flat_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref


def bench_fedavg():
    w = jax.random.dirichlet(jax.random.key(0), jnp.ones(16))
    x = jax.random.normal(jax.random.key(1), (16, 1 << 18))
    ref = jax.jit(fedavg_flat_ref)
    _, us_ref = timed(ref, w, x)
    got = fedavg_tree(w, {"x": x}, interpret=True)["x"]
    err = float(jnp.max(jnp.abs(got - ref(w, x))))
    emit("kernel_fedavg_ref_xla", us_ref, f"n=16x262144")
    emit("kernel_fedavg_interpret", 0.0, f"max_err={err:.2e}")


def bench_flash():
    q = jax.random.normal(jax.random.key(0), (2, 512, 8, 64))
    k = jax.random.normal(jax.random.key(1), (2, 512, 2, 64))
    v = jax.random.normal(jax.random.key(2), (2, 512, 2, 64))
    ref = jax.jit(lambda q, k, v: jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True, window=128), 1, 2))
    want, us_ref = timed(ref, q, k, v)
    got = flash_attention(q, k, v, causal=True, window=128, interpret=True)
    err = float(jnp.max(jnp.abs(got - want)))
    emit("kernel_flash_ref_xla", us_ref, "T=512,h=8,kv=2,w=128")
    emit("kernel_flash_interpret", 0.0, f"max_err={err:.2e}")


def bench_ssd():
    x = 0.5 * jax.random.normal(jax.random.key(0), (2, 512, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (2, 512, 8)))
    A = -jnp.exp(jax.random.normal(jax.random.key(2), (8,)))
    B = 0.5 * jax.random.normal(jax.random.key(3), (2, 512, 32))
    C = 0.5 * jax.random.normal(jax.random.key(4), (2, 512, 32))
    ref = jax.jit(lambda *a: ssd_ref(*a, chunk=128))
    want, us_ref = timed(ref, x, dt, A, B, C)
    got = ssd(x, dt, A, B, C, chunk=128, interpret=True)
    err = float(jnp.max(jnp.abs(got - want)))
    emit("kernel_ssd_ref_xla", us_ref, "T=512,nh=8,ds=32,Q=128")
    emit("kernel_ssd_interpret", 0.0, f"max_err={err:.2e}")


def main():
    bench_fedavg()
    bench_flash()
    bench_ssd()


if __name__ == "__main__":
    main()
