"""Paper Lemmas 1/2 — measured drift vs theoretical bounds on the 2D toy.

Co-simulates FedGAN (local SGD) with the virtual centralized true-gradient
sequence (eq. 7), estimates the (A1)/(A5) constants empirically, and reports
measured drift alongside r1(n)/r2(n).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import (FedGAN, FedGANConfig, estimate_constants,
                        measure_drift, r1_bound, r2_bound)
from repro.data import synthetic
from repro.launch.train import toy2d_task
from repro.optim import SGD, constant, equal_timescale


def main(K=10, lr=0.02, B=5):
    task, _ = toy2d_task()
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(constant(lr)))
    state = fed.init_state(jax.random.key(0))
    rng = jax.random.key(1)
    agent_data = [{"x": synthetic.sample_2d_segment(jax.random.fold_in(rng, i),
                                                    2048, i, B),
                   "z": jax.random.uniform(jax.random.fold_in(rng, 50 + i),
                                           (2048,), minval=-1, maxval=1)}
                  for i in range(B)]
    params = fed.averaged_params(state)
    consts = estimate_constants(task, params, agent_data, jax.random.key(2),
                                minibatch=64, n_var_samples=6, n_lip_samples=6)
    emit("lemma_constants", 0.0,
         f"L={consts.L:.3f};sigma_g={consts.sigma_g:.4f};"
         f"sigma_h={consts.sigma_h:.4f};mu_g={consts.mu_g:.4f}")

    res = measure_drift(fed, state, agent_data, jax.random.key(3),
                        n_steps=2 * K, minibatch=64)
    for n in (1, K // 2, K - 1):
        bound = float(r1_bound(n, a=lr, K=K, L=consts.L, sg=consts.sigma_g,
                               sh=consts.sigma_h, mg=consts.mu_g))
        measured = float(res["agent_drift"][n - 1])
        emit(f"lemma1_n{n}", 0.0,
             f"measured={measured:.5f};bound={bound:.5f};"
             f"holds={measured <= bound * 1.5}")
    r2 = float(r2_bound(K, a=lr, K=K, L=consts.L, sg=consts.sigma_g,
                        sh=consts.sigma_h, mg=consts.mu_g))
    measured2 = float(jnp.max(res["avg_drift"][:K]))
    emit("lemma2", 0.0, f"measured_max={measured2:.5f};bound={r2:.5f}")


if __name__ == "__main__":
    main()
