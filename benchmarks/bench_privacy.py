"""repro.privacy — the cost of each privacy/robustness mechanism on the
paper's mixed-Gaussian GAN (PR 6).

Row families, all machine-readable through ``run.py --json``
(BENCH_privacy.json — part of the committed perf trajectory):

  * ``privacy_cov_*`` — pooled mode coverage at matched (B=8, K=5, steps;
    one mode per agent — maximally non-iid) for: clean FedAvg, FedAvg
    with one planted sign-flip Byzantine agent, trimmed-mean and
    coordinate-median under the same attacker, and DP-SGD (clip=1,
    sigma=0.5; the row carries the accountant's epsilon).  Structured
    extras carry ``robust_coverage_gap`` — clean-FedAvg coverage minus
    trimmed-mean-under-attack coverage — which the CI gate asserts stays
    <= 1 (the robustness headline: one attacker destroys plain FedAvg,
    costs a trimming server at most one mode).  The coordinate-median row
    is the honest counterpoint: its robustness holds (breakdown f < B/2)
    but its per-coordinate bias under this non-iid split costs most of
    the coverage — the robustness/utility tradeoff is real and the
    trimmed mean sits on the useful side of it.
  * ``privacy_masked_sync`` — us/call of the pairwise-mask secure sum vs
    the plain weighted average on the real mixed-Gaussian MLP params
    (the mask generation + uint32 pad arithmetic overhead; the result is
    bit-identical so the derived field is the max |delta| == 0 check).
  * ``privacy_bytes`` — wire accounting: the masked sum ships the same
    4 B/param image as plain FedAvg (masking is compute, not bytes),
    shown against the int8 codec wire it refuses to compose with.

Coverage rows are deliberately small-budget (a 2-core CI container): the
gate is *relative* (trimmed-vs-clean gap), not an absolute quality bar.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import FedAvgSync, FedGAN, FedGANConfig, make_gan_task
from repro.core.strategies import CoordinateMedianSync, TrimmedMeanSync
from repro.data import synthetic
from repro.dist import collectives
from repro.evals import mode_stats
from repro.launch.train import mlp_gan_task
from repro.optim import Adam, constant, equal_timescale
from repro.privacy import DPSGD, SecureAgg, WithByzantine

tmap = jax.tree_util.tree_map


def _coverage(strategy=None, dp=None, steps=1500, B=8, K=5, n=128, seed=0):
    """Train the paper's mixed-Gaussian MLP GAN (B=8 agents, each holding
    ONE of the 8 modes — maximally non-iid) and return (modes covered,
    us/step).  Same (net, lr) recipe as the tier-1 coverage gate
    (tests/test_comm.py::_mixed_gaussian_coverage); B=8 rather than 4 so
    a trim=1 order statistic keeps 6 honest values per coordinate — at
    B=4 it keeps 2 and the robust/quality tradeoff is hopeless for any
    aggregator."""
    from repro.models.gan_nets import MLPDiscriminator, MLPGenerator
    G = MLPGenerator(latent_dim=2, out_dim=2, hidden=64, depth=2)
    D = MLPDiscriminator(in_dim=2, hidden=64, depth=2)
    task = make_gan_task(G, D)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy, dp=dp),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(seed))
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(seed + 1)
    t0 = time.perf_counter()
    for r in range(steps // K):
        rng, r1, r2, r3 = jax.random.split(rng, 4)
        x = jnp.stack([synthetic.sample_mixed_gaussian(
            jax.random.fold_in(r1, r * B + i), K * n,
            mode_subset=[i % 8]).reshape(K, n, 2)
            for i in range(B)], axis=1).reshape(K, 1, B, n, 2)
        z = jax.random.normal(r2, (K, 1, B, n, 2))
        seeds = jax.random.randint(r3, (K, 1, B), 0,
                                   2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, {"x": x, "z": z}, seeds)
    us = (time.perf_counter() - t0) / steps * 1e6
    gp = fed.averaged_params(state)["gen"]
    samples = G.apply(gp, jax.random.normal(jax.random.key(9), (2000, 2)))
    covered, _, _ = mode_stats(samples, synthetic.mixed_gaussian_modes(),
                               radius=0.5)
    return int(covered), us


def bench_robustness(steps=1200):
    """Mode coverage under one planted Byzantine agent: plain FedAvg vs
    the robust reduces.  Extras carry the trimmed-vs-clean gap the CI
    gate asserts (<= 1 mode lost to one attacker)."""
    clean, us = _coverage(FedAvgSync(), steps=steps)
    emit("privacy_cov_clean_fedavg", us, f"modes={clean}/8",
         modes_covered=clean)
    rows = [
        ("privacy_cov_fedavg_byz1", WithByzantine(FedAvgSync())),
        ("privacy_cov_trimmed_byz1", WithByzantine(TrimmedMeanSync())),
        ("privacy_cov_median_byz1", WithByzantine(CoordinateMedianSync())),
    ]
    gap = None
    for name, strat in rows:
        cov, us = _coverage(strat, steps=steps)
        extra = {"modes_covered": cov, "attack": "sign_flip", "byzantine": 1}
        if name == "privacy_cov_trimmed_byz1":
            gap = clean - cov
            extra["robust_coverage_gap"] = gap
        emit(name, us, f"modes={cov}/8;clean={clean}/8", **extra)
    return gap


def bench_dp(steps=1200):
    """DP-SGD cost row: per-example clipping + noise on both players,
    with the closed-form RDP epsilon the run buys at this step budget."""
    dp = DPSGD(clip=1.0, noise_multiplier=0.5)
    cov, us = _coverage(dp=dp, steps=steps)
    eps = dp.epsilon(steps)
    emit("privacy_cov_dp", us,
         f"modes={cov}/8;epsilon={eps:.1f};sigma={dp.noise_multiplier}",
         modes_covered=cov, dp_epsilon=round(eps, 3),
         noise_multiplier=dp.noise_multiplier, clip=dp.clip)


def bench_masked_sync_overhead(B=4):
    """us/call of masked_sync vs average_agents on the real mixed-Gaussian
    MLP params — the price of the one-time-pad wire image (must stay
    bit-identical, so the derived field doubles as an exactness check)."""
    task, _ = mlp_gan_task(hidden=64)
    params = task.init(jax.random.key(0))
    stacked = tmap(lambda l: jnp.broadcast_to(
        l * jnp.arange(1, B + 1, dtype=l.dtype).reshape(1, B, *([1] * l.ndim)),
        (1, B) + l.shape).astype(l.dtype), params)
    w = jnp.full((1, B), 1.0 / B)
    key = collectives.mask_pair_key(jax.random.key(0), jnp.uint32(7))

    plain = jax.jit(lambda t: collectives.average_agents(t, w))
    masked = jax.jit(lambda t, k: collectives.masked_sync(t, w, k))
    ref, us_plain = timed(plain, stacked)
    got, us_masked = timed(masked, stacked, key)
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(ref),
                                jax.tree_util.tree_leaves(got)))
    emit("privacy_masked_sync", us_masked,
         f"plain_us={us_plain:.1f};overhead={us_masked / us_plain:.2f}x;"
         f"max_abs_delta={delta}",
         plain_us=round(us_plain, 1),
         overhead_ratio=round(us_masked / max(us_plain, 1e-9), 3),
         bit_identical=delta == 0.0)


def bench_bytes(K=5):
    """Wire accounting: the secure sum ships the same float32 image as
    plain FedAvg (masking costs compute, not bytes) — shown against the
    int8 codec wire it refuses to compose with."""
    from repro.comm import IntQuant
    task, _ = mlp_gan_task(hidden=64)
    params = task.init(jax.random.key(0))
    fcfg = FedGANConfig(agent_grid=(1, 1), sync_interval=K)
    plain = FedAvgSync().bytes_per_round(fcfg, params)
    secure = FedAvgSync(secure_agg=SecureAgg()).bytes_per_round(fcfg, params)
    int8 = FedAvgSync(codec=IntQuant(bits=8)).bytes_per_round(fcfg, params)
    robust = TrimmedMeanSync().bytes_per_round(fcfg, params)
    emit("privacy_bytes", 0.0,
         f"fedgan_B={plain};secure_B={secure};trimmed_B={robust};"
         f"int8_B={int8} (secure refuses codecs)",
         bytes_per_round=int(plain), secure_bytes_per_round=int(secure),
         secure_equals_plain=int(secure) == int(plain))


def main(fast=False):
    steps = 1500 if fast else 2500
    bench_robustness(steps=steps)
    bench_dp(steps=steps)
    bench_masked_sync_overhead()
    bench_bytes()


if __name__ == "__main__":
    main()
