"""Roofline rows: standalone per-kernel mode + dry-run post-processing.

Two row families, both machine-readable through ``run.py --json``
(BENCH_roofline.json — part of the committed perf trajectory):

  * ``roofline_kernel_*`` — standalone per-kernel rows that need NO prior
    dry-run: each sync-hot-path kernel (qpack pack/unpack, fedavg reduce,
    fused qsync, fused adam+sync) timed on the path ``use_kernel=None``
    actually picks on this backend, with achieved GB/s and elems/s against
    a measured copy roofline (a jitted saxpy stream on the same host — the
    roofline's memory term, since every one of these kernels is
    memory-bound by construction).  ``roofline_frac`` is achieved GB/s over
    stream GB/s; ``memory_term_s`` is the bytes-over-stream-bandwidth floor
    the kernel cannot beat.
  * ``roofline_<arch>_*`` — the original (g) deliverable: roofline terms /
    useful-FLOPs ratio / HBM occupancy post-processed from the dry-run
    artifacts when ``results/dryrun`` exists (unchanged; absent artifacts
    now skip quietly instead of being the suite's only output).

``roofline_fused_vs_composed`` measures the tentpole directly: one bucketed
fused ``coded_sync`` dispatch chain vs the per-leaf composed pipeline on the
same tree, wall-clock AND quantize-site counts from the lowered jaxprs.
NOTE the CI gate is on the dispatch counts (fused = 2 quantize sites per
round regardless of leaf count; composed = 2·leaves), not wall-clock: on
this 2-core CPU container both paths run the vectorized ref, where
bucketing wins ~1.3x on many-small-leaf trees but the concat copies can
eat the win on huge leaves — the HBM-traffic win the fusion exists for
(no per-agent wire image materialized) only shows on a real TPU backend.
"""
from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.configs import get_config
from repro.models.transformer import Backbone


# ---------------------------------------------------------------- kernels

def _count_round_sites(fn, *args) -> int:
    """Quantize sites in fn's jaxpr = number of `round` primitives,
    recursing through scan/cond/pjit sub-jaxprs."""
    from jax.extend import core as jex_core

    def walk(jaxpr) -> int:
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "round":
                total += 1
            for v in eqn.params.values():
                if isinstance(v, jex_core.ClosedJaxpr):
                    total += walk(v.jaxpr)
                elif isinstance(v, jex_core.Jaxpr):
                    total += walk(v)
        return total

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def _stream_gbps(fast=False) -> float:
    """Measured copy roofline: bytes/s of a jitted saxpy over a stream that
    dwarfs cache — the memory-bandwidth ceiling the kernel rows are scored
    against on THIS host."""
    n = 1 << 22 if fast else 1 << 24
    x = jax.random.normal(jax.random.key(0), (n,), jnp.float32)
    _, us = timed(jax.jit(lambda v: v * 1.5 + 2.0), x, iters=5)
    return 2 * 4 * n / (us / 1e6) / 1e9  # read + write


def _kernel_row(name, us, n_elems, n_bytes, stream, **extra):
    gbps = n_bytes / (us / 1e6) / 1e9
    emit(f"roofline_kernel_{name}", us,
         f"GBps={gbps:.2f};elems_per_s={n_elems / (us / 1e6):.3e};"
         f"roofline_frac={gbps / stream:.2f}",
         gb_per_s=round(gbps, 3),
         elems_per_s=round(n_elems / (us / 1e6), 1),
         bytes_touched=n_bytes,
         roofline_frac=round(gbps / stream, 3),
         memory_term_s=round(n_bytes / (stream * 1e9), 6),
         backend=jax.default_backend(), **extra)


def bench_kernel_rooflines(fast=False):
    from repro.kernels.fedavg.ref import fedavg_flat_ref
    from repro.kernels.qpack.ops import (_use_kernel_default,
                                         dequantize_blocks, quantize_blocks)
    from repro.kernels.qsync import ops as qsync_ops

    stream = _stream_gbps(fast=fast)
    emit("roofline_stream", 0.0, f"stream_GBps={stream:.2f}",
         stream_gb_per_s=round(stream, 3), backend=jax.default_backend())

    B, n = 16, (1 << 14 if fast else 1 << 16)
    block = 128
    path = "kernel" if _use_kernel_default() else "ref"
    x = jax.random.normal(jax.random.key(0), (B, n), jnp.float32)
    w = jax.random.dirichlet(jax.random.key(1), jnp.ones(B))

    # qpack pack: read f32, write int8 codes (int4: packed nibbles) + scales
    for bits in (8, 4):
        enc = jax.jit(lambda v, b=bits: quantize_blocks(v, bits=b))
        (q, s), us = timed(enc, x)
        nb = (4 * B * n + B * n * bits // 8
              + s.size * s.dtype.itemsize)
        _kernel_row(f"qpack_pack_int{bits}", us, B * n, nb, stream,
                    path=path)
        dec = jax.jit(lambda qq, ss, b=bits: dequantize_blocks(
            qq, ss, n=n, bits=b))
        _, us_d = timed(dec, q, s)
        _kernel_row(f"qpack_unpack_int{bits}", us_d, B * n, nb, stream,
                    path=path)

    # fedavg: read stacked f32 + weights, write the (n,) average
    _, us = timed(jax.jit(fedavg_flat_ref), w, x)
    _kernel_row("fedavg", us, B * n, 4 * B * n + 4 * B + 4 * n, stream,
                path="ref")

    # fused qsync: read stacked + both residuals, write synced + residuals —
    # the per-agent wire image is the traffic the fusion does NOT pay
    ef = jnp.zeros_like(x)
    efd = jnp.zeros((n,), jnp.float32)
    for bits in (8, 4):
        f = jax.jit(lambda t, e, d, b=bits: qsync_ops.qsync_flat(
            w, t, e, d, bits=b))
        _, us = timed(f, x, ef, efd)
        nb = 4 * B * n * 2 + 4 * n + 4 * B + 4 * n + 4 * B * n + 4 * n
        _kernel_row(f"qsync_fused_int{bits}", us, B * n, nb, stream,
                    path=path, bits=bits)

    # fused adam+sync: read params/grads/moments, write all three + wire
    g, mu, nu = 0.1 * x, 0.2 * x, jnp.abs(0.1 * x)
    cnt = jnp.asarray(3, jnp.int32)
    f = jax.jit(lambda p, gg, m, v: qsync_ops.adam_sync_flat(
        p, gg, m, v, lr=0.01, count=cnt))
    _, us = timed(f, x, g, mu, nu)
    nb = 4 * B * n * 4 + 4 * B * n * 3 + B * n + 2 * B * n // block
    _kernel_row("adam_sync_fused", us, B * n, nb, stream, path=path)


def bench_fused_vs_composed(fast=False):
    from repro.comm import IntQuant
    from repro.dist import collectives

    grid = (2, 4)
    dim = 32 if fast else 64
    shapes = [(dim, dim), (dim,), (dim, 2 * dim), (2 * dim,),
              (2 * dim, dim), (dim,), (dim, 2), (2,)]
    key = jax.random.key(0)
    tree = {}
    for i, s in enumerate(shapes):
        key, k = jax.random.split(key)
        tree[f"l{i}"] = jax.random.normal(k, grid + s, jnp.float32)
    ef = jax.tree.map(jnp.zeros_like, tree)
    efd = {k: jnp.zeros(v.shape[2:], v.dtype) for k, v in tree.items()}
    w = jnp.full(grid, 1.0 / (grid[0] * grid[1]))
    codec = IntQuant(bits=8)

    def sync(fused):
        return jax.jit(lambda t, e, d: collectives.coded_sync(
            t, w, codec, ef=e, ef_down=d, fused=fused))

    # interleaved median-of-3 (the bench_agents trick): the 2-core CI clock
    # drifts enough that back-to-back one-shot timings swing ±40%
    comp, fus = sync(False), sync(True)
    cs, fs = [], []
    for _ in range(3):
        _, us = timed(comp, tree, ef, efd, iters=10)
        cs.append(us)
        _, us = timed(fus, tree, ef, efd, iters=10)
        fs.append(us)
    us_c, us_f = sorted(cs)[1], sorted(fs)[1]
    sites_c = _count_round_sites(sync(False), tree, ef, efd)
    sites_f = _count_round_sites(sync(True), tree, ef, efd)
    n_leaves = len(tree)
    emit("roofline_fused_vs_composed", us_f,
         f"speedup={us_c / us_f:.2f};fused_quant_sites={sites_f};"
         f"composed_quant_sites={sites_c};n_leaves={n_leaves}",
         speedup=round(us_c / us_f, 3),
         fused_quant_sites=sites_f,
         composed_quant_sites=sites_c,
         n_leaves=n_leaves,
         composed_us=round(us_c, 1),
         backend=jax.default_backend())


# --------------------------------------------------- dry-run post-processing

def active_param_count(arch: str) -> tuple[int, int]:
    """(total params N, active params N_active) for the GENERATOR."""
    cfg = get_config(arch)
    params = jax.eval_shape(Backbone(cfg).init, jax.random.key(0))
    total = sum(l.size for l in jax.tree_util.tree_leaves(params))
    if cfg.num_experts:
        # expert weights: stacked (layers, E, ...) under blocks/mlp/experts
        def expert_size(tree, path=""):
            total = 0
            if isinstance(tree, dict):
                for k, v in tree.items():
                    total += expert_size(v, path + "/" + k)
                return total
            return tree.size if "/experts/" in path + "/" else 0
        e_total = expert_size(params)
        active = total - e_total + e_total * cfg.experts_per_token // cfg.num_experts
        return total, active
    return total, total


def model_flops_per_step(arch: str, shape_rec: dict) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for inference."""
    _, n_active = active_param_count(arch)
    meta = shape_rec.get("meta", {})
    kind = meta.get("kind", "train")
    if kind == "train":
        tokens = meta.get("agents", 16) * meta.get("per_agent_batch", 16) * 4096
        return 6.0 * n_active * tokens
    if kind == "prefill":
        from repro.models.config import SHAPES
        s = SHAPES[shape_rec["shape"]]
        return 2.0 * n_active * s.seq_len * s.global_batch
    # decode: one token per sequence
    from repro.models.config import SHAPES
    s = SHAPES[shape_rec["shape"]]
    return 2.0 * n_active * s.global_batch


def bench_dryrun(results_dir="results/dryrun", tag="baseline"):
    rows = sorted(glob.glob(os.path.join(results_dir, f"{tag}__*.json")))
    chips = {"16x16": 256, "2x16x16": 512}
    for path in rows:
        rec = json.load(open(path))
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec.get('mesh','16x16')}"
        if rec.get("status") == "skipped":
            emit(name, 0.0, f"SKIP:{rec.get('reason','')}")
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, f"ERROR:{rec.get('error','')[:80]}")
            continue
        r = rec["roofline_per_step"]
        n_chips = chips.get(rec.get("mesh", "16x16"), 256)
        mf = model_flops_per_step(rec["arch"], rec)
        hlo_flops_fleet = rec["flops"] / rec.get("steps_per_call", 1) * n_chips
        useful = mf / hlo_flops_fleet if hlo_flops_fleet else 0.0
        hbm = rec["memory"]["total_hbm_bytes"] / 2 ** 30
        emit(name, 0.0,
             f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
             f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
             f"useful_flops_ratio={useful:.2f};hbm_GiB_per_dev={hbm:.2f}")


def main(results_dir="results/dryrun", tag="baseline", fast=False):
    bench_kernel_rooflines(fast=fast)
    bench_fused_vs_composed(fast=fast)
    bench_dryrun(results_dir=results_dir, tag=tag)


if __name__ == "__main__":
    main()
