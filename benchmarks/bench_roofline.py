"""Deliverable (g) — roofline table from the dry-run artifacts.

For each (arch x shape x mesh): the three roofline terms (compute / memory /
collective seconds per step, v5e constants), the dominant term, MODEL_FLOPS
(6·N·D dense, 6·N_active·D MoE) vs compiled HLO FLOPs (useful-compute
ratio), and HBM occupancy per device.
"""
from __future__ import annotations

import glob
import json
import os

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.models.adversarial import AdversarialLM
from repro.models.transformer import Backbone


def active_param_count(arch: str) -> tuple[int, int]:
    """(total params N, active params N_active) for the GENERATOR."""
    cfg = get_config(arch)
    params = jax.eval_shape(Backbone(cfg).init, jax.random.key(0))
    total = sum(l.size for l in jax.tree_util.tree_leaves(params))
    if cfg.num_experts:
        # expert weights: stacked (layers, E, ...) under blocks/mlp/experts
        def expert_size(tree, path=""):
            total = 0
            if isinstance(tree, dict):
                for k, v in tree.items():
                    total += expert_size(v, path + "/" + k)
                return total
            return tree.size if "/experts/" in path + "/" else 0
        e_total = expert_size(params)
        active = total - e_total + e_total * cfg.experts_per_token // cfg.num_experts
        return total, active
    return total, total


def model_flops_per_step(arch: str, shape_rec: dict) -> float:
    """6·N_active·tokens for train; 2·N_active·tokens for inference."""
    _, n_active = active_param_count(arch)
    meta = shape_rec.get("meta", {})
    kind = meta.get("kind", "train")
    if kind == "train":
        tokens = meta.get("agents", 16) * meta.get("per_agent_batch", 16) * 4096
        return 6.0 * n_active * tokens
    if kind == "prefill":
        from repro.models.config import SHAPES
        s = SHAPES[shape_rec["shape"]]
        return 2.0 * n_active * s.seq_len * s.global_batch
    # decode: one token per sequence
    from repro.models.config import SHAPES
    s = SHAPES[shape_rec["shape"]]
    return 2.0 * n_active * s.global_batch


def main(results_dir="results/dryrun", tag="baseline"):
    rows = sorted(glob.glob(os.path.join(results_dir, f"{tag}__*.json")))
    if not rows:
        emit("roofline", 0.0, f"no dry-run artifacts under {results_dir}")
        return
    chips = {"16x16": 256, "2x16x16": 512}
    for path in rows:
        rec = json.load(open(path))
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec.get('mesh','16x16')}"
        if rec.get("status") == "skipped":
            emit(name, 0.0, f"SKIP:{rec.get('reason','')}")
            continue
        if rec.get("status") != "ok":
            emit(name, 0.0, f"ERROR:{rec.get('error','')[:80]}")
            continue
        r = rec["roofline_per_step"]
        n_chips = chips.get(rec.get("mesh", "16x16"), 256)
        mf = model_flops_per_step(rec["arch"], rec)
        hlo_flops_fleet = rec["flops"] / rec.get("steps_per_call", 1) * n_chips
        useful = mf / hlo_flops_fleet if hlo_flops_fleet else 0.0
        hbm = rec["memory"]["total_hbm_bytes"] / 2 ** 30
        emit(name, 0.0,
             f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
             f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
             f"useful_flops_ratio={useful:.2f};hbm_GiB_per_dev={hbm:.2f}")


if __name__ == "__main__":
    main()
