"""Round-loop throughput: the pre-refactor blocking ``RunSpec.run()`` loop
vs the ``repro.run`` device-resident donated driver.

Two quantities per config, both old-vs-new:

  * ``steps_per_s`` — local training steps per wall second (warm, compile
    excluded);
  * ``round_gap_ms`` — host time the device waits between rounds (legacy:
    per-round host assembly of the (K, P, A, batch, ...) tensor + the
    forced ``float()`` metric sync; runtime: key bookkeeping only).

The legacy path below is a faithful replica of the seed-era loop
(host-assembled batches, non-donated jit, a blocking metric fetch every
round) kept here as the fixed baseline the perf trajectory is measured
against.  The runtime path samples minibatches inside the jitted round
(``DeviceFederatedData`` + ``FedGAN.round_from_data``), donates the state
buffers, and scans ``rounds_per_chunk`` rounds per dispatch.

The gap the new pipeline removes is per-ROUND host work, so the speedup is
largest where rounds are cheap or frequent: the paper's GAN workloads
(toy/MLP/conv nets) gain several-fold, and any accelerator-backed host
additionally saves the K× host->device transfer this container (CPU-only,
device==host) cannot exhibit — there the backbone smoke config is bound by
its in-round compute and shows the round-gap win instead.

Run directly (``python benchmarks/bench_rounds.py --json``) or as the
``rounds`` suite of ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# support `python benchmarks/bench_rounds.py` directly (run.py does the
# same dance for the suite path)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks import common

tmap = jax.tree_util.tree_map


def _legacy_loop(spec, n_rounds: int):
    """The pre-refactor RunSpec.run() hot loop, replicated verbatim (minus
    prints/checkpoints): per-round host assembly, no donation, blocking
    per-round metric floats.  Returns (steps_per_s, round_gap_s)."""
    fed, rounds = spec.build()
    state = fed.init_state(jax.random.key(spec.seed))
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(spec.seed + 1)

    def one_round(state, rng, t_host):
        # the host-assembly segment is genuine round-gap: the blocking
        # metric sync below means assembly can never overlap the previous
        # round, so the device sits idle for all of it.  (The float() wait
        # itself is NOT counted — that is the device finishing its round.)
        t0 = time.perf_counter()
        rng, rb = jax.random.split(rng)
        batches, seeds = rounds.round_batches(rb)
        t_host += time.perf_counter() - t0
        state, metrics = round_fn(state, batches, seeds)
        _ = tmap(lambda x: float(jnp.mean(x)), metrics)  # the forced sync
        return state, rng, t_host

    state, rng, _ = one_round(state, rng, 0.0)  # compile warmup
    gap = 0.0
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        state, rng, gap = one_round(state, rng, gap)
    total = time.perf_counter() - t0
    return n_rounds * spec.K / total, gap / n_rounds


def _runtime_loop(spec, n_rounds: int, rounds_per_chunk: int):
    """The repro.run driver on device-resident data, timed warm (the
    driver memoizes its jitted chunk executable, so the second run pays no
    compile)."""
    import dataclasses

    from repro.run.driver import RoundDriver
    spec = dataclasses.replace(spec, data_mode="device", log_every=0)
    fed, _ = spec.build()
    driver = RoundDriver(fed, spec.build_data(), n_rounds, log_every=0,
                         rounds_per_chunk=rounds_per_chunk, verbose=False)
    driver.run(jax.random.key(spec.seed + 1))            # compile warmup
    res = driver.run(jax.random.key(spec.seed + 1))      # timed, warm
    return res.timings["steps_per_s"], res.timings["round_gap_s"]


def _bench_pair(label: str, spec, *, n_rounds: int, rounds_per_chunk: int,
                **meta):
    legacy_sps, legacy_gap = _legacy_loop(spec, n_rounds)
    run_sps, run_gap = _runtime_loop(spec, n_rounds, rounds_per_chunk)
    speedup = run_sps / legacy_sps
    gap_ratio = legacy_gap / max(run_gap, 1e-9)
    us_per_step = 1e6 / run_sps
    common.emit(
        f"rounds_{label}", us_per_step,
        f"{speedup:.2f}x steps/s ({legacy_sps:.0f}->{run_sps:.0f}), "
        f"round-gap {legacy_gap * 1e3:.2f}->{run_gap * 1e3:.3f} ms "
        f"({gap_ratio:.0f}x)",
        steps_per_s_legacy=round(legacy_sps, 1),
        steps_per_s_runtime=round(run_sps, 1),
        speedup=round(speedup, 3),
        round_gap_ms_legacy=round(legacy_gap * 1e3, 3),
        round_gap_ms_runtime=round(run_gap * 1e3, 4),
        round_gap_ratio=round(gap_ratio, 1),
        K=spec.K, agents=spec.agent_grid[0] * spec.agent_grid[1],
        batch_size=spec.batch_size, n_rounds=n_rounds,
        rounds_per_chunk=rounds_per_chunk, **meta)
    return speedup


def bench_paper_workloads(*, fast: bool = False):
    """The paper's GAN experiments: cheap rounds, so the per-round host
    assembly + sync the runtime removes IS the bottleneck."""
    from repro.launch.train import experiment_spec
    n = 30 if fast else 100
    for name, K in (("toy_2d", 20), ("toy_2d", 1), ("mixed_gaussian", 20)):
        if fast and name == "mixed_gaussian":
            continue
        spec, _ = experiment_spec(name, K=K, steps=n * K, log_every=0)
        _bench_pair(f"{name}_K{K}", spec, n_rounds=n,
                    rounds_per_chunk=min(10, n), experiment=name)


def bench_arch_smoke(arch: str = "gemma3-4b", *, fast: bool = False):
    """The backbone smoke config (the serving-side generator).  On a
    CPU-only host this round is compute-bound (device==host: no transfer
    to remove), so steps/s moves modestly and the round-gap column carries
    the pipeline win; on accelerators the K× transfer savings move
    steps/s too."""
    from repro.launch.train import arch_smoke_spec
    cases = [(1, 4)] if fast else [(1, 8), (5, 8), (10, 8)]
    for K, bs in cases:
        n = 8 if fast else 10
        spec = arch_smoke_spec(arch, steps=n * K, K=K, seed=0,
                               batch_size=bs, log_every=0)
        _bench_pair(f"{arch}_smoke_K{K}", spec, n_rounds=n,
                    rounds_per_chunk=min(8, n), arch=arch)


def main(*, fast: bool = False, arch: str = "gemma3-4b"):
    bench_paper_workloads(fast=fast)
    bench_arch_smoke(arch, fast=fast)


if __name__ == "__main__":
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_rounds.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.fast, arch=args.arch)
    if args.json:
        with open("BENCH_rounds.json", "w") as f:
            json.dump({"suite": "rounds", "fast": args.fast,
                       "records": common.drain_records()}, f, indent=1)
        print("# wrote BENCH_rounds.json", file=sys.stderr)
