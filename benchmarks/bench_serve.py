"""Serving-path benchmark: decode tokens/sec and tick-latency percentiles
as a function of batch occupancy.

Continuous batching trades per-request latency for throughput: every extra
occupied slot rides the same weight reads, so tokens/sec should grow
near-linearly with occupancy while the per-tick latency stays roughly flat
(until the arithmetic saturates).  This bench measures exactly that curve
on the smoke-size arch — the shape of the curve is the portable signal on
CPU; absolute numbers come from the same harness on TPU.

Rows: ``serve_occ<k>`` with us_per_call = p50 decode-tick latency; the
structured fields (tokens_per_sec, p50/p99 ms, occupancy) land in
``BENCH_serve.json`` via ``run.py --json``.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.serve import ServeEngine


def bench_occupancy(arch: str = "gemma3-4b", *, max_batch: int = 4,
                    prompt_len: int = 16, gen: int = 32, ring: bool = False):
    cfg = get_config(arch).smoke()
    rng = jax.random.key(0)

    occs = sorted({1, max(max_batch // 2, 1), max_batch})
    for n_req in occs:
        eng = ServeEngine(cfg, max_batch=max_batch,
                          max_seq=prompt_len + gen, ring=ring)
        # warmup request triggers the prefill + decode compiles (the
        # executable cache is shared across engines of the same backbone,
        # so later iterations start warm)
        eng.submit(jax.random.randint(rng, (prompt_len,), 0, cfg.vocab_size),
                   max_new_tokens=2)
        eng.run()
        eng.stats = type(eng.stats)()

        for i in range(n_req):
            prompt = jax.random.randint(jax.random.fold_in(rng, i),
                                        (prompt_len,), 0, cfg.vocab_size)
            eng.submit(prompt, max_new_tokens=gen)
        eng.run()

        s = eng.stats
        emit(f"serve_occ{n_req}", s.tick_ms(50) * 1e3,
             f"tok/s={s.tokens_per_sec():.0f},p99_ms={s.tick_ms(99):.1f}",
             tokens_per_sec=round(s.tokens_per_sec(), 1),
             p50_ms=round(s.tick_ms(50), 2),
             p99_ms=round(s.tick_ms(99), 2),
             occupancy=round(s.mean_occupancy(max_batch), 3),
             decode_tokens=s.decode_tokens,
             arch=cfg.name)


def main(fast: bool = False):
    bench_occupancy(gen=16 if fast else 32)


if __name__ == "__main__":
    main()
