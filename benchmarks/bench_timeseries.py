"""Paper Figs 3/4 — time-series FedGAN (PG&E household load + EV charging
stand-ins): train the CGAN-1D pair federated by climate zone / station
category, cluster real vs generated profiles, and report the matched
top-centroid RMSE (quantifying the paper's visual centroid comparison)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import FedGAN, FedGANConfig
from repro.data import synthetic
from repro.evals import centroid_match_score
from repro.launch.train import cgan1d_task
from repro.optim import Adam, constant, equal_timescale


def _train_ts(sampler, K=20, steps=600, B=5, n=64, seed=0):
    task, (G, D) = cgan1d_task(seq_len=24, label_dim=5)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K),
                 opt_g=Adam(b1=0.5), opt_d=Adam(b1=0.5),
                 scales=equal_timescale(constant(4e-4)))
    state = fed.init_state(jax.random.key(seed))
    rng = jax.random.key(seed + 1)
    round_fn = jax.jit(fed.round)
    t0 = time.perf_counter()
    for r in range(max(steps // K, 1)):
        rng, r1, r2, r3 = jax.random.split(rng, 4)
        xs, ys = [], []
        for i in range(B):
            x = sampler(jax.random.fold_in(r1, r * B + i), K * n, i)
            xs.append(x.reshape(K, n, 24))
            ys.append(jnp.broadcast_to(jax.nn.one_hot(i, 5), (K, n, 5)))
        batch = {
            "x": jnp.stack(xs, axis=1).reshape(K, 1, B, n, 24),
            "y": jnp.stack(ys, axis=1).reshape(K, 1, B, n, 5),
            "z": jax.random.normal(r2, (K, 1, B, n, 24)),
        }
        seeds = jax.random.randint(r3, (K, 1, B), 0, 2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, batch, seeds)
    us = (time.perf_counter() - t0) / steps * 1e6
    return fed, state, (G, D), us


def _eval_ts(fed, state, G, sampler, n_eval=900, seed=7):
    """Paper protocol: hold out 10%, generate profiles for the held-out
    labels, k-means both, compare top-9 centroids."""
    gp = fed.averaged_params(state)["gen"]
    rng = jax.random.key(seed)
    per = n_eval // 5
    reals, fakes = [], []
    for i in range(5):
        real = sampler(jax.random.fold_in(rng, i), per, i)
        lab = jnp.broadcast_to(jax.nn.one_hot(i, 5), (per, 5))
        z = jax.random.normal(jax.random.fold_in(rng, 50 + i), (per, 24))
        fakes.append(G.apply(gp, z, lab))
        reals.append(real)
    real = jnp.concatenate(reals)
    fake = jnp.concatenate(fakes)
    return centroid_match_score(real, fake, k=9, top=9)


def bench_household(steps=600):
    def sampler(rng, m, zone):
        return synthetic.sample_household_load(
            rng, m, climate_zone=jnp.full((m,), zone, jnp.int32))

    fed, state, (G, D), us = _train_ts(sampler, steps=steps)
    score = _eval_ts(fed, state, G, sampler)
    emit("fig3_pge_household", us,
         f"matched_rmse={score['matched_rmse']:.4f};random_rmse={score['random_rmse']:.4f}")


def bench_ev(steps=600):
    def sampler(rng, m, cat):
        return synthetic.sample_ev_sessions(
            rng, m, category=jnp.full((m,), cat, jnp.int32))

    fed, state, (G, D), us = _train_ts(sampler, steps=steps)
    score = _eval_ts(fed, state, G, sampler)
    emit("fig4_ev_charging", us,
         f"matched_rmse={score['matched_rmse']:.4f};random_rmse={score['random_rmse']:.4f}")


def main():
    bench_household()
    bench_ev()


if __name__ == "__main__":
    main()
