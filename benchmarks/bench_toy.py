"""Paper Figs 5/6/7 — toy experiments.

fig5_2d_K*:           (theta, psi) distance to the paper's fixed point (1, 0)
                      for K in {1, 5, 20, 50}  (Fig 5 robustness-to-K claim)
fig6_mixed_gaussian*: modes covered / high-quality fraction, FedGAN vs
                      local-only ablation  (Fig 6)
fig7_swissroll:       sliced-W1 distance real vs generated  (Fig 7)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import FedAvgSync, FedGAN, FedGANConfig, LocalOnly
from repro.data import synthetic
from repro.evals import mode_stats, wasserstein_1d_proj
from repro.launch.train import mlp_gan_task, toy2d_task
from repro.optim import Adam, SGD, constant, equal_timescale, power_decay


def bench_2d(steps=2500):
    task, (G, D) = toy2d_task()
    B, n = 5, 64
    for K in (1, 5, 20, 50):
        fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K),
                     opt_g=SGD(), opt_d=SGD(),
                     scales=equal_timescale(power_decay(0.1, tau=200, p=0.6)))
        state = fed.init_state(jax.random.key(0))
        rng = jax.random.key(1)
        round_fn = jax.jit(fed.round)
        t0 = time.perf_counter()
        for r in range(steps // K):
            rng, r1, r2, r3 = jax.random.split(rng, 4)
            x = jnp.stack([synthetic.sample_2d_segment(
                jax.random.fold_in(r1, r * B + i), K * n, i, B).reshape(K, n)
                for i in range(B)], axis=1).reshape(K, 1, B, n)
            z = jax.random.uniform(r2, (K, 1, B, n), minval=-1, maxval=1)
            seeds = jax.random.randint(r3, (K, 1, B), 0,
                                       2 ** 31 - 1).astype(jnp.uint32)
            state, _ = round_fn(state, {"x": x, "z": z}, seeds)
        us = (time.perf_counter() - t0) / steps * 1e6
        avg = fed.averaged_params(state)
        dist = ((float(avg["gen"]["theta"]) - 1.0) ** 2
                + float(avg["disc"]["psi"]) ** 2) ** 0.5
        emit(f"fig5_2d_K{K}", us, f"dist_to_(1;0)={dist:.4f}")


def _run_mlp_gan(sample_agent, B=4, K=5, steps=2000, n=128, strategy=None,
                 seed=0):
    task, (G, D) = mlp_gan_task(hidden=64)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(2e-4)))
    state = fed.init_state(jax.random.key(seed))
    rng = jax.random.key(seed + 1)
    round_fn = jax.jit(fed.round)
    t0 = time.perf_counter()
    for r in range(steps // K):
        rng, r1, r2, r3 = jax.random.split(rng, 4)
        x = jnp.stack([sample_agent(jax.random.fold_in(r1, r * B + i), i,
                                    K * n).reshape(K, n, 2)
                       for i in range(B)], axis=1).reshape(K, 1, B, n, 2)
        z = jax.random.normal(r2, (K, 1, B, n, 2))
        seeds = jax.random.randint(r3, (K, 1, B), 0, 2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, {"x": x, "z": z}, seeds)
    us = (time.perf_counter() - t0) / steps * 1e6
    gp = fed.averaged_params(state)["gen"]
    samples = G.apply(gp, jax.random.normal(jax.random.key(9), (2000, 2)))
    return samples, us


def bench_mixed_gaussian(steps=2000):
    modes = synthetic.mixed_gaussian_modes()

    def agent_sample(rng, i, m):
        return synthetic.sample_mixed_gaussian(rng, m,
                                               mode_subset=[2 * i, 2 * i + 1])

    for strat in (FedAvgSync(), LocalOnly()):
        samples, us = _run_mlp_gan(agent_sample, steps=steps, strategy=strat)
        covered, hq, _ = mode_stats(samples, modes, radius=0.5)
        emit(f"fig6_mixed_gaussian_{strat.name}", us,
             f"modes={covered}/8;hq={hq:.2f}")


def bench_swissroll(steps=2000):
    B = 4

    def agent_sample(rng, i, m):
        return synthetic.sample_swiss_roll(
            rng, m, t_range=(0.25 + 0.75 * i / B, 0.25 + 0.75 * (i + 1) / B))

    samples, us = _run_mlp_gan(agent_sample, B=B, steps=steps)
    real = synthetic.sample_swiss_roll(jax.random.key(10), 2000)
    w1 = wasserstein_1d_proj(real, samples)
    base = wasserstein_1d_proj(
        real, jax.random.normal(jax.random.key(11), (2000, 2)))
    emit("fig7_swissroll", us, f"slicedW1={w1:.3f};noise_ref={base:.3f}")


def main():
    bench_2d()
    bench_mixed_gaussian()
    bench_swissroll()


if __name__ == "__main__":
    main()
