"""Shared helpers for the benchmark harness.

Every bench prints ``name,us_per_call,derived`` CSV rows; ``derived`` carries
the paper-comparable quantity (FID-analog, mode coverage, comm bytes, ...).
"""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Wall-time a jitted callable; returns (result, us_per_call)."""
    r = None
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return r, (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
