"""Shared helpers for the benchmark harness.

Every bench prints ``name,us_per_call,derived`` CSV rows; ``derived`` carries
the paper-comparable quantity (FID-analog, mode coverage, comm bytes, ...).
``emit`` also records each row in a process-local buffer so ``run.py --json``
can persist machine-readable ``BENCH_<suite>.json`` artifacts; pass extra
keyword fields for structured quantities the CSV string would mangle
(``emit("serve_occ4", us, "...", tokens_per_sec=123.4)``).
"""
from __future__ import annotations

import time

import jax

_RECORDS: list[dict] = []


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Wall-time a jitted callable; returns (result, us_per_call)."""
    r = None
    for _ in range(warmup):
        r = fn(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return r, (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived, **extra):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": str(derived), **extra})


def drain_records() -> list[dict]:
    """Return and clear the rows emitted since the last drain."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out
