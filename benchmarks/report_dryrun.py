"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
results/dryrun/*.json.

Usage: PYTHONPATH=src python -m benchmarks.report_dryrun [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.models.config import SHAPES

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["gemma3-4b", "mixtral-8x22b", "qwen3-8b", "phi4-mini-3.8b",
              "whisper-medium", "glm4-9b", "zamba2-7b", "granite-moe-3b-a800m",
              "chameleon-34b", "mamba2-2.7b"]


def load(dirpath, tag):
    recs = {}
    for p in glob.glob(os.path.join(dirpath, f"{tag}__*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], "2x16x16" if r.get("multi_pod") else "16x16")] = r
    return recs


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, mesh):
    lines = [
        f"\n#### Mesh {mesh}\n",
        "| arch | shape | status | compute | memory | collective (ICI) | dominant | HBM/dev | agent-axis B/step |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skip: {r['reason'][:48]} | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR {r['error'][:40]} | | | | | | |")
                continue
            t = r["roofline_per_step"]
            hbm = r["memory"]["total_hbm_bytes"] / 2 ** 30
            ag = r["collective_by_axis"].get("agent", 0) / r["steps_per_call"]
            lines.append(
                f"| {arch} | {shape} | ok | {fmt_s(t['compute_s'])} | "
                f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
                f"{t['dominant'].replace('_s','')} | {hbm:.1f}GiB | {ag/1e6:.1f}MB |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    recs = load(args.dir, args.tag)
    meshes = sorted({k[2] for k in recs})
    for mesh in meshes:
        print(table(recs, mesh))
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    err = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"\n**Totals ({args.tag})**: {ok} compiled, {skip} documented skips, "
          f"{err} errors across {len(recs)} (arch x shape x mesh) entries.")


if __name__ == "__main__":
    main()
