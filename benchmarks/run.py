# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig 5  (2D system, K sweep)          -> bench_toy.bench_2d
#   Fig 6  (mixed Gaussian)              -> bench_toy.bench_mixed_gaussian
#   Fig 7  (Swiss roll)                  -> bench_toy.bench_swissroll
#   Fig 1b (CIFAR FID vs K + baseline)   -> bench_images.bench_fd_vs_k
#   Fig 2b (CelebA attribute split)      -> bench_images.bench_celeba_attributes
#   Fig 3  (PG&E household clusters)     -> bench_timeseries.bench_household
#   Fig 4  (EV charging clusters)        -> bench_timeseries.bench_ev
#   §3.2   (communication complexity)    -> bench_comm
#   rounds (legacy loop vs repro.run driver) -> bench_rounds
#   Lem1/2 (drift vs bounds)             -> bench_lemmas
#   (g)    (roofline from dry-run)       -> bench_roofline
#   kernels (Pallas vs oracle)           -> bench_kernels
#   serving (tok/s + tick latency vs occupancy) -> bench_serve
#   privacy (DP/secure-sum/robust cost surface) -> bench_privacy
#   agents (virtual-client fleet scaling)       -> bench_agents
#
# ``--json`` additionally writes one machine-readable BENCH_<suite>.json per
# executed suite (into --json-dir), so the bench trajectory is comparable
# across commits instead of living only in scrollback.
import argparse
import json
import os
import sys
import time
import traceback

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; the root must be importable for the `benchmarks.*` modules.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench subset")
    ap.add_argument("--fast", action="store_true", help="reduced step budgets")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<suite>.json artifacts")
    ap.add_argument("--json-dir", default=".",
                    help="directory for the --json artifacts")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_agents, bench_comm, bench_images,
                            bench_kernels, bench_lemmas, bench_privacy,
                            bench_roofline, bench_rounds, bench_serve,
                            bench_timeseries, bench_toy, common)

    fast = args.fast
    suites = {
        "toy": lambda: (bench_toy.bench_2d(steps=800 if fast else 2500),
                        bench_toy.bench_mixed_gaussian(steps=600 if fast else 2000),
                        bench_toy.bench_swissroll(steps=600 if fast else 2000)),
        "images": lambda: (bench_images.bench_fd_vs_k(steps=120 if fast else 400),
                           bench_images.bench_celeba_attributes(steps=100 if fast else 300)),
        "timeseries": lambda: (bench_timeseries.bench_household(steps=200 if fast else 600),
                               bench_timeseries.bench_ev(steps=200 if fast else 600)),
        "comm": lambda: bench_comm.main(fast=fast),
        "lemmas": bench_lemmas.main,
        "roofline": lambda: bench_roofline.main(fast=fast),
        "kernels": bench_kernels.main,
        "serve": lambda: bench_serve.main(fast=fast),
        "rounds": lambda: bench_rounds.main(fast=fast),
        "privacy": lambda: bench_privacy.main(fast=fast),
        "agents": lambda: bench_agents.main(fast=fast),
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        common.drain_records()
        error = ""
        try:
            fn()
        except Exception:
            error = traceback.format_exc(limit=1).splitlines()[-1]
            print(f"{name}_SUITE_ERROR,0.0,{error}", flush=True)
        print(f"# suite {name} finished in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)
        if args.json:
            artifact = {"suite": name, "fast": fast,
                        "seconds": round(time.time() - t0, 1),
                        "records": common.drain_records()}
            if error:
                artifact["error"] = error
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(artifact, f, indent=1)
            print(f"# wrote {path}", file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
