# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   Fig 5  (2D system, K sweep)          -> bench_toy.bench_2d
#   Fig 6  (mixed Gaussian)              -> bench_toy.bench_mixed_gaussian
#   Fig 7  (Swiss roll)                  -> bench_toy.bench_swissroll
#   Fig 1b (CIFAR FID vs K + baseline)   -> bench_images.bench_fd_vs_k
#   Fig 2b (CelebA attribute split)      -> bench_images.bench_celeba_attributes
#   Fig 3  (PG&E household clusters)     -> bench_timeseries.bench_household
#   Fig 4  (EV charging clusters)        -> bench_timeseries.bench_ev
#   §3.2   (communication complexity)    -> bench_comm
#   Lem1/2 (drift vs bounds)             -> bench_lemmas
#   (g)    (roofline from dry-run)       -> bench_roofline
#   kernels (Pallas vs oracle)           -> bench_kernels
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench subset")
    ap.add_argument("--fast", action="store_true", help="reduced step budgets")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_comm, bench_images, bench_kernels,
                            bench_lemmas, bench_roofline, bench_timeseries,
                            bench_toy)

    fast = args.fast
    suites = {
        "toy": lambda: (bench_toy.bench_2d(steps=800 if fast else 2500),
                        bench_toy.bench_mixed_gaussian(steps=600 if fast else 2000),
                        bench_toy.bench_swissroll(steps=600 if fast else 2000)),
        "images": lambda: (bench_images.bench_fd_vs_k(steps=120 if fast else 400),
                           bench_images.bench_celeba_attributes(steps=100 if fast else 300)),
        "timeseries": lambda: (bench_timeseries.bench_household(steps=200 if fast else 600),
                               bench_timeseries.bench_ev(steps=200 if fast else 600)),
        "comm": bench_comm.main,
        "lemmas": bench_lemmas.main,
        "roofline": bench_roofline.main,
        "kernels": bench_kernels.main,
    }
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            print(f"{name}_SUITE_ERROR,0.0,{traceback.format_exc(limit=1).splitlines()[-1]}",
                  flush=True)
        print(f"# suite {name} finished in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
