"""End-to-end federated adversarial training of an assigned backbone.

Four agents with non-iid token streams train (G = reduced assigned arch,
D = feature discriminator) under FedGAN; the script reports per-round
losses, the §3.2 communication accounting, and final agent synchrony.

Run:  PYTHONPATH=src python examples/federated_backbone.py \
          --arch mamba2-2.7b --steps 60 --K 5
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core import FedGAN, FedGANConfig, get_strategy, strategies
from repro.data import FederatedRounds, synthetic
from repro.launch.steps import make_lm_gan_task
from repro.optim import Adam, constant, equal_timescale

tmap = jax.tree_util.tree_map


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--K", type=int, default=5)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--strategy", default="fedgan",
                    choices=sorted(strategies.STRATEGIES))
    ap.add_argument("--intra-interval", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    B, K, T = args.agents, args.K, 32
    strat_kw = ({"intra_interval": args.intra_interval}
                if args.strategy == "hierarchical" else {})
    strategy = get_strategy(args.strategy, **strat_kw)
    task = make_lm_gan_task(cfg)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(0))

    rng = jax.random.key(1)
    agent_data = []
    for i in range(B):
        d = {"tokens": synthetic.sample_agent_tokens(
            rng, 512, T, cfg.vocab_size, agent=i, num_agents=B)}
        if cfg.family == "audio":
            d["frames"] = 0.1 * jax.random.normal(
                jax.random.fold_in(rng, 50 + i),
                (512, cfg.encoder_seq, cfg.d_model))
        agent_data.append(d)
    rounds = FederatedRounds(agent_data, (1, B), batch_size=8, sync_interval=K)

    acct = fed.comm_bytes_per_round(state)
    print(f"arch={cfg.name} (smoke) B={B} K={K} strategy={strategy.name}")
    print(f"§3.2 accounting: M={acct['param_bytes_M']/1e6:.1f}MB/agent, "
          f"fedgan {acct['per_agent_per_round']['fedgan']/1e6:.1f}MB/round vs "
          f"distributed {acct['per_agent_per_round']['distributed']/1e6:.1f}MB/round "
          f"(x{acct['ratio']} saving); this strategy moves "
          f"{acct['strategy_bytes_per_round']/1e6:.1f}MB/round")

    round_fn = jax.jit(fed.round)
    for r in range(args.steps // K):
        rng, rb = jax.random.split(rng)
        batches, seeds = rounds.round_batches(rb)
        state, m = round_fn(state, batches, seeds)
        print(f"  round {r:3d} step {(r+1)*K:4d}: "
              f"d_loss={float(jnp.mean(m['d_loss'])):.4f} "
              f"g_loss={float(jnp.mean(m['g_loss'])):.4f} "
              f"lm={float(jnp.mean(m['lm'])):.4f}")

    leaf = jax.tree_util.tree_leaves(state["params"]["gen"])[0]
    synced = bool(jnp.allclose(leaf[0, 0], leaf[0, -1], atol=1e-5))
    # subsampled/adaptive_k legitimately leave agents apart after a round
    # (non-participants keep local state; skip rounds don't sync at all)
    always_syncs = args.strategy not in ("local_only", "subsampled",
                                         "adaptive_k")
    print(f"agents synced after final round: {synced} "
          f"(expected {always_syncs})")


if __name__ == "__main__":
    main()
