"""End-to-end federated image GAN (paper §4.2 shape, synthetic data gate).

B=5 agents each hold TWO of ten image classes (the paper's MNIST/CIFAR
split); an ACGAN pair trains with K=20 local steps per sync.  Reports the
Fréchet-distance score against held-out real data, compares against the
distributed-GAN baseline, and exercises checkpoint save/restore.

Run:  PYTHONPATH=src python examples/federated_images.py [--steps 400]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import FedAvgSync, FedGAN, FedGANConfig, PerStepGradAvg
from repro.data import synthetic
from repro.evals import fd_score
from repro.launch.train import acgan_task
from repro.optim import Adam, constant, equal_timescale

HW, NCLS, B = 16, 10, 5


def train(K, steps, strategy, seed=0, n=32):
    task, (G, D) = acgan_task(hw=HW, num_classes=NCLS)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy),
                 opt_g=Adam(b1=0.5), opt_d=Adam(b1=0.5),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(seed))
    rng = jax.random.key(seed + 1)
    round_fn = jax.jit(fed.round)
    for r in range(max(steps // K, 1)):
        rng, r1, r2, r3, r4 = jax.random.split(rng, 5)
        labs, imgs = [], []
        for i in range(B):
            lab = jax.random.randint(jax.random.fold_in(r1, r * B + i),
                                     (K * n,), 2 * i, 2 * i + 2)
            img = synthetic.sample_class_images(
                jax.random.fold_in(r2, r * B + i), K * n, lab, hw=HW,
                num_classes=NCLS)
            labs.append(lab.reshape(K, n))
            imgs.append(img.reshape(K, n, HW, HW, 3))
        batch = {"x": jnp.stack(imgs, 1).reshape(K, 1, B, n, HW, HW, 3),
                 "y": jnp.stack(labs, 1).reshape(K, 1, B, n),
                 "z": jax.random.normal(r3, (K, 1, B, n, 62))}
        seeds = jax.random.randint(r4, (K, 1, B), 0, 2 ** 31 - 1).astype(jnp.uint32)
        state, m = round_fn(state, batch, seeds)
    return fed, state, (G, D)


def evaluate(fed, state, G, n_eval=512):
    gp = fed.averaged_params(state)["gen"]
    rng = jax.random.key(99)
    lab = jax.random.randint(rng, (n_eval,), 0, NCLS)
    fake = G.apply(gp, jax.random.normal(jax.random.fold_in(rng, 1),
                                         (n_eval, 62)), lab)
    real = synthetic.sample_class_images(jax.random.fold_in(rng, 2), n_eval,
                                         lab, hw=HW, num_classes=NCLS)
    return fd_score(jax.random.key(7), real, fake)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--K", type=int, default=20)
    args = ap.parse_args()

    print(f"FedGAN ACGAN, B={B} agents x 2 classes, K={args.K}")
    fed, state, (G, D) = train(args.K, args.steps, FedAvgSync())
    fd = evaluate(fed, state, G)
    print(f"  FedGAN      (K={args.K}): FD = {fd:.2f}")

    fed_b, state_b, (Gb, _) = train(1, args.steps, PerStepGradAvg())
    fd_b = evaluate(fed_b, state_b, Gb)
    print(f"  distributed (K=1):  FD = {fd_b:.2f}  "
          f"(paper claim: FedGAN stays close at 1/{args.K} the communication)")

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, step=args.steps,
                        metadata={"K": args.K, "fd": fd})
        restored, man = restore_checkpoint(d)
        fd_r = evaluate(fed, restored, G)
        print(f"  checkpoint roundtrip: FD = {fd_r:.2f} (must match)")
        assert abs(fd_r - fd) < 1e-6


if __name__ == "__main__":
    main()
