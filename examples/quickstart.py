"""Quickstart: FedGAN on the paper's 2D system (Appendix C, Fig 5).

Five agents each see one slice of U[-1,1]; local D(x) = psi x^2 and
G(z) = theta z train locally for K steps between parameter syncs.  The run
prints the (theta, psi) trajectory converging to the paper's fixed point
(1, 0) — and is robust to the sync interval K.

Run:  PYTHONPATH=src python examples/quickstart.py [--K 20]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import FedAvgSync, FedGAN, FedGANConfig, make_gan_task
from repro.data import synthetic
from repro.models.gan_nets import Toy2DDiscriminator, Toy2DGenerator
from repro.optim import SGD, equal_timescale, power_decay


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--K", type=int, default=20)
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--agents", type=int, default=5)
    args = ap.parse_args()
    B, K = args.agents, args.K

    G, D = Toy2DGenerator(theta0=0.5), Toy2DDiscriminator(psi0=0.5)
    # the (G, D) pair + the non-saturating loss family -> a GANTask;
    # FedAvgSync() IS the paper's intermediary (swap in PartialSharing(),
    # Hierarchical(...), ... from repro.core.strategies to change how
    # agents aggregate — the training loop below does not change).
    task = make_gan_task(G, D)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=FedAvgSync()),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(power_decay(0.1, tau=200, p=0.6)))
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(1)
    n = 64

    print(f"FedGAN 2D system: B={B} agents, K={K}")
    for r in range(args.steps // K):
        rng, r1, r2, r3 = jax.random.split(rng, 4)
        x = jnp.stack([synthetic.sample_2d_segment(
            jax.random.fold_in(r1, r * B + i), K * n, i, B).reshape(K, n)
            for i in range(B)], axis=1).reshape(K, 1, B, n)
        z = jax.random.uniform(r2, (K, 1, B, n), minval=-1, maxval=1)
        seeds = jax.random.randint(r3, (K, 1, B), 0, 2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, {"x": x, "z": z}, seeds)
        if r % max((args.steps // K) // 10, 1) == 0:
            avg = fed.averaged_params(state)
            print(f"  step {(r+1)*K:5d}: theta={float(avg['gen']['theta']):+.4f} "
                  f"psi={float(avg['disc']['psi']):+.4f}")
    avg = fed.averaged_params(state)
    theta, psi = float(avg["gen"]["theta"]), float(avg["disc"]["psi"])
    print(f"final: (theta, psi) = ({theta:+.4f}, {psi:+.4f})  "
          f"[paper fixed point: (1, 0)]")
    assert abs(theta - 1.0) < 0.1 and abs(psi) < 0.1, "did not converge!"
    print("converged ✓")


if __name__ == "__main__":
    main()
