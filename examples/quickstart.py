"""Quickstart: FedGAN on the paper's 2D system (Appendix C, Fig 5).

Five agents each see one slice of U[-1,1]; local D(x) = psi x^2 and
G(z) = theta z train locally for K steps between parameter syncs.  The run
prints the (theta, psi) trajectory converging to the paper's fixed point
(1, 0) — and is robust to the sync interval K.

The round loop is the ``repro.run`` streaming runtime: every agent's shard
is device-resident (``DeviceFederatedData``), the K minibatches are
sampled inside the jitted round, the state buffers are donated, and ten
rounds run per dispatch — the whole 3000-step run is ~15 XLA calls.

Run:  PYTHONPATH=src python examples/quickstart.py [--K 20]
"""
import argparse

import jax

from repro.core import FedAvgSync, FedGAN, FedGANConfig, make_gan_task
from repro.data import DeviceFederatedData, synthetic
from repro.models.gan_nets import Toy2DDiscriminator, Toy2DGenerator
from repro.optim import SGD, equal_timescale, power_decay
from repro.run import RoundDriver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--K", type=int, default=20)
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--agents", type=int, default=5)
    args = ap.parse_args()
    B, K = args.agents, args.K

    G, D = Toy2DGenerator(theta0=0.5), Toy2DDiscriminator(psi0=0.5)
    # the (G, D) pair + the non-saturating loss family -> a GANTask;
    # FedAvgSync() IS the paper's intermediary (swap in PartialSharing(),
    # Hierarchical(...), ... from repro.core.strategies to change how
    # agents aggregate — the training loop below does not change).
    task = make_gan_task(G, D)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=FedAvgSync()),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(power_decay(0.1, tau=200, p=0.6)))

    # each agent's full shard lives on device; z-draws and index sampling
    # happen inside the jitted round from a threaded PRNG key
    rng = jax.random.key(0)
    data = DeviceFederatedData.from_agent_data(
        [{"x": synthetic.sample_2d_segment(jax.random.fold_in(rng, i),
                                           4096, i, B)} for i in range(B)],
        (1, B), batch_size=64,
        sample_extra=lambda r, s: {"z": jax.random.uniform(r, s, minval=-1,
                                                           maxval=1)})

    n_rounds = args.steps // K
    seg_rounds = max(n_rounds // 10, 1)
    drivers = {}  # one driver per segment length (jit cache lives on it)

    print(f"FedGAN 2D system: B={B} agents, K={K} ({n_rounds} rounds, "
          f"{seg_rounds} per print)")
    state = fed.init_state(jax.random.key(0))
    rng = jax.random.key(1)
    done = seg = 0
    while done < n_rounds:
        c = min(seg_rounds, n_rounds - done)
        if c not in drivers:
            drivers[c] = RoundDriver(fed, data, c, log_every=0,
                                     verbose=False, rounds_per_chunk=c)
        state = drivers[c].run(jax.random.fold_in(rng, seg), state=state).state
        done, seg = done + c, seg + 1
        avg = fed.averaged_params(state)
        print(f"  step {done * K:5d}: "
              f"theta={float(avg['gen']['theta']):+.4f} "
              f"psi={float(avg['disc']['psi']):+.4f}")
    avg = fed.averaged_params(state)
    theta, psi = float(avg["gen"]["theta"]), float(avg["disc"]["psi"])
    print(f"final: (theta, psi) = ({theta:+.4f}, {psi:+.4f})  "
          f"[paper fixed point: (1, 0)]")
    assert abs(theta - 1.0) < 0.1 and abs(psi) < 0.1, "did not converge!"
    print("converged ✓")


if __name__ == "__main__":
    main()
