"""Serve a generator with continuous batching — thin CLI over repro.serve.

Submits ``--requests`` generation requests of staggered prompt lengths to a
:class:`repro.serve.ServeEngine` (any of the 10 assigned archs via --arch,
reduced smoke size) and drains them: requests are admitted into free batch
slots as earlier ones finish, every slot decodes at its own position, and
sliding-window archs can serve with O(window) ring caches (--ring).

Run:  PYTHONPATH=src python examples/serve_generator.py --arch gemma3-4b \
          --requests 6 --batch 4 --prompt-len 32 --gen 16 --ring

Hot-reload a training run live (two terminals, docs/serving.md):

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b \
      --steps 40 --ckpt-dir /tmp/fedgan-ck          # terminal 1
  PYTHONPATH=src python examples/serve_generator.py --arch gemma3-4b \
      --ckpt-dir /tmp/fedgan-ck                     # terminal 2
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4, help="engine batch slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="decode-cache capacity (default prompt+gen)")
    ap.add_argument("--ring", action="store_true",
                    help="O(window) ring caches on sliding-window layers")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default="",
                    help="hot-reload generator params from this train run")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    max_seq = args.max_seq or args.prompt_len + args.gen
    eng = ServeEngine(cfg, max_batch=args.batch, max_seq=max_seq,
                      ring=args.ring, ckpt_dir=args.ckpt_dir)

    rng = jax.random.key(1)
    rids = []
    for i in range(args.requests):
        # staggered lengths exercise bucketing + mid-stream admission
        T = max(4, args.prompt_len - 3 * (i % args.batch))
        prompt = jax.random.randint(jax.random.fold_in(rng, i), (T,), 0,
                                    cfg.vocab_size)
        frames = None
        if cfg.family == "audio":
            frames = 0.1 * jax.random.normal(
                jax.random.fold_in(rng, 1000 + i),
                (cfg.encoder_seq, cfg.d_model))
        rids.append(eng.submit(prompt, max_new_tokens=args.gen,
                               temperature=args.temperature, frames=frames))

    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0

    s = eng.stats
    for rid in rids:
        req = done[rid]
        assert len(req.generated) == args.gen
        assert max(req.generated) < cfg.vocab_size
        print(f"req {rid}: prompt {req.prompt_len:3d} -> {req.generated[:8]}"
              f"{' ...' if args.gen > 8 else ''}")
    print(f"arch={cfg.name} (smoke) ring={args.ring} slots={args.batch} "
          f"buckets={sorted(s.prefill_buckets)}")
    print(f"{s.ticks} ticks, {s.decode_tokens} decode tokens in {wall:.1f}s "
          f"wall ({s.tokens_per_sec():.0f} tok/s decode, "
          f"occupancy {s.mean_occupancy(args.batch):.0%})")
    print(f"tick latency p50={s.tick_ms(50):.1f}ms p99={s.tick_ms(99):.1f}ms; "
          f"reloads={s.reloads}"
          + (f" (step {eng.loaded_step})" if eng.loaded_step is not None else ""))
    print("serve OK ✓")


if __name__ == "__main__":
    main()
