"""End-to-end serving driver: batched prefill + decode of a backbone.

Loads a reduced assigned architecture (any of the 10 via --arch), prefill's
a batch of prompts, then decodes new tokens step by step — the same
prefill/serve_step pair the 32k/500k dry-run shapes lower.  Sliding-window
archs can serve with O(window) ring caches (--ring).

Run:  PYTHONPATH=src python examples/serve_generator.py --arch gemma3-4b \
          --batch 4 --prompt-len 32 --gen 16 --ring
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import Backbone


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ring", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    bb = Backbone(cfg, ring_cache=args.ring)
    params = bb.init(jax.random.key(0))
    rng = jax.random.key(1)
    B, T, G = args.batch, args.prompt_len, args.gen
    max_seq = T + G
    prompts = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(jax.random.fold_in(rng, 2),
                                         (B, cfg.encoder_seq, cfg.d_model))

    # ---- prefill ----
    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, t: bb.prefill(p, t, encoder_frames=frames,
                                              max_seq=max_seq))
    out = prefill(params, prompts)
    jax.block_until_ready(out["logits"])
    t_prefill = time.perf_counter() - t0
    cache = out["cache"]
    if cfg.family == "audio":
        mem = out["memory"]
        blk = bb._block(cross=True)
        cache["cross"] = jax.vmap(
            lambda bp: blk.attn.build_memory_cache(bp["xattn"], mem))(params["blocks"])

    # ---- decode loop (greedy/temperature sampling over the REAL vocab; the
    # head is padded to a multiple of 256 for sharding) ----
    decode = jax.jit(bb.decode)
    logits = out["logits"][:, -1]

    def sample(rng, logits):
        logits = logits[:, :cfg.vocab_size]  # mask vocab padding
        if args.temperature == 0:
            return jnp.argmax(logits, -1)
        return jax.random.categorical(rng, logits / args.temperature, axis=-1)

    tokens = []
    t0 = time.perf_counter()
    tok = sample(jax.random.fold_in(rng, 100), logits)
    for i in range(G):
        tokens.append(tok)
        logits1, cache = decode(params, tok[:, None], cache, jnp.int32(T + i))
        tok = sample(jax.random.fold_in(rng, 101 + i), logits1[:, 0])
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.stack(tokens, axis=1)
    print(f"arch={cfg.name} (smoke) ring_cache={args.ring}")
    print(f"prefill: {B}x{T} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*T/t_prefill:.0f} tok/s incl. compile)")
    print(f"decode:  {G} steps x batch {B} in {t_decode*1e3:.1f} ms "
          f"({B*G/t_decode:.0f} tok/s)")
    print(f"generated ids[0]: {gen[0].tolist()}")
    assert gen.shape == (B, G) and int(gen.max()) < cfg.vocab_size
    print("serve OK ✓")


if __name__ == "__main__":
    main()
