"""FedGAN reproduction (arXiv:2006.07228) grown toward a production-scale
jax sharded training + serving system.  Importing the package installs the
jax version shims (see repro.dist.compat) so the mesh-context API the repo
programs against works on the pinned runtime."""
from repro.dist import compat as _compat

_compat.install()
