"""repro.analysis — two-layer static analysis for the FedGAN repro.

Layer 1 (``trace``/``hotpath``) audits the *built artifacts*: jaxprs of
the round functions and post-SPMD HLO of every strategy x codec cell.
Layer 2 (``lint``) audits the *source and docs*: host-sync calls in hot
paths, kernel/ref pairing, refusal-matrix and catalogue drift.

CLI: ``python -m repro.analysis [--json] [--rules ...]``; the committed
``baseline.json`` makes the gate "zero NEW findings".  See
docs/analysis.md.

This module stays jax-free so the lint layer works in any environment.
"""
from repro.analysis.findings import (Finding, baseline_path, filter_suppressed,
                                     load_baseline, new_findings,
                                     write_baseline)
from repro.analysis.lint import LintContext, run_lint

__all__ = [
    "Finding", "LintContext", "baseline_path", "filter_suppressed",
    "load_baseline", "new_findings", "run_lint", "write_baseline",
]
