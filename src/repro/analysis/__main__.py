"""CLI: ``python -m repro.analysis``.

Default run = lint + trace rules, gated against the committed
``baseline.json`` (exit 1 on any NEW finding).  The wire matrix
(``--rules wire``) compiles every strategy x codec cell on 8 virtual
devices and is opt-in — it is minutes, not seconds.

  python -m repro.analysis                      # gate (lint + trace)
  python -m repro.analysis --json               # machine-readable report
  python -m repro.analysis --rules host-sync    # one rule
  python -m repro.analysis --rules wire         # the strategy x codec matrix
  python -m repro.analysis --update-baseline    # rewrite baseline (reviewed!)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

LINT_RULE_IDS = ("host-sync", "kernel-ref-pair", "refusal-matrix",
                 "catalogue-drift")
TRACE_RULE_IDS = ("host-callback-in-scan", "raw-fold-in", "pad-reuse",
                  "donation-miss")
WIRE_RULE_IDS = ("wire-dtype",)
RULE_GROUPS = {
    "lint": LINT_RULE_IDS,
    "trace": TRACE_RULE_IDS,
    "wire": WIRE_RULE_IDS,
    "all": LINT_RULE_IDS + TRACE_RULE_IDS + WIRE_RULE_IDS,
}
DEFAULT_RULES = LINT_RULE_IDS + TRACE_RULE_IDS


def _parse_rules(spec: str) -> tuple:
    if not spec:
        return DEFAULT_RULES
    out: list = []
    known = RULE_GROUPS["all"]
    for tok in spec.replace(",", " ").split():
        if tok in RULE_GROUPS:
            out.extend(RULE_GROUPS[tok])
        elif tok in known:
            out.append(tok)
        else:
            raise SystemExit(f"unknown rule {tok!r}; known rules: "
                             f"{', '.join(known)}; groups: "
                             f"{', '.join(RULE_GROUPS)}")
    return tuple(dict.fromkeys(out))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true",
                    help="print the full JSON report to stdout")
    ap.add_argument("--rules", default="",
                    help="comma/space-separated rule ids or groups "
                         "(lint, trace, wire, all); default lint+trace")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite baseline.json from this run's findings "
                         "(entries need human reasons before the gate "
                         "accepts them)")
    ap.add_argument("--baseline", default="",
                    help="alternate baseline.json path")
    ap.add_argument("--out", default="",
                    help="also write the JSON report to this path")
    ap.add_argument("--root", default="",
                    help="repo root override (fixtures/tests)")
    args = ap.parse_args(argv)

    rules = _parse_rules(args.rules)
    want_wire = any(r in WIRE_RULE_IDS for r in rules)
    if want_wire:
        # must precede the first jax import in this process
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8").strip()

    from repro.analysis.findings import (load_baseline, new_findings,
                                         write_baseline)
    from repro.analysis.lint import LintContext, repo_root_from_package, run_lint

    root = os.path.abspath(args.root) if args.root else repo_root_from_package()
    findings = []

    lint_rules = [r for r in rules if r in LINT_RULE_IDS]
    if lint_rules:
        findings += run_lint(LintContext.for_repo(root), rules=lint_rules)

    trace_rules = [r for r in rules if r in TRACE_RULE_IDS]
    if trace_rules:
        from repro.analysis.trace import run_trace
        findings += [f for f in run_trace(root) if f.rule in trace_rules]

    cells = []
    if want_wire:
        from repro.analysis.hotpath import run_wire_matrix
        cells, wire_findings = run_wire_matrix(root)
        findings += wire_findings

    if args.update_baseline:
        path = write_baseline(findings, args.baseline or None)
        print(f"wrote {len(findings)} finding(s) to {path}; fill in the "
              "'reason' field of each entry before the gate will accept it")
        return 0

    try:
        baseline = load_baseline(args.baseline or None)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    new = new_findings(findings, baseline)

    report = {
        "root": root,
        "rules": list(rules),
        "findings": [f.to_json() for f in findings],
        "new": [f.to_json() for f in new],
        "baselined": len(findings) - len(new),
    }
    if cells:
        report["wire_cells"] = [
            {"strategy": c.strategy, "class": c.cls_name, "codec": c.codec,
             "status": c.status, "reason": c.reason,
             "agent_bytes_once": c.agent_bytes_once, "billed": c.billed}
            for c in cells]

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1)
            f.write("\n")

    if args.json:
        print(json.dumps(report, indent=1))
    else:
        for f in findings:
            marker = "" if f.key in {n.key for n in new} else " (baselined)"
            print(f.render() + marker)
        for c in cells:
            extra = c.reason if c.status == "refused" else (
                f"agent_bytes_once={c.agent_bytes_once} billed={c.billed}")
            print(f"[wire] {c.strategy:16s} x {c.codec:5s} {c.status:8s} {extra}")
        print(f"{len(findings)} finding(s), {len(new)} new vs baseline, "
              f"rules: {', '.join(rules)}")

    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
