"""Finding model, suppression comments, and the committed baseline.

A finding is one defect instance: (rule, file, line, message, severity).
The JSON report, the ``# analysis: allow(rule-id)`` suppression comments,
and ``baseline.json`` all key off this object.

Baseline matching deliberately EXCLUDES the line number: a baselined
false positive should not resurface because unrelated edits shifted the
file.  The key is (rule, file, message) — if the message changes, the
finding is new and the gate fires.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re

SEVERITIES = ("error", "warning")

# matches both `# analysis: allow(rule-a, rule-b)` in Python and
# `<!-- analysis: allow(rule-a) -->` in markdown
_ALLOW_RE = re.compile(r"analysis:\s*allow\(([^)]*)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    file: str           # repo-relative posix path ("" for synthetic targets)
    line: int           # 1-based; 0 means file-level
    message: str
    severity: str = "error"

    @property
    def key(self) -> tuple:
        """Baseline identity — line-independent on purpose."""
        return (self.rule, self.file, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "file": self.file, "line": self.line,
                "message": self.message, "severity": self.severity}

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(rule=d["rule"], file=d.get("file", ""),
                   line=int(d.get("line", 0)), message=d["message"],
                   severity=d.get("severity", "error"))

    def render(self) -> str:
        loc = f"{self.file}:{self.line}" if self.file else "<synthetic>"
        return f"{loc}: [{self.rule}] {self.severity}: {self.message}"


def allowed_rules_on_line(text_line: str) -> set:
    """Rule ids named by an ``analysis: allow(...)`` marker on this line."""
    m = _ALLOW_RE.search(text_line)
    if not m:
        return set()
    return {r.strip() for r in m.group(1).split(",") if r.strip()}


def is_suppressed(finding: Finding, root: str) -> bool:
    """True when the finding's line — or the line directly above it —
    carries an ``analysis: allow(<rule>)`` marker."""
    if not finding.file or finding.line <= 0:
        return False
    path = os.path.join(root, finding.file)
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError:
        return False
    idx = finding.line - 1
    for i in (idx, idx - 1):
        if 0 <= i < len(lines) and finding.rule in allowed_rules_on_line(lines[i]):
            return True
    return False


def filter_suppressed(findings, root: str) -> list:
    return [f for f in findings if not is_suppressed(f, root)]


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def baseline_path() -> str:
    """The committed baseline that ships with the package."""
    return os.path.join(os.path.dirname(__file__), "baseline.json")


def load_baseline(path: str | None = None) -> set:
    """Set of baselined finding keys.  Every entry in the file must carry a
    ``reason`` — only *documented* false positives may be baselined."""
    path = path or baseline_path()
    try:
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
    except OSError:
        return set()
    keys = set()
    for ent in data.get("findings", []):
        if not ent.get("reason"):
            raise ValueError(
                f"baseline entry without a reason: {ent} — baseline.json only "
                "admits documented false positives (fix true positives instead)")
        keys.add((ent["rule"], ent.get("file", ""), ent["message"]))
    return keys


def new_findings(findings, baseline_keys: set) -> list:
    """The gate: findings not covered by the committed baseline."""
    return [f for f in findings if f.key not in baseline_keys]


def write_baseline(findings, path: str | None = None) -> str:
    """``--update-baseline``: rewrite the baseline from the current run.
    Entries get a placeholder reason that load_baseline will REFUSE until a
    human replaces it — updating the baseline is a reviewed act, not a way
    to silence the gate."""
    path = path or baseline_path()
    data = {
        "comment": "Documented false positives only; every entry needs a "
                   "human-written reason (see docs/analysis.md).",
        "findings": [{**f.to_json(), "reason": ""} for f in findings],
    }
    for ent in data["findings"]:
        ent.pop("line", None)  # line-independent matching
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    return path
