"""Layer 1b: post-SPMD wire auditor — the strategy x codec matrix.

PR 2 checked ONE cell (fedgan + bf16) with a one-off HLO byte assertion.
This module generalizes it: every registered strategy x {none, int8,
int4} (+ the fedgan bf16 dtype-cast cell) is built with
``launch.steps.build_train_round`` on the 8-device test mesh, compiled,
and its post-SPMD collectives audited via
``launch.hlo_analysis.collective_records``:

* ``wire-dtype`` / widening — no agent-axis collective may carry an
  operand wider than f32 (4 B): an f64 leak on the wire path doubles the
  §3.2 budget silently.
* codec cells — codecs decode locally per agent, so the cross-agent
  reduce still moves decoded f32: no once-per-round agent-axis operand
  may be NARROWER than 4 B (an s8/u8/s4/u4/bf16 operand means the encode
  escaped onto the wire), while the *billed* ``strategy.bytes_per_round``
  must be strictly LESS than the none cell's (equality means the codec
  is silently ignored).  Raw byte totals are reported per cell but not
  gated — XLA fuses the gather/reduce differently per cell (the median
  sort path pads differently under codec decode ops), so byte equality
  with the none cell is compiler noise, not a semantic invariant.
* bf16 cell — the dominant once-per-round agent-axis collective must
  actually carry bf16 operands (the declared cast reached the wire).
* fused vs composed — ``coded_sync`` auto-fuses codec cells through the
  bucketed qsync path (``fused_sync=None``), so the plain int8/int4 cells
  now audit the FUSED pipeline; fedgan additionally gets explicit
  ``int8_composed``/``int4_composed`` cells (``fused_sync=False``) so the
  per-leaf composed pipeline stays audited too.  Both variants face the
  same checks: no codec-introduced narrow dtypes on the agent axis, and
  billed bytes strictly < the none cell (the fusion changes dispatch
  structure, never the §3.2 bill).

Cells that the design space REFUSES (``TypeError`` at construction,
``ValueError`` from ``validate``) are recorded as ``refused`` and count
as passing — the refusal matrix rule checks those separately.

Needs >= 8 XLA devices: the CLI sets
``--xla_force_host_platform_device_count=8`` before importing jax; tests
run this in a subprocess.
"""
from __future__ import annotations

import dataclasses
import inspect
import os

from repro.analysis.findings import Finding, filter_suppressed
from repro.analysis.lint import repo_root_from_package

WIRE_RULE = "wire-dtype"

CODEC_CELLS = ("none", "int8", "int4")
MESH_SHAPE = (4, 2)           # ("data", "model") -> 4 agents, TP-2
_F32_BYTES = 4


@dataclasses.dataclass
class WireCell:
    strategy: str             # registry name (canonical, first alias)
    cls_name: str
    codec: str                # none | int8 | int4 | bf16
    status: str               # ok | refused
    reason: str = ""
    agent_bytes_once: int = 0  # non-loop agent-axis collective bytes
    billed: int = 0           # strategy.bytes_per_round
    agent_records: tuple = ()


def _tiny_cfg():
    import jax.numpy as jnp

    from repro.models.config import ArchConfig, ShapeConfig
    cfg = ArchConfig(name="t", family="dense", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
                     dtype=jnp.float32, remat=False,
                     disc_layers=1, disc_d_model=32, disc_heads=2)
    shape = ShapeConfig("t", 16, 8, "train")   # seq 16, global batch 8
    return cfg, shape


def _canonical_strategies():
    """[(canonical registry name, cls)] — classes deduped (ps_fedgan and
    partial_sharing share PartialSharing), first alias wins."""
    from repro.core.strategies import STRATEGIES
    out, seen = [], set()
    for name, cls in STRATEGIES.items():
        if cls not in seen:
            seen.add(cls)
            out.append((name, cls))
    return out


def _make_strategy(cls, codec: str):
    """May raise TypeError (field absent) / ValueError — a refused cell.
    A ``_composed`` suffix (``int8_composed``) pins ``fused_sync=False``
    so the per-leaf composed pipeline is compiled instead of the bucketed
    fused default."""
    import jax.numpy as jnp

    from repro.comm.codecs import CODECS
    kwargs = {}
    if cls.__name__ == "Hierarchical":
        kwargs["intra_interval"] = 1
    if codec == "bf16":
        kwargs["sync_dtype"] = jnp.bfloat16
    elif codec != "none":
        base, _, variant = codec.partition("_")
        kwargs["codec"] = CODECS[base]()
        if variant == "composed":
            kwargs["fused_sync"] = False
    return cls(**kwargs)


def _is_agent_sig(sig: str, agent_size: int) -> bool:
    """Transposed (non-minor-most) replica groups spanning the full agent
    (pod*data) extent — the cross-agent wire."""
    return sig.endswith(("T", "E")) and (sig.rstrip("TE") or "0").isdigit() \
        and int(sig.rstrip("TE")) == agent_size


def _dtype_bytes(dt: str) -> int:
    from repro.launch.hlo_analysis import _DTYPE_BYTES, _SUB_BYTE_ELEMS
    if dt in _SUB_BYTE_ELEMS:
        return 1   # sub-byte: never "wider than f32"
    return _DTYPE_BYTES.get(dt, 4)


def _class_anchor(cls, root: str):
    try:
        path = inspect.getsourcefile(cls)
        line = inspect.getsourcelines(cls)[1]
        rel = os.path.relpath(os.path.abspath(path), root)
        if not rel.startswith(".."):
            return rel.replace(os.sep, "/"), line
    except (OSError, TypeError, ValueError):
        pass
    return "src/repro/core/strategies.py", 1


def _record_anchor(rec, cls, root: str):
    if rec is not None and rec.source_file:
        rel = os.path.relpath(os.path.abspath(rec.source_file), root)
        if not rel.startswith(".."):
            return rel.replace(os.sep, "/"), rec.source_line
    return _class_anchor(cls, root)


def _build_cell(name: str, cls, codec: str, mesh, cfg, shape, K: int):
    """Build + compile one cell; returns a WireCell."""
    import jax

    from repro.launch.hlo_analysis import collective_records
    from repro.launch.mesh import mesh_dims
    from repro.launch.steps import build_train_round

    cell = WireCell(strategy=name, cls_name=cls.__name__, codec=codec,
                    status="ok")
    try:
        strategy = _make_strategy(cls, codec)
        built = build_train_round(cfg, shape, mesh, K=K, strategy=strategy)
        with jax.set_mesh(mesh):
            jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                             out_shardings=built.out_shardings)
            compiled = jitted.lower(*built.input_sds).compile()
        recs = collective_records(compiled.as_text())
    except (TypeError, ValueError) as e:
        cell.status = "refused"
        cell.reason = f"{type(e).__name__}: {e}"
        return cell

    dims = mesh_dims(mesh)
    agent_size = dims.get("pod", 1) * dims["data"]
    agent = tuple(r for r in recs
                  if _is_agent_sig(r.group_signature, agent_size))
    cell.agent_records = agent
    cell.agent_bytes_once = sum(r.bytes for r in agent if not r.in_loop)

    fed_cfg = _fed_cfg_for(mesh, K, strategy)
    params = built.input_sds[0]["params"]
    cell.billed = int(strategy.bytes_per_round(fed_cfg, params))
    return cell


def _fed_cfg_for(mesh, K: int, strategy):
    from repro.core.fedgan import FedGANConfig
    from repro.launch.mesh import mesh_dims
    dims = mesh_dims(mesh)
    return FedGANConfig(agent_grid=(dims.get("pod", 1), dims["data"]),
                        sync_interval=K, strategy=strategy)


def run_wire_matrix(root: str | None = None, *, names=None, codecs=None,
                    K: int = 2):
    """Returns ``(cells, findings)``; findings are suppression-filtered.
    ``names``/``codecs`` restrict the matrix (test sharding)."""
    import repro.dist  # noqa: F401  (installs the jax.set_mesh shim)
    from repro.launch.mesh import make_test_mesh

    root = root or repo_root_from_package()
    mesh = make_test_mesh(MESH_SHAPE, ("data", "model"))
    cfg, shape = _tiny_cfg()
    codec_cells = tuple(codecs) if codecs else CODEC_CELLS

    cells: list = []
    findings: list = []
    for name, cls in _canonical_strategies():
        if names and name not in names:
            continue
        per_codec = {}
        for codec in codec_cells:
            cell = _build_cell(name, cls, codec, mesh, cfg, shape, K)
            per_codec[codec] = cell
            cells.append(cell)
        if name == "fedgan":
            for extra in ("bf16", "int8_composed", "int4_composed"):
                if codecs and extra not in codecs:
                    continue
                cell = _build_cell(name, cls, extra, mesh, cfg, shape, K)
                per_codec[extra] = cell
                cells.append(cell)
        findings.extend(_cell_findings(per_codec, cls, root))

    findings.sort(key=lambda f: (f.file, f.line, f.message))
    return cells, filter_suppressed(findings, root)


def _cell_findings(per_codec: dict, cls, root: str) -> list:
    findings = []
    none_cell = per_codec.get("none")

    for codec, cell in per_codec.items():
        if cell.status != "ok":
            continue
        # (1) widening: no agent-axis operand wider than f32
        for rec in cell.agent_records:
            wide = [dt for dt in rec.operand_dtypes
                    if _dtype_bytes(dt) > _F32_BYTES]
            if wide:
                f, l = _record_anchor(rec, cls, root)
                findings.append(Finding(
                    rule=WIRE_RULE, file=f, line=l,
                    message=f"[{cell.strategy}+{codec}] agent-axis "
                            f"{rec.op} carries {'/'.join(wide)} operands — "
                            "wider than the declared f32 wire (silent "
                            "widening doubles the §3.2 bytes)"))

        if codec.startswith(("int8", "int4")) and none_cell is not None \
                and none_cell.status == "ok":
            # (2) codecs decode locally: the quantized image must never
            # cross the agent axis — a narrow operand the none cell does
            # not also carry means the encode escaped onto the wire
            # before the decode (pre-existing narrow traffic, e.g. a
            # pred subsampling mask, is the strategy's own wire)
            allowed = {dt for r in none_cell.agent_records
                       for dt in r.operand_dtypes}
            for rec in cell.agent_records:
                if rec.in_loop:
                    continue
                narrow = [dt for dt in rec.operand_dtypes
                          if _dtype_bytes(dt) < _F32_BYTES
                          and dt not in allowed]
                if narrow:
                    f, l = _record_anchor(rec, cls, root)
                    findings.append(Finding(
                        rule=WIRE_RULE, file=f, line=l,
                        message=f"[{cell.strategy}+{codec}] agent-axis "
                                f"{rec.op} carries {'/'.join(narrow)} "
                                "operands — the codec's encoded image "
                                "crossed the agent axis (codecs must "
                                "encode/decode locally; the wire moves "
                                "decoded f32)"))
            # (3) billed budget must actually shrink
            if none_cell.billed and cell.billed >= none_cell.billed:
                f, l = _class_anchor(cls, root)
                findings.append(Finding(
                    rule=WIRE_RULE, file=f, line=l,
                    message=f"[{cell.strategy}+{codec}] billed "
                            f"bytes_per_round {cell.billed} is not < the "
                            f"none cell's {none_cell.billed} — the codec "
                            "is being silently ignored in the §3.2 budget"))

        if codec == "bf16":
            once = [r for r in cell.agent_records if not r.in_loop]
            if not once:
                f, l = _class_anchor(cls, root)
                findings.append(Finding(
                    rule=WIRE_RULE, file=f, line=l,
                    message=f"[{cell.strategy}+bf16] no once-per-round "
                            "agent-axis collective found — the sync "
                            "vanished from the compiled round"))
            else:
                biggest = max(once, key=lambda r: r.bytes)
                if any(dt != "bf16" for dt in biggest.operand_dtypes):
                    f, l = _record_anchor(biggest, cls, root)
                    findings.append(Finding(
                        rule=WIRE_RULE, file=f, line=l,
                        message=f"[{cell.strategy}+bf16] dominant "
                                f"once-per-round agent-axis {biggest.op} "
                                "carries "
                                f"{'/'.join(biggest.operand_dtypes)} "
                                "operands, not bf16 — the declared "
                                "sync_dtype cast never reached the wire"))
    return findings
