"""Layer 2: repo-specific AST/doc lint.

The engine is deliberately tiny: a rule is a function
``rule(ctx: LintContext) -> list[Finding]`` registered in
``repro.analysis.rules.LINT_RULES``.  All paths come from the
``LintContext`` so the planted-violation fixtures under
``tests/fixtures/analysis/`` can point the same rules at mini-trees.
"""
from __future__ import annotations

import dataclasses
import os

from repro.analysis.findings import Finding, filter_suppressed

# Modules inside the hot packages that are *documented* host-side code
# (diagnostics and accounting that run between rounds, never under jit);
# see docs/analysis.md for the rationale of each entry.
HOST_SIDE_MODULES = (
    "core/convergence.py",    # Lemma-1/2 diagnostics: host loop over agents
    "run/evals.py",           # eval harness: deliberate device->host fetch
    "run/simclock.py",        # virtual-clock simulator: pure host event math
    "run/async_agg.py",       # async server loop: host event loop between jits
    "privacy/accountant.py",  # closed-form RDP accountant: pure host math
)


@dataclasses.dataclass(frozen=True)
class LintContext:
    """Where the rules look.  ``root`` anchors the repo-relative paths in
    findings; ``src`` is the ``repro`` package directory itself."""

    root: str                 # repo root (for relative paths + suppressions)
    src: str                  # .../src/repro
    docs: str                 # .../docs
    tests: str                # .../tests
    hot_packages: tuple = ("core", "run", "dist", "comm", "privacy")
    host_side_modules: tuple = HOST_SIDE_MODULES

    @classmethod
    def for_repo(cls, root: str) -> "LintContext":
        return cls(root=root,
                   src=os.path.join(root, "src", "repro"),
                   docs=os.path.join(root, "docs"),
                   tests=os.path.join(root, "tests"))

    def rel(self, path: str) -> str:
        return os.path.relpath(path, self.root).replace(os.sep, "/")

    def finding(self, rule: str, path: str, line: int, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule=rule, file=self.rel(path), line=line,
                       message=message, severity=severity)


def repo_root_from_package() -> str:
    """<root>/src/repro/analysis/lint.py -> <root>."""
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def run_lint(ctx: LintContext | None = None, rules=None) -> list:
    """Run the lint rules (all by default); suppression comments applied."""
    from repro.analysis.rules import LINT_RULES
    ctx = ctx or LintContext.for_repo(repo_root_from_package())
    findings = []
    for name, rule in LINT_RULES.items():
        if rules is not None and name not in rules:
            continue
        findings.extend(rule(ctx))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return filter_suppressed(findings, ctx.root)
