"""Lint-rule registry.  A rule is ``fn(ctx: LintContext) -> list[Finding]``;
its dict key is the rule id used in findings, suppression comments, and
``--rules`` selection."""
from repro.analysis.rules.consistency import check_catalogue_drift, check_refusal_matrix
from repro.analysis.rules.hostsync import check_host_sync
from repro.analysis.rules.kernels import check_kernel_ref_pairs

LINT_RULES = {
    "host-sync": check_host_sync,
    "kernel-ref-pair": check_kernel_ref_pairs,
    "refusal-matrix": check_refusal_matrix,
    "catalogue-drift": check_catalogue_drift,
}
