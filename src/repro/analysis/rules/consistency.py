"""Cross-artifact consistency: refusal-matrix and registry<->docs drift.

The strategy x codec x privacy design space refuses incoherent
combinations with loud ``ValueError``s (docs/privacy.md's refusal
matrix).  Both artifacts — the docs tables and the ``validate()`` guards
— are hand-maintained, so these rules check them against each other in
both directions:

* ``refusal-matrix``: every mutually-exclusive knob *pair* named in a
  docs table row (a first cell containing " + ") must have a matching
  ``raise ValueError`` guard in strategies.py/collectives.py, and every
  guarded pair in the code must have a docs row.
* ``catalogue-drift``: every class registered in ``STRATEGIES`` has a row
  in the strategy catalogue table (and vice versa — no rows for ghost
  strategies); same for ``CODECS`` and the codec catalogue.

Everything is AST/text level — the rules never import the modules they
check, so the planted-violation fixtures can feed them mini-trees.
"""
from __future__ import annotations

import ast
import os
import re

REFUSAL_RULE = "refusal-matrix"
CATALOGUE_RULE = "catalogue-drift"

# canonical knob tokens; pairs of these are the refusal-matrix vocabulary
_CODE_IDENT_TOKENS = {
    "codec": "codec",
    "sync_dtype": "sync_dtype",
    "secure_agg": "secure_agg",
    "reduce": "robust",
}
_CONTEXT_TOKENS = {
    "SubsampledFedAvg": "subsampled",
    "TrimmedMeanSync": "robust",
    "CoordinateMedianSync": "robust",
    "masked_sync": "secure_agg",
}
_TEXT_TOKENS = (
    ("sync_dtype", "sync_dtype"),
    ("codec", "codec"),
    ("secure", "secure_agg"),
    ("subsampl", "subsampled"),
    ("robust", "robust"),
    # matches "async" and "asynchronous" — the async-buffer refusal rows
    # (docs/scaling.md) vs the check_async_mergeable guards
    ("async", "async"),
)


def _text_tokens(text: str) -> set:
    low = text.lower()
    return {tok for sub, tok in _TEXT_TOKENS if sub in low}


# ---------------------------------------------------------------------------
# Markdown table parsing (shared)
# ---------------------------------------------------------------------------


def _cells(line: str) -> list:
    return [c.strip() for c in line.strip().strip("|").split("|")]


def _tables(lines):
    """Yield (header_lineno_1based, header_cells, rows) where rows is a
    list of (lineno_1based, cells) for each body row."""
    i = 0
    while i < len(lines):
        if lines[i].lstrip().startswith("|"):
            start = i
            while i < len(lines) and lines[i].lstrip().startswith("|"):
                i += 1
            block = lines[start:i]
            if len(block) >= 2 and set(block[1].replace("|", "").strip()) <= set("- :"):
                rows = [(start + 1 + j, _cells(block[j]))
                        for j in range(2, len(block))]
                yield start + 1, _cells(block[0]), rows
        else:
            i += 1


def _doc_files(ctx) -> list:
    if not os.path.isdir(ctx.docs):
        return []
    return sorted(os.path.join(ctx.docs, n) for n in os.listdir(ctx.docs)
                  if n.endswith(".md"))


def _read_lines(path: str) -> list:
    try:
        with open(path, encoding="utf-8") as f:
            return f.read().splitlines()
    except OSError:
        return []


# ---------------------------------------------------------------------------
# refusal-matrix
# ---------------------------------------------------------------------------

_REFUSAL_CODE_FILES = ("core/strategies.py", "dist/collectives.py")


def _doc_refusal_pairs(ctx):
    """{frozenset(pair) -> (file, line)} from docs table rows whose first
    cell names a combination ('a + b')."""
    pairs = {}
    for path in _doc_files(ctx):
        for _, _, rows in _tables(_read_lines(path)):
            for lineno, cells in rows:
                if not cells or " + " not in cells[0]:
                    continue
                toks = _text_tokens(cells[0])
                if len(toks) >= 2:
                    pairs.setdefault(frozenset(toks), (path, lineno))
    return pairs


def _resolve_raise_text(node: ast.Raise, const_strs: dict) -> str:
    """All string content reachable from the raised exception's args."""
    parts = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            parts.append(sub.value)
        elif isinstance(sub, ast.Name) and sub.id in const_strs:
            parts.append(const_strs[sub.id])
    return " ".join(parts)


def _code_refusal_pairs(ctx):
    """{frozenset(pair) -> (file, line)} from ``raise ValueError`` guards.

    Tokens for one raise come from (a) identifiers in every enclosing
    ``if`` test, (b) the enclosing function/class names, (c) the message
    text (module string constants resolved)."""
    pairs = {}
    for rel in _REFUSAL_CODE_FILES:
        path = os.path.join(ctx.src, rel)
        try:
            with open(path, encoding="utf-8") as f:
                tree = ast.parse(f.read(), filename=path)
        except (OSError, SyntaxError):
            continue
        const_strs = {t.targets[0].id: t.value.value
                      for t in tree.body
                      if isinstance(t, ast.Assign) and len(t.targets) == 1
                      and isinstance(t.targets[0], ast.Name)
                      and isinstance(t.value, ast.Constant)
                      and isinstance(t.value.value, str)}
        parents: dict = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                parents[child] = parent
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Raise) and node.exc is not None):
                continue
            exc = node.exc
            name = (exc.func.id if isinstance(exc, ast.Call)
                    and isinstance(exc.func, ast.Name) else "")
            if name != "ValueError":
                continue
            toks = _text_tokens(_resolve_raise_text(node, const_strs))
            anc = node
            while anc in parents:
                anc = parents[anc]
                if isinstance(anc, ast.If):
                    for sub in ast.walk(anc.test):
                        if isinstance(sub, ast.Name):
                            toks |= ({_CODE_IDENT_TOKENS[sub.id]}
                                     if sub.id in _CODE_IDENT_TOKENS else set())
                        elif isinstance(sub, ast.Attribute):
                            toks |= ({_CODE_IDENT_TOKENS[sub.attr]}
                                     if sub.attr in _CODE_IDENT_TOKENS else set())
                elif isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    if anc.name in _CONTEXT_TOKENS:
                        toks.add(_CONTEXT_TOKENS[anc.name])
            if len(toks) >= 2:
                for pair in _all_pairs(toks):
                    pairs.setdefault(pair, (path, node.lineno))
    return pairs


def _all_pairs(tokens: set):
    toks = sorted(tokens)
    return [frozenset((a, b)) for i, a in enumerate(toks) for b in toks[i + 1:]]


def _pair_name(pair: frozenset) -> str:
    return " + ".join(sorted(pair))


def check_refusal_matrix(ctx) -> list:
    doc_pairs = _doc_refusal_pairs(ctx)
    code_pairs = _code_refusal_pairs(ctx)
    findings = []
    for pair, (path, lineno) in sorted(doc_pairs.items(),
                                       key=lambda kv: _pair_name(kv[0])):
        if pair not in code_pairs:
            findings.append(ctx.finding(
                REFUSAL_RULE, path, lineno,
                f"docs declare the refusal '{_pair_name(pair)}' but no "
                "matching ValueError guard exists in "
                "strategies.py/collectives.py — the incoherent combination "
                "would be accepted silently"))
    for pair, (path, lineno) in sorted(code_pairs.items(),
                                       key=lambda kv: _pair_name(kv[0])):
        if pair not in doc_pairs:
            findings.append(ctx.finding(
                REFUSAL_RULE, path, lineno,
                f"code refuses the combination '{_pair_name(pair)}' but no "
                "docs refusal-matrix row documents it — add the row (see "
                "docs/privacy.md)"))
    return findings


# ---------------------------------------------------------------------------
# catalogue-drift
# ---------------------------------------------------------------------------

_BACKTICK_CALL_RE = re.compile(r"`([A-Za-z_][A-Za-z0-9_]*)\(")
_BACKTICK_NAME_RE = re.compile(r"`([a-z0-9_+]+)`")


def _registry_literal(path: str, dict_name: str):
    """Parse ``NAME = {"key": Value, ...}`` -> {key: value-class-name-or-None}
    without importing the module (fixture-friendly, import-cycle-free)."""
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError):
        return None
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == dict_name
                and isinstance(node.value, ast.Dict)):
            out = {}
            for k, v in zip(node.value.keys, node.value.values):
                if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
                    continue
                cls = None
                if isinstance(v, ast.Name):
                    cls = v.id
                elif isinstance(v, ast.Lambda):
                    for sub in ast.walk(v.body):
                        if isinstance(sub, ast.Name):
                            cls = sub.id
                            break
                out[k.value] = cls
            return out
    return None


def _catalogue_tables(ctx, kind: str):
    """All docs tables whose header first cell is ``kind``."""
    out = []
    for path in _doc_files(ctx):
        for header_line, header, rows in _tables(_read_lines(path)):
            if header and header[0].strip("`* ").lower() == kind:
                out.append((path, header_line, rows))
    return out


def check_catalogue_drift(ctx) -> list:
    findings = []
    findings += _check_strategy_catalogue(ctx)
    findings += _check_codec_catalogue(ctx)
    return findings


def _check_strategy_catalogue(ctx) -> list:
    reg_path = os.path.join(ctx.src, "core", "strategies.py")
    registry = _registry_literal(reg_path, "STRATEGIES")
    if registry is None:
        return []
    reg_classes = {c for c in registry.values() if c}
    tables = _catalogue_tables(ctx, "strategy")
    anchor = (os.path.join(ctx.docs, "strategies.md"), 0)
    findings = []
    doc_classes = set()
    for path, header_line, rows in tables:
        anchor = (path, header_line)
        for lineno, cells in rows:
            if not cells:
                continue
            for cls in _BACKTICK_CALL_RE.findall(cells[0]):
                doc_classes.add(cls)
                if cls not in reg_classes:
                    findings.append(ctx.finding(
                        CATALOGUE_RULE, path, lineno,
                        f"catalogue row for `{cls}(...)` has no matching "
                        "entry in strategies.STRATEGIES — stale row (or an "
                        "unregistered strategy)"))
    for cls in sorted(reg_classes - doc_classes):
        names = sorted(n for n, c in registry.items() if c == cls)
        findings.append(ctx.finding(
            CATALOGUE_RULE, anchor[0], anchor[1],
            f"registered strategy `{cls}` ({'/'.join(names)}) has no row "
            "in the docs strategy catalogue table"))
    return findings


def _check_codec_catalogue(ctx) -> list:
    reg_path = os.path.join(ctx.src, "comm", "codecs.py")
    registry = _registry_literal(reg_path, "CODECS")
    if registry is None:
        return []
    tables = _catalogue_tables(ctx, "codec")
    anchor = (os.path.join(ctx.docs, "communication.md"), 0)
    findings = []
    doc_names = set()
    for path, header_line, rows in tables:
        anchor = (path, header_line)
        for lineno, cells in rows:
            if not cells:
                continue
            m = _BACKTICK_NAME_RE.search(cells[0])
            if not m:
                continue
            name = m.group(1)
            doc_names.add(name)
            if name not in registry:
                findings.append(ctx.finding(
                    CATALOGUE_RULE, path, lineno,
                    f"codec catalogue row for `{name}` has no matching "
                    "entry in codecs.CODECS — stale row"))
    for name in sorted(set(registry) - doc_names):
        findings.append(ctx.finding(
            CATALOGUE_RULE, anchor[0], anchor[1],
            f"registered codec `{name}` has no row in the docs codec "
            "catalogue table"))
    return findings
