"""host-sync: no device->host synchronization in hot-path modules.

``float(traced)``, ``.item()``, ``np.asarray(traced)`` and
``jax.device_get`` all block until the device catches up.  Inside the
round path they either crash the trace (under jit) or — worse — silently
serialize the async dispatch pipeline when called on the results between
dispatches (the PR 4 incident: an eager per-round metric fetch hid the
entire round latency win).  ``jnp.asarray`` is fine (stays on device);
``float(<literal>)`` is fine (pure host constant).

Documented host-side modules (``LintContext.host_side_modules``) are
skipped wholesale; deliberate sites in otherwise-hot modules carry an
``# analysis: allow(host-sync)`` comment.
"""
from __future__ import annotations

import ast
import os

RULE = "host-sync"

_NP_ALIASES = ("np", "numpy", "onp")


def _is_constant(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.USub, ast.UAdd)):
        return _is_constant(node.operand)
    return False


def _flag_call(node: ast.Call) -> str | None:
    fn = node.func
    if isinstance(fn, ast.Name) and fn.id == "float":
        if node.args and not _is_constant(node.args[0]):
            return ("float() forces a device->host sync on a traced/device "
                    "value; keep it as a jnp scalar (or move this to a "
                    "documented host-side module)")
        return None
    if isinstance(fn, ast.Attribute):
        if fn.attr == "item" and not node.args and not node.keywords:
            return (".item() forces a device->host sync; keep the value on "
                    "device or fetch it once at the end of the run")
        base = fn.value
        if (fn.attr == "asarray" and isinstance(base, ast.Name)
                and base.id in _NP_ALIASES):
            return ("np.asarray on a device value copies it to host; use "
                    "jnp.asarray (stays on device) or move this off the "
                    "hot path")
        if (fn.attr == "device_get" and isinstance(base, ast.Name)
                and base.id == "jax"):
            return ("jax.device_get blocks on the device; batch the fetch "
                    "at the end of the run instead of per round/step")
    return None


def check_host_sync(ctx) -> list:
    findings = []
    for pkg in ctx.hot_packages:
        pkg_dir = os.path.join(ctx.src, pkg)
        if not os.path.isdir(pkg_dir):
            continue
        for dirpath, _, names in sorted(os.walk(pkg_dir)):
            for name in sorted(names):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                mod = os.path.relpath(path, ctx.src).replace(os.sep, "/")
                if mod in ctx.host_side_modules:
                    continue
                findings.extend(_scan_file(ctx, path))
    return findings


def _scan_file(ctx, path: str) -> list:
    try:
        with open(path, encoding="utf-8") as f:
            tree = ast.parse(f.read(), filename=path)
    except (OSError, SyntaxError):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            msg = _flag_call(node)
            if msg:
                out.append(ctx.finding(RULE, path, node.lineno, msg))
    return out
