"""kernel-ref-pair: every Pallas kernel ships its oracle and a parity test.

``kernels/<name>/kernel.py`` without a sibling ``ref.py`` has no
bit-parity ground truth; a pair without a test referencing both is an
oracle nobody consults.  The reference pattern in this repo:
``tests/test_kernels.py`` / ``tests/test_comm.py`` import
``repro.kernels.<name>.{ops,ref}`` and assert bit-identity.
"""
from __future__ import annotations

import glob
import os
import re

RULE = "kernel-ref-pair"


def _test_texts(tests_dir: str) -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(tests_dir, "**", "*.py"),
                                 recursive=True)):
        try:
            with open(path, encoding="utf-8") as f:
                out.append(f.read())
        except OSError:
            pass
    return out


def check_kernel_ref_pairs(ctx) -> list:
    kernels_dir = os.path.join(ctx.src, "kernels")
    if not os.path.isdir(kernels_dir):
        return []
    texts = _test_texts(ctx.tests)
    findings = []
    for kpath in sorted(glob.glob(os.path.join(kernels_dir, "*", "kernel.py"))):
        kdir = os.path.dirname(kpath)
        kname = os.path.basename(kdir)
        if not os.path.exists(os.path.join(kdir, "ref.py")):
            findings.append(ctx.finding(
                RULE, kpath, 1,
                f"kernels/{kname}/kernel.py has no sibling ref.py — every "
                "kernel needs a pure-jnp oracle for bit-parity testing"))
            continue
        mod_re = re.compile(rf"kernels\.{re.escape(kname)}\b")
        ref_re = re.compile(r"\bref\b")
        if not any(mod_re.search(t) and ref_re.search(t) for t in texts):
            findings.append(ctx.finding(
                RULE, kpath, 1,
                f"no test references both kernels.{kname} and its ref "
                "oracle — add a bit-parity test (see tests/test_kernels.py)"))
    return findings
