"""Layer 1: jaxpr-level trace auditor over the *built* round functions.

A :class:`TracedFn` names one jit target (fn + example args + its
donation contract).  ``audit_traced`` traces it with ``jax.make_jaxpr``
and walks every sub-jaxpr:

* ``host-callback-in-scan`` — callback primitives (``io_callback``,
  ``pure_callback``, ``debug_callback``/``jax.debug.print``) inside a
  ``scan``/``while`` body: each trip blocks the K-step round on the host,
  serializing exactly the dispatch pipeline the K-scan exists to keep full.
* ``raw-fold-in`` — ``jax.random.key``/``PRNGKey`` *creation*
  (``random_seed``) inside a loop body: the legacy raw-uint32 shim pattern
  (``fold_in(key(0), seed)`` per step) has birthday-collision risk across
  the fleet; keys must be split outside and threaded through the carry.
* ``pad-reuse`` — two ``fold_in`` calls on the same key with the same
  literal salt in one jaxpr: in ``masked_sync`` that is one-time-pad reuse
  (two payloads XORed with the same pad reveal their difference).
* ``donation-miss`` — declared round-state args not covered by
  ``donate_argnums``: the round then keeps two copies of the state live
  (checked at the metadata level because CPU jit ignores donation, so
  alias bytes cannot be measured here).

Findings anchor at the traceback the primitive was bound from
(``eqn.source_info``), so ``# analysis: allow(rule)`` comments work.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

from repro.analysis.findings import Finding, filter_suppressed
from repro.analysis.lint import repo_root_from_package

LOOP_PRIMS = ("scan", "while")
CALLBACK_PRIMS = ("io_callback", "pure_callback", "debug_callback")

HOST_CALLBACK_RULE = "host-callback-in-scan"
RAW_FOLD_IN_RULE = "raw-fold-in"
PAD_REUSE_RULE = "pad-reuse"
DONATION_RULE = "donation-miss"


@dataclasses.dataclass
class TracedFn:
    """One audit target: a jit-able fn, example (abstract ok) args, and the
    donation contract of its production jit site."""

    name: str
    fn: Callable
    args: tuple
    donate_argnums: tuple = ()
    state_argnums: tuple = ()     # args that are round state (donation candidates)
    origin: tuple = ("", 0)       # (file, line) anchoring metadata-level findings

    def resolved_origin(self, root: str) -> tuple:
        if self.origin[0]:
            return self.origin
        code = getattr(self.fn, "__code__", None) or getattr(
            getattr(self.fn, "__func__", None), "__code__", None)
        if code is not None:
            return _relpath_in(code.co_filename, root), code.co_firstlineno
        return "", 0


def _relpath_in(path: str, root: str) -> str:
    try:
        rel = os.path.relpath(os.path.abspath(path), root)
    except ValueError:
        return ""
    return "" if rel.startswith("..") else rel.replace(os.sep, "/")


def _src_of(eqn, root: str) -> tuple:
    """(repo-relative file, line) of the user frame that bound this eqn."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        frame = None
    if frame is None:
        return "", 0
    return _relpath_in(frame.file_name, root), frame.start_line


def _subjaxprs(params: dict):
    """Every sub-jaxpr hiding in an eqn's params (scan/while/cond/pjit/...)."""
    for v in params.values():
        for item in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(item, "jaxpr") and hasattr(item.jaxpr, "eqns"):
                yield item.jaxpr                  # ClosedJaxpr
            elif hasattr(item, "eqns"):
                yield item                        # raw Jaxpr


def _tree_bytes(tree) -> int:
    import jax
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        try:
            n = 1
            for d in leaf.shape:
                n *= d
            total += n * leaf.dtype.itemsize
        except (AttributeError, TypeError):
            pass
    return total


def audit_traced(target: TracedFn, root: str | None = None) -> list:
    """Trace ``target`` and return raw findings (suppressions NOT applied —
    callers go through :func:`run_trace`)."""
    import jax

    root = root or repo_root_from_package()
    findings: list = []

    # --- metadata-level: donation contract -----------------------------
    missing = [i for i in target.state_argnums
               if i not in tuple(target.donate_argnums)]
    if missing:
        ofile, oline = target.resolved_origin(root)
        for i in missing:
            size = _tree_bytes(target.args[i]) if i < len(target.args) else 0
            findings.append(Finding(
                rule=DONATION_RULE, file=ofile, line=oline,
                message=f"[{target.name}] round-state arg {i} "
                        f"({size} bytes here, O(model) at scale) is not in "
                        f"donate_argnums={tuple(target.donate_argnums)} — the "
                        "jitted round keeps two copies of the state live"))

    # --- jaxpr-level rules ---------------------------------------------
    jaxpr = jax.make_jaxpr(target.fn)(*target.args)

    def walk(jx, in_loop: bool):
        fold_ins: dict = {}
        for eqn in jx.eqns:
            prim = eqn.primitive.name
            if in_loop and (prim in CALLBACK_PRIMS or "callback" in prim):
                f, l = _src_of(eqn, root)
                findings.append(Finding(
                    rule=HOST_CALLBACK_RULE, file=f, line=l,
                    message=f"[{target.name}] host callback '{prim}' inside "
                            "the K-scan body — every trip blocks the round "
                            "on a host round-trip"))
            if in_loop and prim == "random_seed":
                f, l = _src_of(eqn, root)
                findings.append(Finding(
                    rule=RAW_FOLD_IN_RULE, file=f, line=l,
                    message=f"[{target.name}] PRNG key created from a raw "
                            "seed inside the loop body (the legacy uint32 "
                            "shim pattern) — split keys outside the scan and "
                            "thread them through the carry"))
            if prim == "random_fold_in" and len(eqn.invars) >= 2:
                key_var, salt = eqn.invars[0], eqn.invars[1]
                lit = getattr(salt, "val", None)   # Literal salt only
                scalar = lit is not None and getattr(lit, "ndim", 0) == 0
                if scalar:
                    sig = (id(key_var), repr(lit))
                    if sig in fold_ins:
                        f, l = _src_of(eqn, root)
                        findings.append(Finding(
                            rule=PAD_REUSE_RULE, file=f, line=l,
                            message=f"[{target.name}] fold_in on the same "
                                    f"key with the same literal salt "
                                    f"({lit!r}) twice in one computation — "
                                    "pad/key reuse (first use at "
                                    f"{fold_ins[sig][0]}:{fold_ins[sig][1]})"))
                    else:
                        fold_ins[sig] = _src_of(eqn, root)
            for sub in _subjaxprs(eqn.params):
                walk(sub, in_loop or prim in LOOP_PRIMS)

    walk(jaxpr.jaxpr, in_loop=False)
    return findings


def audit_built(built, *, donate_argnums: tuple = (), root: str | None = None,
                name: str | None = None) -> list:
    """Audit a ``repro.launch.steps.BuiltStep`` (the dryrun integration).
    Traces ``built.fn`` on its ShapeDtypeStruct inputs — mesh-free, so it
    runs on one device even for production-mesh builds."""
    kind = built.meta.get("kind", "step")
    target = TracedFn(
        name=name or f"built.{kind}",
        fn=built.fn, args=tuple(built.input_sds),
        donate_argnums=tuple(donate_argnums),
        state_argnums=(0,) if kind == "train" else ())
    return audit_traced(target, root)


# ---------------------------------------------------------------------------
# Default targets: the toy rounds the CLI audits on every run
# ---------------------------------------------------------------------------


def default_targets() -> list:
    """Three single-device round targets covering the stream path, the
    device-resident sampling path, and the secure-sum sync path."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FedGAN, FedGANConfig, make_gan_task
    from repro.core.strategies import FedAvgSync
    from repro.data import DeviceFederatedData
    from repro.models.gan_nets import Toy2DDiscriminator, Toy2DGenerator
    from repro.optim import Adam, constant, equal_timescale
    from repro.privacy import SecureAgg

    K, A, b = 4, 3, 8
    task = make_gan_task(Toy2DGenerator(theta0=0.5), Toy2DDiscriminator(psi0=0.5))

    def build(strategy=None):
        return FedGAN(task,
                      FedGANConfig(agent_grid=(1, A), sync_interval=K,
                                   strategy=strategy),
                      opt_g=Adam(), opt_d=Adam(),
                      scales=equal_timescale(constant(1e-3)))

    fed = build()
    state = jax.eval_shape(fed.init_state, jax.random.key(0))
    batches = {"x": jax.ShapeDtypeStruct((K, 1, A, b), jnp.float32),
               "z": jax.ShapeDtypeStruct((K, 1, A, b), jnp.float32)}
    keys = jax.random.split(jax.random.key(0), K * A).reshape(K, 1, A)

    # donation contract: repro.run.RoundDriver._jit donates argnums=0
    targets = [TracedFn("round.stream", fed.round, (state, batches, keys),
                        donate_argnums=(0,), state_argnums=(0,))]

    fed_secure = build(FedAvgSync(secure_agg=SecureAgg(seed=0)))
    targets.append(TracedFn("round.secure", fed_secure.round,
                            (state, batches, keys),
                            donate_argnums=(0,), state_argnums=(0,)))

    agent_data = [{"x": np.zeros((32,), np.float32)} for _ in range(A)]
    data = DeviceFederatedData.from_agent_data(
        agent_data, (1, A), b,
        sample_extra=lambda r, s: {"z": jax.random.uniform(r, s, minval=-1,
                                                           maxval=1)})
    targets.append(TracedFn(
        "round.device",
        lambda st, key: fed.round_from_data(st, data, key),
        (state, jax.random.key(1)),
        donate_argnums=(0,), state_argnums=(0,)))
    return targets


def run_trace(root: str | None = None, targets=None) -> list:
    """Audit the default (or given) targets; suppressions applied."""
    root = root or repo_root_from_package()
    targets = default_targets() if targets is None else targets
    findings: list = []
    for t in targets:
        findings.extend(audit_traced(t, root))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return filter_suppressed(findings, root)
