from repro.checkpoint.store import list_checkpoints, restore_checkpoint, save_checkpoint

__all__ = ["list_checkpoints", "restore_checkpoint", "save_checkpoint"]
