from repro.checkpoint.store import (list_checkpoints, read_latest_step,
                                    restore_checkpoint, save_checkpoint)

__all__ = ["list_checkpoints", "read_latest_step", "restore_checkpoint",
           "save_checkpoint"]
