"""Checkpointing: pytree <-> npz with a JSON manifest.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, plus <dir>/LATEST.
Works for FedGAN agent-stacked states (the (P, A) axis is just leading
dims) and plain model params.  Restore rebuilds the exact pytree structure
and dtypes.

Write ordering is the contract hot-reload (repro.serve.reload) depends on:
a step directory is fully written (arrays, then manifest) *before* LATEST
is pointed at it, and LATEST itself is updated atomically (temp file +
os.replace), so a concurrent reader either sees the previous complete
checkpoint or the new complete one — never a torn pointer or a
half-written step.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16 et al. with numpy
import numpy as np

_NATIVE_KINDS = set("biufc")  # bool/int/uint/float/complex natively savable


def _is_native(dtype: np.dtype) -> bool:
    return dtype.kind in _NATIVE_KINDS and dtype.name not in (
        "bfloat16", "float8_e4m3fn", "float8_e5m2")


def _flatten_with_paths(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _flatten_with_paths(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten_with_paths(v, f"{prefix}/{i}" if prefix else str(i))
    else:
        yield prefix, tree


def _tree_structure(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_structure(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple", "items": [_tree_structure(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list", "items": [_tree_structure(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(struct, leaves_by_path, prefix=""):
    kind = struct["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, leaves_by_path, f"{prefix}/{k}" if prefix else str(k))
                for k, v in struct["items"].items()}
    if kind in ("tuple", "list"):
        seq = [_rebuild(v, leaves_by_path, f"{prefix}/{i}" if prefix else str(i))
               for i, v in enumerate(struct["items"])]
        return tuple(seq) if kind == "tuple" else seq
    return leaves_by_path[prefix]


def save_checkpoint(directory: str, state: Any, *, step: int,
                    metadata: dict | None = None) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    arrays = {}
    dtypes = []
    for i, (p, leaf) in enumerate(_flatten_with_paths(state)):
        arr = np.asarray(leaf)
        if arr.ndim:  # ascontiguousarray would promote 0-d leaves to (1,)
            arr = np.ascontiguousarray(arr)
        dtypes.append(arr.dtype.name)
        if not _is_native(arr.dtype):
            # bfloat16 etc.: store the raw bytes, dtype recorded in manifest
            arr = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        arrays[f"a{i}"] = arr
    paths = [p for p, _ in _flatten_with_paths(state)]
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": dtypes,
        "structure": _tree_structure(state),
        "metadata": metadata or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    _write_latest(directory, os.path.basename(path))
    return path


def _write_latest(directory: str, name: str) -> None:
    """Atomic LATEST update: a plain ``open(..., "w")`` truncates first, so a
    concurrent reader could observe an empty or partial pointer.  Writing a
    temp file and ``os.replace``-ing it makes the swap a single atomic rename
    on POSIX filesystems."""
    tmp = os.path.join(directory, f".LATEST.tmp.{os.getpid()}")
    with open(tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(directory, "LATEST"))


def read_latest_step(directory: str) -> int | None:
    """Step number LATEST points at, or None when no checkpoint exists yet.

    This is the cheap poll hot-reload uses between serve ticks: one small
    file read, no array IO."""
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
    except FileNotFoundError:
        return None
    if not name.startswith("step_"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def restore_checkpoint(directory: str, *, step: int | None = None,
                       to_device: bool = True) -> tuple[Any, dict]:
    """Rebuild (state, manifest).  ``to_device=False`` keeps every leaf a
    host numpy array — the virtual-client runtime restores fleet state
    this way so a 1024-client checkpoint never round-trips through device
    memory that only holds the ``A_active`` slots."""
    if step is None:
        with open(os.path.join(directory, "LATEST")) as f:
            name = f.read().strip()
        path = os.path.join(directory, name)
    else:
        path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    dtypes = manifest.get("dtypes", [])
    leaves_by_path = {}
    for i, p in enumerate(manifest["paths"]):
        arr = data[f"a{i}"]
        name = dtypes[i] if i < len(dtypes) else arr.dtype.name
        if name != arr.dtype.name:  # stored as raw bytes
            dt = np.dtype(name)
            arr = arr.reshape(-1).view(dt).reshape(arr.shape[:-1])
        leaves_by_path[p] = jnp.asarray(arr) if to_device else np.asarray(arr)
    state = _rebuild(manifest["structure"], leaves_by_path)
    return state, manifest


def list_checkpoints(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)
