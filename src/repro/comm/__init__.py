# Compressed-sync wire codecs: block-scaled int8/int4 quantization,
# magnitude top-k sparsification, and chained combinations, with honest
# per-leaf wire accounting (payload + scales + indices).  Strategies carry
# the matching error-feedback residuals in the round state; see
# docs/communication.md.
from repro.comm.codecs import (
    CODECS,
    Codec,
    IntQuant,
    Sequential,
    TopK,
    codec_from_flags,
    get_codec,
)

__all__ = ["CODECS", "Codec", "IntQuant", "Sequential", "TopK",
           "codec_from_flags", "get_codec"]
