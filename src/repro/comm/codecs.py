"""Composable wire codecs for compressed sync — the HOW-bytes-are-encoded
axis of the strategy design space.

A :class:`Codec` maps a float leaf to a wire representation and back:

  ``encode(x, batch_ndims)``   -> (payload, meta) — payload is the array a
                                  further codec may re-encode (top-k values
                                  stay float; quantized codes are terminal),
                                  meta is the side information (scales,
                                  indices) that ships alongside
  ``decode(payload, meta, like, batch_ndims)``
                               -> reconstruction shaped like ``like``
  ``roundtrip(x, batch_ndims)``-> decode(encode(x)) — the lossy wire image,
                                  what the intermediary actually receives
  ``wire_bytes(like)``         -> honest per-leaf wire size: final payload
                                  PLUS every stage's meta (scales + indices
                                  billed, not just payload)

Leaves keep their leading ``batch_ndims`` dims (the (P, A) agent grid when
called from ``repro.dist.collectives``) as batch: blocks, scales and top-k
selections never span agents — an agent can only compress what it holds.

All encode/decode paths are jit-traceable; the quantizers' bit-packing runs
through the ``kernels/qpack`` Pallas kernels on TPU and their vectorized
ref oracle elsewhere (see ``kernels/qpack/ops.py``).

Error feedback lives one level up (``repro.core.strategies`` carries the
per-agent and server-side residuals in the round state); the codecs
themselves are stateless and deterministic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.qpack.ops import (dequantize_blocks, quantize_blocks,
                                     roundtrip_blocks)


def _like_n(like) -> int:
    return int(math.prod(like.shape)) if like.shape else 1


def _nbytes(like) -> int:
    return _like_n(like) * jnp.dtype(like.dtype).itemsize


class Codec:
    """Base protocol.  ``chainable`` marks codecs whose payload is still a
    float stream a further codec can re-encode (quantized codes are not)."""

    name = "identity"
    chainable = True

    def validate(self):
        pass

    def encode(self, x, batch_ndims: int = 0):
        raise NotImplementedError

    def decode(self, payload, meta, like, batch_ndims: int = 0):
        raise NotImplementedError

    def roundtrip(self, x, batch_ndims: int = 0):
        payload, meta = self.encode(x, batch_ndims)
        like = jax.ShapeDtypeStruct(x.shape[batch_ndims:], x.dtype)
        return self.decode(payload, meta, like, batch_ndims)

    def payload_like(self, like):
        """Per-leaf (no batch dims) shape/dtype of the encoded payload."""
        raise NotImplementedError

    def meta_wire_bytes(self, like) -> int:
        """Wire bytes of this stage's side information for one leaf."""
        raise NotImplementedError

    def wire_bytes(self, like) -> int:
        """Total per-leaf wire bytes: payload + all meta."""
        return self.meta_wire_bytes(like) + _nbytes(self.payload_like(like))

    def fused_sync_spec(self):
        """Kwargs for the one-pass fused sync (``kernels/qsync``) when this
        codec's roundtrip can run inside it, else None.  Only the plain
        block quantizers qualify today — chains and sparsifiers reshape the
        payload and fall back to the composed per-leaf pipeline."""
        return None


def _flat(x, batch_ndims):
    lead = x.shape[:batch_ndims]
    return x.reshape(lead + (-1,)), lead


@dataclasses.dataclass(frozen=True)
class IntQuant(Codec):
    """Block-scaled symmetric integer quantization (int8 or packed int4).

    Each ``block``-wide tile of the flattened leaf gets one f16 scale
    (max-abs / qmax); codes are round-to-nearest, clipped to ±qmax.  Wire =
    ``ceil(N·bits/8)`` payload bytes + 2 bytes per block for the scale —
    3.94x (int8) / 7.5x (int4) under f32 at the default block.  Lossy:
    combine with error feedback (the strategy default) for convergence.
    """

    bits: int = 8
    block: int = 128
    use_kernel: Any = None  # None -> Pallas kernel on TPU, ref elsewhere

    chainable = False

    @property
    def name(self):
        return f"int{self.bits}"

    def validate(self):
        if self.bits not in (4, 8):
            raise ValueError(f"IntQuant bits must be 4 or 8, got {self.bits}")
        if self.block < 2 or self.block % 2:
            raise ValueError(f"IntQuant block must be even and >= 2, "
                             f"got {self.block}")

    def encode(self, x, batch_ndims: int = 0):
        flat, _ = _flat(x, batch_ndims)
        payload, scales = quantize_blocks(flat, bits=self.bits,
                                          block=self.block,
                                          use_kernel=self.use_kernel)
        return payload, {"scale": scales}

    def decode(self, payload, meta, like, batch_ndims: int = 0):
        n = _like_n(like)
        out = dequantize_blocks(payload, meta["scale"], n=n, bits=self.bits,
                                block=self.block, use_kernel=self.use_kernel)
        lead = payload.shape[:batch_ndims]
        return out.reshape(lead + like.shape).astype(like.dtype)

    def roundtrip(self, x, batch_ndims: int = 0):
        # the wire image without the int4 nibble pack/unpack — pack4∘unpack4
        # is a bit-exact identity, so the sync hot path skips it
        flat, _ = _flat(x, batch_ndims)
        out = roundtrip_blocks(flat, bits=self.bits, block=self.block,
                               use_kernel=self.use_kernel)
        return out.reshape(x.shape).astype(x.dtype)

    def payload_like(self, like):
        # the wire ships the unpadded stream; padding to the block multiple
        # is a kernel-tiling artifact
        n = _like_n(like)
        return jax.ShapeDtypeStruct(((n * self.bits + 7) // 8,), jnp.int8)

    def fused_sync_spec(self):
        return {"bits": self.bits, "block": self.block,
                "use_kernel": self.use_kernel}

    def meta_wire_bytes(self, like) -> int:
        n_blocks = -(-_like_n(like) // self.block)
        return n_blocks * jnp.dtype(jnp.float16).itemsize


@dataclasses.dataclass(frozen=True)
class TopK(Codec):
    """Magnitude top-k sparsification: keep the ``fraction`` largest-|x|
    entries of each (per-agent) leaf, zero the rest.  Wire = k values at
    the leaf dtype + k int32 indices — the indices are billed.  The values
    payload stays float, so a quantizer can chain behind it
    (``Sequential((TopK(...), IntQuant(...)))``)."""

    fraction: float = 0.1

    name = "topk"

    def validate(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"TopK fraction must be in (0, 1], "
                             f"got {self.fraction}")

    def _k(self, n: int) -> int:
        return max(1, min(n, math.ceil(self.fraction * n)))

    def encode(self, x, batch_ndims: int = 0):
        flat, _ = _flat(x, batch_ndims)
        k = self._k(flat.shape[-1])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        vals = jnp.take_along_axis(flat, idx, axis=-1)
        return vals, {"idx": idx.astype(jnp.int32)}

    def decode(self, payload, meta, like, batch_ndims: int = 0):
        n = _like_n(like)
        lead = payload.shape[:batch_ndims]
        rows = int(math.prod(lead)) if lead else 1
        v = payload.reshape(rows, -1)
        i = meta["idx"].reshape(rows, -1)
        out = jnp.zeros((rows, n), payload.dtype)
        out = out.at[jnp.arange(rows)[:, None], i].set(v)
        return out.reshape(lead + like.shape).astype(like.dtype)

    def payload_like(self, like):
        return jax.ShapeDtypeStruct((self._k(_like_n(like)),), like.dtype)

    def meta_wire_bytes(self, like) -> int:
        return self._k(_like_n(like)) * jnp.dtype(jnp.int32).itemsize


@dataclasses.dataclass(frozen=True)
class Sequential(Codec):
    """Chain codecs left to right: each stage re-encodes the previous
    stage's payload (e.g. sparsify, then quantize the survivors).  Wire =
    the final payload + every stage's meta."""

    codecs: tuple = ()

    @property
    def name(self):
        return "+".join(c.name for c in self.codecs)

    @property
    def chainable(self):
        return self.codecs[-1].chainable if self.codecs else True

    def validate(self):
        if not self.codecs:
            raise ValueError("Sequential needs at least one codec")
        for c in self.codecs:
            c.validate()
        for c in self.codecs[:-1]:
            if not c.chainable:
                raise ValueError(
                    f"{c.name} produces integer codes; it can only be the "
                    f"last stage of a chain (got {self.name})")

    def _likes(self, like):
        """Per-stage input likes: like -> c0.payload_like -> c1... ."""
        likes = [like]
        for c in self.codecs[:-1]:
            likes.append(c.payload_like(likes[-1]))
        return likes

    def encode(self, x, batch_ndims: int = 0):
        payload, metas = x, []
        for c in self.codecs:
            payload, m = c.encode(payload, batch_ndims)
            metas.append(m)
        return payload, {"stages": tuple(metas)}

    def decode(self, payload, meta, like, batch_ndims: int = 0):
        likes = self._likes(like)
        for c, m, lk in zip(reversed(self.codecs),
                            reversed(meta["stages"]), reversed(likes)):
            payload = c.decode(payload, m, lk, batch_ndims)
        return payload

    def payload_like(self, like):
        return self.codecs[-1].payload_like(self._likes(like)[-1])

    def meta_wire_bytes(self, like) -> int:
        return sum(c.meta_wire_bytes(lk)
                   for c, lk in zip(self.codecs, self._likes(like)))


# ---------------------------------------------------------------------------
# Registry + CLI resolution
# ---------------------------------------------------------------------------

CODECS = {
    "int8": lambda: IntQuant(bits=8),
    "int4": lambda: IntQuant(bits=4),
    "topk": lambda: TopK(),
}


def _stages(spec: str, *, bits: int = 0, fraction: float = 0.0,
            block: int = 0) -> list:
    """Spec string -> list of codec stages with knob overrides applied."""
    stages = []
    for part in [p for p in spec.split("+") if p]:
        try:
            c = CODECS[part]()
        except KeyError:
            raise ValueError(f"unknown codec {part!r}; "
                             f"known: {sorted(CODECS)}") from None
        if isinstance(c, IntQuant):
            c = dataclasses.replace(c, bits=bits or c.bits,
                                    block=block or c.block)
        if isinstance(c, TopK) and fraction:
            c = dataclasses.replace(c, fraction=fraction)
        stages.append(c)
    return stages


def _build(stages, spec):
    if not stages:
        raise ValueError(f"empty codec spec {spec!r}")
    codec = stages[0] if len(stages) == 1 else Sequential(tuple(stages))
    codec.validate()
    return codec


def get_codec(spec: str, *, bits: int = 0, fraction: float = 0.0,
              block: int = 0) -> Codec:
    """Resolve a codec spec string — a registry name or a ``+``-chain like
    ``"topk+int8"`` — with optional knob overrides applied to the matching
    stage(s)."""
    return _build(_stages(spec, bits=bits, fraction=fraction, block=block),
                  spec)


def codec_from_flags(spec: str = "", bits: int = 0,
                     topk: float = 0.0) -> Codec | None:
    """CLI flags -> codec.  ``--codec`` names the spec; ``--codec-bits``
    retunes (or appends) the quantizer stage; ``--topk`` retunes (or
    prepends) the sparsifier — so ``--codec int8 --topk 0.25`` is the
    canonical sparsify-then-quantize chain.  Returns None when no codec
    flag was given."""
    if not spec and not bits and not topk:
        return None
    stages = _stages(spec, bits=bits, fraction=topk)
    if spec and not stages:
        raise ValueError(f"empty codec spec {spec!r}")
    if topk and not any(isinstance(c, TopK) for c in stages):
        stages.insert(0, TopK(fraction=topk))
    if bits and not any(isinstance(c, IntQuant) for c in stages):
        stages.append(IntQuant(bits=bits))
    return _build(stages, spec)
