from repro.configs.registry import get_config, get_shape, list_archs, pair_supported

__all__ = ["get_config", "get_shape", "list_archs", "pair_supported"]
