"""chameleon-34b [vlm] — early fusion; VQ image tokens share the text
vocabulary, so the backbone consumes one interleaved token stream.  The
VQ-VAE image tokenizer is the STUBBED frontend (input_specs provides token
ids).  [arXiv:2405.09818]"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    qk_norm=True,            # chameleon uses qk-norm for stability
    frontend_stub=True,
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
    source="arXiv:2405.09818",
)
