"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family card, scaled per assignment]"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,            # gemma3 fixes head_dim=256 independent of d_model
    d_ff=10240,
    vocab_size=262_144,
    sliding_window=1024,     # local layers
    local_global_ratio=5,    # 5 local : 1 global
    qk_norm=True,
    rope_theta=1_000_000.0,
    dtype=jnp.bfloat16,
    source="hf:google/gemma-3-1b-pt",
)
