"""granite-moe-3b-a800m [moe] — 40 experts top-8, narrow experts.
[hf:ibm-granite/granite-3.0-1b-a400m-base family card, scaled per assignment]"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                # per-expert width (narrow-expert regime)
    vocab_size=49_155,
    num_experts=40,
    experts_per_token=8,
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
