"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,            # d_inner = 5120, 80 SSD heads of dim 64
    ssm_chunk=128,
    dtype=jnp.bfloat16,
    source="arXiv:2405.21060",
)
