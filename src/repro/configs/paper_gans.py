"""The paper's own experiment configurations (§4 + Appendices C/D/E).

Each entry bundles the nets, the non-iid split, the FedGAN hyperparameters
(B, K, optimizers, learning rates) from the paper's tables, and the
synthetic stand-in dataset (see repro.data.synthetic for the data gates).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.optim import Adam, SGD, TimeScales, constant_ttur, equal_timescale, power_decay


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    name: str
    num_agents: int
    sync_intervals: tuple[int, ...]   # K values swept in the paper
    default_K: int
    batch_size: int
    iterations: int
    opt: str                          # "sgd" | "adam"
    lr_d: float
    lr_g: float
    notes: str = ""


# §C / Fig 5 — 2D system, B=5 agents on segments of U[-1,1]
TOY_2D = PaperExperiment(
    name="toy_2d", num_agents=5, sync_intervals=(1, 5, 20, 50), default_K=5,
    batch_size=64, iterations=4000, opt="sgd", lr_d=0.1, lr_g=0.1,
    notes="converges to (theta, psi) = (1, 0); robust to K")

# §C / Fig 6 — mixed Gaussian, B=4 agents x 2 modes, K=5
MIXED_GAUSSIAN = PaperExperiment(
    name="mixed_gaussian", num_agents=4, sync_intervals=(5,), default_K=5,
    batch_size=128, iterations=15000, opt="adam", lr_d=2e-4, lr_g=2e-4)

# §C / Fig 7 — Swiss roll, B=4 agents on arc segments, K=5
SWISS_ROLL = PaperExperiment(
    name="swiss_roll", num_agents=4, sync_intervals=(5,), default_K=5,
    batch_size=128, iterations=27000, opt="adam", lr_d=2e-4, lr_g=2e-4)

# §4.2 / Fig 1 — MNIST (K=20) and CIFAR-10 (K sweep), ACGAN nets, B=5
IMAGE_ACGAN = PaperExperiment(
    name="image_acgan", num_agents=5,
    sync_intervals=(10, 20, 100, 500, 3000, 6000), default_K=20,
    batch_size=64, iterations=30000, opt="adam", lr_d=1e-3, lr_g=1e-3,
    notes="Table 1: Adam(b1=0.5, b2=0.999); 2 classes per agent")

# §4.2 / Fig 2 — CelebA, 16 attribute classes over B=5 agents
CELEBA_ACGAN = PaperExperiment(
    name="celeba_acgan", num_agents=5,
    sync_intervals=(10, 20, 50, 100, 200), default_K=50,
    batch_size=128, iterations=16000, opt="adam", lr_d=2e-4, lr_g=1e-4,
    notes="Table 2: TTUR lr_D = 2 lr_G")

# §4.3 / Fig 3-4 — PG&E household load + EV sessions, CGAN 1-D conv, B=5
TIMESERIES_CGAN = PaperExperiment(
    name="timeseries_cgan", num_agents=5, sync_intervals=(20,), default_K=20,
    batch_size=256, iterations=8000, opt="adam", lr_d=4e-4, lr_g=4e-4,
    notes="Table 3; split by climate zone / station category")


def scales_for(exp: PaperExperiment) -> TimeScales:
    if exp.lr_d == exp.lr_g:
        return equal_timescale(power_decay(exp.lr_d, tau=max(exp.iterations // 10, 1), p=0.6)
                               if exp.opt == "sgd" else _const(exp.lr_d))
    return constant_ttur(exp.lr_d, exp.lr_g)


def _const(lr):
    from repro.optim import constant
    return constant(lr)


def optimizer_for(exp: PaperExperiment):
    if exp.opt == "sgd":
        return SGD(), SGD()
    return Adam(b1=0.5, b2=0.999), Adam(b1=0.5, b2=0.999)


ALL_EXPERIMENTS = {
    e.name: e for e in (TOY_2D, MIXED_GAUSSIAN, SWISS_ROLL, IMAGE_ACGAN,
                        CELEBA_ACGAN, TIMESERIES_CGAN)
}
