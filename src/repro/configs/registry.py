"""Architecture registry: ``--arch <id>`` resolution for launch scripts."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ArchConfig, ShapeConfig

_MODULES = {
    "gemma3-4b": "repro.configs.gemma3_4b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "whisper-medium": "repro.configs.whisper_medium",
    "glm4-9b": "repro.configs.glm4_9b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
}


def list_archs() -> list[str]:
    return list(_MODULES.keys())


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def pair_supported(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for the (arch x shape) matrix.

    long_500k needs sub-quadratic attention (see DESIGN.md): SSM/hybrid run
    natively; dense/MoE run only with a sliding-window variant; whisper's
    enc-dec decoder is bounded by its 30 s audio context.
    """
    cfg = get_config(arch)
    if shape != "long_500k":
        return True, ""
    if cfg.family == "audio":
        return False, "enc-dec audio decoder: 500k-token cache out of family (30 s source)"
    if not cfg.supports_long_decode:
        return False, "pure full attention; no sliding-window/block-sparse variant"
    return True, ""
