"""whisper-medium [audio] — enc-dec; mel/conv frontend STUBBED (input_specs
feeds precomputed frame embeddings).  [arXiv:2212.04356]

Adaptation note: whisper's learned absolute positions are replaced with RoPE
(recorded in DESIGN.md); LayerNorm retained.
"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,           # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    encoder_layers=24,
    encoder_seq=1500,        # 30 s of audio after the (stubbed) conv frontend
    cross_attention=True,
    frontend_stub=True,
    norm="layernorm",
    dtype=jnp.bfloat16,
    source="arXiv:2212.04356",
)
