"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

81 blocks; every 6th block applies the SHARED transformer block (single
parameter set reused at 13 positions, remainder 3 blocks are Mamba2),
matching Zamba2's shared-attention design in a scan-friendly grouping.
"""
import jax.numpy as jnp

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    hybrid_period=6,         # 1 shared-attn + 5 mamba per group
    rope_theta=10_000.0,
    dtype=jnp.bfloat16,
    source="arXiv:2411.15242",
)
