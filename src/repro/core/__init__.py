# The paper's primary contribution: FedGAN (Algorithm 1) + its convergence
# instrumentation (Lemmas 1-2) + the distributed-GAN comparison baseline.
from repro.core import losses
from repro.core.convergence import (
    ConstantEstimates,
    estimate_constants,
    measure_drift,
    r1_bound,
    r2_bound,
    tree_diff_norm,
    tree_norm,
)
from repro.core.fedgan import (
    FedGAN,
    FedGANConfig,
    GANTask,
    dataset_weights,
    uniform_weights,
)

__all__ = [
    "ConstantEstimates", "FedGAN", "FedGANConfig", "GANTask",
    "dataset_weights", "estimate_constants", "losses", "measure_drift",
    "r1_bound", "r2_bound", "tree_diff_norm", "tree_norm", "uniform_weights",
]
