# The paper's primary contribution: FedGAN (Algorithm 1) + its convergence
# instrumentation (Lemmas 1-2), the pluggable aggregation strategies, and
# the distributed-GAN comparison baseline.
from repro.core import losses, strategies
from repro.core.convergence import (
    ConstantEstimates,
    estimate_constants,
    measure_drift,
    r1_bound,
    r2_bound,
    tree_diff_norm,
    tree_norm,
)
from repro.core.fedgan import (
    FedGAN,
    FedGANConfig,
    GANTask,
    dataset_weights,
    uniform_weights,
)
from repro.core.strategies import (
    AdaptiveK,
    FedAvgSync,
    Hierarchical,
    LocalOnly,
    PartialSharing,
    PerStepGradAvg,
    SubsampledFedAvg,
    SyncStrategy,
    get_strategy,
    strategy_from_mode,
)
from repro.core.tasks import ACGAN, CONDITIONAL, NS, LossSpec, make_gan_task

__all__ = [
    "ACGAN", "AdaptiveK", "CONDITIONAL", "ConstantEstimates", "FedAvgSync",
    "FedGAN", "FedGANConfig", "GANTask", "Hierarchical", "LocalOnly",
    "LossSpec", "NS", "PartialSharing", "PerStepGradAvg", "SubsampledFedAvg",
    "SyncStrategy", "dataset_weights", "estimate_constants", "get_strategy",
    "losses", "make_gan_task", "measure_drift", "r1_bound", "r2_bound",
    "strategies", "strategy_from_mode", "tree_diff_norm", "tree_norm",
    "uniform_weights",
]
