"""Convergence-theory instrumentation (paper §3.3, Lemmas 1–2).

The paper bounds, for equal time scales and learning rate a(n) constant
within a sync interval:

  Lemma 1 (agent drift vs the virtual centralized sequence (v_n, phi_n)):
      E||w_n^i - v_n|| + E||th_n^i - ph_n||
          <= r1(n) = (sg + mg + sh)/(2L) * [(1 + 2 a L)^(n mod K) - 1]

  Lemma 2 (synced average drift):
      E||w_n - v_n|| + E||th_n - ph_n||
          <= r2(n) = (sg + sh + mg)/(2L) * [(1 + 2 a L)^K - 1] - a mg K

with (A5) constants sg, sh (stochastic-gradient variance bounds), mg
(non-iid gradient divergence bound) and L the Lipschitz constant (A1).

This module provides:
  * r1 / r2 evaluators,
  * empirical estimators for (L, sg, sh, mg) from a GANTask + per-agent data,
  * a drift-measurement harness that co-simulates FedGAN with the virtual
    centralized SGD sequence of eq. (7) and reports measured drift vs bound
    (consumed by benchmarks/bench_lemmas.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core.fedgan import FedGAN, GANTask

tmap = jax.tree_util.tree_map


def tree_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_diff_norm(a, b) -> jax.Array:
    return tree_norm(tmap(lambda x, y: x - y, a, b))


# ---------------------------------------------------------------------------
# Lemma bounds
# ---------------------------------------------------------------------------

def r1_bound(n, *, a, K, L, sg, sh, mg):
    """Lemma 1 RHS at step n (a = a(n-1), constant within the interval)."""
    m = jnp.asarray(n) % K
    return (sg + mg + sh) / (2 * L) * ((1 + 2 * a * L) ** m - 1.0)


def r2_bound(n, *, a, K, L, sg, sh, mg):
    """Lemma 2 RHS (uniform over the interval)."""
    return ((sg + sh + mg) / (2 * L) * ((1 + 2 * a * L) ** K - 1.0)
            - a * mg * K)


# ---------------------------------------------------------------------------
# (A1)/(A5) constant estimation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConstantEstimates:
    L: float
    sigma_g: float   # disc stochastic-gradient deviation bound
    sigma_h: float   # gen stochastic-gradient deviation bound
    mu_g: float      # non-iid gradient divergence bound (disc)


def _grads(task: GANTask, params, batch, rng):
    rd, rg = jax.random.split(rng)
    gd = jax.grad(lambda d: task.disc_loss({**params, "disc": d}, batch, rd))(params["disc"])
    gg = jax.grad(lambda g: task.gen_loss({**params, "gen": g}, batch, rg))(params["gen"])
    return gd, gg


def _sample_minibatch(data, rng, size):
    n = jax.tree_util.tree_leaves(data)[0].shape[0]
    idx = jax.random.randint(rng, (size,), 0, n)
    return tmap(lambda x: x[idx], data)


def estimate_constants(task: GANTask, params, agent_data: Sequence[Any],
                       rng, *, minibatch: int = 64, n_var_samples: int = 8,
                       n_lip_samples: int = 8, lip_eps: float = 1e-2,
                       weights=None) -> ConstantEstimates:
    """Empirical (A1)/(A5) constants at the given parameter point.

    ``agent_data[i]`` is agent i's full local dataset (a batch pytree); the
    pooled "true" gradient is the p_i-weighted mean of per-agent full-data
    gradients (this matches the paper's definition of g = grad of the
    centralized loss on pooled data).
    """
    B = len(agent_data)
    w = (jnp.full((B,), 1.0 / B) if weights is None
         else jnp.asarray(weights, jnp.float32))

    rng, rfull = jax.random.split(rng)
    full_gd, full_gg = [], []
    for i, data in enumerate(agent_data):
        gd, gg = _grads(task, params, data, rfull)
        full_gd.append(gd)
        full_gg.append(gg)
    pooled_gd = tmap(lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *full_gd)

    # mu_g: max_i || g^i - g ||
    mu_g = max(float(tree_diff_norm(full_gd[i], pooled_gd)) for i in range(B))

    # sigma_g / sigma_h: E || minibatch grad - full grad ||  (max over agents)
    sg, sh = 0.0, 0.0
    for i, data in enumerate(agent_data):
        dev_g, dev_h = [], []
        for s in range(n_var_samples):
            rng, r1, r2 = jax.random.split(rng, 3)
            mb = _sample_minibatch(data, r1, minibatch)
            gd, gg = _grads(task, params, mb, r2)
            dev_g.append(float(tree_diff_norm(gd, full_gd[i])))
            dev_h.append(float(tree_diff_norm(gg, full_gg[i])))
        sg = max(sg, sum(dev_g) / len(dev_g))
        sh = max(sh, sum(dev_h) / len(dev_h))

    # L: finite-difference Lipschitz estimate of the joint gradient field
    joint = {"disc": params["disc"], "gen": params["gen"]}
    L = 0.0
    for s in range(n_lip_samples):
        rng, r1, r2 = jax.random.split(rng, 3)
        flat, unflat = jax.flatten_util.ravel_pytree(joint)
        direction = jax.random.normal(r1, flat.shape)
        direction = direction / (jnp.linalg.norm(direction) + 1e-12)
        perturbed = unflat(flat + lip_eps * direction)
        p2 = {**params, **perturbed}
        gd1, gg1 = _grads(task, params, agent_data[0], r2)
        gd2, gg2 = _grads(task, p2, agent_data[0], r2)
        dg = tree_diff_norm({"d": gd1, "g": gg1}, {"d": gd2, "g": gg2})
        L = max(L, float(dg) / lip_eps)

    return ConstantEstimates(L=max(L, 1e-6), sigma_g=sg, sigma_h=sh, mu_g=mu_g)


# ---------------------------------------------------------------------------
# Drift measurement: FedGAN vs the virtual centralized sequence (eq. 7)
# ---------------------------------------------------------------------------


def measure_drift(fed: FedGAN, state, agent_data: Sequence[Any], rng, *,
                  n_steps: int, minibatch: int = 64,
                  pooled_grad_data: Sequence[Any] | None = None) -> dict:
    """Co-simulate ``n_steps`` of FedGAN (SGD) with the virtual centralized
    sequence (v_n, phi_n) that applies the TRUE pooled gradient, resetting
    v to the synced average at every multiple of K (exactly eq. (7)).

    Returns per-step arrays: measured agent drift (Lemma 1 LHS, max over
    agents), measured average drift (Lemma 2 LHS), and the schedule a(n).
    Intended for small models (runs a python loop).
    """
    cfg = fed.cfg
    P, A = cfg.agent_grid
    B = P * A
    K = cfg.sync_interval
    assert B == len(agent_data)
    pooled = pooled_grad_data if pooled_grad_data is not None else agent_data
    w = fed._w().reshape(-1)

    def pooled_grads(params, rng):
        gds, ggs = [], []
        for d in pooled:
            gd, gg = _grads(fed.task, params, d, rng)
            gds.append(gd)
            ggs.append(gg)
        gd = tmap(lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *gds)
        gg = tmap(lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *ggs)
        return gd, gg

    virt = fed.averaged_params(state)
    agent_drift, avg_drift, lrs = [], [], []

    for n in range(n_steps):
        lr_a = float(fed.scales.a(jnp.float32(n)))
        lr_b = float(fed.scales.b(jnp.float32(n)))
        # one FedGAN step across agents
        rng, rb, rs = jax.random.split(rng, 3)
        mbs = [_sample_minibatch(agent_data[i], jax.random.fold_in(rb, i), minibatch)
               for i in range(B)]
        batch = tmap(lambda *xs: jnp.stack(xs).reshape((P, A) + xs[0].shape), *mbs)
        seeds = jax.random.randint(rs, (1, P, A), 0, 2 ** 31 - 1, jnp.uint32)
        state, _ = jax.lax.scan(fed._step, state,
                                (tmap(lambda x: x[None], batch), seeds))
        # virtual centralized true-gradient step
        rng, rv = jax.random.split(rng)
        vgd, vgg = pooled_grads(virt, rv)
        virt = {"disc": tmap(lambda p, g: p - lr_a * g, virt["disc"], vgd),
                "gen": tmap(lambda p, g: p - lr_b * g, virt["gen"], vgg)}

        step = n + 1
        if step % K == 0:
            state = fed._sync(state)
            virt = fed.averaged_params(state)  # v_n := w_n at sync points

        # Lemma 1 LHS: max_i ||w_i - v|| + ||th_i - ph||
        drifts = []
        for p in range(P):
            for a in range(A):
                ap = fed.agent_params(state, p, a)
                drifts.append(float(tree_diff_norm(ap["disc"], virt["disc"])
                                    + tree_diff_norm(ap["gen"], virt["gen"])))
        agent_drift.append(max(drifts))
        avg = fed.averaged_params(state)
        avg_drift.append(float(tree_diff_norm(avg["disc"], virt["disc"])
                               + tree_diff_norm(avg["gen"], virt["gen"])))
        lrs.append(lr_a)

    return {"agent_drift": jnp.asarray(agent_drift),
            "avg_drift": jnp.asarray(avg_drift),
            "lr": jnp.asarray(lrs)}
