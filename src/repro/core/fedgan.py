"""FedGAN — Algorithm 1 of the paper, as a composable JAX module.

Representation: *agent-stacked* state.  Every parameter/optimizer leaf gets a
leading (P, A) axis — P pods x A agents-per-pod, B = P*A agents total.  On
the production mesh that axis is sharded over ("pod", "data"), so

  * local steps  = vmap over (P, A)  ->  embarrassingly parallel, ZERO
    cross-agent communication (tensor-parallel collectives over "model"
    happen inside each agent's step);
  * the K-step sync = dataset-size-weighted average over (P, A)  ->  ONE
    all-reduce over ("pod", "data") — exactly the intermediary of eq. (2),
    realised TPU-idiomatically.

The same code runs unsharded on CPU for the paper's experiments (P=1, A=B).

Modes
  fedgan        local SGD for K steps, then parameter sync (the paper).
  distributed   gradient all-reduce every step (the paper's baseline:
                MD-GAN/FedAvg-GAN-style per-step communication).
  local_only    never sync (ablation lower bound).
  hierarchical  beyond-paper two-tier sync: intra-pod average every
                ``intra_interval`` steps, full average every K.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.dist import collectives
from repro.optim import Adam, Optimizer, TimeScales, equal_timescale, constant

Params = Any
tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class GANTask:
    """Adapter between FedGAN and a concrete (G, D) model pair.

    init(rng) -> {"gen": ..., "disc": ...}
    disc_loss(params, batch, rng) -> scalar minimised in params["disc"]
    gen_loss(params, batch, rng) -> scalar minimised in params["gen"]
    Losses must stop-gradient the other player's contribution themselves
    (simultaneous updates, eq. (1)).
    """

    init: Callable[[jax.Array], Params]
    disc_loss: Callable[[Params, Any, jax.Array], jax.Array]
    gen_loss: Callable[[Params, Any, jax.Array], jax.Array]
    # Optional fused gradient path: (params, batch, rng) ->
    # (grad_disc, grad_gen, metrics).  Used to share the generator forward
    # pass between the two objectives (the separate-loss default runs G
    # forward twice).
    fused_grads: Callable[[Params, Any, jax.Array], Any] | None = None


@dataclasses.dataclass(frozen=True)
class FedGANConfig:
    agent_grid: tuple[int, int] = (1, 5)  # (P pods, A agents/pod); B = P*A
    sync_interval: int = 20               # K
    mode: str = "fedgan"                  # fedgan|distributed|local_only|hierarchical
    intra_interval: int = 0               # K1 for hierarchical; must divide K
    sync_dtype: Any = None                # e.g. jnp.bfloat16 — compressed sync
    average_opt_state: bool = False       # optionally FedAvg the Adam moments too

    @property
    def num_agents(self) -> int:
        return self.agent_grid[0] * self.agent_grid[1]

    def validate(self):
        if self.mode == "hierarchical":
            if not self.intra_interval or self.sync_interval % self.intra_interval:
                raise ValueError("hierarchical mode needs intra_interval | sync_interval")
        if self.mode not in ("fedgan", "distributed", "local_only", "hierarchical"):
            raise ValueError(f"unknown mode {self.mode}")


def uniform_weights(cfg: FedGANConfig) -> jax.Array:
    P, A = cfg.agent_grid
    return jnp.full((P, A), 1.0 / (P * A), jnp.float32)


def dataset_weights(sizes) -> jax.Array:
    """p_i = |R_i| / sum_j |R_j|  (paper §3.1)."""
    s = jnp.asarray(sizes, jnp.float32)
    return s / jnp.sum(s)


@dataclasses.dataclass(frozen=True)
class FedGAN:
    task: GANTask
    cfg: FedGANConfig
    opt_g: Optimizer = Adam()
    opt_d: Optimizer = Adam()
    scales: TimeScales = dataclasses.field(
        default_factory=lambda: equal_timescale(constant(1e-3)))
    weights: Any = None  # (P, A) p_i; None -> uniform

    # ------------------------------------------------------------------
    def _w(self):
        w = uniform_weights(self.cfg) if self.weights is None else jnp.asarray(self.weights)
        return w / jnp.sum(w)

    def init_state(self, rng) -> dict:
        """All agents start from the same (w_hat, theta_hat) — Algorithm 1."""
        P, A = self.cfg.agent_grid
        params = self.task.init(rng)
        opt_g = self.opt_g.init(params["gen"])
        opt_d = self.opt_d.init(params["disc"])
        stacked = tmap(lambda x: jnp.broadcast_to(x, (P, A) + x.shape),
                       {"params": params, "opt_g": opt_g, "opt_d": opt_d})
        return {**stacked, "step": jnp.zeros((), jnp.int32)}

    # ------------------------------------------------------------------
    # averaging primitives
    # ------------------------------------------------------------------
    def _avg_full(self, tree):
        """Weighted average over (P, A) then broadcast back — eq. (2)+(3).
        Lowers to ONE all-reduce over ("pod","data") on the mesh."""
        return collectives.average_agents(tree, self._w(),
                                          sync_dtype=self.cfg.sync_dtype)

    def _avg_intra_pod(self, tree):
        """Average within each pod only (hierarchical tier 1)."""
        return collectives.average_intra_pod(tree, self._w())

    def _sync(self, state):
        new = dict(state)
        new["params"] = self._avg_full(state["params"])
        if self.cfg.average_opt_state:
            new["opt_g"] = self._avg_full(state["opt_g"])
            new["opt_d"] = self._avg_full(state["opt_d"])
        return new

    # ------------------------------------------------------------------
    # one simultaneous local step on every agent
    # ------------------------------------------------------------------
    def _local_grads(self, params, batch, rng):
        if self.task.fused_grads is not None:
            return self.task.fused_grads(params, batch, rng)
        rd, rg = jax.random.split(rng)
        ld, gd = jax.value_and_grad(
            lambda d: self.task.disc_loss({**params, "disc": d}, batch, rd))(params["disc"])
        lg, gg = jax.value_and_grad(
            lambda g: self.task.gen_loss({**params, "gen": g}, batch, rg))(params["gen"])
        return gd, gg, {"d_loss": ld, "g_loss": lg}

    def _step(self, state, step_input):
        """One parallel step across all agents.  step_input = (batch, seeds)
        with leading (P, A) axes."""
        batch, seeds = step_input
        n = state["step"]
        lr_a = self.scales.a(n.astype(jnp.float32))
        lr_b = self.scales.b(n.astype(jnp.float32))

        def agent_grads(params, b, seed):
            rng = jax.random.fold_in(jax.random.key(0), seed)
            return self._local_grads(params, b, rng)

        gd, gg, metrics = jax.vmap(jax.vmap(agent_grads))(state["params"], batch, seeds)

        if self.cfg.mode == "distributed":
            # per-step gradient averaging — the paper's distributed-GAN
            # baseline communication pattern (every iteration).
            gd = self._avg_full(gd)
            gg = self._avg_full(gg)

        def upd_d(d, g, s):
            return self.opt_d.update(d, g, s, lr_a)

        def upd_g(p, g, s):
            return self.opt_g.update(p, g, s, lr_b)

        new_disc, new_opt_d = jax.vmap(jax.vmap(upd_d))(
            state["params"]["disc"], gd, state["opt_d"])
        new_gen, new_opt_g = jax.vmap(jax.vmap(upd_g))(
            state["params"]["gen"], gg, state["opt_g"])

        new_state = {
            "params": {"gen": new_gen, "disc": new_disc},
            "opt_g": new_opt_g, "opt_d": new_opt_d,
            "step": n + 1,
        }
        return new_state, tmap(jnp.mean, metrics)

    # ------------------------------------------------------------------
    # one K-step round (the jitted unit; this is what the dry-run lowers)
    # ------------------------------------------------------------------
    def round(self, state, batches, seeds):
        """batches: pytree with leading (K, P, A, ...); seeds: (K, P, A) u32.
        Runs K local steps then syncs per the configured mode."""
        self.cfg.validate()
        K = self.cfg.sync_interval

        if self.cfg.mode == "hierarchical":
            K1 = self.cfg.intra_interval
            segs = K // K1

            def seg_body(st, seg_in):
                st, m = jax.lax.scan(self._step, st, seg_in)
                st = dict(st)
                st["params"] = self._avg_intra_pod(st["params"])
                return st, m

            seg_in = tmap(lambda x: x.reshape((segs, K1) + x.shape[1:]),
                          (batches, seeds))
            state, metrics = jax.lax.scan(seg_body, state, seg_in)
            metrics = tmap(lambda x: x.reshape((K,) + x.shape[2:]), metrics)
            state = self._sync(state)
            return state, metrics

        state, metrics = jax.lax.scan(self._step, state, (batches, seeds))
        if self.cfg.mode == "fedgan":
            state = self._sync(state)
        # distributed: synced every step already; local_only: never.
        return state, metrics

    # ------------------------------------------------------------------
    def agent_params(self, state, p: int = 0, a: int = 0):
        return tmap(lambda x: x[p, a], state["params"])

    def averaged_params(self, state):
        """The intermediary's (w_n, theta_n) — weighted average, no broadcast."""
        w = self._w()
        return tmap(lambda x: jnp.einsum("pa,pa...->...", w.astype(x.dtype), x),
                    state["params"])

    def comm_bytes_per_round(self, state) -> dict:
        """Analytic §3.2 accounting: FedGAN moves 2·2M per agent per ROUND
        (send + receive of G and D), i.e. 2·2M/K per step; the distributed
        baseline moves 2·2M per STEP."""
        M_bytes = collectives.tree_bytes(self.agent_params(state))
        K = self.cfg.sync_interval
        per_round = {"fedgan": 2 * M_bytes, "distributed": 2 * M_bytes * K}
        return {"param_bytes_M": M_bytes, "per_agent_per_round": per_round,
                "ratio": K}
