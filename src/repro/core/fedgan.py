"""FedGAN — Algorithm 1 of the paper, as a composable JAX module.

Representation: *agent-stacked* state.  Every parameter/optimizer leaf gets a
leading (P, A) axis — P pods x A agents-per-pod, B = P*A agents total.  On
the production mesh that axis is sharded over ("pod", "data"), so

  * local steps  = vmap over (P, A)  ->  embarrassingly parallel, ZERO
    cross-agent communication (tensor-parallel collectives over "model"
    happen inside each agent's step);
  * the K-step sync = dataset-size-weighted average over (P, A)  ->  ONE
    all-reduce over ("pod", "data") — exactly the intermediary of eq. (2),
    realised TPU-idiomatically.

The same code runs unsharded on CPU for the paper's experiments (P=1, A=B).

Aggregation is pluggable: a :class:`repro.core.strategies.SyncStrategy`
owns when / what / how agents sync (and its own §3.2 wire-byte
accounting).  The paper's algorithm is ``FedAvgSync()`` (the default); the
per-step baseline is ``PerStepGradAvg()``; see ``repro.core.strategies``
for generator-only sharing, participation subsampling, hierarchical and
adaptive-K schedules.  The old closed-world ``mode: str`` field remains as
a deprecated shim that resolves to the equivalent strategy.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import strategies as sync_strategies
from repro.dist import collectives
from repro.optim import Adam, Optimizer, TimeScales, equal_timescale, constant

Params = Any
tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class GANTask:
    """Adapter between FedGAN and a concrete (G, D) model pair.

    init(rng) -> {"gen": ..., "disc": ...}
    disc_loss(params, batch, rng) -> scalar minimised in params["disc"]
    gen_loss(params, batch, rng) -> scalar minimised in params["gen"]
    Losses must stop-gradient the other player's contribution themselves
    (simultaneous updates, eq. (1)).
    """

    init: Callable[[jax.Array], Params]
    disc_loss: Callable[[Params, Any, jax.Array], jax.Array]
    gen_loss: Callable[[Params, Any, jax.Array], jax.Array]
    # Optional fused gradient path: (params, batch, rng) ->
    # (grad_disc, grad_gen, metrics).  Used to share the generator forward
    # pass between the two objectives (the separate-loss default runs G
    # forward twice).
    fused_grads: Callable[[Params, Any, jax.Array], Any] | None = None


@dataclasses.dataclass(frozen=True)
class FedGANConfig:
    agent_grid: tuple[int, int] = (1, 5)  # (P pods, A agents/pod); B = P*A
    sync_interval: int = 20               # K
    strategy: Any = None                  # SyncStrategy; None -> FedAvgSync
    dp: Any = None                        # repro.privacy.DPSGD; None -> no DP
    # -- deprecated closed-world fields, kept as a shim ---------------------
    mode: str = ""                        # fedgan|distributed|local_only|hierarchical
    intra_interval: int = 0               # K1 for the hierarchical shim
    sync_dtype: Any = None                # e.g. jnp.bfloat16 — compressed sync
    average_opt_state: bool = False       # optionally FedAvg the Adam moments too

    @property
    def num_agents(self) -> int:
        return self.agent_grid[0] * self.agent_grid[1]

    def resolve_strategy(self) -> sync_strategies.SyncStrategy:
        """The strategy this config denotes.  Explicit ``strategy`` wins;
        a legacy ``mode`` string resolves through the deprecation shim.
        Mixing the two is an error — the legacy knobs would be silently
        ignored otherwise."""
        if self.strategy is not None:
            legacy = {k: v for k, v in
                      (("mode", self.mode),
                       ("intra_interval", self.intra_interval),
                       ("sync_dtype", self.sync_dtype),
                       ("average_opt_state", self.average_opt_state)) if v}
            if legacy:
                raise ValueError(
                    f"strategy={self.strategy!r} conflicts with the "
                    f"deprecated config field(s) {sorted(legacy)}; move "
                    "them onto the strategy (e.g. "
                    "FedAvgSync(sync_dtype=...))")
            return self.strategy
        if self.mode:
            warnings.warn(
                f"FedGANConfig(mode={self.mode!r}) is deprecated; pass "
                "strategy= a repro.core.strategies.SyncStrategy instead "
                f"(e.g. strategies.strategy_from_mode({self.mode!r}))",
                DeprecationWarning, stacklevel=2)
            return sync_strategies.strategy_from_mode(
                self.mode, intra_interval=self.intra_interval,
                sync_dtype=self.sync_dtype,
                average_opt_state=self.average_opt_state)
        return sync_strategies.FedAvgSync(
            sync_dtype=self.sync_dtype,
            average_opt_state=self.average_opt_state)

    def validate(self):
        strat = self.resolve_strategy()  # raises on unknown mode strings
        strat.validate(self)
        if self.dp is not None:
            self.dp.validate()


def uniform_weights(cfg: FedGANConfig) -> jax.Array:
    P, A = cfg.agent_grid
    return jnp.full((P, A), 1.0 / (P * A), jnp.float32)


def dataset_weights(sizes) -> jax.Array:
    """p_i = |R_i| / sum_j |R_j|  (paper §3.1)."""
    s = jnp.asarray(sizes, jnp.float32)
    return s / jnp.sum(s)


@dataclasses.dataclass(frozen=True)
class FedGAN:
    task: GANTask
    cfg: FedGANConfig
    opt_g: Optimizer = Adam()
    opt_d: Optimizer = Adam()
    scales: TimeScales = dataclasses.field(
        default_factory=lambda: equal_timescale(constant(1e-3)))
    weights: Any = None  # (P, A) p_i; None -> uniform

    # ------------------------------------------------------------------
    def _w(self):
        w = uniform_weights(self.cfg) if self.weights is None else jnp.asarray(self.weights)
        return w / jnp.sum(w)

    def init_state(self, rng, *, agent_grid=None) -> dict:
        """All agents start from the same (w_hat, theta_hat) — Algorithm 1.
        Strategies may carry extra entries across rounds (e.g. the
        error-feedback residuals of a compressed sync) — those are merged
        here so every state-construction path gets them.

        ``agent_grid`` overrides the config grid for the broadcast — the
        virtual-client runtime uses a ``(1, 1)`` slot-view init to build
        the one per-client template row every not-yet-materialized client
        shares (Algorithm 1 starts the whole fleet from the same point)."""
        P, A = agent_grid or self.cfg.agent_grid
        params = self.task.init(rng)
        opt_g = self.opt_g.init(params["gen"])
        opt_d = self.opt_d.init(params["disc"])
        stacked = tmap(lambda x: jnp.broadcast_to(x, (P, A) + x.shape),
                       {"params": params, "opt_g": opt_g, "opt_d": opt_d})
        state = {**stacked, "step": jnp.zeros((), jnp.int32)}
        state.update(self.cfg.resolve_strategy().init_round_state(self, state))
        return state

    # ------------------------------------------------------------------
    # averaging primitives (legacy helpers; strategies call collectives
    # directly with their own knobs)
    # ------------------------------------------------------------------
    def _avg_full(self, tree):
        """Weighted average over (P, A) then broadcast back — eq. (2)+(3).
        Lowers to ONE all-reduce over ("pod","data") on the mesh."""
        return collectives.average_agents(tree, self._w(),
                                          sync_dtype=self.cfg.sync_dtype)

    def _avg_intra_pod(self, tree):
        """Average within each pod only (hierarchical tier 1)."""
        return collectives.average_intra_pod(tree, self._w())

    def _sync(self, state):
        new = dict(state)
        new["params"] = self._avg_full(state["params"])
        if self.cfg.average_opt_state:
            new["opt_g"] = self._avg_full(state["opt_g"])
            new["opt_d"] = self._avg_full(state["opt_d"])
        return new

    # ------------------------------------------------------------------
    # one simultaneous local step on every agent
    # ------------------------------------------------------------------
    def _local_grads(self, params, batch, rng):
        if self.task.fused_grads is not None:
            return self.task.fused_grads(params, batch, rng)
        rd, rg = jax.random.split(rng)
        ld, gd = jax.value_and_grad(
            lambda d: self.task.disc_loss({**params, "disc": d}, batch, rd))(params["disc"])
        lg, gg = jax.value_and_grad(
            lambda g: self.task.gen_loss({**params, "gen": g}, batch, rg))(params["gen"])
        return gd, gg, {"d_loss": ld, "g_loss": lg}

    def _step(self, state, step_input):
        """One parallel step across all agents.  step_input = (batch, rngs)
        with leading (P, A) axes.

        ``rngs`` is a (P, A) typed PRNG key array (the canonical path —
        keys are split off the round key, so no two agents/steps can
        collide).  A (P, A) uint32 array is also accepted as a compat shim
        for the seed-threading callers: each seed is folded into a fixed
        base key, which has birthday-collision risk across the fleet and
        survives only for bit-parity with pre-`repro.run` trajectories."""
        batch, rngs = step_input
        strat = self.cfg.resolve_strategy()
        n = state["step"]
        lr_a = self.scales.a(n.astype(jnp.float32))
        lr_b = self.scales.b(n.astype(jnp.float32))

        if self.cfg.dp is not None:
            # per-agent DP-SGD: per-example clip + Gaussian noise replace
            # the plain minibatch gradient (repro.privacy.dpsgd)
            from repro.privacy.dpsgd import dp_grads
            grads_of = lambda params, b, rng: dp_grads(
                self._local_grads, params, b, rng, self.cfg.dp)
        else:
            grads_of = self._local_grads

        if jnp.issubdtype(rngs.dtype, jax.dtypes.prng_key):
            def agent_grads(params, b, rng):
                return grads_of(params, b, rng)
        else:  # legacy uint32 seeds
            def agent_grads(params, b, seed):
                rng = jax.random.fold_in(jax.random.key(0), seed)
                return grads_of(params, b, rng)

        gd, gg, metrics = jax.vmap(jax.vmap(agent_grads))(state["params"], batch, rngs)

        # per-step aggregation hook (PerStepGradAvg averages grads here —
        # the paper's distributed-GAN baseline communication pattern)
        gd, gg = strat.grad_hook(self, gd, gg, state)

        def upd_d(d, g, s):
            return self.opt_d.update(d, g, s, lr_a)

        def upd_g(p, g, s):
            return self.opt_g.update(p, g, s, lr_b)

        new_disc, new_opt_d = jax.vmap(jax.vmap(upd_d))(
            state["params"]["disc"], gd, state["opt_d"])
        new_gen, new_opt_g = jax.vmap(jax.vmap(upd_g))(
            state["params"]["gen"], gg, state["opt_g"])

        new_state = {
            **state,  # strategy-carried entries (e.g. EF residuals) ride along
            "params": {"gen": new_gen, "disc": new_disc},
            "opt_g": new_opt_g, "opt_d": new_opt_d,
            "step": n + 1,
        }
        return new_state, tmap(jnp.mean, metrics)

    # ------------------------------------------------------------------
    # one K-step round (the jitted unit; this is what the dry-run lowers)
    # ------------------------------------------------------------------
    def _run_round(self, state, xs, body):
        """Shared K-step scan + strategy sync.  ``xs`` leaves carry a
        leading K dim; ``body(state, x)`` is one parallel step."""
        strat = self.cfg.resolve_strategy()
        K = self.cfg.sync_interval
        K1 = strat.intra_interval

        if K1:
            segs = K // K1

            def seg_body(st, seg_in):
                st, m = jax.lax.scan(body, st, seg_in)
                return strat.segment_sync(self, st), m

            seg_in = tmap(lambda x: x.reshape((segs, K1) + x.shape[1:]), xs)
            state, metrics = jax.lax.scan(seg_body, state, seg_in)
            metrics = tmap(lambda x: x.reshape((K,) + x.shape[2:]), metrics)
        else:
            state, metrics = jax.lax.scan(body, state, xs)
        return strat.round_sync(self, state), metrics

    def round(self, state, batches, seeds):
        """batches: pytree with leading (K, P, A, ...); seeds: (K, P, A) —
        uint32 seeds (legacy) or a typed PRNG key array.  Runs K local
        steps then syncs per the configured strategy."""
        self.cfg.validate()
        return self._run_round(state, (batches, seeds), self._step)

    def _step_from_data(self, data, state, key):
        """One step whose minibatch is sampled *inside* the trace: draw a
        (P, A, batch, ...) batch from ``data`` and per-agent step keys."""
        P, A = self.cfg.agent_grid
        k_batch, k_step = jax.random.split(key)
        batch = data.sample_step(k_batch)
        rngs = jax.random.split(k_step, P * A).reshape(P, A)
        return self._step(state, (batch, rngs))

    def round_from_data(self, state, data, key):
        """Sampling-aware round: the K minibatches are drawn *inside* the
        jitted round from ``data`` (anything with ``sample_step(key) ->
        (P, A, batch, ...) pytree``, e.g. a device-resident
        ``repro.data.DeviceFederatedData``) instead of being materialized
        on host as a (K, P, A, batch, ...) tensor.  Eliminates the K× per
        round host->device transfer and the per-agent assembly loop; RNG
        is a properly threaded split key (no seed folding)."""
        self.cfg.validate()
        keys = jax.random.split(key, self.cfg.sync_interval)
        body = lambda st, k: self._step_from_data(data, st, k)
        return self._run_round(state, keys, body)

    # ------------------------------------------------------------------
    def agent_params(self, state, p: int = 0, a: int = 0):
        return tmap(lambda x: x[p, a], state["params"])

    def agent_opt_state(self, state, p: int = 0, a: int = 0):
        return {k: tmap(lambda x: x[p, a], state[k])
                for k in ("opt_g", "opt_d")}

    def averaged_params(self, state):
        """The intermediary's (w_n, theta_n) — weighted average, no broadcast."""
        w = self._w()
        return tmap(lambda x: jnp.einsum("pa,pa...->...", w.astype(x.dtype), x),
                    state["params"])

    def comm_bytes_per_round(self, state) -> dict:
        """§3.2 accounting.  The analytic comparison (FedGAN moves 2·2M per
        agent per ROUND, the distributed baseline 2·2M per STEP) plus the
        configured strategy's own wire-byte accounting."""
        strat = self.cfg.resolve_strategy()
        params = self.agent_params(state)
        M_bytes = collectives.tree_bytes(params)
        K = self.cfg.sync_interval
        per_round = {"fedgan": 2 * M_bytes, "distributed": 2 * M_bytes * K}
        codec = getattr(strat, "codec", None)
        return {"param_bytes_M": M_bytes, "per_agent_per_round": per_round,
                "ratio": K, "strategy": strat.name,
                "codec": codec.name if codec is not None else None,
                "strategy_bytes_per_round": strat.bytes_per_round(
                    self.cfg, params, opt=self.agent_opt_state(state))}
