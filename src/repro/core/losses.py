"""GAN loss zoo.

The paper's experiments use the original (non-saturating) GAN loss for the
toy/MLP nets, the ACGAN objective (binary + auxiliary classification) for
images, and a CGAN objective for time series.  We expose each as a pair of
pure loss functions

    d_loss(d_logits_real, d_logits_fake) -> scalar   (minimised by D)
    g_loss(d_logits_fake) -> scalar                  (minimised by G)

plus the ACGAN auxiliary terms.  All reductions are means, f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _f32(x):
    return x.astype(jnp.float32)


# -- non-saturating GAN (Goodfellow et al.) ---------------------------------

def ns_d_loss(real_logits, fake_logits):
    return (jnp.mean(jax.nn.softplus(-_f32(real_logits)))
            + jnp.mean(jax.nn.softplus(_f32(fake_logits))))


def ns_g_loss(fake_logits):
    return jnp.mean(jax.nn.softplus(-_f32(fake_logits)))


# -- minimax (the 2D toy analysis uses the raw zero-sum form) ----------------

def minimax_value(real_scores, fake_scores):
    """V(D, G) with sigmoid-free quadratic D (paper's 2D system uses
    f(x) = D(x) directly);  D ascends V, G descends V."""
    return jnp.mean(_f32(real_scores)) - jnp.mean(_f32(fake_scores))


# -- least squares GAN -------------------------------------------------------

def ls_d_loss(real_logits, fake_logits):
    return 0.5 * (jnp.mean((_f32(real_logits) - 1.0) ** 2)
                  + jnp.mean(_f32(fake_logits) ** 2))


def ls_g_loss(fake_logits):
    return 0.5 * jnp.mean((_f32(fake_logits) - 1.0) ** 2)


# -- hinge --------------------------------------------------------------------

def hinge_d_loss(real_logits, fake_logits):
    return (jnp.mean(jax.nn.relu(1.0 - _f32(real_logits)))
            + jnp.mean(jax.nn.relu(1.0 + _f32(fake_logits))))


def hinge_g_loss(fake_logits):
    return -jnp.mean(_f32(fake_logits))


# -- WGAN (+ gradient penalty helper) ----------------------------------------

def w_d_loss(real_logits, fake_logits):
    return jnp.mean(_f32(fake_logits)) - jnp.mean(_f32(real_logits))


def w_g_loss(fake_logits):
    return -jnp.mean(_f32(fake_logits))


def gradient_penalty(d_apply, d_params, real, fake, rng, weight=10.0):
    """WGAN-GP penalty on interpolates (used by the Swiss-roll experiment,
    following Gulrajani et al. [9])."""
    eps_shape = (real.shape[0],) + (1,) * (real.ndim - 1)
    eps = jax.random.uniform(rng, eps_shape)
    inter = eps * real + (1.0 - eps) * fake

    def scalar_d(x):
        return jnp.sum(d_apply(d_params, x))

    grads = jax.grad(scalar_d)(inter)
    gn = jnp.sqrt(jnp.sum(jnp.square(_f32(grads)),
                          axis=tuple(range(1, grads.ndim))) + 1e-12)
    return weight * jnp.mean((gn - 1.0) ** 2)


# -- ACGAN auxiliary classification -------------------------------------------

def aux_class_loss(cls_logits, labels):
    lp = jax.nn.log_softmax(_f32(cls_logits), axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=-1))


def acgan_d_loss(real_bin, fake_bin, real_cls, fake_cls, labels):
    """D maximises binary discrimination + classifies BOTH real and fake."""
    return (ns_d_loss(real_bin, fake_bin)
            + aux_class_loss(real_cls, labels)
            + aux_class_loss(fake_cls, labels))


def acgan_g_loss(fake_bin, fake_cls, labels):
    return ns_g_loss(fake_bin) + aux_class_loss(fake_cls, labels)
