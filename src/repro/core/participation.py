"""Per-round participation sampling — the single source of cohort draws.

Cross-device FL fleets are far larger than any per-round cohort: of
``A_total`` registered clients only ``m`` participate in round ``r``.  Two
consumers need the *same* draw:

  * the host-side scheduler (``repro.run.virtual``) needs the cohort as
    concrete client ids, to page their state into the device slots;
  * the traced sync path (``SubsampledFedAvg``) needs it as a (P, A) bool
    mask folded into the §3.1 averaging weights.

Before this module each path rolled its own RNG, so seeds could silently
diverge.  :class:`ParticipationSchedule` centralises the draw: both views
derive from one ``_scores`` stream keyed only by ``(seed, round_idx)`` —
stateless, so a resumed run replays the identical cohort sequence with no
RNG state in the checkpoint beyond the seed and the round counter.

Sampling is uniform without replacement by default; ``weights`` switches
to probability-proportional-to-weight sampling via Efraimidis–Spirakis
reservoir keys (top-m of ``log(u_i)/w_i``).

The async runtime (``repro.run.async_agg``) adds a third consumer: the
virtual-clock simulator needs per-dispatch *arrival-time* draws.  Those
come from :meth:`ParticipationSchedule.arrival_uniforms` — the same
``(seed, index)`` keying discipline, folded on a disjoint stream so
cohort membership and arrival latency never share randomness.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# fold constant separating the arrival-time stream from the cohort-score
# stream: cohort scores fold (seed, round); arrival draws fold
# (seed, dispatch, _ARRIVAL_FOLD + salt).  Any value >= 2**20 keeps the
# two uses of fold_in's second argument disjoint for realistic salts.
_ARRIVAL_FOLD = 1 << 20


@dataclasses.dataclass(frozen=True)
class ParticipationSchedule:
    """Seeded, resumable per-round cohort sampler.

    ``seed`` keys the whole schedule; ``weights`` (len ``A_total``, all
    positive) biases the draw toward larger-weight clients (Efraimidis–
    Spirakis A-Res — inclusion frequency grows with weight, exactly
    proportional in the m=1 case).  Hashable, so it can ride static jit
    arguments.
    """

    seed: int = 0
    weights: tuple | None = None

    def validate(self, n_total: int | None = None) -> None:
        if self.weights is not None:
            # static config check on a python tuple — nothing device-side
            w = np.asarray(self.weights, np.float64)  # analysis: allow(host-sync)
            if w.ndim != 1 or w.size == 0:
                raise ValueError(f"weights must be a flat non-empty tuple, "
                                 f"got shape {w.shape}")
            if not np.isfinite(w).all() or (w <= 0).any():
                raise ValueError("participation weights must be finite and "
                                 "strictly positive")
            if n_total is not None and w.size != n_total:
                raise ValueError(f"got {w.size} participation weights for "
                                 f"{n_total} clients")

    # ------------------------------------------------------------------
    def _scores(self, round_idx, n: int):
        """Per-client priority scores for a round; the ``m`` largest win.

        Shared by the host :meth:`cohort` and the traced :meth:`mask` so
        the two views can never diverge.  ``round_idx`` may be a tracer.
        """
        key = jax.random.fold_in(jax.random.key(self.seed), round_idx)
        u = jax.random.uniform(key, (n,))
        if self.weights is None:
            return u
        w = jnp.asarray(self.weights, jnp.float32)
        # Efraimidis–Spirakis keys: top-m of u^(1/w), in log space
        return jnp.log(u) / w

    def cohort(self, round_idx: int, n_total: int, m: int) -> np.ndarray:
        """The ``m`` participating client ids for ``round_idx``, sorted
        ascending.  ``m == n_total`` is the identity cohort (every client,
        in id order) — the full-participation fast path draws nothing."""
        self.validate(n_total)
        if not 1 <= m <= n_total:
            raise ValueError(f"cohort size m={m} must be in [1, {n_total}]")
        if m == n_total:
            return np.arange(n_total)
        # one-time host fetch per round *plan*, before any dispatch — the
        # scheduler needs concrete ids to page state
        scores = np.asarray(self._scores(int(round_idx), n_total))  # analysis: allow(host-sync)
        top = np.argpartition(scores, n_total - m)[n_total - m:]
        return np.sort(top)

    def arrival_uniforms(self, index: int, n: int, salt: int = 0) -> np.ndarray:
        """Per-client uniforms in [0, 1) for arrival-time sampling.

        ``index`` is the dispatch sequence number (the async server's
        monotone dispatch counter — each dispatch gets fresh draws);
        ``salt`` separates multiple draws per dispatch (jitter vs the
        straggler coin, retry attempts).  Pure function of
        ``(seed, index, salt)`` — the virtual-clock simulator's replay
        guarantee rests on exactly this statelessness.  Disjoint from the
        :meth:`cohort` score stream by the ``_ARRIVAL_FOLD`` offset."""
        key = jax.random.fold_in(jax.random.key(self.seed), int(index))
        key = jax.random.fold_in(key, _ARRIVAL_FOLD + int(salt))
        # host-side simulator planning, never inside a traced round
        return np.asarray(jax.random.uniform(key, (n,)))  # analysis: allow(host-sync)

    def mask(self, round_idx, grid: tuple[int, int], m: int):
        """(P, A) bool participation mask — the traced view of
        :meth:`cohort` for the dense all-agents-on-device layout (client
        id = flattened (p, a) index).  Same score stream, so
        ``mask(...).reshape(-1)[i] == (i in cohort(...))``."""
        P, A = grid
        n = P * A
        scores = self._scores(round_idx, n)
        kth = jnp.sort(scores)[-m]
        return (scores >= kth).reshape(P, A)
