"""Pluggable sync strategies — the open-world replacement for ``mode: str``.

The paper's intermediary (eq. (2)+(3)) is one point in a design space that
related work explores along three independent axes:

  * WHEN to sync — every K steps (FedGAN), every step (the distributed
    baseline), on a two-tier intra-pod/cross-pod schedule (hierarchical),
    or adaptively across rounds (sync often while agents drift fast, then
    back off — warmup-K);
  * WHAT to sync — the full (G, D) parameter set, only the generator
    subtree (PS-FedGAN, Wijesinghe et al. 2023 keep D local), optionally
    the Adam moments too;
  * HOW — dataset-size-weighted averaging over the agent grid, optionally
    cast to a wire dtype (compressed sync) or restricted to a per-round
    participation subsample (FedAvg client sampling);
  * how bytes are ENCODED — a ``repro.comm`` codec (block-scaled int8/int4
    quantization, magnitude top-k sparsification, chains of both) applied
    to both directions of the sync, with per-agent uplink and shared
    downlink error-feedback residuals carried in the round state so the
    lossy wire still converges (see docs/communication.md).

A :class:`SyncStrategy` owns all three plus its own §3.2 wire-byte
accounting (:meth:`SyncStrategy.bytes_per_round`).  Strategies compose with
``repro.dist.collectives``: every aggregation is a weighted einsum over the
leading (P, A) agent grid, so under jit on the production mesh each strategy
still lowers to the minimal all-reduce over the ("pod", "data") axes — a
gen-only strategy moves strictly fewer agent-axis bytes, visible in the HLO
audit (``repro.launch.hlo_analysis``).

Strategy hooks called from ``FedGAN.round`` / ``FedGAN._step``:

  ``validate(cfg)``              static config check (raise ValueError)
  ``init_round_state(fed, st)``  extra state entries the strategy carries
                                 across rounds (e.g. error-feedback
                                 residuals); merged by ``init_state``
  ``intra_interval``             int attr; nonzero splits the K-scan into
                                 segments of this length (must divide K)
  ``grad_hook(fed, gd, gg, st)`` per-step gradient transform (runs inside
                                 the scan body, before the optimizer)
  ``segment_sync(fed, st)``      after every ``intra_interval`` segment
  ``round_sync(fed, st)``        after the K-step scan
  ``bytes_per_round(cfg, params, opt=None)``
                                 per-agent send+receive wire bytes per
                                 round (ShapeDtypeStructs accepted)

``fed`` is the :class:`repro.core.fedgan.FedGAN` instance (gives access to
the normalised agent weights ``fed._w()`` and ``fed.cfg``); ``st`` is the
agent-stacked state dict.  All hooks must stay jit-traceable.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import collectives

tmap = jax.tree_util.tree_map

_OPT_KEY = {"gen": "opt_g", "disc": "opt_d"}


def _select(mask, new, old):
    """Per-agent select: mask (P, A) -> new where mask else old, leafwise."""
    return tmap(
        lambda a, x: jnp.where(mask.reshape(mask.shape + (1,) * (x.ndim - 2)),
                               a, x), new, old)


def _fedavg(fed, state, *, subtrees, average_opt_state, sync_dtype, mask=None,
            codec=None, error_feedback=True, reduce=None, secure_agg=None,
            fused=None):
    """The eq. (2)+(3) aggregation restricted to ``subtrees`` (and optionally
    a participation ``mask``): weighted average over (P, A), broadcast back.
    Non-participating agents keep their local values (including their
    error-feedback residuals — they never hit the wire this round).

    With ``codec`` the sync runs through ``collectives.coded_sync``: both
    wire directions move the compressed representation, and when
    ``error_feedback`` the per-agent uplink residuals (``state["ef"]``) and
    the shared downlink residual (``state["ef_down"]``) are updated in
    place of being discarded.

    ``reduce`` swaps the weighted-mean einsum for a pluggable per-leaf
    aggregate (``collectives.make_robust_reduce``) on both the plain and
    coded paths.  ``secure_agg`` routes the plain path through
    ``collectives.masked_sync`` — each subtree gets its own fold of the
    per-round mask key (``salt``) so no leaf pad is ever reused."""
    w = fed._w()
    if mask is not None:
        w = w * mask
        w = w / jnp.sum(w)

    def avg(tree, salt=0):
        if secure_agg is not None:
            k = jax.random.fold_in(secure_agg.round_key(state["step"]), salt)
            out = collectives.masked_sync(tree, w, k, reduce=reduce)
        else:
            out = collectives.average_agents(tree, w, sync_dtype=sync_dtype,
                                             reduce=reduce)
        return out if mask is None else _select(mask, out, tree)

    new = dict(state)
    params = dict(state["params"])
    if codec is None:
        for i, k in enumerate(subtrees):
            params[k] = avg(state["params"][k], salt=i)
    else:
        use_ef = error_feedback and "ef" in state
        ef = dict(state["ef"]) if use_ef else None
        ef_down = dict(state["ef_down"]) if use_ef else None
        for k in subtrees:
            synced, e2, ed2 = collectives.coded_sync(
                state["params"][k], w, codec,
                ef=ef[k] if use_ef else None,
                ef_down=ef_down[k] if use_ef else None, reduce=reduce,
                fused=fused)
            if mask is not None:
                synced = _select(mask, synced, state["params"][k])
                if use_ef:
                    e2 = _select(mask, e2, ef[k])
            params[k] = synced
            if use_ef:
                ef[k], ef_down[k] = e2, ed2
        if use_ef:
            new["ef"], new["ef_down"] = ef, ef_down
    new["params"] = params
    if average_opt_state:
        for i, k in enumerate(subtrees):
            if codec is None:
                new[_OPT_KEY[k]] = avg(state[_OPT_KEY[k]],
                                       salt=i + len(subtrees))
            else:
                # optimizer moments ride the coded wire too, but without
                # residuals — the moments are re-estimated every step anyway
                synced, _, _ = collectives.coded_sync(state[_OPT_KEY[k]], w,
                                                      codec, reduce=reduce,
                                                      fused=fused)
                new[_OPT_KEY[k]] = (synced if mask is None else
                                    _select(mask, synced, state[_OPT_KEY[k]]))
    return new


class SyncStrategy:
    """Base protocol; the defaults are the never-sync ablation."""

    name = "local_only"
    intra_interval = 0

    def validate(self, cfg):
        pass

    def init_round_state(self, fed, state) -> dict:
        """Extra entries the strategy carries in the round state (merged by
        ``FedGAN.init_state``); base strategies carry nothing."""
        return {}

    def state_axes(self) -> dict:
        """Per-entry paging axis for everything :meth:`init_round_state`
        carries: ``"client"`` (agent-stacked — one row per client, paged
        host<->device with the cohort by ``repro.run.virtual.ClientStore``)
        or ``"shared"`` (one fleet-wide copy that stays on device).  A
        strategy that carries state without declaring it here cannot run
        under the virtual-client scheduler — the store refuses to guess."""
        return {}

    def grad_hook(self, fed, grad_disc, grad_gen, state):
        return grad_disc, grad_gen

    def segment_sync(self, fed, state):
        return state

    def round_sync(self, fed, state):
        return state

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        return 0


@dataclasses.dataclass(frozen=True)
class LocalOnly(SyncStrategy):
    """Never sync (ablation lower bound)."""


@dataclasses.dataclass(frozen=True)
class FedAvgSync(SyncStrategy):
    """The paper's Algorithm 1 intermediary: K local steps, then a
    dataset-size-weighted parameter average of ``subtrees``.

    ``sync_dtype`` casts leaves to a wire dtype for the average (compressed
    sync); ``average_opt_state`` additionally FedAvgs the optimizer moments
    of the synced subtrees.

    ``codec`` (a ``repro.comm.Codec``) replaces the dtype cast with a real
    wire encoding — quantized and/or sparsified payloads in both sync
    directions.  Lossy codecs converge through ``error_feedback``: each
    agent carries an uplink residual (``state["ef"]``, per-agent) and the
    intermediary a downlink residual (``state["ef_down"]``, shared), both
    added back before the next encode so quantization error accumulates
    into the stream instead of being lost.  ``codec`` and ``sync_dtype``
    are mutually exclusive (no double compression — chain codecs with
    ``repro.comm.Sequential`` instead).

    ``fused_sync`` picks the execution path of the coded sync (values on
    the wire are identical either way): ``None`` (default) lets
    ``collectives.coded_sync`` auto-fuse float32 leaves through the
    one-pass bucketed ``kernels/qsync`` kernels whenever the codec supports
    it; ``False`` forces the composed per-leaf pipeline; ``True`` requires
    the fused path (raises at validate time when the codec or a robust
    reduce cannot ride it).

    ``secure_agg`` (a ``repro.privacy.SecureAgg``) routes the sync through
    ``collectives.masked_sync``: pairwise one-time-pad masking of the wire
    image with the §3.1 weight folded in agent-side (weight-then-mask — a
    sum-only server cannot weight per agent), bit-identical result.  It
    refuses to stack with anything that
    would need per-agent server-side decoding (``codec``, ``sync_dtype``)
    or per-agent visibility (subsampling, robust reduces) — see
    docs/privacy.md for the full matrix.
    """

    sync_dtype: Any = None
    average_opt_state: bool = False
    subtrees: tuple = ("gen", "disc")
    codec: Any = None
    error_feedback: bool = True
    secure_agg: Any = None
    fused_sync: Any = None
    name = "fedgan"

    def validate(self, cfg):
        bad = [k for k in self.subtrees if k not in _OPT_KEY]
        if bad or not self.subtrees:
            raise ValueError(f"subtrees must be a non-empty subset of "
                             f"{tuple(_OPT_KEY)}, got {self.subtrees}")
        if self.codec is not None:
            self.codec.validate()
            if self.sync_dtype is not None:
                raise ValueError(
                    "codec= and sync_dtype= are both wire compressions; "
                    "pick one (chain codecs with repro.comm.Sequential "
                    "instead of stacking a dtype cast on top)")
        if self.fused_sync:
            if self.codec is None:
                raise ValueError(
                    "fused_sync=True needs a codec= — the fused path IS the "
                    "coded sync; the plain average has nothing to fuse")
            if self.codec.fused_sync_spec() is None:
                raise ValueError(
                    f"fused_sync=True needs a codec with a fused_sync_spec; "
                    f"{self.codec.name!r} reshapes the payload and can only "
                    "run the composed per-leaf pipeline")
            if self.sync_reduce() is not None:
                raise ValueError(
                    "fused_sync=True cannot apply a robust reduce: the "
                    "fused kernel hard-wires the weighted mean — drop "
                    "fused_sync or fall back to the composed pipeline")
        if self.secure_agg is not None:
            self.secure_agg.validate()
            if self.codec is not None:
                raise ValueError(
                    "secure_agg= cannot ride a codec= wire: decoding a "
                    "lossy payload happens per agent at the server, which "
                    "reveals exactly the individual updates the masking "
                    "hides; pick one")
            if self.sync_dtype is not None:
                raise ValueError(
                    "secure_agg= pads the 32-bit wire image; sync_dtype= "
                    "re-encodes it per agent and breaks the pad "
                    "cancellation; pick one")

    def init_round_state(self, fed, state) -> dict:
        if self.codec is None or not self.error_feedback:
            return {}
        zeros = lambda t: tmap(jnp.zeros_like, t)
        return {
            # per-agent uplink residuals, agent-stacked like the params
            "ef": {k: zeros(state["params"][k]) for k in self.subtrees},
            # the intermediary's downlink residual — one shared copy
            "ef_down": {k: tmap(lambda x: jnp.zeros(x.shape[2:], x.dtype),
                                state["params"][k]) for k in self.subtrees},
        }

    def state_axes(self) -> dict:
        if self.codec is None or not self.error_feedback:
            return {}
        # uplink residuals are per-agent (they follow the client between
        # rounds); the intermediary's downlink residual is fleet-shared
        return {"ef": "client", "ef_down": "shared"}

    def participation_mask(self, fed, state):
        """(P, A) bool mask of agents taking part in this round's sync, or
        None for all.  Evaluated at round end (state['step'] = (r+1)*K)."""
        return None

    def sync_reduce(self):
        """The pluggable per-leaf aggregate, or None for the weighted-mean
        einsum.  Robust strategies override this."""
        return None

    def round_sync(self, fed, state):
        return _fedavg(fed, state, subtrees=self.subtrees,
                       average_opt_state=self.average_opt_state,
                       sync_dtype=self.sync_dtype, codec=self.codec,
                       error_feedback=self.error_feedback,
                       mask=self.participation_mask(fed, state),
                       reduce=self.sync_reduce(),
                       secure_agg=self.secure_agg,
                       fused=self.fused_sync)

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        wire = sum(collectives.sync_bytes(params[k],
                                          sync_dtype=self.sync_dtype,
                                          codec=self.codec)
                   for k in self.subtrees)
        if self.average_opt_state and opt is not None:
            wire += sum(collectives.sync_bytes(opt[_OPT_KEY[k]],
                                               sync_dtype=self.sync_dtype,
                                               codec=self.codec)
                        for k in self.subtrees if _OPT_KEY[k] in opt)
        return 2 * wire  # send + receive, once per round


@dataclasses.dataclass(frozen=True)
class PartialSharing(FedAvgSync):
    """PS-FedGAN-style generator-only sharing (Wijesinghe et al. 2023):
    the intermediary averages the ``gen`` subtree; every discriminator
    stays local, adapted to its agent's data.  Halves the wire bytes when
    G and D are the same size, and removes D from the agent-axis
    all-reduce entirely."""

    subtrees: tuple = ("gen",)
    name = "partial_sharing"


# warn-once latch for the mask_seed deprecation shim (reset by tests)
_MASK_SEED_WARNED = False


@dataclasses.dataclass(frozen=True)
class SubsampledFedAvg(FedAvgSync):
    """Partial participation: each round, ``ceil(fraction * B)`` agents are
    drawn (deterministically from the round index) and the participation
    mask is folded into the weights — participants average among
    themselves and receive the result, the rest keep their local state.

    The draw comes from a ``repro.core.participation.ParticipationSchedule``
    (``schedule=``) — the same sampler the virtual-client runtime uses to
    pick which clients are paged onto the device, so the two paths share
    one seed stream by construction.  The old ``mask_seed=`` knob is a
    deprecated alias for ``schedule=ParticipationSchedule(seed=...)``."""

    fraction: float = 0.5
    mask_seed: Any = None       # deprecated — use schedule=
    schedule: Any = None        # ParticipationSchedule; None -> seed 0
    name = "subsampled"

    def __post_init__(self):
        global _MASK_SEED_WARNED
        if self.mask_seed is not None and not _MASK_SEED_WARNED:
            # warn once per process: sweep configs construct hundreds of
            # strategy instances and a per-instance warning drowns the log
            _MASK_SEED_WARNED = True
            warnings.warn(
                "SubsampledFedAvg(mask_seed=...) is deprecated: the "
                "participation draw is owned by repro.core.participation."
                "ParticipationSchedule so the traced mask and the "
                "virtual-client scheduler cannot diverge — pass "
                "schedule=ParticipationSchedule(seed=...) instead",
                DeprecationWarning, stacklevel=3)

    def validate(self, cfg):
        super().validate(cfg)
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got {self.fraction}")
        if self.mask_seed is not None and self.schedule is not None:
            raise ValueError(
                "mask_seed= is the deprecated spelling of schedule="
                "ParticipationSchedule(seed=...); passing both would leave "
                "two competing seed streams — drop mask_seed")
        self.resolve_schedule().validate(cfg.num_agents)
        if self.secure_agg is not None:
            raise ValueError(
                "secure_agg= needs every pair's both mask halves on the "
                "wire; per-round dropouts (subsampled participation) break "
                "the cancellation — real SecAgg recovers dropped seeds via "
                "a protocol this simulation does not model")

    def resolve_schedule(self):
        """The single sampling source for this strategy's cohort draws."""
        from repro.core.participation import ParticipationSchedule
        if self.schedule is not None:
            return self.schedule
        return ParticipationSchedule(
            seed=0 if self.mask_seed is None else int(self.mask_seed))

    def num_participants(self, cfg) -> int:
        return max(1, int(round(self.fraction * cfg.num_agents)))

    def participation_mask(self, fed, state):
        P, A = fed.cfg.agent_grid
        m = self.num_participants(fed.cfg)
        if m == P * A:
            return None
        r_idx = state["step"] // fed.cfg.sync_interval - 1
        return self.resolve_schedule().mask(r_idx, (P, A), m)

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        # fleet-average per agent: only m of B agents hit the wire per round
        full = super().bytes_per_round(cfg, params, opt)
        return full * self.num_participants(cfg) // cfg.num_agents


@dataclasses.dataclass(frozen=True)
class AdaptiveK(FedAvgSync):
    """Warmup-K: sync every round for the first ``warmup_rounds`` rounds
    (agents drift fastest early), then only every ``sync_every`` rounds —
    an effective interval of K·sync_every at steady state."""

    warmup_rounds: int = 4
    sync_every: int = 2
    name = "adaptive_k"

    def validate(self, cfg):
        super().validate(cfg)
        if self.warmup_rounds < 0 or self.sync_every < 1:
            raise ValueError("need warmup_rounds >= 0 and sync_every >= 1")

    def round_sync(self, fed, state):
        r = state["step"] // fed.cfg.sync_interval - 1
        do = jnp.logical_or(
            r < self.warmup_rounds,
            (r - self.warmup_rounds + 1) % self.sync_every == 0)
        return jax.lax.cond(do,
                            lambda s: FedAvgSync.round_sync(self, fed, s),
                            lambda s: s, state)

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        # steady-state amortised (post-warmup) cost
        return super().bytes_per_round(cfg, params, opt) // self.sync_every


@dataclasses.dataclass(frozen=True)
class PerStepGradAvg(SyncStrategy):
    """The paper's distributed-GAN baseline: gradient all-reduce every
    step (MD-GAN / FedAvg-GAN-style per-step communication)."""

    sync_dtype: Any = None
    name = "distributed"

    def grad_hook(self, fed, grad_disc, grad_gen, state):
        w = fed._w()
        return (collectives.average_agents(grad_disc, w,
                                           sync_dtype=self.sync_dtype),
                collectives.average_agents(grad_gen, w,
                                           sync_dtype=self.sync_dtype))

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        wire = collectives.sync_bytes(params, sync_dtype=self.sync_dtype)
        return 2 * wire * cfg.sync_interval


@dataclasses.dataclass(frozen=True)
class Hierarchical(FedAvgSync):
    """Two-tier sync for multi-pod meshes: weighted intra-pod average every
    ``intra_interval`` steps (fast ICI), full cross-pod average every K
    (slower DCI)."""

    intra_interval: int = 0
    name = "hierarchical"

    def validate(self, cfg):
        super().validate(cfg)
        if not self.intra_interval or cfg.sync_interval % self.intra_interval:
            raise ValueError("hierarchical sync needs intra_interval | "
                             "sync_interval (got "
                             f"{self.intra_interval} vs {cfg.sync_interval})")

    def segment_sync(self, fed, state):
        new = dict(state)
        new["params"] = collectives.average_intra_pod(state["params"],
                                                      fed._w())
        return new

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        full = FedAvgSync.bytes_per_round(self, cfg, params, opt)
        n_segs = cfg.sync_interval // self.intra_interval
        # segment_sync moves the WHOLE params tree at storage dtype (no
        # sync_dtype cast, no opt state) on the cheap intra-pod links;
        # the cross-pod round sync gets the FedAvgSync treatment
        intra = 2 * collectives.sync_bytes(params)
        return full + n_segs * intra


_ROBUST_SECURE_ERR = (
    "robust aggregation needs the individual per-agent values a secure "
    "sum hides (order statistics cannot run on a masked total); drop "
    "secure_agg or fall back to strategy='fedgan'")


@dataclasses.dataclass(frozen=True)
class TrimmedMeanSync(FedAvgSync):
    """Byzantine-robust FedAvg: per coordinate, drop the ``trim`` smallest
    and largest of the B agent values and average the rest.  Any f <= trim
    arbitrarily-corrupted agents (sign-flipped, x100-scaled, NaN-emitting)
    cannot move the aggregate outside the honest agents' range.  The §3.1
    dataset-size weights are deliberately ignored (weight-oblivious — a
    poisoned agent could otherwise buy influence via a claimed dataset
    size)."""

    trim: int = 1
    name = "trimmed_mean"

    def validate(self, cfg):
        super().validate(cfg)
        if self.trim < 1:
            raise ValueError(f"trim must be >= 1, got {self.trim}")
        if cfg.num_agents <= 2 * self.trim:
            raise ValueError(
                f"trimmed_mean needs num_agents > 2*trim = {2 * self.trim}, "
                f"got {cfg.num_agents} — no honest values would survive")
        if self.secure_agg is not None:
            raise ValueError(_ROBUST_SECURE_ERR)

    def sync_reduce(self):
        return collectives.make_robust_reduce("trimmed_mean", trim=self.trim)


@dataclasses.dataclass(frozen=True)
class CoordinateMedianSync(FedAvgSync):
    """Byzantine-robust FedAvg via the per-coordinate (lower) median:
    breakdown point f < B/2 — the strongest of the robust reduces, at the
    cost of discarding all magnitude information.  Weight-oblivious, like
    :class:`TrimmedMeanSync`."""

    name = "median"

    def validate(self, cfg):
        super().validate(cfg)
        if self.secure_agg is not None:
            raise ValueError(_ROBUST_SECURE_ERR)

    def sync_reduce(self):
        return collectives.make_robust_reduce("median")


def check_async_mergeable(strategy) -> None:
    """Refuse strategies whose sync cannot ride the async buffered merge.

    ``repro.run.async_agg`` applies staleness-weighted parameter *deltas*
    (``theta_post - theta_dispatch``) as they arrive, so the server never
    sees a synchronous cohort; anything whose aggregation is not a plain
    weighted mean of the declared subtrees must refuse loudly here rather
    than merge wrongly.  Each incoherent knob raises separately so the
    ``repro.analysis`` refusal-matrix rule maps one docs row per guard
    (docs/scaling.md has the async rows, docs/privacy.md the sync ones).
    """
    if isinstance(strategy, SubsampledFedAvg):
        raise ValueError(
            "subsampled participation draws its own per-round mask inside "
            "the traced sync; under asynchronous buffering the server "
            "already decides who contributes to each flush — drop "
            "SubsampledFedAvg and pass the schedule to the async driver")
    if getattr(strategy, "sync_reduce", None) is not None \
            and strategy.sync_reduce() is not None:
        raise ValueError(
            "a robust reduce is an order statistic over one synchronous "
            "cohort's values; an asynchronous buffer mixes deltas taken "
            "against different server versions, which voids the breakdown "
            "bound — run strategy='fedgan' or the per-round driver")
    if getattr(strategy, "secure_agg", None) is not None:
        raise ValueError(
            "secure_agg= pairwise masks only cancel when every cohort "
            "member's update is summed in one shot; an asynchronous "
            "buffer flushes partial sums, leaving pads uncancelled — "
            "drop secure_agg or use the per-round driver")
    if getattr(strategy, "codec", None) is not None:
        raise ValueError(
            "codec= residual feedback assumes every agent decodes the "
            "same aggregate each round; an asynchronous flush would "
            "replay stale payloads against a moved server — drop the "
            "codec for async runs")
    if getattr(strategy, "sync_dtype", None) is not None:
        raise ValueError(
            "sync_dtype= casts the wire image of a synchronous average; "
            "the asynchronous buffered merge applies host-side deltas and "
            "has no wire cast point — drop sync_dtype for async runs")
    if getattr(strategy, "average_opt_state", False):
        raise ValueError(
            "average_opt_state= needs one agent-stacked moment tensor to "
            "average; under asynchronous buffering each client's moments "
            "stay local between its own dispatches — drop it")
    if type(strategy) not in (FedAvgSync, PartialSharing):
        raise ValueError(
            f"asynchronous buffered aggregation supports plain FedAvgSync/"
            f"PartialSharing only; {strategy.name!r} schedules or "
            f"transforms its aggregation in ways a delta buffer cannot "
            f"replay — use the per-round driver for it")


# ---------------------------------------------------------------------------
# Registry + legacy-mode shim
# ---------------------------------------------------------------------------

STRATEGIES = {
    "fedgan": FedAvgSync,
    "distributed": PerStepGradAvg,
    "local_only": LocalOnly,
    "hierarchical": Hierarchical,
    "partial_sharing": PartialSharing,
    "ps_fedgan": PartialSharing,
    "subsampled": SubsampledFedAvg,
    "adaptive_k": AdaptiveK,
    "trimmed_mean": TrimmedMeanSync,
    "median": CoordinateMedianSync,
}


def get_strategy(name: str, **kwargs) -> SyncStrategy:
    """Instantiate a registered strategy by name (the CLI entry point)."""
    try:
        cls = STRATEGIES[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"known: {sorted(STRATEGIES)}") from None
    return cls(**kwargs)


def strategy_from_mode(mode: str, *, intra_interval: int = 0,
                       sync_dtype=None,
                       average_opt_state: bool = False) -> SyncStrategy:
    """Resolve a legacy ``FedGANConfig.mode`` string (+ its companion config
    fields) to the equivalent strategy.  Bit-identical to the pre-strategy
    hard-coded paths."""
    if mode == "fedgan":
        return FedAvgSync(sync_dtype=sync_dtype,
                          average_opt_state=average_opt_state)
    if mode == "distributed":
        return PerStepGradAvg(sync_dtype=sync_dtype)
    if mode == "local_only":
        return LocalOnly()
    if mode == "hierarchical":
        return Hierarchical(intra_interval=intra_interval,
                            sync_dtype=sync_dtype,
                            average_opt_state=average_opt_state)
    raise ValueError(f"unknown mode {mode!r}")
