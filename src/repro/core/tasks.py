"""Declarative GANTask builder: (G, D, LossSpec) -> GANTask.

Every paper experiment pairs a generator and discriminator with one of two
adversarial objectives, differing only in which batch fields feed each
network.  ``make_gan_task`` captures that whole family, replacing the
per-experiment copy-pasted init/disc_loss/gen_loss closures:

  * toy2d / MLP GANs      — make_gan_task(G, D)                       (NS)
  * conditional 1D GAN    — make_gan_task(G, D, CONDITIONAL)          (NS,
                            G and D both see the label)
  * ACGAN images          — make_gan_task(G, D, ACGAN)                (D
                            returns (real/fake, class logits))

Batch protocol: ``x`` real data, ``z`` latent noise, ``y`` labels (only for
conditional specs).  Losses stop-gradient the other player (simultaneous
updates, eq. (1)).
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import losses
from repro.core.fedgan import GANTask


@dataclasses.dataclass(frozen=True)
class LossSpec:
    kind: str = "ns"         # "ns" (non-saturating GAN) | "acgan"
    cond_gen: bool = False   # G.apply(params, z, y) instead of (params, z)
    cond_disc: bool = False  # D.apply(params, x, y) instead of (params, x)


NS = LossSpec()
CONDITIONAL = LossSpec(cond_gen=True, cond_disc=True)
ACGAN = LossSpec(kind="acgan", cond_gen=True)


def make_gan_task(G, D, spec: LossSpec = NS) -> GANTask:
    """Build the GANTask for a (G, D) pair under ``spec``."""
    if spec.kind not in ("ns", "acgan"):
        raise ValueError(f"unknown loss kind {spec.kind!r}")

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def fake_of(params, batch):
        args = (batch["z"], batch["y"]) if spec.cond_gen else (batch["z"],)
        return G.apply(params["gen"], *args)

    def d_of(params, x, batch):
        args = (x, batch["y"]) if spec.cond_disc else (x,)
        return D.apply(params["disc"], *args)

    if spec.kind == "ns":
        def disc_loss(params, batch, rng):
            fake = jax.lax.stop_gradient(fake_of(params, batch))
            return losses.ns_d_loss(d_of(params, batch["x"], batch),
                                    d_of(params, fake, batch))

        def gen_loss(params, batch, rng):
            return losses.ns_g_loss(d_of(params, fake_of(params, batch), batch))
    else:  # acgan: D returns (real/fake logit, class logits)
        def disc_loss(params, batch, rng):
            fake = jax.lax.stop_gradient(fake_of(params, batch))
            rb, rc = D.apply(params["disc"], batch["x"])
            fb, fc = D.apply(params["disc"], fake)
            return losses.acgan_d_loss(rb, fb, rc, fc, batch["y"])

        def gen_loss(params, batch, rng):
            fb, fc = D.apply(params["disc"], fake_of(params, batch))
            return losses.acgan_g_loss(fb, fc, batch["y"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)
