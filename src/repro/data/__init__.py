from repro.data import synthetic
from repro.data.federated import (
    DeviceFederatedData,
    FederatedData,
    FederatedRounds,
    FleetRounds,
    StreamingFederatedData,
    dirichlet_partition,
    label_shard_partition,
    partition_sizes,
    round_key_schedule,
)

__all__ = [
    "DeviceFederatedData", "FederatedData", "FederatedRounds", "FleetRounds",
    "StreamingFederatedData", "dirichlet_partition", "label_shard_partition",
    "partition_sizes", "round_key_schedule", "synthetic",
]
