from repro.data import synthetic
from repro.data.federated import (
    FederatedRounds,
    dirichlet_partition,
    label_shard_partition,
    partition_sizes,
)

__all__ = [
    "FederatedRounds", "dirichlet_partition", "label_shard_partition",
    "partition_sizes", "synthetic",
]
