"""Non-iid federated partitioners + the per-round batch pipeline.

The paper's splits: MNIST/CIFAR — B=5 agents x 2 classes each; CelebA — 16
attribute classes over 5 agents; PG&E/EV — by climate zone / station
category.  We provide label-sharding (the paper's scheme) and a Dirichlet
partitioner (standard federated-learning benchmark knob) plus a loader that
assembles the (K, P, A, batch, ...) round inputs FedGAN.round consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

tmap = jax.tree_util.tree_map


def label_shard_partition(labels, num_agents: int, *, classes_per_agent=None,
                          seed: int = 0):
    """Paper-style split: sort classes, deal ``classes_per_agent`` to each
    agent (classes may be divided across two agents to balance sizes).
    Returns a list of index arrays."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    rng = np.random.RandomState(seed)
    order = rng.permutation(classes)
    buckets = np.array_split(order, num_agents)
    out = []
    for b in buckets:
        idx = np.nonzero(np.isin(labels, b))[0]
        rng.shuffle(idx)
        out.append(jnp.asarray(idx))
    return out


def dirichlet_partition(labels, num_agents: int, *, alpha: float = 0.3,
                        seed: int = 0):
    """Dirichlet(alpha) class-mixture split (Hsu et al. style)."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    rng = np.random.RandomState(seed)
    agent_idx = [[] for _ in range(num_agents)]
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_agents)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for a, part in enumerate(np.split(idx, cuts)):
            agent_idx[a].extend(part.tolist())
    return [jnp.asarray(sorted(a)) for a in agent_idx]


def partition_sizes(parts) -> jnp.ndarray:
    return jnp.asarray([p.shape[0] for p in parts], jnp.float32)


@dataclasses.dataclass
class FederatedRounds:
    """Assembles FedGAN round inputs from per-agent datasets.

    agent_data: list (len B = P*A) of batch pytrees (full local data).
    sample_extra: optional fn(rng, batch_size) -> pytree merged into each
    minibatch (e.g. latent z draws).
    """

    agent_data: Sequence[Any]
    agent_grid: tuple[int, int]
    batch_size: int
    sync_interval: int
    sample_extra: Callable | None = None

    def __post_init__(self):
        P, A = self.agent_grid
        if P * A != len(self.agent_data):
            raise ValueError(f"agent_grid {self.agent_grid} != {len(self.agent_data)} datasets")

    def round_batches(self, rng):
        """Returns (batches, seeds): pytree with leading (K, P, A, batch)."""
        P, A = self.agent_grid
        K = self.sync_interval
        r_idx, r_extra, r_seed = jax.random.split(rng, 3)
        per_agent = []
        for i, data in enumerate(self.agent_data):
            n = jax.tree_util.tree_leaves(data)[0].shape[0]
            idx = jax.random.randint(jax.random.fold_in(r_idx, i),
                                     (K, self.batch_size), 0, n)
            mb = tmap(lambda x: x[idx], data)            # (K, batch, ...)
            if self.sample_extra is not None:
                extra = self.sample_extra(jax.random.fold_in(r_extra, i),
                                          (K, self.batch_size))
                mb = {**mb, **extra}
            per_agent.append(mb)
        stacked = tmap(lambda *xs: jnp.stack(xs, axis=1), *per_agent)
        batches = tmap(
            lambda x: x.reshape((K, P, A) + x.shape[2:]), stacked)
        seeds = jax.random.randint(r_seed, (K, P, A), 0, 2 ** 31 - 1).astype(jnp.uint32)
        return batches, seeds
