"""Non-iid federated partitioners + the per-round data pipelines.

The paper's splits: MNIST/CIFAR — B=5 agents x 2 classes each; CelebA — 16
attribute classes over 5 agents; PG&E/EV — by climate zone / station
category.  We provide label-sharding (the paper's scheme) and a Dirichlet
partitioner (standard federated-learning benchmark knob) plus the round
input pipelines (the :class:`FederatedData` protocol):

  * :class:`DeviceFederatedData` — every agent's full shard lives on
    device, stacked under the (P, A) agent grid; the K minibatches of a
    round are gathered *inside* the jitted round (`FedGAN.round_from_data`)
    from a threaded PRNG key.  No per-round host assembly, no K× transfer.
  * :class:`StreamingFederatedData` — for datasets too large for device
    memory: host-assembled (K, P, A, batch, ...) round tensors, double
    buffered with async ``jax.device_put`` so round r+1 uploads while
    round r computes.
  * :class:`FederatedRounds` — the legacy blocking assembler both of the
    above build on (kept as the bit-parity reference).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

tmap = jax.tree_util.tree_map


def label_shard_partition(labels, num_agents: int, *, classes_per_agent=None,
                          seed: int = 0):
    """Paper-style split: sort classes, deal ``classes_per_agent`` to each
    agent (classes may be divided across two agents to balance sizes).
    Returns a list of index arrays."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    rng = np.random.RandomState(seed)
    order = rng.permutation(classes)
    buckets = np.array_split(order, num_agents)
    out = []
    for b in buckets:
        idx = np.nonzero(np.isin(labels, b))[0]
        rng.shuffle(idx)
        out.append(jnp.asarray(idx))
    return out


def dirichlet_partition(labels, num_agents: int, *, alpha: float = 0.3,
                        seed: int = 0):
    """Dirichlet(alpha) class-mixture split (Hsu et al. style)."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    rng = np.random.RandomState(seed)
    agent_idx = [[] for _ in range(num_agents)]
    for c in classes:
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * num_agents)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for a, part in enumerate(np.split(idx, cuts)):
            agent_idx[a].extend(part.tolist())
    return [jnp.asarray(sorted(a)) for a in agent_idx]


def partition_sizes(parts) -> jnp.ndarray:
    return jnp.asarray([p.shape[0] for p in parts], jnp.float32)


@dataclasses.dataclass
class FederatedRounds:
    """Assembles FedGAN round inputs from per-agent datasets.

    agent_data: list (len B = P*A) of batch pytrees (full local data).
    sample_extra: optional fn(rng, batch_size) -> pytree merged into each
    minibatch (e.g. latent z draws).
    """

    agent_data: Sequence[Any]
    agent_grid: tuple[int, int]
    batch_size: int
    sync_interval: int
    sample_extra: Callable | None = None

    def __post_init__(self):
        P, A = self.agent_grid
        if P * A != len(self.agent_data):
            raise ValueError(f"agent_grid {self.agent_grid} != {len(self.agent_data)} datasets")

    def round_batches(self, rng):
        """Returns (batches, seeds): pytree with leading (K, P, A, batch)."""
        return _assemble_round(self.agent_data, range(len(self.agent_data)),
                               self.agent_grid, self.batch_size,
                               self.sync_interval, self.sample_extra, rng)


def _assemble_round(agent_data, salts, slot_grid, batch_size, sync_interval,
                    sample_extra, rng):
    """The one host-side round assembler.  Per agent, index/extra draws are
    folded with that agent's ``salt``; seeds come from the slot grid.  Both
    :class:`FederatedRounds` (salt = position, the legacy bit-parity
    contract) and :class:`FleetRounds` (salt = global client id, so a
    client's data stream is independent of which slot it lands in) call
    this, which is what makes identity-cohort parity hold by construction
    rather than by test alone."""
    P, A = slot_grid
    K = sync_interval
    r_idx, r_extra, r_seed = jax.random.split(rng, 3)
    per_agent = []
    for data, salt in zip(agent_data, salts):
        n = jax.tree_util.tree_leaves(data)[0].shape[0]
        idx = jax.random.randint(jax.random.fold_in(r_idx, salt),
                                 (K, batch_size), 0, n)
        mb = tmap(lambda x: x[idx], data)            # (K, batch, ...)
        if sample_extra is not None:
            extra = sample_extra(jax.random.fold_in(r_extra, salt),
                                 (K, batch_size))
            mb = {**mb, **extra}
        per_agent.append(mb)
    stacked = tmap(lambda *xs: jnp.stack(xs, axis=1), *per_agent)
    batches = tmap(
        lambda x: x.reshape((K, P, A) + x.shape[2:]), stacked)
    seeds = jax.random.randint(r_seed, (K, P, A), 0, 2 ** 31 - 1).astype(jnp.uint32)
    return batches, seeds


@dataclasses.dataclass
class FleetRounds:
    """Round assembler for a fleet larger than the device: ``agent_data``
    holds every registered client's local dataset (len ``A_total``), but
    each round only the sampled cohort — ``P * A_active`` clients — is
    assembled into the dense ``(K, P, A_active, batch, ...)`` slot tensor.

    Draws are salted with the *global* client id, not the slot position,
    so (a) a client sees the same data stream no matter which slot it is
    paged into, and (b) with the identity cohort this is bit-identical to
    :class:`FederatedRounds` over the same ``agent_data``.
    """

    agent_data: Sequence[Any]          # len A_total
    slot_grid: tuple[int, int]         # (P, A_active)
    batch_size: int
    sync_interval: int
    sample_extra: Callable | None = None

    @property
    def num_clients(self) -> int:
        return len(self.agent_data)

    @property
    def cohort_size(self) -> int:
        return self.slot_grid[0] * self.slot_grid[1]

    def __post_init__(self):
        if self.num_clients < self.cohort_size:
            raise ValueError(
                f"fleet of {self.num_clients} clients cannot fill "
                f"{self.cohort_size} device slots {self.slot_grid}")

    def client_sizes(self) -> np.ndarray:
        """Per-client dataset sizes |R_i| (len A_total) — the §3.1 weight
        numerators for dataset-size weighting."""
        return np.asarray([jax.tree_util.tree_leaves(d)[0].shape[0]
                           for d in self.agent_data], np.int64)

    def round_batches(self, rng, slot_clients):
        """Assemble one round for ``slot_clients`` — the global client id
        occupying each slot, in slot order (len ``P * A_active``)."""
        ids = [int(c) for c in slot_clients]
        if len(ids) != self.cohort_size:
            raise ValueError(f"got {len(ids)} cohort ids for "
                             f"{self.cohort_size} slots")
        return _assemble_round([self.agent_data[c] for c in ids], ids,
                               self.slot_grid, self.batch_size,
                               self.sync_interval, self.sample_extra, rng)


# ---------------------------------------------------------------------------
# FederatedData protocol + the two production pipelines
# ---------------------------------------------------------------------------


class FederatedData:
    """What a training driver needs from a data pipeline.

    Exactly one of the two capabilities is provided:

      * device-resident: ``sample_step(key) -> (P, A, batch, ...) pytree``,
        callable inside a jit trace (consumed by
        ``FedGAN.round_from_data``);
      * host-streaming: ``iter_rounds(rng, n_rounds)`` yielding the
        ``(batches, seeds)`` round inputs ``FedGAN.round`` consumes.

    ``kind`` is ``"device"`` or ``"stream"`` accordingly.
    """

    kind: str = ""

    def sample_step(self, key):
        raise NotImplementedError(f"{type(self).__name__} is not device-resident")

    def iter_rounds(self, rng, n_rounds: int) -> Iterator:
        raise NotImplementedError(f"{type(self).__name__} does not stream rounds")


def round_key_schedule(rng, n_rounds: int):
    """The per-round key sequence every host-side pipeline uses: ``rng, rb =
    split(rng)`` per round.  Centralised so streaming/prefetching pipelines
    stay bit-identical to the legacy blocking loop."""
    keys = []
    for _ in range(n_rounds):
        rng, rb = jax.random.split(rng)
        keys.append(rb)
    return keys


@dataclasses.dataclass
class DeviceFederatedData(FederatedData):
    """Agent shards stacked on device under the (P, A) grid.

    ``data`` leaves are (P, A, N, ...) with every agent's shard padded (by
    wrapping) to the fleet max N; ``sizes`` (P, A) holds the true per-agent
    sample counts so sampling never sees padding.  The instance is a jax
    pytree — pass it straight through ``jax.jit`` boundaries (arrays are
    traced, the static fields key the compilation cache).

    ``sample_step(key)`` draws one (P, A, batch, ...) parallel minibatch
    uniformly per agent and merges ``sample_extra(key, (P, A, batch))``
    (e.g. latent z draws) — the same callable contract
    :class:`FederatedRounds` uses, evaluated inside the jitted round.
    """

    data: Any                      # pytree, leaves (P, A, N, ...)
    sizes: Any                     # (P, A) int32 true shard sizes
    batch_size: int
    sample_extra: Callable | None = None

    kind = "device"

    @property
    def agent_grid(self) -> tuple[int, int]:
        return tuple(np.shape(self.sizes)[:2])

    @classmethod
    def from_agent_data(cls, agent_data: Sequence[Any], agent_grid,
                        batch_size: int, *, sample_extra: Callable | None = None,
                        mesh=None) -> "DeviceFederatedData":
        """Stack per-agent datasets (len B = P*A, arbitrary sizes) into the
        device-resident layout.  With ``mesh``, leaves are placed with the
        (P, A) lead sharded over ("pod", "data") — each agent's shard lands
        on its own mesh slice."""
        P, A = agent_grid
        if P * A != len(agent_data):
            raise ValueError(f"agent_grid {agent_grid} != {len(agent_data)} datasets")
        sizes = np.asarray([jax.tree_util.tree_leaves(d)[0].shape[0]
                            for d in agent_data], np.int32)
        n_max = int(sizes.max())

        def pad(x):
            n = x.shape[0]
            return x if n == n_max else x[np.arange(n_max) % n]

        stacked = tmap(lambda *xs: jnp.stack([pad(x) for x in xs]), *agent_data)
        data = tmap(lambda x: x.reshape((P, A) + x.shape[1:]), stacked)
        out = cls(data=data, sizes=jnp.asarray(sizes.reshape(P, A)),
                  batch_size=batch_size, sample_extra=sample_extra)
        return out.place(mesh) if mesh is not None else out

    def place(self, mesh) -> "DeviceFederatedData":
        """Explicit placement: shard the (P, A) lead over the mesh's
        ("pod", "data") axes via the repro.dist batch specs."""
        from repro.dist.sharding import filter_spec, named_shardings

        def put(x):
            spec = filter_spec(mesh, ("pod", "data") + (None,) * (x.ndim - 2),
                               x.shape)
            return jax.device_put(x, named_shardings(mesh, spec))

        return dataclasses.replace(
            self, data=tmap(put, self.data), sizes=put(self.sizes))

    def sample_step(self, key):
        P, A = self.agent_grid
        k_idx, k_extra = jax.random.split(key)
        idx = jax.random.randint(k_idx, (P, A, self.batch_size), 0,
                                 self.sizes[..., None])
        gather = jax.vmap(jax.vmap(lambda shard, i: shard[i]))
        batch = tmap(lambda x: gather(x, idx), self.data)
        if self.sample_extra is not None:
            extra = self.sample_extra(k_extra, (P, A, self.batch_size))
            batch = {**batch, **extra}
        return batch

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.sizes), (self.batch_size, self.sample_extra)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, sizes = children
        batch_size, sample_extra = aux
        return cls(data=data, sizes=sizes, batch_size=batch_size,
                   sample_extra=sample_extra)


jax.tree_util.register_pytree_node(
    DeviceFederatedData,
    lambda d: d.tree_flatten(),
    DeviceFederatedData.tree_unflatten)


@dataclasses.dataclass
class StreamingFederatedData(FederatedData):
    """Host-streaming rounds with double-buffered prefetch.

    Wraps a :class:`FederatedRounds` assembler: ``iter_rounds`` assembles
    and ``jax.device_put``s up to ``prefetch`` future rounds while the
    current round computes, so the device never waits on host assembly.
    The key schedule (and therefore every batch) is bit-identical to the
    legacy blocking loop — held by the driver parity test."""

    rounds: FederatedRounds
    prefetch: int = 2

    kind = "stream"

    @classmethod
    def from_agent_data(cls, agent_data, agent_grid, batch_size: int,
                        sync_interval: int, *, sample_extra=None,
                        prefetch: int = 2) -> "StreamingFederatedData":
        return cls(FederatedRounds(agent_data, agent_grid, batch_size,
                                   sync_interval, sample_extra=sample_extra),
                   prefetch=prefetch)

    def iter_rounds(self, rng, n_rounds: int):
        if self.prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {self.prefetch}")
        keys = iter(round_key_schedule(rng, n_rounds))

        def assemble(rb):
            # device_put is async: the upload overlaps the in-flight round
            return jax.device_put(self.rounds.round_batches(rb))

        buf = collections.deque()
        for rb in keys:
            buf.append(assemble(rb))
            if len(buf) >= self.prefetch:
                break
        for rb in keys:
            yield buf.popleft()
            buf.append(assemble(rb))
        while buf:
            yield buf.popleft()
