"""Synthetic data generators standing in for the paper's gated datasets.

repro band = 2: MNIST/CIFAR-10/CelebA, the PG&E household-load data and the
EV-charging sessions are not available offline, so each is simulated with a
generator that preserves the *structure the experiment depends on*:
class-conditional image statistics, daily load shapes conditioned on
climate/income attributes, and charging-session profiles conditioned on
station category.  The toy distributions (2D segments, 8-mode ring of
Gaussians, Swiss roll) are exact.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Toy distributions (§4.1 / Appendix C)
# ---------------------------------------------------------------------------

def sample_2d_segment(rng, n: int, agent: int, num_agents: int = 5):
    """Agent i's real data: uniform on its 2/num_agents-wide slice of [-1,1]."""
    width = 2.0 / num_agents
    lo = -1.0 + width * agent
    return jax.random.uniform(rng, (n,), minval=lo, maxval=lo + width)


def mixed_gaussian_modes(num_modes: int = 8, radius: float = 2.0):
    ang = jnp.arange(num_modes) * (2 * math.pi / num_modes)
    return jnp.stack([radius * jnp.cos(ang), radius * jnp.sin(ang)], axis=-1)


def sample_mixed_gaussian(rng, n: int, modes=None, std: float = 0.05,
                          mode_subset=None):
    """8 Gaussians on a circle (Metz et al.).  ``mode_subset`` restricts to an
    agent's local modes (non-iid split: 2 modes per agent for B=4)."""
    modes = mixed_gaussian_modes() if modes is None else modes
    if mode_subset is not None:
        modes = modes[jnp.asarray(mode_subset)]
    k1, k2 = jax.random.split(rng)
    idx = jax.random.randint(k1, (n,), 0, modes.shape[0])
    return modes[idx] + std * jax.random.normal(k2, (n, 2))


def sample_swiss_roll(rng, n: int, *, noise: float = 0.05,
                      t_range=(0.25, 1.0)):
    """2-D Swiss roll (Gulrajani et al.).  ``t_range`` in (0,1] selects the
    arc segment — agents get disjoint, equal-sized parts of the roll."""
    k1, k2 = jax.random.split(rng)
    t0, t1 = t_range
    t = 3 * math.pi * (t0 + (t1 - t0) * jax.random.uniform(k1, (n,)))
    x = t * jnp.cos(t) / (3 * math.pi)
    y = t * jnp.sin(t) / (3 * math.pi)
    pts = jnp.stack([x, y], axis=-1)
    return pts + noise * jax.random.normal(k2, (n, 2))


# ---------------------------------------------------------------------------
# Synthetic class-conditional images (MNIST/CIFAR stand-in)
# ---------------------------------------------------------------------------

def sample_class_images(rng, n: int, labels, *, hw: int = 32, channels: int = 3,
                        num_classes: int = 10):
    """Deterministic class-specific structure + instance noise.

    Class c renders an oriented sinusoidal grating (orientation and frequency
    indexed by the class) with a class-colored gradient — enough structure
    that a conv discriminator must learn per-class statistics, which is what
    the ACGAN experiment exercises.  Output in [-1, 1], NHWC.
    """
    labels = jnp.asarray(labels)
    k1, k2, k3 = jax.random.split(rng, 3)
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, hw), jnp.linspace(-1, 1, hw),
                          indexing="ij")
    theta = labels.astype(jnp.float32) * (math.pi / num_classes)      # (n,)
    freq = 2.0 + (labels % 5).astype(jnp.float32)                     # (n,)
    cx = jnp.cos(theta)[:, None, None]
    sx = jnp.sin(theta)[:, None, None]
    proj = cx * xx[None] + sx * yy[None]                              # (n,hw,hw)
    phase = 2 * math.pi * jax.random.uniform(k1, (n, 1, 1))
    base = jnp.sin(freq[:, None, None] * math.pi * proj + phase)      # (n,hw,hw)
    # class-colored channel mixture
    col_ang = labels.astype(jnp.float32) * (2 * math.pi / num_classes)
    cols = jnp.stack([jnp.cos(col_ang), jnp.cos(col_ang + 2.1),
                      jnp.cos(col_ang + 4.2)], axis=-1)               # (n,3)
    img = base[..., None] * (0.6 + 0.4 * cols[:, None, None, :])
    img = img[..., :channels]
    img = img + 0.15 * jax.random.normal(k2, img.shape)
    shift = 0.1 * jax.random.normal(k3, (n, 1, 1, channels))
    return jnp.clip(img + shift, -1.0, 1.0)


def sample_attribute_faces(rng, n: int, attrs, *, hw: int = 32):
    """CelebA stand-in: 4 binary attributes -> 16 'identity classes'
    (Eyeglasses, Male, Smiling, Young in the paper).  attrs: (n,) in [0,16)."""
    return sample_class_images(rng, n, attrs, hw=hw, channels=3, num_classes=16)


# ---------------------------------------------------------------------------
# Synthetic time series (PG&E household load / EV charging sessions)
# ---------------------------------------------------------------------------

def sample_household_load(rng, n: int, *, climate_zone, seq_len: int = 24):
    """Daily household consumption profile, normalised.

    Structure mirroring the PG&E description: morning + evening peaks whose
    relative magnitude / timing depend on the climate zone (the non-iid
    split key in §4.3), plus weekday noise.  climate_zone: (n,) int in [0,5).
    """
    cz = jnp.asarray(climate_zone).astype(jnp.float32)
    k1, k2, k3 = jax.random.split(rng, 3)
    t = jnp.arange(seq_len, dtype=jnp.float32)[None, :]               # hours
    morning_peak = 6.5 + 0.5 * cz[:, None] + 0.5 * jax.random.normal(k1, (n, 1))
    evening_peak = 18.0 + 0.4 * cz[:, None] + 0.5 * jax.random.normal(k2, (n, 1))
    morning_h = 0.4 + 0.1 * cz[:, None]
    evening_h = 1.0 - 0.08 * cz[:, None]
    base = 0.25 + 0.03 * cz[:, None]
    prof = (base
            + morning_h * jnp.exp(-0.5 * ((t - morning_peak) / 1.5) ** 2)
            + evening_h * jnp.exp(-0.5 * ((t - evening_peak) / 2.0) ** 2))
    prof = prof + 0.05 * jax.random.normal(k3, (n, seq_len))
    return prof / jnp.max(prof, axis=1, keepdims=True)


def sample_ev_sessions(rng, n: int, *, category, seq_len: int = 24):
    """EV charging power profile over 24 15-min-aggregated-to-hour bins.

    category (station POI): 0=high-tech workplace (day charging),
    1=shopping (till midnight), 2=municipal, 3=retail, 4=residential
    (overnight) — matching the paper's Fig. 10 contrast.
    """
    cat = jnp.asarray(category)
    k1, k2, k3 = jax.random.split(rng, 3)
    t = jnp.arange(seq_len, dtype=jnp.float32)[None, :]
    starts = jnp.asarray([8.5, 16.0, 10.0, 12.0, 21.0])[cat][:, None]
    durs = jnp.asarray([4.0, 5.0, 3.0, 2.0, 7.0])[cat][:, None]
    start = starts + 1.0 * jax.random.normal(k1, (n, 1))
    dur = jnp.maximum(durs + 0.8 * jax.random.normal(k2, (n, 1)), 0.5)
    ramp = jax.nn.sigmoid(2.0 * (t - start)) * jax.nn.sigmoid(2.0 * (start + dur - t))
    power = ramp * (0.7 + 0.3 * jax.random.uniform(k3, (n, 1)))
    peak = jnp.max(power, axis=1, keepdims=True)
    return power / jnp.where(peak == 0, 1.0, peak)


# ---------------------------------------------------------------------------
# Synthetic token streams (LM-backbone federated training)
# ---------------------------------------------------------------------------

def sample_agent_tokens(rng, n: int, seq_len: int, vocab: int, *, agent: int,
                        num_agents: int):
    """Non-iid token sequences: each agent draws from a distinct Zipf-permuted
    slice of the vocabulary (two-level: shared head + agent-specific tail)."""
    k1, k2 = jax.random.split(jax.random.fold_in(rng, agent))
    # agent-specific vocabulary slice (non-iid), 30% shared head
    shard = max(vocab // num_agents, 2)
    base = jax.random.randint(k1, (n, seq_len), 0, shard)
    offset = min(agent * shard, max(vocab - shard, 0))
    shared = jax.random.randint(k2, (n, seq_len), 0, vocab)
    use_shared = jax.random.bernoulli(k2, 0.3, (n, seq_len))
    return jnp.where(use_shared, shared, base + offset).astype(jnp.int32)
