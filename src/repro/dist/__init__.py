"""Distribution substrate: meshes plans program against.

``repro.dist.sharding`` answers *where tensors live* (batch-axes context,
name-rule parameter specs, divisibility-safe constraint helpers);
``repro.dist.collectives`` answers *what moves on the wire* (agent-grid
averages and their byte accounting).  ``repro.dist.compat`` papers over
jax version drift and is installed on import of :mod:`repro`.

See docs/sharding.md for the API walkthrough.
"""
from repro.dist.collectives import (agent_axes, average_agents,
                                    average_intra_pod, sync_bytes, tree_bytes)
from repro.dist.sharding import (DEFAULT_BATCH_AXES, batch_axes, batch_spec,
                                 current_batch_axes, dp_param_specs,
                                 filter_spec, named_shardings, param_specs,
                                 shape_of, shard, shard_attn_qkv)

__all__ = [
    "DEFAULT_BATCH_AXES", "agent_axes", "average_agents", "average_intra_pod",
    "batch_axes", "batch_spec", "current_batch_axes", "dp_param_specs",
    "filter_spec", "named_shardings", "param_specs", "shape_of", "shard",
    "shard_attn_qkv", "sync_bytes", "tree_bytes",
]
