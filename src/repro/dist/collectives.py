"""Agent-grid collectives for FedGAN state.

FedGAN state is *agent-stacked*: every leaf carries a leading (P, A) grid
which the mesh plans shard over ("pod", "data").  The averaging primitives
here are written as plain einsums over those leading dims — under jit on the
mesh, XLA lowers the weighted mean + broadcast of :func:`average_agents` to
ONE all-reduce over ("pod","data") per leaf group, which *is* the paper's
intermediary sync (eq. (2)+(3)) realised SPMD-style.  Off-mesh (CPU paper
experiments) the same einsums are just math.

``sync_dtype`` implements compressed sync: leaves are cast before the
average and back after, so the all-reduce moves 2-byte (or fp8) words while
the master copy stays full precision — the same width contract the fedavg
Pallas kernel (repro.kernels.fedavg) uses for its on-chip reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def agent_axes(mesh=None) -> tuple:
    """The mesh axes carrying the agent grid that are present on ``mesh``
    (falls back to the canonical ("pod", "data") when no mesh is given)."""
    names = ("pod", "data")
    if mesh is None:
        return names
    return tuple(n for n in names if n in mesh.axis_names)


def average_agents(tree, weights, *, sync_dtype=None):
    """Weighted average over the leading (P, A) dims, broadcast back.

    ``weights``: (P, A), assumed normalised.  One all-reduce over
    ("pod","data") per fusion group when the leading dims are sharded there.
    """

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            # integer/bool state (e.g. the Adam step count) is identical
            # across lockstep agents; averaging with float weights would
            # truncate it to zero
            return x
        xs = x.astype(sync_dtype) if sync_dtype is not None else x
        m = jnp.einsum("pa,pa...->...", weights.astype(xs.dtype), xs)
        return jnp.broadcast_to(m.astype(x.dtype), x.shape)

    return tmap(avg, tree)


def average_intra_pod(tree, weights):
    """Average within each pod only (tier 1 of hierarchical sync): weighted
    mean over the A dim, renormalised per pod, broadcast back."""
    w_intra = weights / jnp.sum(weights, axis=1, keepdims=True)

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        m = jnp.einsum("pa,pa...->p...", w_intra.astype(x.dtype), x)
        return jnp.broadcast_to(m[:, None], x.shape)

    return tmap(avg, tree)


def tree_bytes(tree) -> int:
    """Total bytes of the array leaves (the 'M' of the §3.2 accounting)."""
    return sum(int(l.size) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def sync_bytes(tree, *, sync_dtype=None) -> int:
    """Bytes one agent moves per direction in one parameter sync — i.e. the
    wire size of ``tree`` after the optional ``sync_dtype`` compression."""
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        itemsize = (jnp.dtype(sync_dtype).itemsize if sync_dtype is not None
                    else l.dtype.itemsize)
        total += int(l.size) * itemsize
    return total
