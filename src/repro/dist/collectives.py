"""Agent-grid collectives for FedGAN state.

FedGAN state is *agent-stacked*: every leaf carries a leading (P, A) grid
which the mesh plans shard over ("pod", "data").  The averaging primitives
here are written as plain einsums over those leading dims — under jit on the
mesh, XLA lowers the weighted mean + broadcast of :func:`average_agents` to
ONE all-reduce over ("pod","data") per leaf group, which *is* the paper's
intermediary sync (eq. (2)+(3)) realised SPMD-style.  Off-mesh (CPU paper
experiments) the same einsums are just math.

``sync_dtype`` implements compressed sync: leaves are cast before the
average and back after, so the all-reduce moves 2-byte (or fp8) words while
the master copy stays full precision — the same width contract the fedavg
Pallas kernel (repro.kernels.fedavg) uses for its on-chip reduction.

``codec`` goes further (:func:`coded_sync`): each agent's leaf is run
through a ``repro.comm`` codec, the decode→weighted-average happens at the
reduce, and the average is re-encoded for the broadcast — both directions
of the agent-grid all-reduce move the *compressed* representation, with
optional error-feedback residuals (per-agent uplink + shared downlink)
threaded through so the lossy wire still converges.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map


def agent_axes(mesh=None) -> tuple:
    """The mesh axes carrying the agent grid that are present on ``mesh``
    (falls back to the canonical ("pod", "data") when no mesh is given)."""
    names = ("pod", "data")
    if mesh is None:
        return names
    return tuple(n for n in names if n in mesh.axis_names)


def average_agents(tree, weights, *, sync_dtype=None):
    """Weighted average over the leading (P, A) dims, broadcast back.

    ``weights``: (P, A), assumed normalised.  One all-reduce over
    ("pod","data") per fusion group when the leading dims are sharded there.
    """

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            # integer/bool state (e.g. the Adam step count) is identical
            # across lockstep agents; averaging with float weights would
            # truncate it to zero
            return x
        xs = x.astype(sync_dtype) if sync_dtype is not None else x
        m = jnp.einsum("pa,pa...->...", weights.astype(xs.dtype), xs)
        return jnp.broadcast_to(m.astype(x.dtype), x.shape)

    return tmap(avg, tree)


def average_intra_pod(tree, weights):
    """Average within each pod only (tier 1 of hierarchical sync): weighted
    mean over the A dim, renormalised per pod, broadcast back."""
    w_intra = weights / jnp.sum(weights, axis=1, keepdims=True)

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        m = jnp.einsum("pa,pa...->p...", w_intra.astype(x.dtype), x)
        return jnp.broadcast_to(m[:, None], x.shape)

    return tmap(avg, tree)


def coded_sync(tree, weights, codec, *, ef=None, ef_down=None):
    """The full compressed intermediary sync for one subtree.

    Per inexact leaf: the agent adds its carried residual (``ef``), encodes
    through ``codec`` (the uplink wire image — blocks/top-k never span
    agents), the reduce decodes and weighted-averages over (P, A), the
    server adds its own residual (``ef_down``), re-encodes the average (the
    downlink wire image) and broadcasts it back.  Integer leaves pass
    through untouched (they are identical across lockstep agents).

    Returns ``(synced, new_ef, new_ef_down)`` — the residual trees are None
    when the corresponding input residuals are None (no error feedback).
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    e_leaves = (jax.tree_util.tree_leaves(ef) if ef is not None
                else [None] * len(leaves))
    ed_leaves = (jax.tree_util.tree_leaves(ef_down) if ef_down is not None
                 else [None] * len(leaves))
    outs, new_e, new_ed = [], [], []
    for x, e, ed in zip(leaves, e_leaves, ed_leaves):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            outs.append(x)
            new_e.append(e)
            new_ed.append(ed)
            continue
        y = x + e if e is not None else x
        q = codec.roundtrip(y, batch_ndims=2)           # uplink wire image
        m = jnp.einsum("pa,pa...->...", weights.astype(q.dtype), q)
        yd = m + ed if ed is not None else m
        qd = codec.roundtrip(yd)                        # downlink wire image
        outs.append(jnp.broadcast_to(qd.astype(x.dtype), x.shape))
        new_e.append(y - q if e is not None else None)
        new_ed.append(yd - qd if ed is not None else None)
    unflat = jax.tree_util.tree_unflatten
    return (unflat(treedef, outs),
            unflat(treedef, new_e) if ef is not None else None,
            unflat(treedef, new_ed) if ef_down is not None else None)


def tree_bytes(tree) -> int:
    """Total bytes of the array leaves (the 'M' of the §3.2 accounting)."""
    return sum(int(l.size) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def sync_bytes(tree, *, sync_dtype=None, codec=None) -> int:
    """Bytes one agent moves per direction in one parameter sync — i.e. the
    wire size of ``tree`` after the optional ``sync_dtype`` cast or
    ``codec`` encoding (payload + scales + indices; integer leaves pass
    through uncompressed).  ``tree`` leaves may be ShapeDtypeStructs."""
    if sync_dtype is not None and codec is not None:
        raise ValueError("sync_dtype and codec are both wire compressions; "
                         "pick one")
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if codec is not None and jnp.issubdtype(l.dtype, jnp.inexact):
            total += codec.wire_bytes(l)
            continue
        itemsize = (jnp.dtype(sync_dtype).itemsize if sync_dtype is not None
                    else l.dtype.itemsize)
        total += int(l.size) * itemsize
    return total
