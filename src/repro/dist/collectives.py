"""Agent-grid collectives for FedGAN state.

FedGAN state is *agent-stacked*: every leaf carries a leading (P, A) grid
which the mesh plans shard over ("pod", "data").  The averaging primitives
here are written as plain multiply+reduce contractions over those leading
dims — under jit on the mesh, XLA lowers the weighted mean + broadcast of
:func:`average_agents` to ONE all-reduce over ("pod","data") per leaf
group, which *is* the paper's intermediary sync (eq. (2)+(3)) realised
SPMD-style.  Off-mesh (CPU paper experiments) the same contractions are
just math.

``sync_dtype`` implements compressed sync: leaves are cast before the
average and back after, so the all-reduce moves 2-byte (or fp8) words while
the master copy stays full precision — the same width contract the fedavg
Pallas kernel (repro.kernels.fedavg) uses for its on-chip reduction.

``codec`` goes further (:func:`coded_sync`): each agent's leaf is run
through a ``repro.comm`` codec, the decode→weighted-average happens at the
reduce, and the average is re-encoded for the broadcast — both directions
of the agent-grid all-reduce move the *compressed* representation, with
optional error-feedback residuals (per-agent uplink + shared downlink)
threaded through so the lossy wire still converges.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qsync import ops as qsync_ops

tmap = jax.tree_util.tree_map


def agent_axes(mesh=None) -> tuple:
    """The mesh axes carrying the agent grid that are present on ``mesh``
    (falls back to the canonical ("pod", "data") when no mesh is given)."""
    names = ("pod", "data")
    if mesh is None:
        return names
    return tuple(n for n in names if n in mesh.axis_names)


def weighted_mean(x, weights):
    """The default reduce: weighted mean over the leading (P, A) dims —
    one broadcast-multiply + reduce-sum that XLA fuses to a single
    all-reduce per fusion group.  The per-agent products are materialized
    before the sum (rather than contracted in one einsum, whose eager
    dot_general may FMA-accumulate) so the numerics are EXACTLY those of
    the weight-then-mask secure path, whose wire carries the rounded
    product w_i·x_i — what keeps :func:`masked_sync` bit-identical to the
    plain average."""
    w = weights.astype(x.dtype).reshape(weights.shape + (1,) * (x.ndim - 2))
    return jnp.sum(w * x, axis=(0, 1))


def average_agents(tree, weights, *, sync_dtype=None, reduce=None):
    """Weighted average over the leading (P, A) dims, broadcast back.

    ``weights``: (P, A), assumed normalised.  One all-reduce over
    ("pod","data") per fusion group when the leading dims are sharded there.

    ``reduce`` replaces the einsum with a pluggable per-leaf aggregate
    ``reduce(x, weights) -> x.shape[2:]`` — e.g. a Byzantine-robust
    trimmed mean or coordinate median (:func:`robust_reduce`).
    """
    reduce = weighted_mean if reduce is None else reduce

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            # integer/bool state (e.g. the Adam step count) is identical
            # across lockstep agents; averaging with float weights would
            # truncate it to zero
            return x
        xs = x.astype(sync_dtype) if sync_dtype is not None else x
        m = reduce(xs, weights)
        return jnp.broadcast_to(m.astype(x.dtype), x.shape)

    return tmap(avg, tree)


def make_robust_reduce(kind: str, *, trim: int = 1):
    """A pluggable ``reduce(x, weights)`` that tolerates Byzantine agents.

    ``kind="trimmed_mean"``: per coordinate, sort the B = P·A agent values,
    drop the ``trim`` smallest and ``trim`` largest, average the rest — any
    f <= trim arbitrarily-corrupted agents (sign-flipped, scaled, NaN: NaN
    sorts last, into the trimmed tail) cannot move the result outside the
    honest agents' range.  ``kind="median"``: the per-coordinate median
    (lower-median order statistic), breakdown point f < B/2.

    Robust aggregation is weight-oblivious: the §3.1 dataset-size weights
    are ignored (a poisoned agent could otherwise buy influence through a
    claimed dataset size) — callers should treat agents uniformly.
    """
    if kind not in ("trimmed_mean", "median"):
        raise ValueError(f"unknown robust reduce {kind!r}; "
                         "known: ['median', 'trimmed_mean']")

    def reduce(x, weights):
        B = x.shape[0] * x.shape[1]
        flat = jnp.sort(x.reshape((B,) + x.shape[2:]), axis=0)
        if kind == "median":
            # lower median: an actual honest value whenever f < B/2 (NaNs
            # and scaled outliers sort to the tails, never the middle)
            return flat[(B - 1) // 2]
        if B <= 2 * trim:
            raise ValueError(f"trimmed_mean needs more than 2*trim={2 * trim} "
                             f"agents, got {B}")
        return jnp.mean(flat[trim:B - trim], axis=0)

    return reduce


def mask_pair_key(key, step):
    """The per-round mask PRG key: derived from the static fleet seed and
    the (checkpointed) step counter, so masks are never reused across
    rounds yet a restored run regenerates them exactly."""
    return jax.random.fold_in(key, step)


def _pairwise_masks(key, grid, shape):
    """Net uint32 pairwise masks, one per agent: m_i = sum_{j>i} r_ij -
    sum_{j<i} r_ji  (mod 2^32).  Summed over agents the r_ij terms
    telescope to EXACTLY zero (modular integer arithmetic — no float
    rounding), which is the cancellation real secure aggregation relies
    on.

    Each pair's mask is drawn from its own ``fold_in(key, pair_index)``
    and folded into a running (B,) + shape accumulator inside a scan, so
    peak memory is O(B·leaf) — never the (B, B)·leaf tensor a
    materialized pair matrix would need (which OOMs at exactly the
    fleet/model sizes secure aggregation targets)."""
    P, A = grid
    B = P * A
    m = jnp.zeros((B,) + shape, jnp.uint32)
    pairs = [(i, j) for i in range(B) for j in range(i + 1, B)]
    if not pairs:
        return m.reshape((P, A) + shape)
    ii = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def body(acc, pair):
        i, j, p = pair
        r = jax.random.bits(jax.random.fold_in(key, p), shape, jnp.uint32)
        return acc.at[i].add(r).at[j].add(-r), None

    m, _ = jax.lax.scan(body, m, (ii, jj, jnp.arange(len(pairs),
                                                     dtype=jnp.uint32)))
    return m.reshape((P, A) + shape)


def masked_sync(tree, weights, key, *, sync_dtype=None, reduce=None):
    """Secure-aggregation-style sum: every agent's wire image is one-time-
    padded with pairwise PRG masks before it leaves the agent.

    Per inexact leaf: agent (p, a) folds its public §3.1 weight into the
    payload FIRST (weight-then-mask — a server that only ever sees masked
    payloads cannot apply per-agent weights, since sum_i w_i·(x_i + m_i)
    does not telescope unless the weights are uniform), then ships the
    uint32 bit pattern of w_i·x_i plus its net pairwise mask, mod 2^32 —
    uniformly random to anyone without the pair seeds (an exact one-time
    pad; no quantization of the data, so the recovered values are
    bit-identical).  At the reduce the masks cancel (they telescope to
    zero modularly, see :func:`_pairwise_masks`) and the server's only
    coherent aggregate — the plain UNWEIGHTED sum of the pre-weighted
    payloads — proceeds on the recovered values.  The products and the
    reduce order are identical to the weighted einsum, so the output is
    bit-identical to :func:`average_agents` on the same weights.

    ``key`` must be fresh per round (derive via :func:`mask_pair_key` from
    the step counter — mask reuse breaks the pad).  The wire moves the same
    4 bytes/element as the uncompressed float32 sync, so the §3.2
    accounting is unchanged; a lossy codec cannot ride this wire (the
    server would need per-agent decode — refuse upstream).
    """
    if reduce is not None:
        raise ValueError(
            "masked_sync cannot apply a robust reduce: order statistics "
            "need the individual per-agent values a secure sum hides")
    if sync_dtype is not None:
        raise ValueError(
            "masked_sync pads the 32-bit wire image; a sync_dtype recast "
            "would break the pad cancellation — drop one of the two")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    outs = []
    for i, x in enumerate(leaves):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            outs.append(x)
            continue
        if x.dtype.itemsize != 4:
            raise ValueError(
                f"masked_sync pads the 32-bit wire image; got {x.dtype} — "
                "cast the synced tree to float32 or drop secure_agg")
        w = weights.astype(x.dtype).reshape(weights.shape
                                            + (1,) * (x.ndim - 2))
        k_leaf = jax.random.fold_in(key, i)
        m = _pairwise_masks(k_leaf, x.shape[:2], x.shape[2:])
        wire = jax.lax.bitcast_convert_type(x * w, jnp.uint32) + m  # uplink
        recovered = jax.lax.bitcast_convert_type(wire - m, x.dtype)
        outs.append(recovered)
    unmasked = jax.tree_util.tree_unflatten(treedef, outs)
    return average_agents(unmasked, jnp.ones_like(weights))


def average_intra_pod(tree, weights):
    """Average within each pod only (tier 1 of hierarchical sync): weighted
    mean over the A dim, renormalised per pod, broadcast back."""
    w_intra = weights / jnp.sum(weights, axis=1, keepdims=True)

    def avg(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        m = jnp.einsum("pa,pa...->p...", w_intra.astype(x.dtype), x)
        return jnp.broadcast_to(m[:, None], x.shape)

    return tmap(avg, tree)


def coded_sync(tree, weights, codec, *, ef=None, ef_down=None, reduce=None,
               fused=None):
    """The full compressed intermediary sync for one subtree.

    Per inexact leaf: the agent adds its carried residual (``ef``), encodes
    through ``codec`` (the uplink wire image — blocks/top-k never span
    agents), the reduce decodes and weighted-averages over (P, A), the
    server adds its own residual (``ef_down``), re-encodes the average (the
    downlink wire image) and broadcasts it back.  Integer leaves pass
    through untouched (they are identical across lockstep agents).

    Returns ``(synced, new_ef, new_ef_down)`` — the residual trees are None
    when the corresponding input residuals are None (no error feedback).

    ``reduce`` swaps the weighted mean at the decode→aggregate point for a
    pluggable per-leaf aggregate (e.g. :func:`make_robust_reduce`) — the
    robust statistics then run on the decoded per-agent wire images.

    ``fused`` selects the one-pass path: ``None`` (default) auto-fuses the
    float32 leaves through the bucketed ``kernels/qsync`` pass whenever the
    codec advertises a ``fused_sync_spec()`` and no custom ``reduce`` is
    installed; ``False`` forces the composed per-leaf pipeline; ``True``
    *requires* the fused path and raises when the codec or reduce cannot
    ride it.  Fused or composed, the wire values, billed bytes and EF
    residuals are bit-identical — the fused kernels reuse the exact qpack
    arithmetic and reduce in the weights' grid shape (the pure-jnp
    ``kernels/qsync/ref.py`` oracle is the parity proof).  Leaves the fused
    kernel cannot take (non-f32, or missing the (P, A) grid) fall back to
    the composed loop leaf by leaf.
    """
    spec = getattr(codec, "fused_sync_spec", lambda: None)()
    fusable = spec is not None and reduce is None
    if fused is None:
        fused = fusable
    elif fused and not fusable:
        raise ValueError(
            "fused=True needs a codec with a fused_sync_spec "
            f"(got {getattr(codec, 'name', codec)!r}) and the default "
            "weighted-mean reduce" if reduce is None else
            "fused=True cannot apply a custom reduce: the fused kernel "
            "hard-wires the weighted mean")
    reduce = weighted_mean if reduce is None else reduce
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    e_leaves = (jax.tree_util.tree_leaves(ef) if ef is not None
                else [None] * len(leaves))
    ed_leaves = (jax.tree_util.tree_leaves(ef_down) if ef_down is not None
                 else [None] * len(leaves))
    outs = [None] * len(leaves)
    new_e = [None] * len(leaves)
    new_ed = [None] * len(leaves)
    fuse_idx = [i for i, x in enumerate(leaves)
                if fused and qsync_ops.fusable_leaf(x)]
    fuse_set = set(fuse_idx)
    for i, (x, e, ed) in enumerate(zip(leaves, e_leaves, ed_leaves)):
        if i in fuse_set:
            continue
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            outs[i] = x
            new_e[i] = e
            new_ed[i] = ed
            continue
        y = x + e if e is not None else x
        q = codec.roundtrip(y, batch_ndims=2)           # uplink wire image
        m = reduce(q, weights)
        yd = m + ed if ed is not None else m
        qd = codec.roundtrip(yd)                        # downlink wire image
        outs[i] = jnp.broadcast_to(qd.astype(x.dtype), x.shape)
        new_e[i] = y - q if e is not None else None
        new_ed[i] = yd - qd if ed is not None else None
    if fuse_idx:
        # ONE bucketed dispatch for the whole fusable group — O(1) launches
        # instead of O(leaves); see kernels/qsync/ops.qsync_leaves
        f_out, f_ne, f_ned = qsync_ops.qsync_leaves(
            [leaves[i] for i in fuse_idx], weights,
            [e_leaves[i] for i in fuse_idx] if ef is not None else None,
            [ed_leaves[i] for i in fuse_idx] if ef_down is not None else None,
            **spec)
        for j, i in enumerate(fuse_idx):
            outs[i], new_e[i], new_ed[i] = f_out[j], f_ne[j], f_ned[j]
    unflat = jax.tree_util.tree_unflatten
    return (unflat(treedef, outs),
            unflat(treedef, new_e) if ef is not None else None,
            unflat(treedef, new_ed) if ef_down is not None else None)


def tree_bytes(tree) -> int:
    """Total bytes of the array leaves (the 'M' of the §3.2 accounting)."""
    return sum(int(l.size) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def sync_bytes(tree, *, sync_dtype=None, codec=None) -> int:
    """Bytes one agent moves per direction in one parameter sync — i.e. the
    wire size of ``tree`` after the optional ``sync_dtype`` cast or
    ``codec`` encoding (payload + scales + indices; integer leaves pass
    through uncompressed).  ``tree`` leaves may be ShapeDtypeStructs."""
    if sync_dtype is not None and codec is not None:
        raise ValueError("sync_dtype and codec are both wire compressions; "
                         "pick one")
    total = 0
    for l in jax.tree_util.tree_leaves(tree):
        if codec is not None and jnp.issubdtype(l.dtype, jnp.inexact):
            total += codec.wire_bytes(l)
            continue
        itemsize = (jnp.dtype(sync_dtype).itemsize if sync_dtype is not None
                    else l.dtype.itemsize)
        total += int(l.size) * itemsize
    return total
