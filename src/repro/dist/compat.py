"""JAX version compatibility for the distribution substrate.

The repo programs against the modern mesh-context API — ``jax.set_mesh``,
``jax.sharding.AxisType``, dict-valued ``Compiled.cost_analysis()`` — while
the pinned runtime may be an older 0.4-series jax where those are absent
(``jax.set_mesh`` arrived in 0.6, ``AxisType`` in 0.5.x, and
``cost_analysis()`` returned a one-element *list* of dicts until 0.4.38).

``install()`` (run once on ``import repro``) adds hasattr-guarded
equivalents so every call site — including the ``python -c`` subprocess
snippets in the tier-1 tests — runs unmodified on either side:

  * ``jax.set_mesh(mesh)``      -> context manager entering ``with mesh:``
                                   (the legacy thread-resources mesh context,
                                   which with_sharding_constraint + the
                                   partitioner already consult)
  * ``Compiled.cost_analysis``  -> normalised to a flat dict
  * ``make_mesh(shape, axes)``  -> drops ``axis_types`` when unsupported

Nothing is patched when the running jax already provides the API.
"""
from __future__ import annotations

import contextlib

import jax


def current_mesh():
    """The concrete mesh made current by ``jax.set_mesh(mesh)`` /
    ``with mesh:``, or None when no mesh context is active (single-device
    CPU paper runs — sharding constraints become no-ops there)."""
    # modern jax: a concrete mesh set via jax.set_mesh
    try:
        from jax._src.mesh import get_concrete_mesh  # jax >= 0.6

        m = get_concrete_mesh()
        if m is not None and getattr(m, "axis_names", None):
            return m
    except (ImportError, TypeError):
        pass
    # legacy thread-resources context (entered by `with mesh:`)
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types when the running jax has them."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def _version_tuple() -> tuple:
    try:
        return tuple(int(x) for x in jax.__version__.split(".")[:3])
    except ValueError:
        return (0, 0, 0)


def install():
    """Idempotently install the shims on the running jax."""
    if not hasattr(jax.sharding, "AxisType"):
        import enum

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    import inspect

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        def make_mesh_compat(axis_shapes, axis_names, *, axis_types=None,
                             devices=None):
            # pre-AxisType jax is all-Auto implicitly; drop the kwarg
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh_compat

    if not hasattr(jax, "set_mesh"):

        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    # pallas-tpu renamed TPUCompilerParams -> CompilerParams; alias the
    # modern spelling the kernel modules use.
    try:
        from jax.experimental.pallas import tpu as pltpu

        if (not hasattr(pltpu, "CompilerParams")
                and hasattr(pltpu, "TPUCompilerParams")):
            pltpu.CompilerParams = pltpu.TPUCompilerParams
    except ImportError:  # pragma: no cover - pallas-free jax builds
        pass

    # Compiled.cost_analysis returned [dict] (one entry per partition, always
    # length 1 under SPMD) before 0.4.38; normalise to the modern flat dict.
    # The returned mapping still answers the old `ca[0]` idiom with itself so
    # third-party callers in the same process keep working either way.
    try:
        from jax._src import stages

        class _CostAnalysis(dict):
            def __getitem__(self, key):
                if key == 0 and 0 not in self:
                    return self
                return super().__getitem__(key)

        if not getattr(stages.Compiled.cost_analysis, "_repro_compat", False):
            _orig = stages.Compiled.cost_analysis

            def cost_analysis(self):
                ca = _orig(self)
                if isinstance(ca, (list, tuple)):
                    ca = ca[0] if ca else {}
                return _CostAnalysis(ca)

            cost_analysis._repro_compat = True
            if _version_tuple() < (0, 4, 38):
                stages.Compiled.cost_analysis = cost_analysis
    except Exception:  # pragma: no cover - exotic jax builds
        pass
