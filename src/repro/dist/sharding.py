"""Sharding substrate: who holds which parameters, and what moves on the wire.

The FedGAN mapping (see repro.core.fedgan) stacks every parameter leaf with a
leading (P, A) agent grid sharded over the ("pod", "data") mesh axes; tensor
parallelism over "model" lives *inside* each agent.  This module supplies the
two halves of that story:

  activations  ``batch_axes`` / ``batch_spec`` / ``shard`` — model code
               declares constraints positionally ("batch dims, then these
               trailing entries") and the active :func:`batch_axes` context
               decides which mesh axes the batch dims actually occupy.  The
               same model code therefore serves the agent-sharded train step
               (batch over ("pod","data")), the intra-agent DP plan (batch
               over "model") and the single-device CPU paper runs (no mesh:
               every constraint is a no-op).

  parameters   ``param_specs`` — name-rule tensor parallelism (column-/row-
               parallel by module name, divisibility fallback to replicated),
               with ``lead=`` for the agent-stacked leading dims and
               ``fsdp_axis=`` for additionally sharding weights inside an
               agent.  ``dp_param_specs`` is the ZeRO-style variant for the
               intra-agent DP plan: weights *stored* sharded over "model" and
               gathered at use.

Every public helper funnels through :func:`filter_spec`, which adapts a
requested spec to a concrete mesh: axis names the mesh lacks are dropped,
a dim whose size the remaining axes do not divide falls back to replicated,
and an axis already consumed by an earlier dim is never reused (this is what
lets the DP plan put "model" under the batch and silently disable the
tensor-parallel trailing entries of the very same model code).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist import compat

# The production default: activation batch dims live on the agent grid.
DEFAULT_BATCH_AXES = ("pod", "data")

_local = threading.local()


# ---------------------------------------------------------------------------
# batch-axes context
# ---------------------------------------------------------------------------


def current_batch_axes() -> tuple:
    """Mesh axes currently carrying activation batch dims."""
    return getattr(_local, "batch_axes", DEFAULT_BATCH_AXES)


@contextmanager
def batch_axes(*axes: str):
    """Rebind the activation batch axes for the enclosed trace.

    ``batch_axes()`` (no arguments) means *no* batch sharding — used for
    per-agent compute whose batch dim is already inside an agent — while
    ``batch_axes("model")`` is the intra-agent DP plan.  Nests and restores
    (the previous binding returns on exit, even on exception).
    """
    prev = current_batch_axes()
    _local.batch_axes = tuple(axes)
    try:
        yield
    finally:
        _local.batch_axes = prev


def batch_spec(*trailing):
    """Positional spec entries: the batch entry, then ``trailing`` verbatim.

    The batch entry is the current :func:`batch_axes` tuple, or None when the
    context is empty.  ``shard(x, *batch_spec(None, "model"))`` therefore
    reads "batch over whatever the plan says, dim1 replicated, dim2 tensor-
    parallel"."""
    axes = current_batch_axes()
    return ((tuple(axes) if axes else None),) + trailing


# ---------------------------------------------------------------------------
# spec filtering (mesh adaptation)
# ---------------------------------------------------------------------------


def mesh_dims(mesh) -> dict:
    """{axis name: size} for a mesh (canonical copy; launch.mesh re-exports)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


_mesh_dims = mesh_dims


def filter_spec(mesh, entries, shape) -> P:
    """Adapt requested spec ``entries`` to ``mesh`` and ``shape``.

    Per dim (entry may be an axis name, a tuple of axis names, or None):
      1. drop axis names the mesh does not have (e.g. "pod" on a single-pod
         ("data","model") mesh);
      2. drop axis names already used by an earlier dim (an axis can shard
         at most one dim; first dim wins);
      3. if the surviving axes do not evenly divide the dim size, the whole
         dim falls back to replicated (never uneven shards).
    Returns a PartitionSpec with exactly ``len(entries)`` entries.
    """
    dims = _mesh_dims(mesh)
    if len(entries) > len(shape):
        raise ValueError(f"spec {entries} has more entries than shape {shape}")
    used: set = set()
    out = []
    for entry, size in zip(entries, shape):
        if entry is None:
            out.append(None)
            continue
        names = tuple(entry) if isinstance(entry, (tuple, list)) else (entry,)
        keep = [n for n in names if n in dims and n not in used]
        prod = 1
        for n in keep:
            prod *= dims[n]
        if not keep or prod == 1 or size % prod != 0:
            out.append(None)
            continue
        used.update(keep)
        out.append(tuple(keep) if len(keep) > 1 else keep[0])
    return P(*out)


# The seed's call sites bound this private spelling before the public export
# existed; kept as an alias so both names resolve.
_filter_spec = filter_spec


# ---------------------------------------------------------------------------
# activation constraints
# ---------------------------------------------------------------------------


def shard(x, *entries):
    """Constrain ``x`` to ``entries`` on the current mesh context.

    Entries beyond ``x.ndim`` are rejected; missing trailing entries mean
    replicated.  Outside any mesh context (single-device paper runs, unit
    tests) this is the identity, so model code can call it unconditionally.
    """
    mesh = compat.current_mesh()
    if mesh is None or not entries:
        return x
    spec = filter_spec(mesh, entries, x.shape)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_attn_qkv(q, k, v):
    """Constrain attention projections (B, T, heads, head_dim).

    Batch over the active batch axes; heads over "model" when the head count
    divides, otherwise head_dim (GQA kv heads are often fewer than the model
    axis — sharding head_dim keeps the tensor distributed instead of
    replicating it).  Under the DP plan the batch entry consumes "model" and
    the head entries are dropped by :func:`filter_spec`'s reuse rule.
    """
    mesh = compat.current_mesh()
    if mesh is None:
        return q, k, v
    model = _mesh_dims(mesh).get("model", 1)

    def one(t):
        if t.ndim < 4:
            return shard(t, *batch_spec())
        if model > 1 and t.shape[-2] % model == 0:
            ent = (None, "model", None)
        else:
            ent = (None, None, "model")
        return shard(t, *batch_spec(*ent))

    return one(q), one(k), one(v)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# Tensor-parallel name rules (matched against any component of the leaf's
# key path; ROW wins over COL when both appear).
#   COL — output-dim ("column") parallel: shard dim -1 over "model".
#   ROW — input-dim ("row") parallel: shard dim -2 over "model" (their
#         matmul contracts the sharded dim; XLA inserts the one all-reduce
#         the Megatron pattern pays per block).
# Everything unmatched (norm scales/biases, ssd scalars, router aux, ...)
# is replicated within the agent.
COL_PARALLEL = frozenset({
    "embed", "lm_head", "wq", "wk", "wv", "w_gate", "w_up", "router",
    "z_proj", "x_proj", "b_proj", "c_proj", "dt_proj", "proj_in", "head",
    "conv",
})
ROW_PARALLEL = frozenset({"wo", "w_down", "out_proj"})


def _path_names(path) -> tuple:
    names = []
    for e in path:
        key = getattr(e, "key", None)
        if key is None:
            key = getattr(e, "name", None)
        if key is None and hasattr(e, "idx"):
            key = str(e.idx)
        names.append(str(key))
    return tuple(names)


def _rule_entries(names, shape, *, fsdp_axis=None) -> list:
    """Trailing-dim entries for one leaf under the TP name rules."""
    nd = len(shape)
    ent: list = [None] * nd
    if nd == 0:
        return ent
    hit = set(names)
    if hit & ROW_PARALLEL:
        if nd >= 2:
            ent[-2] = "model"
            if fsdp_axis:
                ent[-1] = fsdp_axis
    elif hit & COL_PARALLEL:
        ent[-1] = "model"
        if fsdp_axis and nd >= 2:
            ent[-2] = fsdp_axis
    elif fsdp_axis:
        # unmatched leaves (norms, biases, ssd params): plain FSDP on the
        # trailing dim — pure memory sharding, gathered at use
        ent[-1] = fsdp_axis
    return ent


def param_specs(tree, mesh, *, lead: tuple = (), fsdp_axis: str | None = None):
    """Name-rule PartitionSpec tree for a parameter (or optimizer) pytree.

    ``lead`` names one mesh axis per *leading* dim of every leaf — the
    agent-stacked (P, A) dims of FedGAN state.  The TP rules anchor to the
    *trailing* dims, so the same rules serve stacked (lead + layer-stacked)
    and flat serving params.  ``fsdp_axis`` additionally shards the matmul-
    complement dim of every weight over that axis (weights gathered at use).
    Divisibility fallback is per-dim via :func:`filter_spec`.
    """
    lead = tuple(lead)

    def spec_of(path, leaf):
        shape = tuple(leaf.shape)
        n_lead = min(len(lead), len(shape))
        entries = list(lead[:n_lead]) + _rule_entries(
            _path_names(path), shape[n_lead:], fsdp_axis=fsdp_axis)
        return filter_spec(mesh, tuple(entries), shape)

    return jax.tree_util.tree_map_with_path(spec_of, tree)


def dp_param_specs(tree, mesh, *, lead: tuple = ()):
    """ZeRO-style specs for the intra-agent DP plan (``agents-data-dp``).

    Every leaf is *stored* sharded over "model" along its innermost evenly-
    divisible dim (weights, optimizer moments, norms alike) and gathered at
    use — the per-step wire cost becomes O(params) weight gathers + gradient
    reduce-scatters instead of O(activations·layers) TP all-reduces, which
    is the §Perf win ``test_dp_plan_reduces_collectives`` measures.
    """
    lead = tuple(lead)
    model = _mesh_dims(mesh).get("model", 1)

    def spec_of(path, leaf):
        shape = tuple(leaf.shape)
        n_lead = min(len(lead), len(shape))
        entries = list(lead[:n_lead]) + [None] * (len(shape) - n_lead)
        if model > 1:
            for i in range(len(shape) - 1, n_lead - 1, -1):
                if shape[i] % model == 0:
                    entries[i] = "model"
                    break
        return filter_spec(mesh, tuple(entries), shape)

    return jax.tree_util.tree_map_with_path(spec_of, tree)


# ---------------------------------------------------------------------------
# small utilities
# ---------------------------------------------------------------------------


def named_shardings(mesh, tree):
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh`` (non-spec
    leaves pass through, so mixed spec/None trees stay jit-compatible)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree)


def shape_of(x) -> tuple:
    """Shape of an array, ShapeDtypeStruct, or anything with ``.shape``."""
    return tuple(x.shape)
