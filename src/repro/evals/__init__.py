from repro.evals.fd import fd_score, frechet_distance, random_feature_fn
from repro.evals.kmeans import centroid_match_score, kmeans
from repro.evals.modes import mode_stats, wasserstein_1d_proj

__all__ = [
    "centroid_match_score", "fd_score", "frechet_distance", "kmeans",
    "mode_stats", "random_feature_fn", "wasserstein_1d_proj",
]
