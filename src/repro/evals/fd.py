"""Fréchet distance score (FID stand-in).

No pretrained Inception-v3 is available offline, so we keep the metric's
Gaussian-Fréchet form but swap the feature extractor for a *fixed* random
two-layer ReLU projection (seeded once per evaluation run; identical for
real and generated batches, so the score is comparable across K sweeps and
against the distributed-GAN baseline — which is exactly how the paper uses
FID in Fig. 1b / 2b).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def random_feature_fn(rng, in_dim: int, feat_dim: int = 64, hidden: int = 256):
    k1, k2 = jax.random.split(rng)
    w1 = jax.random.normal(k1, (in_dim, hidden)) / jnp.sqrt(in_dim)
    w2 = jax.random.normal(k2, (hidden, feat_dim)) / jnp.sqrt(hidden)

    def feats(x):
        h = jnp.maximum(x.reshape(x.shape[0], -1) @ w1, 0.0)
        return h @ w2

    return feats


def _sqrtm_psd(mat):
    """Matrix square root of a symmetric PSD matrix via eigh."""
    vals, vecs = np.linalg.eigh(mat)
    vals = np.clip(vals, 0.0, None)
    return (vecs * np.sqrt(vals)) @ vecs.T


def frechet_distance(feats_real, feats_fake) -> float:
    """d^2 = ||mu_r - mu_f||^2 + Tr(S_r + S_f - 2 (S_r^1/2 S_f S_r^1/2)^1/2)."""
    fr = np.asarray(feats_real, np.float64)
    ff = np.asarray(feats_fake, np.float64)
    mu_r, mu_f = fr.mean(0), ff.mean(0)
    cr = np.cov(fr, rowvar=False) + 1e-6 * np.eye(fr.shape[1])
    cf = np.cov(ff, rowvar=False) + 1e-6 * np.eye(ff.shape[1])
    sr = _sqrtm_psd(cr)
    mid = _sqrtm_psd(sr @ cf @ sr)
    d2 = float(np.sum((mu_r - mu_f) ** 2) + np.trace(cr + cf - 2 * mid))
    return max(d2, 0.0)


def fd_score(rng, real, fake, *, feat_dim: int = 64) -> float:
    """End-to-end FD between two sample batches (any shape; flattened)."""
    in_dim = int(np.prod(real.shape[1:]))
    feats = random_feature_fn(rng, in_dim, feat_dim)
    return frechet_distance(feats(real), feats(fake))
