"""k-means clustering + centroid-matching score for the time-series
experiments (paper Fig. 3/4: visually compare top-9 cluster centroids of
real vs generated profiles; we quantify the comparison with an optimal
assignment between the two centroid sets)."""
from __future__ import annotations

import numpy as np
from scipy.optimize import linear_sum_assignment


def _kmeanspp_init(x, k: int, rng):
    """k-means++ seeding (Arthur & Vassilvitskii): each next center is
    drawn proportional to squared distance from the chosen set.  The old
    uniform-point init regularly split one true cluster and merged two
    others, so even real-vs-real centroid matching scored far from zero —
    clustering noise drowning the signal the Fig. 3/4 comparison needs."""
    cent = np.empty((k, x.shape[1]))
    cent[0] = x[rng.randint(len(x))]
    d2 = ((x - cent[0]) ** 2).sum(-1)
    for j in range(1, k):
        total = d2.sum()
        if total <= 0:
            cent[j:] = x[rng.randint(len(x), size=k - j)]
            break
        cent[j] = x[rng.choice(len(x), p=d2 / total)]
        d2 = np.minimum(d2, ((x - cent[j]) ** 2).sum(-1))
    return cent


def kmeans(x, k: int, *, iters: int = 50, seed: int = 0):
    """Lloyd's algorithm with k-means++ seeding.  Returns (centroids (k,d)
    sorted by cluster size desc, assignments, sizes)."""
    x = np.asarray(x, np.float64)
    rng = np.random.RandomState(seed)
    cent = _kmeanspp_init(x, k, rng)
    for _ in range(iters):
        d = ((x[:, None, :] - cent[None]) ** 2).sum(-1)
        assign = d.argmin(1)
        for j in range(k):
            pts = x[assign == j]
            if len(pts):
                cent[j] = pts.mean(0)
    d = ((x[:, None, :] - cent[None]) ** 2).sum(-1)
    assign = d.argmin(1)
    sizes = np.bincount(assign, minlength=k)
    order = np.argsort(-sizes)
    remap = np.empty(k, int)
    remap[order] = np.arange(k)
    return cent[order], remap[assign], sizes[order]


def centroid_match_score(real, fake, *, k: int = 9, top: int = 9,
                         seed: int = 0) -> dict:
    """Cluster real and generated profiles separately, optimally match the
    top-``top`` centroids, and report the mean matched-centroid RMSE plus a
    baseline (RMSE against shuffled matching) for scale."""
    cr, _, _ = kmeans(real, k, seed=seed)
    cf, _, _ = kmeans(fake, k, seed=seed + 1)
    cr, cf = cr[:top], cf[:top]
    cost = np.sqrt(((cr[:, None, :] - cf[None]) ** 2).mean(-1))
    ri, ci = linear_sum_assignment(cost)
    matched = float(cost[ri, ci].mean())
    baseline = float(cost.mean())
    return {"matched_rmse": matched, "random_rmse": baseline,
            "real_centroids": cr, "fake_centroids": cf[ci]}
