"""Mode-coverage metrics for the mixed-Gaussian experiment (Fig. 6)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mode_stats(samples, modes, *, radius: float = 0.3):
    """Returns (modes_covered, high_quality_fraction, per-mode counts).

    A sample is 'high quality' if within ``radius`` of its nearest mode; a
    mode is covered if it captures >= 1% of the samples."""
    s = np.asarray(samples)
    m = np.asarray(modes)
    d = np.linalg.norm(s[:, None, :] - m[None, :, :], axis=-1)
    nearest = d.argmin(axis=1)
    near_dist = d.min(axis=1)
    hq = near_dist < radius
    counts = np.bincount(nearest[hq], minlength=m.shape[0])
    covered = int((counts >= max(1, int(0.01 * len(s)))).sum())
    return covered, float(hq.mean()), counts


def wasserstein_1d_proj(a, b, n_proj: int = 32, seed: int = 0) -> float:
    """Sliced 1-D Wasserstein distance (cheap distributional distance for the
    Swiss-roll comparison)."""
    rng = np.random.RandomState(seed)
    a = np.asarray(a)
    b = np.asarray(b)
    total = 0.0
    for _ in range(n_proj):
        v = rng.randn(a.shape[1])
        v /= np.linalg.norm(v) + 1e-12
        pa = np.sort(a @ v)
        pb = np.sort(b @ v)
        n = min(len(pa), len(pb))
        ia = np.linspace(0, len(pa) - 1, n).astype(int)
        ib = np.linspace(0, len(pb) - 1, n).astype(int)
        total += float(np.abs(pa[ia] - pb[ib]).mean())
    return total / n_proj
