# Pallas TPU kernels for the paper's compute hot spots, each validated in
# interpret mode against its pure-jnp ref.py oracle:
#   fedavg/          — fused weighted parameter average (the sync reduction)
#   qpack/           — block-scaled int8/int4 quantize + nibble pack/unpack
#                      (the repro.comm compressed-sync wire transform)
#   flash_attention/ — online-softmax GQA attention, causal + sliding window
#   ssd_scan/        — Mamba2 SSD chunked scan (intra-chunk + recurrent state)
