# Pallas TPU kernels for the paper's compute hot spots, each validated in
# interpret mode against its pure-jnp ref.py oracle:
#   fedavg/          — fused weighted parameter average (the sync reduction)
#   flash_attention/ — online-softmax GQA attention, causal + sliding window
#   ssd_scan/        — Mamba2 SSD chunked scan (intra-chunk + recurrent state)
