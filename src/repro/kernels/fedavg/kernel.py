"""Pallas TPU kernel for the FedGAN sync: fused weighted average of B
agent parameter shards.

This is the intermediary's eq. (2) compute: out = sum_i p_i * W_i over the
agent axis, fused with the dtype cast of a compressed sync.  On the wire the
average is an all-reduce; this kernel is the on-chip reduction used when the
agent-stacked shard is resident (e.g. per-host staging of the sync, or the
B-way average inside one pod's shard before the cross-pod collective of the
hierarchical mode).

Tiling: parameters are flattened to (B, N); the grid walks N in
``block``-wide tiles that sit in VMEM (8 agents x 512 f32 lanes = 16 KiB per
tile — deliberately small so the averaging stream overlaps the HBM loads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(w_ref, x_ref, o_ref, *, acc_dtype):
    # w_ref: (B, 1) f32 weights; x_ref: (B, block); o_ref: (1, block)
    x = x_ref[...].astype(acc_dtype)
    w = w_ref[...].astype(acc_dtype)
    o_ref[...] = jnp.sum(w * x, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fedavg_flat(weights: jax.Array, stacked: jax.Array, *,
                block: int = 512, interpret: bool = True) -> jax.Array:
    """stacked: (B, N) agent-stacked flat params; weights: (B,) summing to 1.
    Returns (N,) weighted average in stacked.dtype."""
    B, N = stacked.shape
    pad = (-N) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    n_blocks = stacked.shape[1] // block

    out = pl.pallas_call(
        functools.partial(_fedavg_kernel, acc_dtype=jnp.float32),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((B, 1), lambda i: (0, 0)),
            pl.BlockSpec((B, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, stacked.shape[1]), stacked.dtype),
        interpret=interpret,
    )(weights.astype(jnp.float32)[:, None], stacked)
    return out[0, :N]
