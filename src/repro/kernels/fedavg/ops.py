"""jit'd public wrapper for the fedavg kernel: pytree-level weighted average.

``interpret`` defaults to True off-TPU so the kernel body executes (and is
validated) on CPU; on a real TPU backend the compiled Mosaic kernel runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.kernel import fedavg_flat


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def fedavg_tree(weights, stacked_tree, *, block: int = 512,
                interpret: bool | None = None):
    """Weighted average over the leading agent axis of every leaf of
    ``stacked_tree`` (leaves shaped (B, ...) or (P, A, ...) flattened by the
    caller).  Returns the averaged tree (agent axis removed)."""
    interp = _default_interpret() if interpret is None else interpret
    w = jnp.reshape(weights, (-1,))
    B = int(w.shape[0])

    def avg(x):
        # consume as many leading dims as make up the agent axis (B or (P, A))
        prod, nd = 1, 0
        while prod < B:
            prod *= x.shape[nd]
            nd += 1
        if prod != B:
            raise ValueError(f"leaf shape {x.shape} incompatible with {B} agents")
        flat = x.reshape(B, -1)
        out = fedavg_flat(w, flat, block=block, interpret=interp)
        return out.reshape(x.shape[nd:]).astype(x.dtype)

    return jax.tree_util.tree_map(avg, stacked_tree)
