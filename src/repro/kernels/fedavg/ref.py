"""Pure-jnp oracle for the fedavg kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg_flat_ref(weights: jax.Array, stacked: jax.Array) -> jax.Array:
    """stacked: (B, N); weights: (B,).  f32 accumulate, output in input dtype."""
    acc = jnp.einsum("b,bn->n", weights.astype(jnp.float32),
                     stacked.astype(jnp.float32))
    return acc.astype(stacked.dtype)


def fedavg_tree_ref(weights, stacked_tree):
    """Weighted average over the leading agent axis of every leaf."""
    w = weights.reshape(-1).astype(jnp.float32)

    def avg(x):
        flat = x.reshape(w.shape[0], -1)
        return fedavg_flat_ref(w, flat).reshape(x.shape[1:]).astype(x.dtype)

    return jax.tree_util.tree_map(avg, stacked_tree)
