"""Pallas TPU flash attention (online softmax), GQA + causal + sliding window.

TPU adaptation notes (vs the CUDA FlashAttention formulation):
  * tiles are BlockSpec-mapped VMEM windows; the MXU wants the contraction
    dims to be multiples of 128 — block_q/block_k default to 128;
  * the kv loop is the innermost ("arbitrary") grid dimension, with the
    running (max, denom, acc) held in VMEM scratch across grid steps — the
    revisiting-output pattern — instead of a warp-level register pipeline;
  * causal + sliding-window block skipping happens at two levels: fully
    masked kv blocks are skipped via pl.when (no MXU work issued), partially
    masked blocks apply an element mask.

Layout: q (B, nh, T, hd), k/v (B, nkv, S, hd); GQA maps query head h to kv
head h // (nh // nkv) in the index_map, so no kv replication is materialised.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, block_q, block_k, causal, window, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level skip decision (static shapes, dynamic predicate)
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window > 0:
        # kv block entirely below the window of every query row in the block
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: (B, nh, T, hd); k/v: (B, nkv, S, hd); returns (B, nh, T, hd)."""
    B, nh, T, hd = q.shape
    _, nkv, S, _ = k.shape
    group = nh // nkv
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, T)
    bk = min(block_k, S)
    pad_q = (-T) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Tq, Sk = q.shape[2], k.shape[2]

    grid = (B, nh, Tq // bq, Sk // bk)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, window=window, seq_k=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T]
