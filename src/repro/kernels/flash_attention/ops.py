"""Public flash-attention wrapper in model layout (B, T, nh, hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, T, nh, hd); k/v: (B, S, nkv, hd) -> (B, T, nh, hd)."""
    interp = _default_interpret() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interp)
    return jnp.swapaxes(out, 1, 2)
