"""Pure-jnp oracle for flash attention (GQA + causal + sliding window)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, nh, T, hd); k/v: (B, nkv, S, hd) -> (B, nh, T, hd)."""
    B, nh, T, hd = q.shape
    _, nkv, S, _ = k.shape
    group = nh // nkv
    qg = q.reshape(B, nkv, group, T, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, kf) / math.sqrt(hd)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", p, vf)
    return o.reshape(B, nh, T, hd).astype(q.dtype)
