"""Pallas TPU kernels for the comm codecs' bit-packing: block-scaled
int8/int4 quantize (pack) and dequantize (unpack).

This is the wire transform of a compressed sync (repro.comm): before the
agent-axis all-reduce each agent's flat parameter stream is cut into
``block``-wide tiles, every tile gets one f16 scale (max-abs / qmax, the
value that actually ships, so encode and decode agree bit-for-bit), and the
payload is rounded to ``bits``-wide signed codes — two codes per byte for
int4.  The grid walks the flat stream exactly like ``kernels/fedavg``:
(R, block) tiles resident in VMEM so the quantize stream overlaps the HBM
loads, with the (R, 1) scale column written alongside.

Zero-blocks: a tile whose max-abs underflows f16 gets scale 0 on the wire
and decodes to exact zeros — the decode-side ``where`` keeps the division
well-defined without inventing a floor the wire couldn't represent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wire_scale(amax, qmax, scale_dtype):
    """The f16 scale that ships, and the f32 value both ends divide by.
    Clamped to the scale dtype's finite range: an overflowing block clips
    hard (error feedback absorbs it) instead of shipping inf and decoding
    0 * inf = NaN."""
    fmax = float(jnp.finfo(scale_dtype).max)
    s_wire = jnp.minimum(amax / qmax, fmax).astype(scale_dtype)
    s_dec = jnp.where(s_wire > 0, s_wire.astype(jnp.float32), 1.0)
    return s_wire, s_dec


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax, scale_dtype):
    # x_ref: (R, block) source tile; q_ref: (R, block) int8 codes;
    # s_ref: (R, 1) wire-dtype scales
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    s_wire, s_dec = _wire_scale(amax, qmax, scale_dtype)
    q = jnp.clip(jnp.round(x / s_dec), -qmax, qmax)
    q_ref[...] = q.astype(q_ref.dtype)
    s_ref[...] = s_wire


def _dequant_kernel(q_ref, s_ref, o_ref):
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = q_ref[...].astype(jnp.float32) * jnp.where(s > 0, s, 1.0)


def _pack4_kernel(q_ref, p_ref):
    # q_ref: (R, block) int8 codes in [-7, 7]; p_ref: (R, block//2) uint8 —
    # consecutive pairs packed low-nibble-first
    q = q_ref[...].astype(jnp.uint8) & 0xF
    pairs = q.reshape(q.shape[0], -1, 2)
    p_ref[...] = pairs[:, :, 0] | (pairs[:, :, 1] << 4)


def _unpack4_kernel(p_ref, q_ref):
    p = p_ref[...]
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    # sign-extend the 4-bit two's complement nibbles
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    q_ref[...] = jnp.stack([lo, hi], axis=-1).reshape(q_ref.shape)


@functools.partial(jax.jit, static_argnames=("qmax", "block", "scale_dtype",
                                             "interpret"))
def quant_flat(x: jax.Array, *, qmax: int, block: int = 128,
               scale_dtype=jnp.float16, interpret: bool = True):
    """x: (R, N) with N a multiple of ``block``.  Returns (codes int8 (R, N),
    scales ``scale_dtype`` (R, N // block))."""
    R, N = x.shape
    n_blocks = N // block
    return pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax, scale_dtype=scale_dtype),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((R, block), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((R, block), lambda i: (0, i)),
                   pl.BlockSpec((R, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((R, N), jnp.int8),
                   jax.ShapeDtypeStruct((R, n_blocks), scale_dtype)],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dequant_flat(q: jax.Array, scales: jax.Array, *, block: int = 128,
                 interpret: bool = True) -> jax.Array:
    """codes (R, N) + scales (R, N // block) -> f32 (R, N)."""
    R, N = q.shape
    return pl.pallas_call(
        _dequant_kernel,
        grid=(N // block,),
        in_specs=[pl.BlockSpec((R, block), lambda i: (0, i)),
                  pl.BlockSpec((R, 1), lambda i: (0, i))],
        out_specs=pl.BlockSpec((R, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, N), jnp.float32),
        interpret=interpret,
    )(q, scales)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pack4_flat(q: jax.Array, *, block: int = 128,
               interpret: bool = True) -> jax.Array:
    """int8 codes (R, N) in [-7, 7] -> packed uint8 nibbles (R, N // 2)."""
    R, N = q.shape
    return pl.pallas_call(
        _pack4_kernel,
        grid=(N // block,),
        in_specs=[pl.BlockSpec((R, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((R, block // 2), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, N // 2), jnp.uint8),
        interpret=interpret,
    )(q)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unpack4_flat(p: jax.Array, *, block: int = 128,
                 interpret: bool = True) -> jax.Array:
    """packed uint8 nibbles (R, M) -> int8 codes (R, 2 M)."""
    R, M = p.shape
    return pl.pallas_call(
        _unpack4_kernel,
        grid=(M // (block // 2),),
        in_specs=[pl.BlockSpec((R, block // 2), lambda i: (0, i))],
        out_specs=pl.BlockSpec((R, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, 2 * M), jnp.int8),
        interpret=interpret,
    )(p)
