"""Public pack/unpack entry points for the comm codecs.

``quantize_blocks`` / ``dequantize_blocks`` flatten any batch of flat
streams to (R, N), pad N up to the block multiple, and run either the
Pallas kernels (the device path; interpret-mode execution validates the
kernel bodies off-TPU) or the pure-jnp ref oracle.  Like
``kernels/fedavg``, the two paths are interchangeable — ``use_kernel=None``
picks the kernel on a real TPU backend and the vectorized ref elsewhere, so
the jitted round on CPU never pays interpret-mode overhead.

Wire format (what ``repro.comm`` bills): ``ceil(N * bits / 8)`` payload
bytes + one f16 scale per ``block`` — padding lanes are a tiling artifact
and are trimmed before anything ships.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qpack import kernel, ref


def _use_kernel_default() -> bool:
    return jax.default_backend() == "tpu"


def _to_rows(x: jax.Array, block: int):
    """(..., N) -> (R, Np) padded to the block multiple, + restore info."""
    lead = x.shape[:-1]
    N = x.shape[-1]
    rows = x.reshape(-1, N)
    pad = (-N) % block
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
    return rows, lead, N


def quantize_blocks(x: jax.Array, *, bits: int = 8, block: int = 128,
                    use_kernel: bool | None = None):
    """x: (..., N) -> (payload, scales).

    payload: int8 codes (..., Np) for bits=8, packed uint8 nibbles
    (..., Np // 2) for bits=4 (Np = N padded to ``block``); scales: f16
    (..., Np // block)."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if block < 2 or block % 2:
        raise ValueError(f"block must be even and >= 2, got {block}")
    kern = _use_kernel_default() if use_kernel is None else use_kernel
    qmax = 2 ** (bits - 1) - 1
    rows, lead, _ = _to_rows(x, block)
    if kern:
        q, s = kernel.quant_flat(rows, qmax=qmax, block=block,
                                 interpret=jax.default_backend() != "tpu")
        if bits == 4:
            q = kernel.pack4_flat(q, block=block,
                                  interpret=jax.default_backend() != "tpu")
    else:
        q, s = ref.quant_blocks_ref(rows, qmax=qmax, block=block)
        if bits == 4:
            q = ref.pack4_ref(q)
    return q.reshape(lead + q.shape[1:]), s.reshape(lead + s.shape[1:])


def roundtrip_blocks(x: jax.Array, *, bits: int = 8, block: int = 128,
                     use_kernel: bool | None = None) -> jax.Array:
    """Fused quantize→dequantize: the lossy wire image without the nibble
    pack/unpack (pack4∘unpack4 is a bit-exact identity — wasted work on
    the sync hot path, where only the values matter, not the wire bytes)."""
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    kern = _use_kernel_default() if use_kernel is None else use_kernel
    qmax = 2 ** (bits - 1) - 1
    rows, lead, n = _to_rows(x, block)
    if kern:
        interp = jax.default_backend() != "tpu"
        q, s = kernel.quant_flat(rows, qmax=qmax, block=block,
                                 interpret=interp)
        out = kernel.dequant_flat(q, s, block=block, interpret=interp)
    else:
        q, s = ref.quant_blocks_ref(rows, qmax=qmax, block=block)
        out = ref.dequant_blocks_ref(q, s, block=block)
    return out[:, :n].reshape(lead + (n,))


def dequantize_blocks(payload: jax.Array, scales: jax.Array, *, n: int,
                      bits: int = 8, block: int = 128,
                      use_kernel: bool | None = None) -> jax.Array:
    """Inverse of :func:`quantize_blocks`; returns f32 (..., n) with the
    padding lanes trimmed."""
    kern = _use_kernel_default() if use_kernel is None else use_kernel
    lead = payload.shape[:-1]
    p = payload.reshape((-1,) + payload.shape[-1:])
    s = scales.reshape((-1,) + scales.shape[-1:])
    if kern:
        interp = jax.default_backend() != "tpu"
        q = kernel.unpack4_flat(p, block=block, interpret=interp) \
            if bits == 4 else p
        out = kernel.dequant_flat(q, s, block=block, interpret=interp)
    else:
        q = ref.unpack4_ref(p) if bits == 4 else p
        out = ref.dequant_blocks_ref(q, s, block=block)
    return out[:, :n].reshape(lead + (n,))
