"""Pure-jnp oracle for the qpack kernels.  Every op mirrors the kernel's
arithmetic exactly (same f16 scale round-trip, same rounding, same nibble
order) so kernel-vs-ref parity is bit-identical, not merely close."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_blocks_ref(x: jax.Array, *, qmax: int, block: int,
                     scale_dtype=jnp.float16):
    """x: (R, N), N a multiple of ``block``.  Returns (codes int8 (R, N),
    scales (R, N // block)).  The scale that ships is the f16 cast of
    max-abs / qmax, clamped to f16's finite range; BOTH ends divide by that
    f16 value (1.0 for underflowed all-but-zero blocks), so encode and
    decode agree exactly."""
    R, N = x.shape
    tiles = x.astype(jnp.float32).reshape(R, N // block, block)
    amax = jnp.max(jnp.abs(tiles), axis=-1, keepdims=True)
    # clamp to the wire dtype's finite range: overflowing blocks clip hard
    # (EF absorbs it) instead of shipping inf and decoding 0*inf = NaN
    fmax = float(jnp.finfo(scale_dtype).max)
    s_wire = jnp.minimum(amax / qmax, fmax).astype(scale_dtype)
    s_dec = jnp.where(s_wire > 0, s_wire.astype(jnp.float32), 1.0)
    q = jnp.clip(jnp.round(tiles / s_dec), -qmax, qmax).astype(jnp.int8)
    return q.reshape(R, N), s_wire[..., 0]


def dequant_blocks_ref(q: jax.Array, scales: jax.Array, *,
                       block: int) -> jax.Array:
    R, N = q.shape
    s = scales.astype(jnp.float32)
    s = jnp.where(s > 0, s, 1.0)[..., None]
    tiles = q.astype(jnp.float32).reshape(R, N // block, block)
    return (tiles * s).reshape(R, N)


def pack4_ref(q: jax.Array) -> jax.Array:
    """int8 codes (R, N) in [-7, 7] -> uint8 (R, N // 2), low nibble first."""
    pairs = (q.astype(jnp.uint8) & 0xF).reshape(q.shape[0], -1, 2)
    return pairs[:, :, 0] | (pairs[:, :, 1] << 4)


def unpack4_ref(p: jax.Array) -> jax.Array:
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)
