"""Fused Pallas kernels for the coded-sync hot path.

``coded_sync`` composed from the qpack pieces runs EF-add → quantize →
dequantize → weighted reduce → downlink re-quantize as separate dispatches,
each materializing its full (B, N) intermediate in HBM.  The fused kernel
here does the whole chain per VMEM tile: for every ``block``-wide column of
the agent-stacked stream it adds the carried uplink residual, builds the
per-agent wire image (block max-abs → f16 scale → rounded codes, EXACTLY
the qpack arithmetic — ``_wire_scale`` is imported, not re-derived), reduces
the decoded images over the agent axis with the §3.1 weights, adds the
server's downlink residual, re-encodes the average for the broadcast, and
emits the synced block plus both new residuals — the per-agent wire image
never exists in HBM at all.

Bit parity with the composed pipeline is exact, not approximate: the codes
are integral f32 in [-qmax, qmax] (an int8 cast round-trips them
losslessly, so skipping the cast changes nothing), and the reduce is the
same materialized w·x then sum in agent order as
``collectives.weighted_mean``.

``adam_sync_flat`` fuses the other half of the round boundary: the K-th
local Adam step and the uplink wire cast in one pass over the parameters —
moment update, bias-corrected step, and block-scaled quantize of the new
parameters without re-reading them from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qpack.kernel import _wire_scale


def _qsync_kernel(*refs, grid, qmax, scale_dtype, has_ef, has_ef_down,
                  barrier):
    # refs: w (B, 1) f32, x (B, block), [e (B, block)], [ed (1, block)] ->
    #       synced (1, block), [new_e (B, block)], [new_ed (1, block)]
    it = iter(refs)
    w_ref, x_ref = next(it), next(it)
    e_ref = next(it) if has_ef else None
    ed_ref = next(it) if has_ef_down else None
    o_ref = next(it)
    ne_ref = next(it) if has_ef else None
    ned_ref = next(it) if has_ef_down else None

    x = x_ref[...].astype(jnp.float32)
    y = x + e_ref[...] if has_ef else x
    # uplink wire image: per-agent block quantize -> dequantize (qpack math)
    amax = jnp.max(jnp.abs(y), axis=1, keepdims=True)
    _, s_dec = _wire_scale(amax, qmax, scale_dtype)
    dq = jnp.clip(jnp.round(y / s_dec), -qmax, qmax) * s_dec
    # eq. (2): weighted reduce over the agent axis — products materialized
    # before the sum AND reduced in the (P, A) grid shape, because XLA's
    # multi-axis reduce groups differently from a flat axis-0 sum; only the
    # grid-shaped reduce is bit-identical to collectives.weighted_mean
    prod = (w_ref[...] * dq).reshape(grid + (-1,))
    if barrier:
        # interpret mode only: keep the product from fusing into the
        # reduction, which changes XLA:CPU's accumulation grouping — the
        # standalone reduce is the one that matches weighted_mean bit-for-bit
        prod = jax.lax.optimization_barrier(prod)
    m = jnp.sum(prod, axis=tuple(range(len(grid))))[None, :]
    # downlink: server residual + re-encode of the average
    yd = m + ed_ref[...] if has_ef_down else m
    amax_d = jnp.max(jnp.abs(yd), axis=1, keepdims=True)
    _, sd_dec = _wire_scale(amax_d, qmax, scale_dtype)
    dqd = jnp.clip(jnp.round(yd / sd_dec), -qmax, qmax) * sd_dec
    o_ref[...] = dqd
    if has_ef:
        ne_ref[...] = y - dq
    if has_ef_down:
        ned_ref[...] = yd - dqd


@functools.partial(jax.jit, static_argnames=("qmax", "block", "scale_dtype",
                                             "interpret"))
def qsync_flat(weights, stacked, ef=None, ef_down=None, *, qmax: int,
               block: int = 128, scale_dtype=jnp.float16,
               interpret: bool = True):
    """weights shaped like the agent grid ((P, A) or (B,)) with B total
    entries, stacked (B, N) f32 with N a multiple of ``block``; optional
    per-agent uplink residual ``ef`` (B, N) and shared downlink residual
    ``ef_down`` (N,).  The reduce runs over the weights' own grid shape
    (bit parity with ``collectives.weighted_mean``).  Returns
    ``(synced (N,), new_ef | None, new_ef_down | None)`` — residual
    outputs mirror the inputs."""
    grid = weights.shape
    B, N = stacked.shape
    has_ef = ef is not None
    has_ef_down = ef_down is not None
    inputs = [weights.astype(jnp.float32).reshape(-1, 1), stacked]
    in_specs = [pl.BlockSpec((B, 1), lambda i: (0, 0)),
                pl.BlockSpec((B, block), lambda i: (0, i))]
    if has_ef:
        inputs.append(ef)
        in_specs.append(pl.BlockSpec((B, block), lambda i: (0, i)))
    if has_ef_down:
        inputs.append(ef_down.reshape(1, N))
        in_specs.append(pl.BlockSpec((1, block), lambda i: (0, i)))
    out_shape = [jax.ShapeDtypeStruct((1, N), jnp.float32)]
    out_specs = [pl.BlockSpec((1, block), lambda i: (0, i))]
    if has_ef:
        out_shape.append(jax.ShapeDtypeStruct((B, N), jnp.float32))
        out_specs.append(pl.BlockSpec((B, block), lambda i: (0, i)))
    if has_ef_down:
        out_shape.append(jax.ShapeDtypeStruct((1, N), jnp.float32))
        out_specs.append(pl.BlockSpec((1, block), lambda i: (0, i)))
    outs = pl.pallas_call(
        functools.partial(_qsync_kernel, grid=grid, qmax=qmax,
                          scale_dtype=scale_dtype, has_ef=has_ef,
                          has_ef_down=has_ef_down, barrier=interpret),
        grid=(N // block,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*inputs)
    it = iter(outs)
    synced = next(it)[0]
    new_e = next(it) if has_ef else None
    new_ed = next(it)[0] if has_ef_down else None
    return synced, new_e, new_ed


def _adam_sync_kernel(*refs, b1, b2, eps, qmax, scale_dtype, pin):
    # refs: h (1, 3) f32 [lr, bc1, bc2], then (B, block) tiles of
    # params/grads/mu/nu -> new params/mu/nu tiles, int8 codes, (B, 1) wire
    # scales, and (pin only) the step/quotient pinning outputs.
    #
    # Bit parity with the jitted oracle needs every mul/add chain pinned to
    # ONE materialization: XLA:CPU re-contracts a*x + b*y (FMA) and the
    # lr*(mu/bc1)/(sqrt(nu/bc2)+eps) chain per fusion context, below the
    # level HLO barriers alone control — barriers between the stages AND
    # emitting the two quotients + step as REAL outputs is the combination
    # that holds bit-exact across the randomized parity sweep.  The pinning
    # outputs exist only on the interpret path (pin=True) and are dropped
    # by ``ops.adam_sync_flat``.
    (h_ref, p_ref, g_ref, mu_ref, nu_ref,
     po_ref, mo_ref, no_ref, q_ref, s_ref) = refs[:10]
    lr, bc1, bc2 = h_ref[0, 0], h_ref[0, 1], h_ref[0, 2]
    g = g_ref[...].astype(jnp.float32)
    mu = b1 * mu_ref[...] + (1 - b1) * g
    nu = b2 * nu_ref[...] + (1 - b2) * jnp.square(g)
    if pin:
        mu, nu = jax.lax.optimization_barrier((mu, nu))
    q1 = mu / bc1
    q2 = jnp.sqrt(nu / bc2) + eps
    if pin:
        q1, q2 = jax.lax.optimization_barrier((q1, q2))
    step = lr * q1 / q2
    if pin:
        step = jax.lax.optimization_barrier(step)
    p = p_ref[...] - step
    po_ref[...] = p
    mo_ref[...] = mu
    no_ref[...] = nu
    amax = jnp.max(jnp.abs(p), axis=1, keepdims=True)
    s_wire, s_dec = _wire_scale(amax, qmax, scale_dtype)
    q_ref[...] = jnp.clip(jnp.round(p / s_dec), -qmax, qmax).astype(jnp.int8)
    s_ref[...] = s_wire
    if pin:
        for r, v in zip(refs[10:], (step, q1, q2)):
            r[...] = v


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "qmax",
                                             "block", "scale_dtype",
                                             "interpret"))
def adam_sync_flat(hyper, params, grads, mu, nu, *, b1: float, b2: float,
                   eps: float, qmax: int, block: int = 128,
                   scale_dtype=jnp.float16, interpret: bool = True):
    """One fused pass over (B, N) f32 params: Adam moment update +
    bias-corrected step + block-scaled quantize of the new params (the
    uplink wire cast of the K-th local step).  ``hyper`` is the (1, 3) f32
    [lr, bc1, bc2] scalar row (bias corrections precomputed by the caller,
    identically to ``optim.Adam.update``).  Returns (new_params, new_mu,
    new_nu, codes int8 (B, N), scales (B, N // block)) — in interpret mode
    followed by three pinning outputs (step and the two quotients) that
    exist only to fix the compiler's materialization choices; callers drop
    them OUTSIDE this jit boundary (slicing inside would let dead-code
    elimination re-roll the codegen the parity depends on)."""
    B, N = params.shape
    n_blocks = N // block
    tile = pl.BlockSpec((B, block), lambda i: (0, i))
    out_specs = [tile, tile, tile, tile,
                 pl.BlockSpec((B, 1), lambda i: (0, i))]
    out_shape = [jax.ShapeDtypeStruct((B, N), jnp.float32),
                 jax.ShapeDtypeStruct((B, N), jnp.float32),
                 jax.ShapeDtypeStruct((B, N), jnp.float32),
                 jax.ShapeDtypeStruct((B, N), jnp.int8),
                 jax.ShapeDtypeStruct((B, n_blocks), scale_dtype)]
    if interpret:
        out_specs += [tile, tile, tile]
        out_shape += [jax.ShapeDtypeStruct((B, N), jnp.float32)] * 3
    outs = pl.pallas_call(
        functools.partial(_adam_sync_kernel, b1=b1, b2=b2, eps=eps,
                          qmax=qmax, scale_dtype=scale_dtype,
                          pin=interpret),
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, 3), lambda i: (0, 0)),
                  tile, tile, tile, tile],
        out_specs=out_specs, out_shape=out_shape,
        interpret=interpret)(hyper, params, grads, mu, nu)
    return tuple(outs)
