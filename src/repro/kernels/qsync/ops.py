"""Public entry points for the fused coded sync.

``qsync_flat`` runs one agent-stacked flat stream through the fused
EF-add → quantize → dequantize → weighted-reduce → re-encode pass, padding
to the block multiple like ``qpack.ops`` and trimming the tiling lanes on
the way out.  Like the other kernel packages, ``use_kernel=None`` picks the
Pallas kernel on a real TPU backend and the vectorized ref oracle
elsewhere, so the jitted round on CPU never pays interpret-mode overhead.

``qsync_leaves`` is the flatten-once leaf bucketer (the ``ClientStore``
gather/scatter trick applied to ``coded_sync``): every f32 leaf of a
(P, A)-stacked subtree is flattened to (B, n_i), padded PER LEAF to the
block multiple, and concatenated into one (B, N_flat) buffer — so syncing a
whole subtree is a constant number of dispatches instead of O(leaves).
Padding each leaf before concatenating (rather than once at the end)
preserves every leaf's block boundaries, which is what keeps the bucketed
sync bit-identical to the per-leaf composed pipeline: the quantizer sees
exactly the same tiles either way, and the zero pad lanes neither move a
block's max-abs nor survive the trim.

``adam_sync_flat`` / ``adam_sync_tree`` fuse the K-th local Adam step with
the uplink wire cast (moment update + bias-corrected step + block quantize
of the new params in one pass); the tree form buckets the leaves the same
way and returns the wire image of the bucketed stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qsync import kernel, ref


def _use_kernel_default() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _check(bits: int, block: int):
    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    if block < 2 or block % 2:
        raise ValueError(f"block must be even and >= 2, got {block}")


def qsync_flat(weights, stacked, ef=None, ef_down=None, *, bits: int = 8,
               block: int = 128, use_kernel: bool | None = None):
    """Fused coded sync of one flat stream: weights shaped like the agent
    grid ((P, A) or (B,)), stacked (B, n) f32 (any n), optional uplink
    residual ef (B, n) and downlink residual ef_down (n,).  Returns
    ``(synced (n,), new_ef | None, new_ef_down | None)`` — bit-identical
    to the composed roundtrip→weighted_mean→roundtrip pipeline (the
    reduce runs in the weights' grid shape, see ``ref.qsync_flat_ref``)."""
    _check(bits, block)
    kern = _use_kernel_default() if use_kernel is None else use_kernel
    qmax = 2 ** (bits - 1) - 1
    B, n = stacked.shape
    pad = (-n) % block
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        if ef is not None:
            ef = jnp.pad(ef, ((0, 0), (0, pad)))
        if ef_down is not None:
            ef_down = jnp.pad(ef_down, (0, pad))
    if kern:
        synced, ne, ned = kernel.qsync_flat(weights, stacked, ef, ef_down,
                                            qmax=qmax, block=block,
                                            interpret=_interpret())
    else:
        synced, ne, ned = ref.qsync_flat_ref(weights, stacked, ef, ef_down,
                                             qmax=qmax, block=block)
    return (synced[:n],
            ne[:, :n] if ne is not None else None,
            ned[:n] if ned is not None else None)


def fusable_leaf(x) -> bool:
    """Whether a leaf can ride the fused path: (P, A)-stacked float32 (the
    kernel reduces in f32 — a bf16 leaf would reduce wider than the
    composed pipeline, breaking bit parity, so it falls back)."""
    return (hasattr(x, "dtype") and x.dtype == jnp.float32
            and getattr(x, "ndim", 0) >= 2)


def _bucket(leaves, B: int, block: int):
    """[(B, ...)] -> one (B, N_flat) buffer + per-leaf (offset, n) spans.
    Each leaf is padded to its own block multiple before concatenation so
    block boundaries match the per-leaf pipeline exactly."""
    cols, spans, off = [], [], 0
    for x in leaves:
        flat = x.reshape(B, -1)
        n = flat.shape[1]
        pad = (-n) % block
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        cols.append(flat)
        spans.append((off, n))
        off += n + pad
    return (cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1),
            spans)


def qsync_leaves(leaves, weights, ef_leaves=None, ef_down_leaves=None, *,
                 bits: int = 8, block: int = 128,
                 use_kernel: bool | None = None):
    """Bucketed fused sync of a group of (P, A, ...) f32 leaves: O(1)
    dispatches for the whole group.  ``ef_leaves`` match the leaves'
    shapes; ``ef_down_leaves`` their per-agent shapes (leaf.shape[2:]).
    Returns ``(synced, new_ef, new_ef_down)`` leaf lists — synced leaves
    broadcast back over the agent grid like ``coded_sync``."""
    _check(bits, block)
    B = int(weights.size)
    stacked, spans = _bucket(leaves, B, block)
    ef = None
    if ef_leaves is not None:
        ef, _ = _bucket(ef_leaves, B, block)
    ef_down = None
    if ef_down_leaves is not None:
        ef_down, _ = _bucket([e[None] for e in ef_down_leaves], 1, block)
        ef_down = ef_down[0]
    synced, ne, ned = qsync_flat(weights, stacked, ef, ef_down, bits=bits,
                                 block=block, use_kernel=use_kernel)
    outs, new_e, new_ed = [], [], []
    for x, (off, n) in zip(leaves, spans):
        seg = synced[off:off + n]
        outs.append(jnp.broadcast_to(seg.reshape(x.shape[2:]), x.shape))
        new_e.append(ne[:, off:off + n].reshape(x.shape)
                     if ne is not None else None)
        new_ed.append(ned[off:off + n].reshape(x.shape[2:])
                      if ned is not None else None)
    return outs, new_e, new_ed


def adam_sync_flat(params, grads, mu, nu, *, lr, count, b1: float = 0.5,
                   b2: float = 0.999, eps: float = 1e-8, bits: int = 8,
                   block: int = 128, use_kernel: bool | None = None):
    """Fused Adam step + uplink wire cast over (B, n) f32 params.  ``count``
    is the PRE-increment step counter (``opt_state["count"]``); the bias
    corrections are computed here exactly as ``optim.Adam.update`` does.
    Returns (new_params (B, n), new_mu, new_nu, codes int8 (B, Np),
    scales f16 (B, Np // block)) — codes/scales keep the kernel's padded
    lanes, like ``qpack.ops.quantize_blocks``."""
    _check(bits, block)
    kern = _use_kernel_default() if use_kernel is None else use_kernel
    qmax = 2 ** (bits - 1) - 1
    c = (count + 1).astype(jnp.float32)
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       1.0 - b1 ** c, 1.0 - b2 ** c]).reshape(1, 3)
    B, n = params.shape
    pad = (-n) % block
    if pad:
        params, grads, mu, nu = (jnp.pad(a, ((0, 0), (0, pad)))
                                 for a in (params, grads, mu, nu))
    if kern:
        # [:5] drops the interpret-mode pinning outputs OUTSIDE the kernel's
        # jit boundary (see kernel.adam_sync_flat)
        p2, mu2, nu2, q, s = kernel.adam_sync_flat(
            hyper, params, grads, mu, nu, b1=b1, b2=b2, eps=eps, qmax=qmax,
            block=block, interpret=_interpret())[:5]
    else:
        p2, mu2, nu2, q, s = ref.adam_sync_flat_ref(
            hyper, params, grads, mu, nu, b1=b1, b2=b2, eps=eps, qmax=qmax,
            block=block)
    return p2[:, :n], mu2[:, :n], nu2[:, :n], q, s


def adam_sync_tree(params, grads, opt_state, *, lr, b1: float = 0.5,
                   b2: float = 0.999, eps: float = 1e-8, bits: int = 8,
                   block: int = 128, use_kernel: bool | None = None):
    """Tree form: bucket every (B, ...) leaf into one (B, N_flat) buffer
    and run ONE fused Adam+quantize pass.  Returns (new_params,
    new_opt_state, codes, scales) — trees mirror the inputs; codes/scales
    are the uplink wire image of the bucketed stream."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = jax.tree_util.tree_leaves(grads)
    mu_leaves = jax.tree_util.tree_leaves(opt_state["mu"])
    nu_leaves = jax.tree_util.tree_leaves(opt_state["nu"])
    B = leaves[0].shape[0]
    p, spans = _bucket(leaves, B, block)
    g, _ = _bucket(g_leaves, B, block)
    mu, _ = _bucket(mu_leaves, B, block)
    nu, _ = _bucket(nu_leaves, B, block)
    p2, mu2, nu2, q, s = adam_sync_flat(p, g, mu, nu, lr=lr,
                                        count=opt_state["count"], b1=b1,
                                        b2=b2, eps=eps, bits=bits,
                                        block=block, use_kernel=use_kernel)
    unflat = jax.tree_util.tree_unflatten

    def split(flat):
        return unflat(treedef, [flat[:, off:off + n].reshape(x.shape)
                                for x, (off, n) in zip(leaves, spans)])

    new_state = {"count": opt_state["count"] + 1,
                 "mu": split(mu2), "nu": split(nu2)}
    return split(p2), new_state, q, s
