"""Pure-jnp oracle for the fused qsync kernels.

Built from the same qpack ref pieces the composed ``coded_sync`` pipeline
uses (``quant_blocks_ref`` / ``dequant_blocks_ref``) plus the
``collectives.weighted_mean`` contraction written out inline, so
fused-vs-composed parity is bit-identical by construction — this oracle IS
the composed pipeline, minus the per-leaf Python loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qpack.ref import dequant_blocks_ref, quant_blocks_ref


def qsync_flat_ref(weights, stacked, ef=None, ef_down=None, *, qmax: int,
                   block: int, scale_dtype=jnp.float16):
    """Same contract as ``kernel.qsync_flat``: weights shaped like the
    agent grid ((P, A) or (B,)), stacked (B, N) with N a block multiple,
    optional residuals; returns ``(synced (N,), new_ef | None,
    new_ef_down | None)``.  The reduce runs in the weights' own grid shape
    — XLA's multi-axis reduce groups differently from a flat axis-0 sum,
    and only the grid-shaped reduce matches ``collectives.weighted_mean``
    bit for bit."""
    grid = weights.shape
    w = weights.astype(jnp.float32).reshape(-1, 1)
    y = stacked + ef if ef is not None else stacked
    q, s = quant_blocks_ref(y, qmax=qmax, block=block,
                            scale_dtype=scale_dtype)
    dq = dequant_blocks_ref(q, s, block=block)        # uplink wire image
    prod = (w * dq).reshape(grid + (-1,))             # eq. (2) reduce
    m = jnp.sum(prod, axis=tuple(range(len(grid))))[None, :]
    yd = m + ef_down.reshape(1, -1) if ef_down is not None else m
    qd, sd = quant_blocks_ref(yd, qmax=qmax, block=block,
                              scale_dtype=scale_dtype)
    dqd = dequant_blocks_ref(qd, sd, block=block)     # downlink wire image
    return (dqd[0],
            y - dq if ef is not None else None,
            yd[0] - dqd[0] if ef_down is not None else None)


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "qmax",
                                             "block", "scale_dtype"))
def _adam_sync_pinned(hyper, params, grads, mu, nu, *, b1: float, b2: float,
                      eps: float, qmax: int, block: int,
                      scale_dtype=jnp.float16):
    """Jitted core of ``adam_sync_flat_ref``: ``optim.Adam.update``'s exact
    arithmetic followed by the qpack block quantize of the new params.

    Jitted on purpose: under jit XLA:CPU contracts the ``a·x + b·y`` moment
    updates into fused multiply-adds (a 1-ulp shift vs the op-by-op eager
    dispatch — the contraction happens in LLVM instruction selection, below
    what HLO barriers control).  The interpret-mode kernel is jitted too, so
    kernel, ref, and ``jax.jit(Adam.update)`` — the form the trainer actually
    runs — agree bit for bit, while EAGER ``Adam.update`` is the odd one out.

    Which contraction each fusion gets depends on the whole fusion graph, so
    parity also needs every stage of the update pinned to ONE
    materialization: barriers between the stages AND the two quotients +
    step returned as REAL jit outputs (a value that is an output cannot be
    rematerialized inside the quantize fusion with a different contraction).
    The kernel emits the same three pinning outputs."""
    lr, bc1, bc2 = hyper[0, 0], hyper[0, 1], hyper[0, 2]
    g = grads.astype(jnp.float32)
    mu2 = b1 * mu + (1 - b1) * g
    nu2 = b2 * nu + (1 - b2) * jnp.square(g)
    mu2, nu2 = jax.lax.optimization_barrier((mu2, nu2))
    q1 = mu2 / bc1
    q2 = jnp.sqrt(nu2 / bc2) + eps
    q1, q2 = jax.lax.optimization_barrier((q1, q2))
    step = lr * q1 / q2
    step = jax.lax.optimization_barrier(step)
    p2 = params - step
    q, s = quant_blocks_ref(p2, qmax=qmax, block=block,
                            scale_dtype=scale_dtype)
    return p2, mu2, nu2, q, s, step, q1, q2


def adam_sync_flat_ref(hyper, params, grads, mu, nu, *, b1: float, b2: float,
                       eps: float, qmax: int, block: int,
                       scale_dtype=jnp.float16):
    """Mirror of ``kernel.adam_sync_flat``: returns (new_params, new_mu,
    new_nu, codes, scales).  The pinning outputs of the jitted core are
    dropped HERE, outside the jit boundary — slicing inside it would let
    dead-code elimination re-roll the codegen the bit parity depends on."""
    return _adam_sync_pinned(hyper, params, grads, mu, nu, b1=b1, b2=b2,
                             eps=eps, qmax=qmax, block=block,
                             scale_dtype=scale_dtype)[:5]
