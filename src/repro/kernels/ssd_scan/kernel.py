"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the GPU version
pipelines chunk outer products through shared memory; here each grid step
processes one (batch, head-block, chunk) tile entirely in VMEM, and the
inter-chunk recurrent state — shape (head_block * hd, ds), kept 2-D so it
maps onto (sublane, lane) tiles — lives in VMEM scratch carried across the
innermost "arbitrary" grid dimension (the chunk axis).

Per chunk (Q = chunk length):
  intra:  y = M @ u              M[q,p] = exp(L_q - L_p) * (C_q . B_p)  (q>=p)
  inter:  y += exp(L) * (C @ S_prev^T)
  state:  S = exp(L_last) * S_prev + sum_p exp(L_last - L_p) u_p B_p^T
with u = dt * x, all in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, s_ref, *,
                chunk, nh_blk, hd, ds):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    Q = chunk
    x = x_ref[0].astype(jnp.float32)            # (Q, nh_blk, hd)
    dt = dt_ref[0].astype(jnp.float32)          # (Q, nh_blk)
    A = a_ref[0, 0].astype(jnp.float32)         # (nh_blk,)
    Bm = b_ref[0].astype(jnp.float32)           # (Q, ds)
    Cm = c_ref[0].astype(jnp.float32)           # (Q, ds)

    la = dt * A[None, :]                        # (Q, nh_blk) log decay
    L = jnp.cumsum(la, axis=0)                  # inclusive
    Llast = L[-1:, :]                           # (1, nh_blk)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, Q)
    u = dt[:, :, None] * x                      # (Q, nh_blk, hd)

    qpos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ppos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = qpos >= ppos

    S_prev = s_ref[...].reshape(nh_blk, hd, ds)

    # per-head-block compute; nh_blk is small (<= 8) so unrolled python loop
    outs = []
    new_states = []
    for h in range(nh_blk):
        # clamp masked (p > q) entries: valid log-decays are <= 0
        decay = jnp.exp(jnp.minimum(L[:, h][:, None] - L[:, h][None, :], 0.0))
        M = jnp.where(tri, scores * decay, 0.0)
        y_intra = jax.lax.dot_general(M, u[:, h, :], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        # inter-chunk: y += exp(L) * (C @ S_prev_h^T)
        cs = jax.lax.dot_general(Cm, S_prev[h], (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q, hd)
        y = y_intra + jnp.exp(L[:, h])[:, None] * cs
        outs.append(y)
        # state update: S_loc = sum_p exp(L_last - L_p) u_p B_p^T (u = dt*x)
        S_loc = jax.lax.dot_general(u[:, h, :] * jnp.exp(Llast[0, h] - L[:, h])[:, None],
                                    Bm, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)  # (hd, ds)
        new_states.append(jnp.exp(Llast[0, h]) * S_prev[h] + S_loc)

    o_ref[0] = jnp.stack(outs, axis=1).astype(o_ref.dtype)         # (Q, nh_blk, hd)
    s_ref[...] = jnp.stack(new_states, axis=0).reshape(nh_blk * hd, ds)


@functools.partial(jax.jit, static_argnames=("chunk", "head_block", "interpret"))
def ssd_bthd(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 4,
             interpret: bool = True):
    """x: (Bsz, T, nh, hd); dt: (Bsz, T, nh) f32; A: (nh,) f32;
    B, C: (Bsz, T, ds).  Returns (Bsz, T, nh, hd) in x.dtype."""
    Bsz, T, nh, hd = x.shape
    ds = B.shape[-1]
    Q = min(chunk, T)
    if T % Q:
        raise ValueError(f"T={T} % chunk={Q} != 0")
    nhb = min(head_block, nh)
    if nh % nhb:
        nhb = 1
    NC = T // Q
    grid = (Bsz, nh // nhb, NC)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=Q, nh_blk=nhb, hd=hd, ds=ds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, nhb, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, nhb), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1, nhb), lambda b, h, c: (0, 0, h)),
            pl.BlockSpec((1, Q, ds), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, ds), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, nhb, hd), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, T, nh, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((nhb * hd, ds), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A.reshape(1, 1, nh), B, C)
    return out
