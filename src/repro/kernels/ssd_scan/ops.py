"""Public SSD wrapper (model layout)."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_bthd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def ssd(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 4,
        interpret: bool | None = None):
    """Mamba2 SSD scan.  x: (Bsz, T, nh, hd); dt: (Bsz, T, nh); A: (nh,);
    B, C: (Bsz, T, ds) -> (Bsz, T, nh, hd)."""
    interp = _default_interpret() if interpret is None else interpret
    return ssd_bthd(x, dt, A, B, C, chunk=chunk, head_block=head_block,
                    interpret=interp)
