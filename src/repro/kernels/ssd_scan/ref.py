"""Pure-jnp oracle for the Mamba2 SSD chunked scan.

Recurrence (per batch b, head h):
    S_t = a_t * S_{t-1} + dt_t * x_t ⊗ B_t          S in R^{hd x ds}
    y_t = C_t · S_t
with a_t = exp(A_h * dt_t), A_h < 0.

Chunked form (chunk Q): inclusive log-decay cumsum L within each chunk,
  intra:  y_i += Σ_{j<=i} exp(L_i - L_j) (C_i·B_j) dt_j x_j
  local end state:  S_loc = Σ_j exp(L_Q - L_j) dt_j x_j ⊗ B_j
  inter (scan over chunks):  S_c = exp(L_Q) S_{c-1} + S_loc,
                             y_i += C_i · (exp(L_i) S_{c-1})
All math in f32; output cast back to x.dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, *, chunk: int = 128, return_final_state: bool = False):
    """x: (B,T,nh,hd); dt: (B,T,nh) f32 post-softplus; A: (nh,) f32 (<0);
    B, C: (B,T,ds).  Returns (B,T,nh,hd) in x.dtype
    (plus the final (B,nh,hd,ds) f32 state if requested)."""
    Bsz, T, nh, hd = x.shape
    ds = B.shape[-1]
    Q = int(min(chunk, T))
    if T % Q:
        raise ValueError(f"T={T} not divisible by chunk={Q}")
    NC = T // Q

    xf = x.astype(jnp.float32).reshape(Bsz, NC, Q, nh, hd)
    dtf = dt.astype(jnp.float32).reshape(Bsz, NC, Q, nh)
    Bf = B.astype(jnp.float32).reshape(Bsz, NC, Q, ds)
    Cf = C.astype(jnp.float32).reshape(Bsz, NC, Q, ds)

    la = A[None, None, None, :] * dtf                    # log a_t  (B,NC,Q,nh)
    L = jnp.cumsum(la, axis=2)                           # inclusive
    Llast = L[:, :, -1:, :]                              # (B,NC,1,nh)

    # ---- intra-chunk (quadratic within chunk) ----
    scores = jnp.einsum("bnqs,bnps->bnqp", Cf, Bf)       # (B,NC,Q,Q) q=i,p=j
    # valid (j <= i) log-decays are <= 0; clamp the masked j > i entries so
    # exp() cannot overflow (inf * 0 under the mask would NaN the backward)
    diff = jnp.minimum(L[:, :, :, None, :] - L[:, :, None, :, :], 0.0)
    decay = jnp.exp(diff)                                # (B,NC,Q,Q,nh)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask[None, None, :, :, None], scores[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bnqph,bnph,bnphd->bnqhd", M, dtf, xf)

    # ---- chunk-local end states ----
    w = jnp.exp(Llast - L) * dtf                         # (B,NC,Q,nh)
    S_loc = jnp.einsum("bnqh,bnqhd,bnqs->bnhds", w, xf, Bf)  # (B,NC,nh,hd,ds)
    chunk_decay = jnp.exp(Llast[:, :, 0, :])             # (B,NC,nh)

    # ---- inter-chunk recurrence ----
    def step(S_prev, inp):
        S_loc_c, dec_c = inp                             # (B,nh,hd,ds), (B,nh)
        S_new = dec_c[..., None, None] * S_prev + S_loc_c
        return S_new, S_prev

    S0 = jnp.zeros((Bsz, nh, hd, ds), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        step, S0,
        (jnp.moveaxis(S_loc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                # (B,NC,nh,hd,ds)

    y_inter = jnp.einsum("bnqs,bnqh,bnhds->bnqhd",
                         Cf, jnp.exp(L), S_prevs)

    y = (y_intra + y_inter).reshape(Bsz, T, nh, hd).astype(x.dtype)
    if return_final_state:
        return y, S_final
    return y


def ssd_decode_ref(state, x1, dt1, A, B1, C1):
    """One recurrent step.  state: (B,nh,hd,ds) f32; x1: (B,nh,hd);
    dt1: (B,nh); B1, C1: (B,ds).  Returns (y1, new_state)."""
    decay = jnp.exp(A[None] * dt1)                       # (B,nh)
    new_state = (decay[..., None, None] * state
                 + dt1[..., None, None]
                 * x1.astype(jnp.float32)[..., None]
                 * B1.astype(jnp.float32)[:, None, None, :])
    y1 = jnp.einsum("bhds,bs->bhd", new_state, C1.astype(jnp.float32))
    return y1.astype(x1.dtype), new_state
