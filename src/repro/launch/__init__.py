from repro.launch.mesh import (
    DCI_BW,
    HBM_BW,
    HBM_BYTES,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
    make_test_mesh,
    mesh_dims,
)

__all__ = [
    "DCI_BW", "HBM_BW", "HBM_BYTES", "ICI_BW", "PEAK_FLOPS_BF16",
    "make_production_mesh", "make_test_mesh", "mesh_dims",
]
