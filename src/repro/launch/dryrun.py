import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape), lower + compile the step on the
production mesh — 16x16 (single pod) and 2x16x16 (two pods) — and record:
  * memory_analysis (bytes per device: argument/output/temp/generated code)
  * cost_analysis (FLOPs, bytes accessed)
  * loop-aware collective bytes (per device), split by mesh axis
  * the roofline terms (compute / memory / collective seconds, v5e constants)

Results are cached as JSON under --out so the sweep is resumable.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--plan agents-data]
"""
import argparse
import json
import time
import traceback


def _roofline(flops, hbm_bytes, coll_bytes_by_axis):
    from repro.launch.mesh import DCI_BW, HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_BW
    ici = coll_bytes_by_axis.get("model", 0) + coll_bytes_by_axis.get("other", 0)
    dci = coll_bytes_by_axis.get("agent", 0)
    # agent-axis traffic crosses pods in the multi-pod mesh; single-pod it is
    # ICI too — we report both the ICI-only and the DCI-penalised variants.
    collective_s = ici / ICI_BW + dci / ICI_BW
    collective_s_dci = ici / ICI_BW + dci / DCI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "collective_s_dci": collective_s_dci}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["dominant"] = dom
    return terms


def run_pair(arch: str, shape_name: str, *, multi_pod: bool, plan: str = "agents-data",
             mode: str = "fedgan", K: int = 20, ring_cache: bool = False,
             fsdp: bool = False, sync_dtype: str = "", intra: int = 0,
             save_hlo: str = "", analyze: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_shape, pair_supported
    from repro.launch.hlo_analysis import collective_bytes
    from repro.launch.mesh import make_production_mesh, mesh_dims
    from repro.launch.steps import PLANS, build_step, round_donation

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = pair_supported(arch, shape_name)
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "plan": plan, "mode": mode, "ring_cache": ring_cache, "fsdp": fsdp,
           "sync_dtype": sync_dtype, "intra_interval": intra}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = {}
    if shape.kind == "train":
        kw = dict(plan=PLANS[plan], K=K, mode=mode,
                  sync_dtype=jnp.bfloat16 if sync_dtype == "bf16" else None,
                  intra_interval=intra)
    elif shape.kind == "decode":
        kw = dict(ring_cache=ring_cache, fsdp=fsdp)
    else:
        kw = dict(fsdp=fsdp)

    t0 = time.time()
    built = build_step(cfg, shape, mesh, **kw)
    if analyze:
        from repro.analysis.trace import audit_built
        rec["analysis"] = [f.to_json() for f in audit_built(
            built, donate_argnums=round_donation(built))]
    with jax.set_mesh(mesh):
        # donate the round state — without this the compiled module keeps
        # two copies of params+opt live (alias_size_in_bytes was 0)
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         out_shardings=built.out_shardings,
                         donate_argnums=round_donation(built))
        lowered = jitted.lower(*built.input_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.hlo_analysis import program_costs

    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k, 0)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes")}
    mem["total_hbm_bytes"] = (mem["argument_size_in_bytes"]
                              + mem["temp_size_in_bytes"]
                              + mem["generated_code_size_in_bytes"]
                              + mem["output_size_in_bytes"]
                              - mem.get("alias_size_in_bytes", 0))
    ca = compiled.cost_analysis() or {}

    txt = compiled.as_text()
    # loop-aware per-device accounting (cost_analysis counts while bodies
    # once — verified; see hlo_analysis docstring)
    pc = program_costs(txt)
    flops = float(pc["flops"])
    bytes_accessed = float(pc["hbm_bytes"])
    stats = collective_bytes(txt)
    by_axis = stats.bytes_by_axis(mesh_dims(mesh))
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(txt)

    steps_per_call = K if shape.kind == "train" else 1
    roof = _roofline(flops / steps_per_call, bytes_accessed / steps_per_call,
                     {k: v / steps_per_call for k, v in by_axis.items()})

    rec.update(
        status="ok",
        mesh="2x16x16" if multi_pod else "16x16",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory=mem,
        flops=flops, bytes_accessed=bytes_accessed,
        xla_cost_analysis={k: float(v) for k, v in ca.items()
                           if isinstance(v, (int, float))},
        steps_per_call=steps_per_call,
        collectives=stats.summary(),
        collective_by_axis=by_axis,
        roofline_per_step=roof,
        meta={k: v for k, v in built.meta.items() if k != "state_specs"},
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--plan", default="agents-data")
    ap.add_argument("--mode", default="fedgan")
    ap.add_argument("--K", type=int, default=20)
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--sync-dtype", default="")
    ap.add_argument("--intra", type=int, default=0)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--analyze", action="store_true",
                    help="run the repro.analysis trace auditor on each "
                         "built step and record findings in the JSON")
    args = ap.parse_args()

    from repro.configs import list_archs
    from repro.models.config import SHAPES

    os.makedirs(args.out, exist_ok=True)
    pairs = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    for arch, shape, mp in pairs:
        key = f"{args.tag}__{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.out, key + ".json")
        if os.path.exists(path):
            print(f"[cached] {key}")
            continue
        print(f"[run]    {key} ...", flush=True)
        try:
            rec = run_pair(arch, shape, multi_pod=mp, plan=args.plan,
                           mode=args.mode, K=args.K, ring_cache=args.ring_cache,
                           fsdp=args.fsdp, sync_dtype=args.sync_dtype,
                           intra=args.intra, save_hlo=args.save_hlo,
                           analyze=args.analyze)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline_per_step"]
            extra = (f" compile={rec['compile_s']}s dom={r['dominant']}"
                     f" c={r['compute_s']*1e3:.2f}ms m={r['memory_s']*1e3:.2f}ms"
                     f" coll={r['collective_s']*1e3:.2f}ms"
                     f" hbm/dev={rec['memory']['total_hbm_bytes']/2**30:.2f}GiB")
        elif status == "error":
            extra = " " + rec["error"][:160]
        else:
            extra = " " + rec.get("reason", "")
        print(f"[{status}] {key}{extra}", flush=True)


if __name__ == "__main__":
    main()
