"""Loop-aware cost accounting from post-SPMD HLO text.

``compiled.cost_analysis()`` counts while bodies ONCE (verified empirically:
a 10-trip scanned matmul reports 1 trip of FLOPs), which makes it useless
for scanned layer stacks and the K-step FedGAN round.  We therefore parse
``compiled.as_text()`` ourselves:

  * collectives — every all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute contributes its OUTPUT bytes (documented
    wire-bytes proxy), split by replica-group size (mesh axis);
  * FLOPs — 2 x out_elems x contracted_size for every dot (including dots
    inside fusion computations);
  * HBM bytes — operands + outputs of every top-level op, with fusions
    counted once at their boundary (internal intermediates stay on-chip);
    bookkeeping ops (tuple/gte/parameter/constant/bitcast) are free;

all multiplied through while-loop trip counts read from the while op's
``backend_config known_trip_count``.  Shapes in the partitioned module are
PER-DEVICE, so totals are per-chip — exactly what the roofline terms need.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
# sub-byte types: bytes = ceil(elems / elems-per-byte), NOT elems * 1 —
# a u4[1000] buffer is 500 bytes, and counting it at 4 (the unknown-type
# fallback) overstated int4 wire traffic 8x.
_SUB_BYTE_ELEMS = {"s4": 2, "u4": 2, "s2": 4, "u2": 4}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-~]+)\s*\(")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%([\w\.\-~]+),\s*body=%([\w\.\-~]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALL_RE = re.compile(r"\b(?:conditional|call)\(.*?to_apply=%([\w\.\-~]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OP_RES = {op: re.compile(rf"\b{op}(?:-start)?\(") for op in COLLECTIVE_OPS}
_DONE_RE = re.compile(r"-done\(")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[[\d,]+\](T\([\d,]+\))?")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(type_str: str, dims_str: str) -> int:
    n = 1
    for d in dims_str.split(","):
        if d:
            n *= int(d)
    if type_str in _SUB_BYTE_ELEMS:
        per_byte = _SUB_BYTE_ELEMS[type_str]
        return (n + per_byte - 1) // per_byte
    return n * _DTYPE_BYTES.get(type_str, 4)


def _split_computations(text: str):
    """name -> list of body lines; also returns the ENTRY computation name."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for raw in text.splitlines():
        if current is None:
            if (raw.startswith("%") or raw.startswith("ENTRY")) and raw.rstrip().endswith("{"):
                m = _HEAD_RE.match(raw)
                if m:
                    current = m.group(1)
                    comps[current] = []
                    if raw.startswith("ENTRY"):
                        entry = current
            continue
        stripped = raw.strip()
        if stripped == "}":
            current = None
            continue
        comps[current].append(stripped)
    return comps, entry


def _group_size(line: str) -> str:
    """Replica-group signature: '<size>' for minor-most (consecutive-id,
    i.e. model-axis) groups, '<size>T' for transposed (data/pod-axis) groups,
    '<size>E' for explicit lists (stride tells the axis; E treated as
    non-minor)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return f"{m.group(2)}{'T' if m.group(3) else ''}"
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        consecutive = all(b - a == 1 for a, b in zip(ids, ids[1:]))
        return f"{len(ids)}{'' if consecutive else 'T'}"
    if "collective-permute" in line:
        return "2T"
    return "0"


def _line_collectives(line: str):
    if _DONE_RE.search(line):
        return None
    for op, rx in _OP_RES.items():
        m = rx.search(line)
        if m:
            seg = line.split("=", 1)
            seg = seg[1] if len(seg) > 1 else line
            opidx = seg.find(op)
            total = 0
            for sm in _SHAPE_RE.finditer(seg[:opidx]):
                total += _shape_bytes(sm.group(1), sm.group(2))
            return op, total, _group_size(line)
    return None


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict
    bytes_by_group_size: dict  # replica-group size -> bytes (classifies axes)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def bytes_by_axis(self, mesh_dims: dict) -> dict:
        """Classify traffic by replica-group signature.  Minor-most
        (consecutive-id) groups of the model-axis size are tensor-parallel
        ICI within an agent; transposed groups span the data/pod (agent)
        axis; partial sizes land in 'other' (sub-axis resharding)."""
        model = mesh_dims.get("model", 0)
        data = mesh_dims.get("data", 0)
        pod = mesh_dims.get("pod", 1)
        out = {"model": 0, "agent": 0, "other": 0}
        for gs, b in self.bytes_by_group_size.items():
            gs = str(gs)
            transposed = gs.endswith("T")
            size = int(gs.rstrip("TE") or 0)
            if not transposed and size == model:
                out["model"] += b
            elif transposed and size in (data, data * pod, pod) and size > 1:
                out["agent"] += b
            else:
                out["other"] += b
        return out

    def summary(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "by_op_bytes": dict(self.bytes_by_op),
                "by_op_count": dict(self.count_by_op),
                "by_group_size": {str(k): v for k, v in
                                  self.bytes_by_group_size.items()}}


def collective_bytes(hlo_text: str, *, skip_loops: bool = False) -> CollectiveStats:
    """``skip_loops=True`` drops every while-body contribution — what's left
    is the once-per-call traffic (e.g. the FedGAN round's post-scan
    parameter sync), separating it from the per-step collectives the trip
    counts would otherwise drown it in."""
    comps, entry = _split_computations(hlo_text)
    memo: dict = {}

    def _merge(dst, src, mult=1):
        for k, v in src.items():
            dst[k] += v * mult

    def analyze(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return {}, {}, {}
        by_op: dict = defaultdict(int)
        cnt: dict = defaultdict(int)
        by_gs: dict = defaultdict(int)
        for line in comps[name]:
            res = _line_collectives(line)
            if res:
                op, b, gs = res
                by_op[op] += b
                cnt[op] += 1
                by_gs[gs] += b
            wm = _WHILE_RE.search(line)
            if wm:
                if skip_loops:
                    continue
                _, body = wm.groups()
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                sb, sc, sg = analyze(body, stack + (name,))
                _merge(by_op, sb, trip)
                _merge(cnt, sc, trip)
                _merge(by_gs, sg, trip)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                sb, sc, sg = analyze(cm.group(1), stack + (name,))
                _merge(by_op, sb)
                _merge(cnt, sc)
                _merge(by_gs, sg)
            bm = _BRANCH_RE.search(line)
            if bm:
                best = ({}, {}, {})
                for br in re.findall(r"%([\w\.\-~]+)", bm.group(1)):
                    sub = analyze(br, stack + (name,))
                    if sum(sub[0].values()) > sum(best[0].values() or [0]):
                        best = sub
                _merge(by_op, best[0])
                _merge(cnt, best[1])
                _merge(by_gs, best[2])
        memo[name] = (dict(by_op), dict(cnt), dict(by_gs))
        return memo[name]

    if entry is None:
        by_op: dict = defaultdict(int)
        cnt: dict = defaultdict(int)
        by_gs: dict = defaultdict(int)
        for ln in hlo_text.splitlines():
            res = _line_collectives(ln.strip())
            if res:
                by_op[res[0]] += res[1]
                cnt[res[0]] += 1
                by_gs[res[2]] += res[1]
        return CollectiveStats(dict(by_op), dict(cnt), dict(by_gs))

    b, c, g = analyze(entry)
    return CollectiveStats(b, c, g)


# ---------------------------------------------------------------------------
# Loop-aware FLOPs + HBM bytes
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-~]+)\s*=\s*(.*?)\s(\w[\w\-]*)\(")
_OPND_RE = re.compile(r"%([\w\.\-~]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_FUSION_CALLS_RE = re.compile(r"fusion\(.*?calls=%([\w\.\-~]+)")
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "broadcast",
             "reshape"}


def _parse_shapes(shape_str: str) -> list[tuple[str, tuple]]:
    """'f32[4,8]{1,0}' or '(f32[2], s32[])' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _shapes_bytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        if dt in _SUB_BYTE_ELEMS:
            per_byte = _SUB_BYTE_ELEMS[dt]
            total += (n + per_byte - 1) // per_byte
        else:
            total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def program_costs(hlo_text: str) -> dict:
    """Returns {"flops", "hbm_bytes", "dot_count"} per device, loop-aware."""
    comps, entry = _split_computations(hlo_text)

    # symbol table: computation -> {op name -> output shapes}
    tables: dict[str, dict] = {}
    for name, lines in comps.items():
        tab = {}
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                tab[m.group(1)] = _parse_shapes(m.group(2))
        tables[name] = tab

    memo: dict = {}

    def flops_of_dot(line, tab):
        m = _DEF_RE.match(line)
        if not m:
            return 0
        out_elems = 0
        for dt, dims in _parse_shapes(m.group(2)):
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        # contracted size from lhs operand shape
        after = line[line.find("dot(") + 4:]
        ops = _OPND_RE.findall(after[:after.find(")")])
        lc = _LHS_CONTRACT_RE.search(line)
        csize = 1
        if ops and lc and ops[0] in tab:
            lhs_dims = tab[ops[0]][0][1] if tab[ops[0]] else ()
            for d in (int(x) for x in lc.group(1).split(",") if x):
                if d < len(lhs_dims):
                    csize *= lhs_dims[d]
        return 2 * out_elems * csize

    def fusion_flops(name, stack=()):
        """Dots inside a fusion computation (counted once per fusion exec)."""
        if name in stack or name not in comps:
            return 0
        total = 0
        tab = tables.get(name, {})
        for ln in comps[name]:
            if re.search(r"\bdot\(", ln):
                total += flops_of_dot(ln, tab)
            fm = _FUSION_CALLS_RE.search(ln)
            if fm:
                total += fusion_flops(fm.group(1), stack + (name,))
        return total

    def analyze(name, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0, 0
        flops = 0
        hbm = 0
        tab = tables.get(name, {})
        for ln in comps[name]:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            opname = m.group(3)
            if opname in _FREE_OPS:
                continue
            wm = _WHILE_RE.search(ln)
            if wm:
                _, body = wm.groups()
                tm = _TRIP_RE.search(ln)
                trip = int(tm.group(1)) if tm else 1
                f, b = analyze(body, stack + (name,))
                flops += f * trip
                hbm += b * trip
                continue
            cm = _CALL_RE.search(ln)
            if cm:
                f, b = analyze(cm.group(1), stack + (name,))
                flops += f
                hbm += b
                continue
            # hbm: output + operands
            out_b = _shapes_bytes(_parse_shapes(m.group(2)))
            opnd_b = 0
            call = ln[ln.find(opname + "(") + len(opname) + 1:]
            for ref in _OPND_RE.findall(call[:call.find(")")]):
                if ref in tab:
                    opnd_b += _shapes_bytes(tab[ref])
            hbm += out_b + opnd_b
            if opname == "dot":
                flops += flops_of_dot(ln, tab)
            elif opname == "fusion":
                fm = _FUSION_CALLS_RE.search(ln)
                if fm:
                    flops += fusion_flops(fm.group(1))
        memo[name] = (flops, hbm)
        return memo[name]

    if entry is None:
        return {"flops": 0, "hbm_bytes": 0}
    f, b = analyze(entry)
    return {"flops": f, "hbm_bytes": b}


# ---------------------------------------------------------------------------
# Per-collective records (the repro.analysis wire auditor's substrate)
# ---------------------------------------------------------------------------

_META_SRC_RE = re.compile(r'source_file="([^"]+)"')
_META_LINE_RE = re.compile(r"source_line=(\d+)")


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective op occurrence in the post-SPMD module.

    Unlike :func:`collective_bytes` (aggregated, trip-multiplied), records
    enumerate each op ONCE with its structural position (``in_loop``) and
    its OPERAND dtypes — which is what dtype-widening audits need: an f32
    operand feeding an agent-axis all-reduce in a bf16-wire build is the
    bug, regardless of trip counts."""

    op: str
    bytes: int                 # output bytes (wire-bytes proxy, per device)
    group_signature: str       # _group_size() signature, e.g. '4', '2T'
    operand_dtypes: tuple      # HLO type strings of the operands, in order
    in_loop: bool              # inside a while (K-scan) body?
    computation: str           # enclosing HLO computation name
    source_file: str = ""      # from op metadata, when the compiler kept it
    source_line: int = 0


def _line_record(line: str, comp: str, in_loop: bool):
    if _DONE_RE.search(line):
        return None
    for op, rx in _OP_RES.items():
        if not rx.search(line):
            continue
        seg = line.split("=", 1)
        seg = seg[1] if len(seg) > 1 else line
        opidx = seg.find(op)
        out_bytes = sum(_shape_bytes(m.group(1), m.group(2))
                        for m in _SHAPE_RE.finditer(seg[:opidx]))
        paren = seg.find("(", opidx)
        close = seg.find(")", paren)
        operand_seg = seg[paren + 1:close] if paren != -1 and close != -1 else ""
        dtypes = tuple(m.group(1) for m in _SHAPE_RE.finditer(operand_seg))
        sf = _META_SRC_RE.search(line)
        sl = _META_LINE_RE.search(line)
        return CollectiveRecord(
            op=op, bytes=out_bytes, group_signature=_group_size(line),
            operand_dtypes=dtypes, in_loop=in_loop, computation=comp,
            source_file=sf.group(1) if sf else "",
            source_line=int(sl.group(1)) if sl else 0)
    return None


def collective_records(hlo_text: str) -> list:
    """Every collective in the module, visited through while bodies
    (``in_loop=True``), calls, and ALL conditional branches (a widening
    hiding in one branch still counts).  Each computation is visited at
    most once per loop-context, so records are per-occurrence-in-source,
    not per-trip."""
    comps, entry = _split_computations(hlo_text)
    records: list = []
    visited: set = set()

    def visit(name: str, in_loop: bool):
        if (name, in_loop) in visited or name not in comps:
            return
        visited.add((name, in_loop))
        for line in comps[name]:
            rec = _line_record(line, name, in_loop)
            if rec:
                records.append(rec)
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                visit(cond, True)
                visit(body, True)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                visit(cm.group(1), in_loop)
            bm = _BRANCH_RE.search(line)
            if bm:
                for br in re.findall(r"%([\w\.\-~]+)", bm.group(1)):
                    visit(br, in_loop)

    if entry is None:
        for ln in hlo_text.splitlines():
            rec = _line_record(ln.strip(), "", False)
            if rec:
                records.append(rec)
    else:
        visit(entry, False)
    return records
