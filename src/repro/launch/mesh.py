"""Production meshes.

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 197 bf16 TFLOP/s,
16 GiB HBM @ 819 GB/s, ~50 GB/s/link ICI per chip.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): (16, 16) "data" x "model" single-pod, or
(2, 16, 16) "pod" x "data" x "model" for the 2-pod = 512-chip fleet.
FedGAN maps agents onto ("pod", "data") — see repro.core.fedgan.
"""
from __future__ import annotations

import jax

from repro.dist.compat import make_mesh as _make_mesh
from repro.dist.sharding import mesh_dims  # noqa: F401  (canonical copy)

# v5e roofline constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (~ per-chip usable, 1 link)
DCI_BW = 25e9                     # bytes/s cross-pod (data-center links, est.)
HBM_BYTES = 16 * 1024 ** 3


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_serving_mesh(*, model_parallel: int = 1, devices=None):
    """Serving mesh shaped from the devices actually present: ("data",
    "model") with ``model_parallel`` chips of tensor parallelism per replica
    and the rest as batch parallelism.  The 1-device CPU case degenerates to
    a (1, 1) mesh on which every constraint is a no-op, so the
    ``repro.serve`` engine runs the identical code path from laptop to pod.
    """
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs)
    if model_parallel < 1 or n % model_parallel:
        raise ValueError(f"model_parallel {model_parallel} must divide the "
                         f"{n} available devices")
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (requires the XLA host-device
    flag to have been set before jax initialised)."""
    return _make_mesh(shape, axes)
