"""Step builders: (arch x shape x mesh x plan) -> jittable fn + shardings +
ShapeDtypeStruct input specs.

Three step kinds map to the assigned input shapes:
  train   — FedGAN round: K local adversarial steps + sync (train_4k)
  prefill — generator forward + decode-cache build, last-token logits
  decode  — ONE new token against a seq_len KV/SSM cache

Mesh plans for training:
  agents-data      (baseline, the paper's mapping): one agent per
                   (pod, data) index; tensor parallel over "model" within
                   each agent; sync = all-reduce over ("pod","data").
  agents-pod-fsdp  (beyond-paper memory optimisation): agents = pods only,
                   weights additionally sharded over "data" (FSDP) inside
                   each agent — for the >10B-param archs whose per-agent
                   TP-16 shard exceeds v5e HBM.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.fedgan import FedGAN, FedGANConfig, GANTask
from repro.core.strategies import strategy_from_mode
from repro.dist.sharding import (batch_axes, filter_spec, named_shardings,
                                 param_specs, shape_of)
from repro.launch.mesh import mesh_dims
from repro.models.adversarial import AdversarialLM
from repro.models.config import ArchConfig, ShapeConfig
from repro.models.transformer import Backbone
from repro.optim import Adam, constant, equal_timescale

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# Mesh plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    name: str
    agent_lead: tuple          # mesh axes carrying the (P, A) agent grid
    fsdp_axis: str | None      # extra weight-sharding axis inside an agent
    act_batch_axes: tuple      # axes for per-agent activation batch dims
    dp_over_model: bool = False  # intra-agent DP: batch over "model", FSDP weights

    def agent_grid(self, mesh) -> tuple[int, int]:
        dims = mesh_dims(mesh)
        if self.name == "agents-pod-fsdp":
            return (dims.get("pod", 1), 1)
        return (dims.get("pod", 1), dims["data"])

    def specs(self, tree, mesh):
        from repro.dist.sharding import dp_param_specs
        if self.dp_over_model:
            return dp_param_specs(tree, mesh, lead=self.agent_lead)
        return param_specs(tree, mesh, lead=self.agent_lead,
                           fsdp_axis=self.fsdp_axis)


# Baseline (the paper's mapping): one agent per (pod, data) index, tensor
# parallel over "model" within each agent.
AGENTS_DATA = MeshPlan("agents-data", ("pod", "data"), None, ())
# Beyond-paper: intra-agent DATA parallelism over the model axis — per-agent
# batch sharded 16-ways, weights FSDP-stored over "model", gathered at use.
AGENTS_DATA_DP = MeshPlan("agents-data-dp", ("pod", "data"), None, ("model",),
                          dp_over_model=True)
# Beyond-paper: agents = pods only; weights FSDP over "data" (for >10B archs
# whose per-agent TP-16 shard exceeds HBM).
AGENTS_POD_FSDP = MeshPlan("agents-pod-fsdp", ("pod",), "data", ("data",))
SERVING = MeshPlan("serving", (), None, ("pod", "data"))

PLANS = {p.name: p for p in (AGENTS_DATA, AGENTS_DATA_DP, AGENTS_POD_FSDP,
                             SERVING)}


# ---------------------------------------------------------------------------
# LM adversarial task (fused grads: single G forward)
# ---------------------------------------------------------------------------


def make_lm_gan_task(cfg: ArchConfig, *, adv_weight: float = 0.1) -> GANTask:
    model = AdversarialLM(cfg, adv_weight=adv_weight)

    def fused(params, batch, rng):
        tokens = batch["tokens"]
        frames = batch.get("frames")
        gen, disc = params["gen"], params["disc"]

        def gfwd(gp):
            out = model.generator.apply(gp, tokens, encoder_frames=frames)
            return out["hidden"], out["logits"], out["aux"]

        (h, logits, aux), g_vjp = jax.vjp(gfwd, gen)
        real = jax.lax.stop_gradient(model.real_features(gen, tokens))
        h_sg = jax.lax.stop_gradient(h)

        def dloss(dp):
            lr_ = model.discriminator.apply(dp, real)
            lf_ = model.discriminator.apply(dp, h_sg)
            return (jnp.mean(jax.nn.softplus(-lr_))
                    + jnp.mean(jax.nn.softplus(lf_)))

        ld, gd = jax.value_and_grad(dloss)(disc)

        def gobj(h_, logits_):
            adv = jnp.mean(jax.nn.softplus(
                -model.discriminator.apply(disc, h_)))
            lm = model.lm_loss(logits_, tokens)
            return lm + model.adv_weight * adv, (lm, adv)

        (lg, (lm, adv)), (dh, dlogits) = jax.value_and_grad(
            gobj, argnums=(0, 1), has_aux=True)(h, logits)
        gg = g_vjp((dh, dlogits,
                    jnp.asarray(cfg.router_aux_weight, jnp.float32)))[0]
        return gd, gg, {"d_loss": ld, "g_loss": lg, "lm": lm, "adv": adv,
                        "aux": aux}

    def disc_loss(params, batch, rng):
        fake, _, _ = model.fake_features(params["gen"], batch["tokens"],
                                         batch.get("frames"))
        real = model.real_features(params["gen"], batch["tokens"])
        return model.disc_loss(params["disc"], real, fake)

    def gen_loss(params, batch, rng):
        total, _ = model.gen_loss(params["gen"], params["disc"],
                                  batch["tokens"], batch.get("frames"))
        return total

    return GANTask(init=model.init, disc_loss=disc_loss, gen_loss=gen_loss,
                   fused_grads=fused)


# ---------------------------------------------------------------------------
# Cache sharding
# ---------------------------------------------------------------------------


def cache_specs(cache_sds, mesh, *, batch: int):
    """PartitionSpec tree for a decode cache.

    k/v: (...stack, B, S, nkv, hd) — shard B over ("pod","data") when
    divisible, otherwise shard S over "data" (context parallelism for the
    batch-1 long-decode); heads over "model" when divisible, else head_dim.
    ssm: (...stack, B, nh, hd, ds) — heads over "model".
    conv: (...stack, B, k, ch) — channels over "model".
    """
    dims = mesh_dims(mesh)
    bdiv = dims.get("pod", 1) * dims["data"]
    batch_ok = batch % bdiv == 0

    def leaf_spec(path_key, leaf):
        nd = leaf.ndim
        ent = [None] * nd
        if path_key in ("k", "v"):
            b_dim, s_dim, h_dim, d_dim = nd - 4, nd - 3, nd - 2, nd - 1
            if batch_ok:
                ent[b_dim] = ("pod", "data")
            else:
                ent[s_dim] = "data"
            if leaf.shape[h_dim] % dims["model"] == 0:
                ent[h_dim] = "model"
            elif leaf.shape[d_dim] % dims["model"] == 0:
                ent[d_dim] = "model"
        elif path_key == "ssm":
            b_dim, h_dim = nd - 4, nd - 3
            if batch_ok:
                ent[b_dim] = ("pod", "data")
            if leaf.shape[h_dim] % dims["model"] == 0:
                ent[h_dim] = "model"
        elif path_key.startswith("conv"):
            b_dim, c_dim = nd - 3, nd - 1
            if batch_ok:
                ent[b_dim] = ("pod", "data")
            if path_key == "conv_x" and leaf.shape[c_dim] % dims["model"] == 0:
                ent[c_dim] = "model"
        # pos and anything else: replicated
        return filter_spec(mesh, tuple(ent), leaf.shape)

    def walk(tree, key=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(walk(v, key) for v in tree)
        return leaf_spec(key, tree)

    return walk(cache_sds)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BuiltStep:
    fn: Callable                  # jit-able, positional args
    input_sds: tuple              # ShapeDtypeStruct pytree per arg
    in_shardings: tuple
    out_shardings: Any
    meta: dict


def round_donation(built: "BuiltStep") -> tuple:
    """``donate_argnums`` for jitting a BuiltStep.  Train rounds return the
    new state as output 0, so arg 0 (the old state) is donatable — without
    it the jitted round holds TWO copies of params+opt live (the PR 7
    dryrun finding: memory_analysis showed zero alias bytes).  Serving
    steps return fresh outputs and donate nothing."""
    return (0,) if built.meta.get("kind") == "train" else ()


def _token_sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_train_round(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                      plan: MeshPlan = AGENTS_DATA, K: int = 20,
                      strategy=None, mode: str = "fedgan", sync_dtype=None,
                      intra_interval: int = 0,
                      adv_weight: float = 0.1) -> BuiltStep:
    """The FedGAN round for the LM adversarial task on this mesh.  Pass a
    ``repro.core.strategies.SyncStrategy`` as ``strategy``; the legacy
    ``mode``/``sync_dtype``/``intra_interval`` trio resolves to one."""
    Pn, A = plan.agent_grid(mesh)
    B_agents = Pn * A
    if shape.global_batch % B_agents:
        raise ValueError(f"global_batch {shape.global_batch} % {B_agents} agents")
    per_agent = shape.global_batch // B_agents

    if strategy is None:
        strategy = strategy_from_mode(mode, intra_interval=intra_interval,
                                      sync_dtype=sync_dtype)
    task = make_lm_gan_task(cfg, adv_weight=adv_weight)
    fed = FedGAN(task,
                 FedGANConfig(agent_grid=(Pn, A), sync_interval=K,
                              strategy=strategy),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(1e-4)))

    state_sds = jax.eval_shape(fed.init_state, jax.random.key(0))
    state_specs = {
        "params": plan.specs(state_sds["params"], mesh),
        "opt_g": plan.specs(state_sds["opt_g"], mesh),
        "opt_d": plan.specs(state_sds["opt_d"], mesh),
        "step": P(),
    }
    # strategy-carried entries (e.g. repro.comm error feedback): anything
    # agent-stacked — every leaf leading with the (P, A) grid, like the EF
    # uplink residuals — shards exactly like the params; shared per-leaf
    # state (the downlink residual) has no agent lead and is replicated
    for k, sds in state_sds.items():
        if k in state_specs:
            continue
        leaves = jax.tree_util.tree_leaves(sds)
        stacked = bool(leaves) and all(l.shape[:2] == (Pn, A)
                                       for l in leaves)
        state_specs[k] = (plan.specs(sds, mesh) if stacked
                          else tmap(lambda _: P(), sds))

    batch = {"tokens": _token_sds((K, Pn, A, per_agent, shape.seq_len))}
    batch_specs = {"tokens": filter_spec(
        mesh, (None, "pod", "data", plan.act_batch_axes or None, None),
        batch["tokens"].shape)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (K, Pn, A, per_agent, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        batch_specs["frames"] = filter_spec(
            mesh, (None, "pod", "data", plan.act_batch_axes or None, None, None),
            batch["frames"].shape)
    seeds = _token_sds((K, Pn, A), jnp.uint32)
    seeds_spec = filter_spec(mesh, (None, "pod", "data"), seeds.shape)

    def round_fn(state, batches, seeds):
        with batch_axes(*plan.act_batch_axes):
            return fed.round(state, batches, seeds)

    in_shardings = (named_shardings(mesh, state_specs),
                    named_shardings(mesh, batch_specs),
                    named_shardings(mesh, seeds_spec))
    out_shardings = (named_shardings(mesh, state_specs), None)

    return BuiltStep(
        fn=round_fn,
        input_sds=(state_sds, batch, seeds),
        in_shardings=in_shardings,
        out_shardings=out_shardings,
        meta={"kind": "train", "plan": plan.name, "K": K,
              "mode": strategy.name,
              "agents": B_agents, "per_agent_batch": per_agent,
              "state_specs": state_specs},
    )


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                  fsdp: bool = False) -> BuiltStep:
    bb = Backbone(cfg)
    dims = mesh_dims(mesh)
    B = shape.global_batch

    pspecs = param_specs(
        jax.eval_shape(bb.init, jax.random.key(0)), mesh,
        fsdp_axis="data" if fsdp else None)

    tokens = _token_sds((B, shape.seq_len))
    tok_spec = filter_spec(mesh, (("pod", "data"), None), tokens.shape)
    args_sds = [jax.eval_shape(bb.init, jax.random.key(0)), tokens]
    arg_specs = [pspecs, tok_spec]
    if cfg.family == "audio":
        frames = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        args_sds.append(frames)
        arg_specs.append(filter_spec(mesh, (("pod", "data"), None, None),
                                      frames.shape))

    def prefill_fn(params, tokens, frames=None):
        out = bb.prefill(params, tokens, encoder_frames=frames,
                         logits_mode="last")
        return {"logits": out["logits"], "cache": out["cache"]}

    return BuiltStep(
        fn=prefill_fn,
        input_sds=tuple(args_sds),
        in_shardings=tuple(named_shardings(mesh, s) for s in arg_specs),
        out_shardings=None,
        meta={"kind": "prefill", "plan": "serving", "fsdp": fsdp},
    )


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                 ring_cache: bool = False, fsdp: bool = False) -> BuiltStep:
    bb = Backbone(cfg, ring_cache=ring_cache)
    B = shape.global_batch
    S = shape.seq_len

    params_sds = jax.eval_shape(bb.init, jax.random.key(0))
    pspecs = param_specs(params_sds, mesh, fsdp_axis="data" if fsdp else None)
    cache_sds = jax.eval_shape(lambda: bb.init_cache(B, S))
    cspecs = cache_specs(cache_sds, mesh, batch=B)

    token = _token_sds((B, 1))
    tok_spec = filter_spec(mesh, (("pod", "data"), None), token.shape)
    index = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, token, cache, index):
        return bb.decode(params, token, cache, index)

    return BuiltStep(
        fn=decode_fn,
        input_sds=(params_sds, token, cache_sds, index),
        in_shardings=(named_shardings(mesh, pspecs),
                      named_shardings(mesh, tok_spec),
                      named_shardings(mesh, cspecs),
                      None),
        out_shardings=None,
        meta={"kind": "decode", "plan": "serving", "ring": ring_cache,
              "fsdp": fsdp, "cache_seq": S},
    )


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_round(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, **kw)
    if shape.kind == "decode":
        ring = kw.pop("ring_cache", cfg.sliding_window > 0 and
                      shape.name == "long_500k")
        return build_decode(cfg, shape, mesh, ring_cache=ring, **kw)
    raise ValueError(shape.kind)


def input_specs(arch_cfg: ArchConfig, shape: ShapeConfig, mesh, **kw):
    """The deliverable-(e) entry point: ShapeDtypeStruct stand-ins for every
    model input of the (arch x shape) step on this mesh."""
    return build_step(arch_cfg, shape, mesh, **kw).input_sds
