"""FedGAN training launcher.

Two entry modes:
  --experiment <paper_exp>   run one of the paper's experiments on synthetic
                             stand-in data (CPU-friendly; §4 of the paper)
  --arch <id>                federated adversarial training of an assigned
                             backbone at reduced scale (smoke-size by
                             default; full scale only makes sense on TPU)

Aggregation is selected with --strategy (see repro.core.strategies), e.g.:

  PYTHONPATH=src python -m repro.launch.train --experiment toy_2d --K 20
  PYTHONPATH=src python -m repro.launch.train --experiment toy_2d \
      --strategy hierarchical --intra-interval 5
  PYTHONPATH=src python -m repro.launch.train --experiment swiss_roll \
      --strategy partial_sharing --sync-dtype bf16
  PYTHONPATH=src python -m repro.launch.train --experiment mixed_gaussian \
      --codec int8          # quantized sync wire + error feedback
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --steps 40

The legacy --mode flag still works (it resolves through the deprecation
shim, including the hierarchical/--intra-interval plumbing that used to be
unreachable from the CLI).
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ACGAN, CONDITIONAL, FedGAN, FedGANConfig, GANTask,
                        make_gan_task, strategies)
from repro.data import (DeviceFederatedData, FederatedRounds,
                        StreamingFederatedData, synthetic)
from repro.optim import Adam, constant, equal_timescale

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# Paper experiment tasks (all through the make_gan_task factory)
# ---------------------------------------------------------------------------


def toy2d_task():
    from repro.models.gan_nets import Toy2DDiscriminator, Toy2DGenerator
    G, D = Toy2DGenerator(theta0=0.5), Toy2DDiscriminator(psi0=0.5)
    return make_gan_task(G, D), (G, D)


def mlp_gan_task(data_dim=2, latent=2, hidden=128):
    from repro.models.gan_nets import MLPDiscriminator, MLPGenerator
    G = MLPGenerator(latent_dim=latent, out_dim=data_dim, hidden=hidden)
    D = MLPDiscriminator(in_dim=data_dim, hidden=hidden)
    return make_gan_task(G, D), (G, D)


def acgan_task(hw=16, channels=3, num_classes=10, latent=62):
    from repro.models.gan_nets import ACGANDiscriminator, ACGANGenerator
    G = ACGANGenerator(latent_dim=latent, num_classes=num_classes, image_hw=hw,
                       channels=channels)
    D = ACGANDiscriminator(num_classes=num_classes, image_hw=hw, channels=channels)
    return make_gan_task(G, D, ACGAN), (G, D)


def cgan1d_task(seq_len=24, label_dim=5):
    from repro.models.gan_nets import CGAN1DDiscriminator, CGAN1DGenerator
    G = CGAN1DGenerator(seq_len=seq_len, label_dim=label_dim)
    D = CGAN1DDiscriminator(seq_len=seq_len, label_dim=label_dim)
    return make_gan_task(G, D, CONDITIONAL), (G, D)


# ---------------------------------------------------------------------------
# RunSpec: one value object instead of the kwargs soup
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Everything one simulated federated GAN run needs (agents stacked on
    one host).  ``build()`` gives the (FedGAN, FederatedRounds) pair;
    ``run()`` executes the round loop.

    Since the ``repro.run`` runtime landed, ``run()`` is a thin shim over
    :class:`repro.run.RoundDriver`: the default ``data_mode="stream"``
    keeps trajectories bit-identical to the pre-runtime blocking loop
    (held by ``tests/test_run_driver.py``), while ``data_mode="device"``
    switches to the device-resident sampling pipeline (different RNG
    stream, much less per-round host work).  Prefer driving the runtime
    directly for new code; this object remains the one-stop experiment
    config."""

    task: GANTask
    agent_data: list
    agent_grid: tuple[int, int] = (1, 5)
    K: int = 20
    steps: int = 100
    batch_size: int = 64
    scales: Any = None              # None -> equal_timescale(constant(1e-3))
    opt_g: Any = dataclasses.field(default_factory=Adam)
    opt_d: Any = dataclasses.field(default_factory=Adam)
    strategy: Any = None            # SyncStrategy; None -> FedAvgSync
    dp: Any = None                  # repro.privacy.DPSGD; None -> no DP
    sample_extra: Any = None
    weights: Any = None
    seed: int = 0
    log_every: int = 1
    ckpt_dir: str = ""
    data_mode: str = "stream"       # "stream" (legacy-parity) | "device"
    rounds_per_chunk: int = 1       # device mode: rounds per scan dispatch
    eval_every: int = 0             # rounds between eval-hook points
    eval_hooks: Any = ()
    # -- virtual-client scheduler (repro.run.virtual) -----------------------
    a_total: int = 0                # fleet size; 0 = dense (all on device)
    participation_seed: int = 0     # ParticipationSchedule seed
    straggler_policy: str = "block"  # "block" | "defer"

    @property
    def n_rounds(self) -> int:
        return max(self.steps // self.K, 1)

    @property
    def virtual(self) -> bool:
        """True when this spec runs the virtual-client scheduler: the fleet
        (``agent_data``, len ``a_total``) is larger than the device slot
        grid ``agent_grid`` and cohorts are paged per round."""
        return self.a_total > 0

    def build(self):
        fed = FedGAN(self.task,
                     FedGANConfig(agent_grid=self.agent_grid,
                                  sync_interval=self.K,
                                  strategy=self.strategy, dp=self.dp),
                     opt_g=self.opt_g, opt_d=self.opt_d,
                     scales=self.scales or equal_timescale(constant(1e-3)),
                     weights=self.weights)
        rounds = FederatedRounds(self.agent_data, self.agent_grid,
                                 self.batch_size, self.K,
                                 sample_extra=self.sample_extra)
        return fed, rounds

    def build_data(self):
        """The FederatedData pipeline ``data_mode`` denotes."""
        if self.data_mode == "device":
            return DeviceFederatedData.from_agent_data(
                self.agent_data, self.agent_grid, self.batch_size,
                sample_extra=self.sample_extra)
        if self.data_mode == "stream":
            return StreamingFederatedData.from_agent_data(
                self.agent_data, self.agent_grid, self.batch_size, self.K,
                sample_extra=self.sample_extra)
        raise ValueError(f"unknown data_mode {self.data_mode!r} "
                         "(expected 'stream' or 'device')")

    def build_fleet(self):
        """Virtual mode: the (FedGAN, FleetRounds) pair — the model on the
        ``agent_grid`` slot grid, the data over all ``a_total`` clients."""
        from repro.data.federated import FleetRounds
        if len(self.agent_data) != self.a_total:
            raise ValueError(f"a_total={self.a_total} but agent_data holds "
                             f"{len(self.agent_data)} client datasets")
        fed = FedGAN(self.task,
                     FedGANConfig(agent_grid=self.agent_grid,
                                  sync_interval=self.K,
                                  strategy=self.strategy, dp=self.dp),
                     opt_g=self.opt_g, opt_d=self.opt_d,
                     scales=self.scales or equal_timescale(constant(1e-3)),
                     weights=self.weights)
        fleet = FleetRounds(self.agent_data, self.agent_grid,
                            self.batch_size, self.K,
                            sample_extra=self.sample_extra)
        return fed, fleet

    def run_result(self):
        """Execute through the ``repro.run`` runtime; returns the full
        :class:`repro.run.RunResult` (state, history, evals, timings)."""
        from repro.run.driver import RoundDriver
        if self.virtual:
            from repro.core.participation import ParticipationSchedule
            from repro.run.virtual import (StragglerPolicy,
                                           VirtualClientDriver)
            fed, fleet = self.build_fleet()
            driver = VirtualClientDriver(
                fed, fleet, self.n_rounds,
                schedule=ParticipationSchedule(seed=self.participation_seed),
                straggler=StragglerPolicy(mode=self.straggler_policy),
                log_every=self.log_every, verbose=bool(self.log_every),
                eval_every=self.eval_every, eval_hooks=self.eval_hooks,
                ckpt_dir=self.ckpt_dir,
                ckpt_every=max(self.n_rounds // 4, 1) if self.ckpt_dir else 0)
            return driver.run(jax.random.key(self.seed + 1))
        fed, _ = self.build()
        state = fed.init_state(jax.random.key(self.seed))
        driver = RoundDriver(
            fed, self.build_data(), self.n_rounds,
            log_every=self.log_every,
            eval_every=self.eval_every, eval_hooks=self.eval_hooks,
            ckpt_dir=self.ckpt_dir,
            ckpt_every=max(self.n_rounds // 4, 1) if self.ckpt_dir else 0,
            rounds_per_chunk=self.rounds_per_chunk)
        return driver.run(jax.random.key(self.seed + 1), state=state)

    def run(self):
        """Legacy entry point: returns (fed, state, history)."""
        return self.run_result().legacy_tuple()


def train_fedgan(task, *, agent_data, agent_grid, K, steps, batch_size,
                 scales, opt_d, opt_g, strategy=None, mode="",
                 sample_extra=None, seed=0, log_every=1, ckpt_dir="",
                 weights=None):
    """Compat wrapper over RunSpec (prefer RunSpec(...).run() directly)."""
    if strategy is None and mode:
        strategy = strategies.strategy_from_mode(mode)
    return RunSpec(task=task, agent_data=agent_data, agent_grid=agent_grid,
                   K=K, steps=steps, batch_size=batch_size, scales=scales,
                   opt_g=opt_g, opt_d=opt_d, strategy=strategy,
                   sample_extra=sample_extra, weights=weights, seed=seed,
                   log_every=log_every, ckpt_dir=ckpt_dir).run()


def _pooled_real(agent_data, seed: int = 0):
    """Cross-agent pooled real samples, shuffled so any prefix is an
    unbiased draw from the GLOBAL distribution (what the paper's metrics
    compare against — never one agent's slice)."""
    xs = np.concatenate([np.asarray(d["x"]) for d in agent_data])
    return xs[np.random.RandomState(seed).permutation(len(xs))]


def experiment_spec(name: str, *, K: int | None = None,
                    steps: int | None = None, seed: int = 0, strategy=None,
                    dp=None, ckpt_dir: str = "",
                    batch_size: int | None = None,
                    agents: int | None = None, log_every: int | None = None,
                    eval_every: int = 0, data_mode: str = "stream",
                    rounds_per_chunk: int = 1, a_total: int = 0,
                    a_active: int = 0, participation_seed: int = 0,
                    straggler_policy: str = "block",
                    samples_per_agent: int | None = None):
    """Build (RunSpec, EvalSuite) for one of the paper's experiments on the
    synthetic stand-in data.  ``batch_size``/``agents``/``log_every``
    override the experiment-config defaults (the CLI knobs); the EvalSuite
    feeds the ``repro.run`` eval harness and the K-sweep runner.

    ``a_total`` switches to the virtual-client scheduler: the experiment's
    non-iid partition is dealt over ``a_total`` registered clients (mode
    assignments wrap, per-client shards shrink to ``samples_per_agent``,
    default 512, so a 1024-client fleet fits host memory) of which the
    ``ParticipationSchedule(participation_seed)``-sampled cohort of
    ``a_active`` runs per round on the device slots."""
    from repro.configs.paper_gans import ALL_EXPERIMENTS, optimizer_for, scales_for
    from repro.run.evals import EvalSuite, eval_hook
    exp = ALL_EXPERIMENTS[name]
    K = K or exp.default_K
    steps = steps or exp.iterations
    if a_total:
        if agents:
            raise ValueError("--agents conflicts with --a-total (the fleet "
                             "size IS the client count); use --a-active for "
                             "the per-round cohort size")
        B = a_total
        a_active = a_active or exp.num_agents
        if not 1 <= a_active <= a_total:
            raise ValueError(f"a_active={a_active} must be in [1, "
                             f"a_total={a_total}]")
    else:
        B = agents or exp.num_agents
    if samples_per_agent is None:
        # thousand-client fleets live host-side; shrink per-client shards so
        # the whole fleet's data fits (dense runs keep the paper-size shards)
        samples_per_agent = 512 if a_total else 0
    n_of = lambda default: samples_per_agent or default
    batch_size = batch_size or exp.batch_size
    rng = jax.random.key(seed)

    if name == "toy_2d":
        task, (G, _) = toy2d_task()
        agent_data = [{"x": synthetic.sample_2d_segment(
            jax.random.fold_in(rng, i), n_of(4096), i, B)} for i in range(B)]
        extra = lambda r, s: {"z": jax.random.uniform(r, s, minval=-1, maxval=1)}
        suite = EvalSuite(
            real=_pooled_real(agent_data, seed),
            sample_fake=lambda gp, r, n: G.apply(
                gp, jax.random.uniform(r, (n,), minval=-1, maxval=1)))
    elif name == "mixed_gaussian":
        task, (G, _) = mlp_gan_task()
        # 8 modes on the circle; with an --agents override beyond 4 the
        # mode assignment wraps (agents share modes, still non-iid pairs)
        agent_data = [{"x": synthetic.sample_mixed_gaussian(
            jax.random.fold_in(rng, i), n_of(8192),
            mode_subset=[(2 * i) % 8, (2 * i + 1) % 8])}
            for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (2,))}
        suite = EvalSuite(
            real=_pooled_real(agent_data, seed),
            sample_fake=lambda gp, r, n: G.apply(
                gp, jax.random.normal(r, (n, 2))),
            modes=np.asarray(synthetic.mixed_gaussian_modes()))
    elif name == "swiss_roll":
        task, (G, _) = mlp_gan_task()
        agent_data = [{"x": synthetic.sample_swiss_roll(
            jax.random.fold_in(rng, i), n_of(8192),
            t_range=(0.25 + 0.75 * i / B, 0.25 + 0.75 * (i + 1) / B))}
            for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (2,))}
        suite = EvalSuite(
            real=_pooled_real(agent_data, seed),
            sample_fake=lambda gp, r, n: G.apply(
                gp, jax.random.normal(r, (n, 2))))
    elif name in ("image_acgan", "celeba_acgan"):
        ncls = 16 if name == "celeba_acgan" else 10
        task, (G, _) = acgan_task(hw=16, num_classes=ncls)
        per = max(ncls // B, 1)
        def mk(i):
            # class slice wraps under an --agents override larger than the
            # class count (keeps randint bounds valid: lo < hi <= ncls)
            lo = (i * per) % ncls
            lab = jax.random.randint(jax.random.fold_in(rng, 100 + i),
                                     (n_of(2048),), lo, min(lo + per, ncls))
            img = synthetic.sample_class_images(
                jax.random.fold_in(rng, 200 + i), n_of(2048), lab, hw=16,
                num_classes=ncls)
            return {"x": img, "y": lab}
        agent_data = [mk(i) for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (62,))}

        def sample_images(gp, r, n, ncls=ncls):
            kz, kl = jax.random.split(r)
            lab = jax.random.randint(kl, (n,), 0, ncls)
            return G.apply(gp, jax.random.normal(kz, (n, 62)), lab)

        suite = EvalSuite(real=_pooled_real(agent_data, seed),
                          sample_fake=sample_images)
    elif name == "timeseries_cgan":
        task, (G, _) = cgan1d_task()
        def mk(i):
            cz = jnp.full((n_of(4096),), i % 5, jnp.int32)  # 5 climate zones
            x = synthetic.sample_household_load(jax.random.fold_in(rng, i),
                                                n_of(4096), climate_zone=cz)
            return {"x": x, "y": jax.nn.one_hot(cz, 5)}
        agent_data = [mk(i) for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (24,))}

        def sample_profiles(gp, r, n):
            kz, kl = jax.random.split(r)
            y = jax.nn.one_hot(jax.random.randint(kl, (n,), 0, 5), 5)
            return G.apply(gp, jax.random.normal(kz, (n, 24)), y)

        suite = EvalSuite(real=_pooled_real(agent_data, seed),
                          sample_fake=sample_profiles, kind="timeseries")
    else:
        raise KeyError(name)

    opt_d, opt_g = optimizer_for(exp)
    grid = (1, a_active) if a_total else (1, B)
    spec = RunSpec(
        task=task, agent_data=agent_data, agent_grid=grid, K=K, steps=steps,
        batch_size=batch_size, scales=scales_for(exp), opt_d=opt_d,
        opt_g=opt_g, strategy=strategy, dp=dp, sample_extra=extra, seed=seed,
        log_every=max((steps // K) // 10, 1) if log_every is None else log_every,
        ckpt_dir=ckpt_dir, data_mode=data_mode,
        rounds_per_chunk=rounds_per_chunk, eval_every=eval_every,
        eval_hooks=(eval_hook(suite, seed=seed),) if eval_every else (),
        a_total=a_total, participation_seed=participation_seed,
        straggler_policy=straggler_policy)
    return spec, suite


def run_experiment(name: str, *, K: int | None, steps: int | None, seed: int,
                   strategy=None, dp=None, ckpt_dir: str = "",
                   batch_size=None, agents=None, log_every=None,
                   eval_every: int = 0, data_mode: str = "stream",
                   a_total: int = 0, a_active: int = 0,
                   participation_seed: int = 0,
                   straggler_policy: str = "block",
                   samples_per_agent: int | None = None):
    spec, _ = experiment_spec(
        name, K=K, steps=steps, seed=seed, strategy=strategy, dp=dp,
        ckpt_dir=ckpt_dir, batch_size=batch_size, agents=agents,
        log_every=log_every, eval_every=eval_every, data_mode=data_mode,
        a_total=a_total, a_active=a_active,
        participation_seed=participation_seed,
        straggler_policy=straggler_policy,
        samples_per_agent=samples_per_agent)
    return spec.run()


def arch_smoke_spec(arch: str, *, steps: int, K: int, seed: int,
                    strategy=None, dp=None, ckpt_dir: str = "",
                    batch_size: int | None = None, agents: int | None = None,
                    log_every: int | None = None, data_mode: str = "stream",
                    rounds_per_chunk: int = 1) -> RunSpec:
    """RunSpec for federated adversarial training of a reduced assigned
    backbone (see :func:`run_arch_smoke`)."""
    from repro.configs import get_config
    from repro.launch.steps import make_lm_gan_task
    cfg = get_config(arch).smoke()
    task = make_lm_gan_task(cfg)
    B = agents or 4
    T = 32
    rng = jax.random.key(seed)
    agent_data = []
    for i in range(B):
        d = {"tokens": synthetic.sample_agent_tokens(
            rng, 256, T, cfg.vocab_size, agent=i, num_agents=B)}
        if cfg.family == "audio":
            d["frames"] = 0.1 * jax.random.normal(
                jax.random.fold_in(rng, 50 + i), (256, cfg.encoder_seq, cfg.d_model))
        agent_data.append(d)
    return RunSpec(
        task=task, agent_data=agent_data, agent_grid=(1, B), K=K, steps=steps,
        batch_size=batch_size or 8, scales=equal_timescale(constant(1e-3)),
        opt_d=Adam(), opt_g=Adam(), strategy=strategy, dp=dp, seed=seed,
        log_every=1 if log_every is None else log_every, ckpt_dir=ckpt_dir,
        data_mode=data_mode, rounds_per_chunk=rounds_per_chunk)


def run_arch_smoke(arch: str, *, steps: int, K: int, seed: int, strategy=None,
                   dp=None, ckpt_dir: str = "", batch_size=None, agents=None,
                   log_every=None, data_mode: str = "stream"):
    """Federated adversarial training of a reduced assigned backbone.

    With ``ckpt_dir`` the run checkpoints its FedGAN state, which a
    ``repro.serve`` engine in another process can hot-reload live — the
    two-terminal walkthrough in docs/serving.md."""
    return arch_smoke_spec(
        arch, steps=steps, K=K, seed=seed, strategy=strategy, dp=dp,
        ckpt_dir=ckpt_dir, batch_size=batch_size, agents=agents,
        log_every=log_every, data_mode=data_mode).run()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

_SYNC_DTYPES = {"": None, "f32": jnp.float32, "bf16": jnp.bfloat16,
                "bfloat16": jnp.bfloat16, "f16": jnp.float16}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default="")
    ap.add_argument("--arch", default="")
    ap.add_argument("--K", type=int, default=0)
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--strategy", default="",
                    choices=[""] + sorted(strategies.STRATEGIES))
    ap.add_argument("--mode", default="",
                    help="DEPRECATED: legacy mode string (use --strategy)")
    ap.add_argument("--intra-interval", type=int, default=0,
                    help="hierarchical: steps between intra-pod averages")
    ap.add_argument("--sync-dtype", default="", choices=sorted(_SYNC_DTYPES),
                    help="wire dtype for compressed sync (e.g. bf16)")
    ap.add_argument("--codec", default="",
                    help="wire codec spec for compressed sync (repro.comm): "
                         "int8 | int4 | topk | chains like topk+int8")
    ap.add_argument("--codec-bits", type=int, default=0, choices=[0, 4, 8],
                    help="quantizer bits; retunes (or appends) the codec's "
                         "quantizer stage")
    ap.add_argument("--topk", type=float, default=0.0,
                    help="top-k sparsification fraction; retunes (or "
                         "prepends) the codec's sparsifier stage")
    ap.add_argument("--average-opt-state", action="store_true",
                    help="FedAvg the optimizer moments along with the params")
    ap.add_argument("--participation", type=float, default=0.0,
                    help="subsampled: per-round participating fraction")
    ap.add_argument("--warmup-rounds", type=int, default=0,
                    help="adaptive_k: rounds that sync every round")
    ap.add_argument("--sync-every", type=int, default=0,
                    help="adaptive_k: post-warmup rounds between syncs")
    ap.add_argument("--dp-clip", type=float, default=0.0,
                    help="DP-SGD per-example clip norm C (enables DP; "
                         "defaults to 1.0 when only --dp-noise is given)")
    ap.add_argument("--dp-noise", type=float, default=0.0,
                    help="DP-SGD noise multiplier sigma (0 = clip-only)")
    ap.add_argument("--dp-delta", type=float, default=1e-5,
                    help="delta at which the accountant reports epsilon")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-mask secure summing at the sync "
                         "(bit-identical result; refuses --codec/--sync-dtype)")
    ap.add_argument("--robust", default="",
                    choices=["", "trimmed_mean", "median"],
                    help="Byzantine-robust aggregation (shorthand for "
                         "--strategy trimmed_mean|median)")
    ap.add_argument("--trim", type=int, default=0,
                    help="trimmed_mean: agents trimmed per tail (default 1)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--batch-size", type=int, default=0,
                    help="per-agent minibatch size (0 = experiment default)")
    ap.add_argument("--agents", type=int, default=0,
                    help="number of agents B (0 = experiment default)")
    ap.add_argument("--log-every", type=int, default=-1,
                    help="rounds between metric logs; 0 silences, "
                         "-1 = experiment default")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="rounds between repro.evals scorings of the "
                         "averaged generator (experiments only; 0 = off)")
    ap.add_argument("--data-mode", default="stream",
                    choices=["stream", "device"],
                    help="round data pipeline: host-streaming (legacy-"
                         "parity) or device-resident in-round sampling")
    ap.add_argument("--a-total", type=int, default=0,
                    help="virtual-client fleet size A_total (0 = dense run; "
                         "conflicts with --agents)")
    ap.add_argument("--a-active", type=int, default=0,
                    help="per-round cohort size A_active — the device slot "
                         "count (0 = experiment's num_agents)")
    ap.add_argument("--participation-seed", type=int, default=0,
                    help="seed of the per-round cohort draw "
                         "(repro.core.participation)")
    ap.add_argument("--straggler-policy", default="block",
                    choices=["block", "defer"],
                    help="block: wait for every cohort member; defer: merge "
                         "late deltas into a later round with staleness "
                         "decay")
    ap.add_argument("--samples-per-agent", type=int, default=0,
                    help="per-client dataset size override (0 = experiment "
                         "default, or 512 under --a-total)")
    return ap


def strategy_from_args(args) -> strategies.SyncStrategy | None:
    """CLI flags -> SyncStrategy (None keeps the library default).  A knob
    that the chosen strategy does not declare is an error, not a silent
    no-op (mirroring FedGANConfig.resolve_strategy's conflict check)."""
    from repro.comm import codec_from_flags
    sync_dtype = _SYNC_DTYPES[args.sync_dtype]
    codec = codec_from_flags(args.codec, bits=args.codec_bits,
                             topk=args.topk)
    if codec is not None and args.sync_dtype:
        raise ValueError(
            "--codec and --sync-dtype are both wire compressions; pick one "
            "(chain codecs via --codec a+b instead)")
    robust = getattr(args, "robust", "")
    if robust:
        # --robust is shorthand for --strategy trimmed_mean|median
        if args.strategy and args.strategy != robust:
            raise ValueError(f"--robust {robust} conflicts with "
                             f"--strategy {args.strategy}; pick one")
        args.strategy = robust
    secure = getattr(args, "secure_agg", False)
    if args.strategy or ((codec is not None or secure) and not args.mode):
        # a bare --codec/--secure-agg implies the FedAvgSync base strategy,
        # through the same knob validation (no silent drops of e.g.
        # --participation)
        cls = (strategies.STRATEGIES[args.strategy] if args.strategy
               else strategies.FedAvgSync)
        fields = {f.name for f in dataclasses.fields(cls)}
        requested = {}
        if args.sync_dtype:
            requested["sync_dtype"] = sync_dtype
        if codec is not None:
            requested["codec"] = codec
        if secure:
            from repro.privacy import SecureAgg
            requested["secure_agg"] = SecureAgg(seed=args.seed)
        if args.average_opt_state:
            requested["average_opt_state"] = True
        if args.intra_interval:
            requested["intra_interval"] = args.intra_interval
        if args.participation:
            requested["fraction"] = args.participation
        if args.warmup_rounds:
            requested["warmup_rounds"] = args.warmup_rounds
        if args.sync_every:
            requested["sync_every"] = args.sync_every
        if getattr(args, "trim", 0):
            requested["trim"] = args.trim
        stray = sorted(set(requested) - fields)
        if stray:
            name = args.strategy or "fedgan (implied by --codec/--secure-agg)"
            raise ValueError(
                f"--strategy {name} does not accept {stray} "
                f"(its knobs: {sorted(fields)})")
        return cls(**requested)
    if args.mode:
        if codec is not None:
            raise ValueError("--codec requires --strategy (the legacy "
                             "--mode strings predate the codec axis)")
        if secure:
            raise ValueError("--secure-agg requires --strategy (the legacy "
                             "--mode strings predate the privacy axis)")
        return strategies.strategy_from_mode(
            args.mode, intra_interval=args.intra_interval,
            sync_dtype=sync_dtype, average_opt_state=args.average_opt_state)
    return None


def dp_from_args(args):
    """CLI flags -> repro.privacy.DPSGD (None when no DP flag is set).
    ``--dp-noise`` alone enables DP at the default clip of 1.0."""
    if not (getattr(args, "dp_clip", 0.0) or getattr(args, "dp_noise", 0.0)):
        return None
    from repro.privacy import DPSGD
    return DPSGD(clip=args.dp_clip or 1.0, noise_multiplier=args.dp_noise,
                 delta=getattr(args, "dp_delta", 1e-5))


def main():
    ap = build_parser()
    args = ap.parse_args()
    strategy = strategy_from_args(args)
    dp = dp_from_args(args)
    overrides = dict(batch_size=args.batch_size or None,
                     agents=args.agents or None,
                     log_every=None if args.log_every < 0 else args.log_every,
                     data_mode=args.data_mode)

    if args.experiment:
        run_experiment(args.experiment, K=args.K or None, steps=args.steps or None,
                       seed=args.seed, strategy=strategy, dp=dp,
                       ckpt_dir=args.ckpt_dir,
                       eval_every=args.eval_every,
                       a_total=args.a_total, a_active=args.a_active,
                       participation_seed=args.participation_seed,
                       straggler_policy=args.straggler_policy,
                       samples_per_agent=args.samples_per_agent or None,
                       **overrides)
    elif args.arch:
        if args.a_total:
            ap.error("--a-total needs --experiment (the backbone smoke "
                     "runs are dense by construction)")
        if args.eval_every:
            ap.error("--eval-every needs --experiment (no eval suite exists "
                     "for backbone smoke runs)")
        run_arch_smoke(args.arch, steps=args.steps or 20, K=args.K or 5,
                       seed=args.seed, strategy=strategy, dp=dp,
                       ckpt_dir=args.ckpt_dir, **overrides)
    else:
        ap.error("need --experiment or --arch")


if __name__ == "__main__":
    main()
