"""FedGAN training launcher.

Two entry modes:
  --experiment <paper_exp>   run one of the paper's experiments on synthetic
                             stand-in data (CPU-friendly; §4 of the paper)
  --arch <id>                federated adversarial training of an assigned
                             backbone at reduced scale (smoke-size by
                             default; full scale only makes sense on TPU)

Examples:
  PYTHONPATH=src python -m repro.launch.train --experiment toy_2d --K 20
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --steps 40
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.core import FedGAN, FedGANConfig, GANTask, losses
from repro.data import FederatedRounds, synthetic
from repro.optim import Adam, SGD, constant, constant_ttur, equal_timescale, power_decay

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# Paper experiment tasks
# ---------------------------------------------------------------------------


def toy2d_task():
    from repro.models.gan_nets import Toy2DDiscriminator, Toy2DGenerator
    G, D = Toy2DGenerator(theta0=0.5), Toy2DDiscriminator(psi0=0.5)

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def disc_loss(params, batch, rng):
        fake = jax.lax.stop_gradient(G.apply(params["gen"], batch["z"]))
        return losses.ns_d_loss(D.apply(params["disc"], batch["x"]),
                                D.apply(params["disc"], fake))

    def gen_loss(params, batch, rng):
        fake = G.apply(params["gen"], batch["z"])
        return losses.ns_g_loss(D.apply(params["disc"], fake))

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss), (G, D)


def mlp_gan_task(data_dim=2, latent=2, hidden=128):
    from repro.models.gan_nets import MLPDiscriminator, MLPGenerator
    G = MLPGenerator(latent_dim=latent, out_dim=data_dim, hidden=hidden)
    D = MLPDiscriminator(in_dim=data_dim, hidden=hidden)

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def disc_loss(params, batch, rng):
        fake = jax.lax.stop_gradient(G.apply(params["gen"], batch["z"]))
        return losses.ns_d_loss(D.apply(params["disc"], batch["x"]),
                                D.apply(params["disc"], fake))

    def gen_loss(params, batch, rng):
        fake = G.apply(params["gen"], batch["z"])
        return losses.ns_g_loss(D.apply(params["disc"], fake))

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss), (G, D)


def acgan_task(hw=16, channels=3, num_classes=10, latent=62):
    from repro.models.gan_nets import ACGANDiscriminator, ACGANGenerator
    G = ACGANGenerator(latent_dim=latent, num_classes=num_classes, image_hw=hw,
                       channels=channels)
    D = ACGANDiscriminator(num_classes=num_classes, image_hw=hw, channels=channels)

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def disc_loss(params, batch, rng):
        img, lab, z = batch["x"], batch["y"], batch["z"]
        fake = jax.lax.stop_gradient(G.apply(params["gen"], z, lab))
        rb, rc = D.apply(params["disc"], img)
        fb, fc = D.apply(params["disc"], fake)
        return losses.acgan_d_loss(rb, fb, rc, fc, lab)

    def gen_loss(params, batch, rng):
        lab, z = batch["y"], batch["z"]
        fake = G.apply(params["gen"], z, lab)
        fb, fc = D.apply(params["disc"], fake)
        return losses.acgan_g_loss(fb, fc, lab)

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss), (G, D)


def cgan1d_task(seq_len=24, label_dim=5):
    from repro.models.gan_nets import CGAN1DDiscriminator, CGAN1DGenerator
    G = CGAN1DGenerator(seq_len=seq_len, label_dim=label_dim)
    D = CGAN1DDiscriminator(seq_len=seq_len, label_dim=label_dim)

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def disc_loss(params, batch, rng):
        x, lab, z = batch["x"], batch["y"], batch["z"]
        fake = jax.lax.stop_gradient(G.apply(params["gen"], z, lab))
        return losses.ns_d_loss(D.apply(params["disc"], x, lab),
                                D.apply(params["disc"], fake, lab))

    def gen_loss(params, batch, rng):
        lab, z = batch["y"], batch["z"]
        fake = G.apply(params["gen"], z, lab)
        return losses.ns_g_loss(D.apply(params["disc"], fake, lab))

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss), (G, D)


# ---------------------------------------------------------------------------
# Trainer loop (simulation mode: agents stacked on one host)
# ---------------------------------------------------------------------------


def train_fedgan(task, *, agent_data, agent_grid, K, steps, batch_size,
                 scales, opt_d, opt_g, mode="fedgan", sample_extra=None,
                 seed=0, log_every=1, ckpt_dir="", weights=None):
    fed = FedGAN(task, FedGANConfig(agent_grid=agent_grid, sync_interval=K,
                                    mode=mode),
                 opt_g=opt_g, opt_d=opt_d, scales=scales, weights=weights)
    state = fed.init_state(jax.random.key(seed))
    rounds = FederatedRounds(agent_data, agent_grid, batch_size, K,
                             sample_extra=sample_extra)
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(seed + 1)
    history = []
    n_rounds = max(steps // K, 1)
    t0 = time.time()
    for r in range(n_rounds):
        rng, rb = jax.random.split(rng)
        batches, seeds = rounds.round_batches(rb)
        state, metrics = round_fn(state, batches, seeds)
        m = tmap(lambda x: float(jnp.mean(x)), metrics)
        history.append(m)
        if log_every and (r % log_every == 0 or r == n_rounds - 1):
            print(f"round {r:5d}/{n_rounds} step {(r+1)*K:6d} "
                  f"d_loss={m['d_loss']:.4f} g_loss={m['g_loss']:.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if ckpt_dir and (r + 1) % max(n_rounds // 4, 1) == 0:
            save_checkpoint(ckpt_dir, state, step=(r + 1) * K,
                            metadata={"round": r, "K": K})
    return fed, state, history


def run_experiment(name: str, *, K: int | None, steps: int | None, seed: int,
                   mode: str, ckpt_dir: str):
    from repro.configs.paper_gans import ALL_EXPERIMENTS, optimizer_for, scales_for
    exp = ALL_EXPERIMENTS[name]
    K = K or exp.default_K
    steps = steps or exp.iterations
    B = exp.num_agents
    rng = jax.random.key(seed)

    if name == "toy_2d":
        task, _ = toy2d_task()
        agent_data = [{"x": synthetic.sample_2d_segment(
            jax.random.fold_in(rng, i), 4096, i, B)} for i in range(B)]
        extra = lambda r, s: {"z": jax.random.uniform(r, s, minval=-1, maxval=1)}
    elif name == "mixed_gaussian":
        task, _ = mlp_gan_task()
        agent_data = [{"x": synthetic.sample_mixed_gaussian(
            jax.random.fold_in(rng, i), 8192, mode_subset=[2 * i, 2 * i + 1])}
            for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (2,))}
    elif name == "swiss_roll":
        task, _ = mlp_gan_task()
        agent_data = [{"x": synthetic.sample_swiss_roll(
            jax.random.fold_in(rng, i), 8192,
            t_range=(0.25 + 0.75 * i / B, 0.25 + 0.75 * (i + 1) / B))}
            for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (2,))}
    elif name in ("image_acgan", "celeba_acgan"):
        ncls = 16 if name == "celeba_acgan" else 10
        task, _ = acgan_task(hw=16, num_classes=ncls)
        per = max(ncls // B, 1)
        def mk(i):
            lab = jax.random.randint(jax.random.fold_in(rng, 100 + i), (2048,),
                                     i * per, min((i + 1) * per, ncls))
            img = synthetic.sample_class_images(
                jax.random.fold_in(rng, 200 + i), 2048, lab, hw=16,
                num_classes=ncls)
            return {"x": img, "y": lab}
        agent_data = [mk(i) for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (62,))}
    elif name == "timeseries_cgan":
        task, _ = cgan1d_task()
        def mk(i):
            cz = jnp.full((4096,), i, jnp.int32)
            x = synthetic.sample_household_load(jax.random.fold_in(rng, i), 4096,
                                                climate_zone=cz)
            return {"x": x, "y": jax.nn.one_hot(cz, 5)}
        agent_data = [mk(i) for i in range(B)]
        extra = lambda r, s: {"z": jax.random.normal(r, s + (24,))}
    else:
        raise KeyError(name)

    opt_d, opt_g = optimizer_for(exp)
    fed, state, hist = train_fedgan(
        task, agent_data=agent_data, agent_grid=(1, B), K=K, steps=steps,
        batch_size=exp.batch_size, scales=scales_for(exp), opt_d=opt_d,
        opt_g=opt_g, mode=mode, sample_extra=extra, seed=seed,
        log_every=max((steps // K) // 10, 1), ckpt_dir=ckpt_dir)
    return fed, state, hist


def run_arch_smoke(arch: str, *, steps: int, K: int, seed: int):
    """Federated adversarial training of a reduced assigned backbone."""
    from repro.configs import get_config
    from repro.launch.steps import make_lm_gan_task
    cfg = get_config(arch).smoke()
    task = make_lm_gan_task(cfg)
    B = 4
    T = 32
    rng = jax.random.key(seed)
    agent_data = []
    for i in range(B):
        d = {"tokens": synthetic.sample_agent_tokens(
            rng, 256, T, cfg.vocab_size, agent=i, num_agents=B)}
        if cfg.family == "audio":
            d["frames"] = 0.1 * jax.random.normal(
                jax.random.fold_in(rng, 50 + i), (256, cfg.encoder_seq, cfg.d_model))
        agent_data.append(d)
    fed, state, hist = train_fedgan(
        task, agent_data=agent_data, agent_grid=(1, B), K=K, steps=steps,
        batch_size=8, scales=equal_timescale(constant(1e-3)),
        opt_d=Adam(), opt_g=Adam(), seed=seed, log_every=1)
    return fed, state, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default="")
    ap.add_argument("--arch", default="")
    ap.add_argument("--K", type=int, default=0)
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--mode", default="fedgan")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.experiment:
        run_experiment(args.experiment, K=args.K or None, steps=args.steps or None,
                       seed=args.seed, mode=args.mode, ckpt_dir=args.ckpt_dir)
    elif args.arch:
        run_arch_smoke(args.arch, steps=args.steps or 20, K=args.K or 5,
                       seed=args.seed)
    else:
        ap.error("need --experiment or --arch")


if __name__ == "__main__":
    main()
