from repro.models.adversarial import AdversarialLM, FeatureDiscriminator
from repro.models.config import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ArchConfig,
    ShapeConfig,
)
from repro.models.transformer import Backbone

__all__ = [
    "AdversarialLM", "ArchConfig", "Backbone", "FeatureDiscriminator",
    "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K",
]
