"""Adversarial pair for backbone LMs — the FedGAN train_4k step operand.

Each agent holds (G = assigned backbone, D = compact transformer encoder).
The discriminator scores *feature sequences* in the generator's embedding
space (real path: embed(real tokens); fake path: G's final hidden states) —
this keeps the (B, T, vocab) softmax out of the feature path, which matters
at 262k vocab.  G's total loss = LM cross-entropy (the ACGAN-style auxiliary
task the paper uses) + non-saturating adversarial term.

This module only defines the models + losses; the federated update schedule
lives in repro.core.fedgan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import nn
from repro.dist.sharding import batch_spec, shard
from repro.models.config import ArchConfig
from repro.models.layers import Attention, SwiGLU, make_norm
from repro.models.transformer import Backbone, stack_init


@dataclasses.dataclass(frozen=True)
class FeatureDiscriminator(nn.Module):
    """Bidirectional transformer encoder over (B, T, d_model) features ->
    per-sequence real/fake logit + auxiliary class logits (unused for LM)."""

    cfg: ArchConfig

    def _dcfg(self) -> ArchConfig:
        c = self.cfg
        return c.scaled(
            d_model=c.disc_d_model, num_heads=c.disc_heads,
            num_kv_heads=c.disc_heads, head_dim=c.disc_d_model // c.disc_heads,
            d_ff=4 * c.disc_d_model, num_experts=0, sliding_window=0,
            local_global_ratio=0, qk_norm=False)

    def _block(self):
        from repro.models.transformer import DecoderBlock
        return DecoderBlock(self._dcfg(), causal=False)

    def init(self, rng):
        c = self.cfg
        dc = self._dcfg()
        k_in, k_blocks, k_norm, k_head = jax.random.split(rng, 4)
        return {
            "proj_in": nn.Dense(c.d_model, dc.d_model, use_bias=False,
                                dtype=c.param_dtype).init(k_in),
            "blocks": stack_init(self._block(), k_blocks, c.disc_layers),
            "norm": make_norm(dc, dc.d_model).init(k_norm),
            "head": nn.Dense(dc.d_model, 1, dtype=c.param_dtype).init(k_head),
        }

    def apply(self, params, feats):
        """feats: (B, T, d_model) -> (B,) real/fake logits."""
        c = self.cfg
        dc = self._dcfg()
        h = (feats.astype(c.dtype) @ params["proj_in"]["w"].astype(c.dtype))
        h = shard(h, *batch_spec(None, None))
        block = self._block()

        def body(carry, bp):
            hh, _ = block.apply(bp, carry, window=None)
            return hh, None

        h, _ = jax.lax.scan(body, h, params["blocks"])
        h = make_norm(dc, dc.d_model).apply(params["norm"], h)
        pooled = jnp.mean(h.astype(jnp.float32), axis=1)
        logit = pooled @ params["head"]["w"].astype(jnp.float32)
        logit = logit + params["head"]["b"].astype(jnp.float32)
        return logit[..., 0]


@dataclasses.dataclass(frozen=True)
class AdversarialLM(nn.Module):
    """The (G, D) pair.  params = {"gen": ..., "disc": ...}."""

    cfg: ArchConfig
    use_flash: bool = False
    adv_weight: float = 0.1

    @property
    def generator(self) -> Backbone:
        return Backbone(self.cfg, use_flash=self.use_flash)

    @property
    def discriminator(self) -> FeatureDiscriminator:
        return FeatureDiscriminator(self.cfg)

    def init(self, rng):
        kg, kd = jax.random.split(rng)
        return {"gen": self.generator.init(kg), "disc": self.discriminator.init(kd)}

    # ---- feature extraction ----
    def real_features(self, gen_params, tokens):
        emb = nn.Embedding(self.cfg.padded_vocab, self.cfg.d_model).apply(
            gen_params["embed"], tokens)
        return emb.astype(self.cfg.dtype)

    def fake_features(self, gen_params, tokens, encoder_frames=None):
        out = self.generator.apply(gen_params, tokens,
                                   encoder_frames=encoder_frames)
        return out["hidden"], out["logits"], out["aux"]

    # ---- losses ----
    def lm_loss(self, logits, tokens):
        """Next-token cross entropy (teacher forcing)."""
        tgt = tokens[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    def disc_loss(self, disc_params, real_feats, fake_feats):
        """Non-saturating GAN loss for D (features are stop-gradient'd)."""
        d = self.discriminator
        lr_ = d.apply(disc_params, jax.lax.stop_gradient(real_feats))
        lf_ = d.apply(disc_params, jax.lax.stop_gradient(fake_feats))
        loss = jnp.mean(jax.nn.softplus(-lr_)) + jnp.mean(jax.nn.softplus(lf_))
        return loss

    def gen_loss(self, gen_params, disc_params, tokens, encoder_frames=None):
        """LM CE + adversarial (fool D) + MoE router aux."""
        fake, logits, aux = self.fake_features(gen_params, tokens, encoder_frames)
        lm = self.lm_loss(logits, tokens)
        adv = jnp.mean(jax.nn.softplus(-self.discriminator.apply(disc_params, fake)))
        total = lm + self.adv_weight * adv + self.cfg.router_aux_weight * aux
        return total, {"lm": lm, "adv": adv, "aux": aux, "fake_feats": fake}
