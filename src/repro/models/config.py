"""Architecture configuration.

One ``ArchConfig`` describes any backbone in the zoo (dense / MoE / SSM /
hybrid / enc-dec audio / early-fusion VLM).  The FedGAN technique is
architecture-agnostic (it averages parameter pytrees), so the same config
type drives training, prefill, decode and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention pattern ---
    sliding_window: int = 0          # >0 -> local layers use this window
    local_global_ratio: int = 0      # e.g. 5 -> 5 local : 1 global (gemma3)
    global_uses_window: bool = False # beyond-paper long-context variant
    qk_norm: bool = False
    rope_theta: float = 10_000.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_group_size: int = 1024       # token group size for einsum dispatch
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_heads: int = 0               # 0 -> d_inner // 64
    ssm_chunk: int = 128
    conv_kernel: int = 4

    # --- hybrid (zamba2): shared attention block every `hybrid_period` ---
    hybrid_period: int = 0           # >0 -> block i is shared-attn if i%period==0

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # frames after the (stubbed) conv frontend
    cross_attention: bool = False

    # --- modality stub (audio/vlm): embeddings come from input_specs ---
    frontend_stub: bool = False

    # --- norms / misc ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    # Untied output head by default: the LM head stays vocab-sharded over
    # "model" while the embedding is d_model-sharded, which keeps both the
    # lookup gather and the logits matmul SPMD-clean (see DESIGN.md).
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True

    # --- adversarial (FedGAN) head: discriminator encoder dims ---
    disc_layers: int = 4
    disc_d_model: int = 512
    disc_heads: int = 8

    # provenance
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return max(self.d_inner // 64, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """True iff a 500k-token decode is sub-quadratic *and* cache-bounded.

        SSM: O(1) state.  Hybrid: O(1) state + shared-attn windowed variant.
        Dense/MoE with sliding windows: window-bounded cache on local layers;
        we additionally window the sparse global layers for the long-decode
        variant (recorded in DESIGN.md).  Pure full-attention archs: skipped.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def is_global_layer(self, i: int) -> bool:
        if self.local_global_ratio <= 0:
            return self.sliding_window == 0
        return (i + 1) % (self.local_global_ratio + 1) == 0

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family variant for CPU smoke tests."""
        kv = min(self.num_kv_heads, 2) if self.num_kv_heads else 0
        heads = min(self.num_heads, 4) if self.num_heads else 0
        if heads and kv and heads % kv:
            kv = 1
        over = dict(
            num_layers=3 if self.hybrid_period else 2,
            local_global_ratio=1 if self.local_global_ratio else 0,
            d_model=128,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32 if heads else 0,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=2 if self.family in ("ssm", "hybrid") else 0,
            ssm_chunk=8,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            hybrid_period=3 if self.hybrid_period else 0,
            disc_layers=2,
            disc_d_model=64,
            disc_heads=2,
            dtype=jnp.float32,
            remat=False,
        )
        if self.num_experts:
            over.update(num_experts=4, experts_per_token=min(self.experts_per_token, 2),
                        moe_group_size=16, d_ff=64)
        return self.scaled(**over)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
