"""The paper's experiment networks.

- 2D system (Appendix C / Nagarajan & Kolter):  D(x) = psi * x^2,  G(z) = theta * z.
- MLP GAN for mixed-Gaussian / Swiss-roll (Kodali et al. DRAGAN nets).
- ACGAN conv nets for the image experiments (Odena et al., Table 1/2).
- CGAN with stacked 1-D convs for the time-series experiments (Table 3).

All are repro.nn Modules so FedGAN's parameter averaging applies uniformly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn


# ---------------------------------------------------------------------------
# 2D system: scalar generator/discriminator (exactly the paper's toy)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Toy2DGenerator(nn.Module):
    """G(z) = theta * z, z ~ U[-1, 1]."""

    theta0: float = 0.1

    def init(self, rng):
        return {"theta": jnp.asarray(self.theta0, jnp.float32)}

    def apply(self, params, z):
        return params["theta"] * z


@dataclasses.dataclass(frozen=True)
class Toy2DDiscriminator(nn.Module):
    """D(x) = psi * x^2 (the paper uses the quadratic discriminator)."""

    psi0: float = 0.1

    def init(self, rng):
        return {"psi": jnp.asarray(self.psi0, jnp.float32)}

    def apply(self, params, x):
        return params["psi"] * jnp.square(x)


# ---------------------------------------------------------------------------
# MLP GAN (mixed Gaussian / Swiss roll)
# ---------------------------------------------------------------------------


def _mlp(sizes, final_act=None):
    layers = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        layers.append(nn.Dense(a, b))
        if i < len(sizes) - 2:
            layers.append(jax.nn.relu)
    if final_act is not None:
        layers.append(final_act)
    return nn.Sequential(layers)


@dataclasses.dataclass(frozen=True)
class MLPGenerator(nn.Module):
    latent_dim: int = 2
    out_dim: int = 2
    hidden: int = 128
    depth: int = 3

    def _net(self):
        return _mlp([self.latent_dim] + [self.hidden] * self.depth + [self.out_dim])

    def init(self, rng):
        return self._net().init(rng)

    def apply(self, params, z):
        return self._net().apply(params, z)


@dataclasses.dataclass(frozen=True)
class MLPDiscriminator(nn.Module):
    in_dim: int = 2
    hidden: int = 128
    depth: int = 3

    def _net(self):
        return _mlp([self.in_dim] + [self.hidden] * self.depth + [1])

    def init(self, rng):
        return self._net().init(rng)

    def apply(self, params, x):
        return self._net().apply(params, x)[..., 0]  # logits


# ---------------------------------------------------------------------------
# ACGAN conv nets (paper Table 1, CIFAR-10 / MNIST layout, NHWC)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ACGANGenerator(nn.Module):
    """z (latent) + class label -> image.  Table 1: Linear 1024 -> Linear
    128*(H/4)*(W/4) -> convT 64 -> convT C, BN+ReLU, tanh output."""

    latent_dim: int = 62
    num_classes: int = 10
    image_hw: int = 32
    channels: int = 3
    base: int = 128

    def _seed_hw(self):
        return self.image_hw // 4

    def init(self, rng):
        k = jax.random.split(rng, 8)
        s = self._seed_hw()
        in_dim = self.latent_dim + self.num_classes
        return {
            "fc1": nn.Dense(in_dim, 1024).init(k[0]),
            "bn1": nn.BatchNorm(1024).init(k[1]),
            "fc2": nn.Dense(1024, self.base * s * s).init(k[2]),
            "bn2": nn.BatchNorm(self.base * s * s).init(k[3]),
            "ct1": nn.ConvTranspose2D(self.base, 64).init(k[4]),
            "bn3": nn.BatchNorm(64).init(k[5]),
            "ct2": nn.ConvTranspose2D(64, self.channels).init(k[6]),
        }

    def apply(self, params, z, labels):
        oh = jax.nn.one_hot(labels, self.num_classes)
        h = jnp.concatenate([z, oh], axis=-1)
        h = jax.nn.relu(nn.BatchNorm(1024).apply(
            params["bn1"], h @ params["fc1"]["w"] + params["fc1"]["b"]))
        h = jax.nn.relu(nn.BatchNorm(1).apply(
            params["bn2"], h @ params["fc2"]["w"] + params["fc2"]["b"]))
        s = self._seed_hw()
        h = h.reshape(-1, s, s, self.base)
        h = jax.nn.relu(nn.BatchNorm(64).apply(
            params["bn3"], nn.ConvTranspose2D(self.base, 64).apply(params["ct1"], h)))
        img = jnp.tanh(nn.ConvTranspose2D(64, self.channels).apply(params["ct2"], h))
        return img


@dataclasses.dataclass(frozen=True)
class ACGANDiscriminator(nn.Module):
    """Table 1 D: conv 64 -> conv 128(BN) -> Linear 1024(BN) -> heads
    (binary real/fake logit + aux class logits)."""

    num_classes: int = 10
    image_hw: int = 32
    channels: int = 3

    def init(self, rng):
        k = jax.random.split(rng, 8)
        s = self.image_hw // 4
        return {
            "c1": nn.Conv2D(self.channels, 64).init(k[0]),
            "c2": nn.Conv2D(64, 128).init(k[1]),
            "bn2": nn.BatchNorm(128).init(k[2]),
            "fc": nn.Dense(128 * s * s, 1024).init(k[3]),
            "bn3": nn.BatchNorm(1024).init(k[4]),
            "head_bin": nn.Dense(1024, 1).init(k[5]),
            "head_cls": nn.Dense(1024, self.num_classes).init(k[6]),
        }

    def apply(self, params, img):
        lrelu = nn.leaky_relu(0.2)
        h = lrelu(nn.Conv2D(self.channels, 64).apply(params["c1"], img))
        h = lrelu(nn.BatchNorm(128).apply(params["bn2"],
                                          nn.Conv2D(64, 128).apply(params["c2"], h)))
        h = h.reshape(h.shape[0], -1)
        h = lrelu(nn.BatchNorm(1024).apply(params["bn3"],
                                           h @ params["fc"]["w"] + params["fc"]["b"]))
        logit = (h @ params["head_bin"]["w"] + params["head_bin"]["b"])[..., 0]
        cls = h @ params["head_cls"]["w"] + params["head_cls"]["b"]
        return logit, cls


# ---------------------------------------------------------------------------
# CGAN with 1-D convs (time-series, paper Table 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CGAN1DGenerator(nn.Module):
    """(label, noise) channels x 24 steps -> 24-step profile.
    Table 3: conv1d(5,64) x ~8 with ReLU, then conv1d(1,1)."""

    seq_len: int = 24
    label_dim: int = 4
    hidden: int = 64
    depth: int = 8

    def _layers(self):
        chans = self.label_dim + 1
        layers = [nn.Conv1D(chans, self.hidden)]
        for _ in range(self.depth):
            layers += [jax.nn.relu, nn.Conv1D(self.hidden, self.hidden)]
        layers += [jax.nn.relu, nn.Conv1D(self.hidden, 1, kernel=1)]
        return nn.Sequential(layers)

    def init(self, rng):
        return self._layers().init(rng)

    def apply(self, params, z, labels):
        # z: (B, T); labels: (B, label_dim) broadcast along time
        lab = jnp.broadcast_to(labels[:, None, :], (z.shape[0], self.seq_len, self.label_dim))
        x = jnp.concatenate([z[..., None], lab], axis=-1)
        return self._layers().apply(params, x)[..., 0]


@dataclasses.dataclass(frozen=True)
class CGAN1DDiscriminator(nn.Module):
    seq_len: int = 24
    label_dim: int = 4
    hidden: int = 64
    depth: int = 8

    def _layers(self):
        chans = self.label_dim + 1
        layers = [nn.Conv1D(chans, self.hidden)]
        for _ in range(self.depth):
            layers += [jax.nn.relu, nn.Conv1D(self.hidden, self.hidden)]
        return nn.Sequential(layers)

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"conv": self._layers().init(k1),
                "head": nn.Dense(self.hidden, 1).init(k2)}

    def apply(self, params, x, labels):
        lab = jnp.broadcast_to(labels[:, None, :], (x.shape[0], self.seq_len, self.label_dim))
        h = jnp.concatenate([x[..., None], lab], axis=-1)
        h = self._layers().apply(params["conv"], h)
        h = jnp.mean(h, axis=1)  # pool over time
        return (h @ params["head"]["w"] + params["head"]["b"])[..., 0]
