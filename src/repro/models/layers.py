"""Transformer building blocks: RoPE, GQA attention (full / sliding-window /
cache-decode), SwiGLU, norms.  All modules follow the repro.nn init/apply
convention and carry explicit sharding constraints.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro import nn
from repro.dist.sharding import batch_spec, shard
from repro.models.config import ArchConfig

NEG_INF = -2.0 ** 30  # large-but-finite mask value (NaN-safe under softmax)


def decode_positions(index, batch: int) -> jax.Array:
    """Normalize a decode index — scalar () or per-row (B,) — to (B,) int32.

    The scalar form is the lockstep case (every row writes the same cache
    position); the vector form is what continuous batching needs, where each
    batch slot sits at its own sequence position."""
    idx = jnp.asarray(index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.broadcast_to(idx, (batch,))
    return idx


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    angles = angles[..., None, :]                     # (..., T, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norm factory
# ---------------------------------------------------------------------------

def make_norm(cfg: ArchConfig, dim: int) -> nn.Module:
    if cfg.norm == "layernorm":
        return nn.LayerNorm(dim, dtype=cfg.param_dtype)
    return nn.RMSNorm(dim, dtype=cfg.param_dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Attention(nn.Module):
    """Grouped-query attention with RoPE, optional qk-norm and sliding window.

    Modes:
      full-sequence  apply(params, x, *, window, positions, causal) -> y
      decode         decode(params, x1, cache, index, *, window) -> y1, cache'
    KV cache layout: (B, S, n_kv, head_dim) per layer (stacked outside).
    """

    cfg: ArchConfig
    causal: bool = True
    use_flash: bool = False  # route full-seq path through the Pallas kernel

    @property
    def dims(self):
        c = self.cfg
        hd = c.resolved_head_dim
        return c.num_heads, c.num_kv_heads, hd

    def init(self, rng):
        c = self.cfg
        nh, nkv, hd = self.dims
        kq, kk, kv, ko, kn1, kn2 = jax.random.split(rng, 6)
        d = c.d_model
        p = {
            "wq": nn.Dense(d, nh * hd, use_bias=False, dtype=c.param_dtype).init(kq),
            "wk": nn.Dense(d, nkv * hd, use_bias=False, dtype=c.param_dtype).init(kk),
            "wv": nn.Dense(d, nkv * hd, use_bias=False, dtype=c.param_dtype).init(kv),
            "wo": nn.Dense(nh * hd, d, use_bias=False, dtype=c.param_dtype).init(ko),
        }
        if c.qk_norm:
            p["q_norm"] = nn.RMSNorm(hd, dtype=c.param_dtype).init(kn1)
            p["k_norm"] = nn.RMSNorm(hd, dtype=c.param_dtype).init(kn2)
        return p

    # -- shared projection helpers ------------------------------------------------
    def _qkv(self, params, x, positions):
        c = self.cfg
        nh, nkv, hd = self.dims
        B, T = x.shape[0], x.shape[1]
        q = (x @ params["wq"]["w"].astype(c.dtype)).reshape(B, T, nh, hd)
        k = (x @ params["wk"]["w"].astype(c.dtype)).reshape(B, T, nkv, hd)
        v = (x @ params["wv"]["w"].astype(c.dtype)).reshape(B, T, nkv, hd)
        if c.qk_norm:
            q = nn.RMSNorm(hd).apply(params["q_norm"], q)
            k = nn.RMSNorm(hd).apply(params["k_norm"], k)
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        return q, k, v

    # -- full-sequence (train / prefill) ------------------------------------------
    def apply(self, params, x, *, window=None, positions=None,
              memory=None, return_kv: bool = False):
        """x: (B, T, d_model).  ``memory``: (B, S_enc, d) for cross-attention
        (whisper decoder); when given, k/v come from memory and no mask/rope
        asymmetry applies beyond standard cross-attn."""
        c = self.cfg
        nh, nkv, hd = self.dims
        B, T, _ = x.shape
        if positions is None:
            positions = jnp.arange(T)[None, :]

        if memory is None:
            q, k, v = self._qkv(params, x, positions)
        else:
            # cross-attention: queries from x, keys/values from memory
            S = memory.shape[1]
            q = (x @ params["wq"]["w"].astype(c.dtype)).reshape(B, T, nh, hd)
            k = (memory @ params["wk"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)
            v = (memory @ params["wv"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)

        from repro.dist.sharding import shard_attn_qkv
        q, k, v = shard_attn_qkv(q, k, v)

        if (self.use_flash and memory is None and q.shape[1] == k.shape[1]
                and isinstance(window, (int, type(None)))):
            from repro.kernels.flash_attention import ops as flash_ops
            y = flash_ops.flash_attention(
                q, k, v, causal=self.causal, window=window or 0)
        else:
            y = self._sdpa(q, k, v, window=window, causal=self.causal and memory is None,
                           q_positions=positions)
        y = y.reshape(B, T, nh * hd)
        y = y @ params["wo"]["w"].astype(c.dtype)
        y = shard(y, *batch_spec(None, None))
        if return_kv:
            return y, {"k": k, "v": v}
        return y

    def _sdpa(self, q, k, v, *, window, causal, q_positions=None,
              k_positions=None):
        nh, nkv, hd = self.dims
        group = nh // max(nkv, 1)
        B, T = q.shape[0], q.shape[1]
        S = k.shape[1]
        qh = q.reshape(B, T, nkv, group, hd)
        logits = jnp.einsum("btkgd,bskd->bkgts", qh, k).astype(jnp.float32)
        logits *= 1.0 / math.sqrt(hd)
        qpos = jnp.arange(T) if q_positions is None else q_positions[0]
        kpos = jnp.arange(S) if k_positions is None else k_positions
        mask = jnp.ones((T, S), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:  # window may be a traced per-layer scalar
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        y = jnp.einsum("bkgts,bskd->btkgd", probs, v)
        return y.reshape(B, T, nh, hd)

    # -- single-token decode against a KV cache -----------------------------------
    def decode(self, params, x, cache, index, *, window=None, memory=None):
        """x: (B, 1, d); cache: dict(k=(B,S,nkv,hd), v=...); index: the
        position being written — a scalar int (lockstep batch) or a (B,)
        vector of per-row positions (continuous batching).  Returns
        (y, new_cache)."""
        c = self.cfg
        nh, nkv, hd = self.dims
        B = x.shape[0]

        if memory is not None:
            S = memory.shape[1]
            q = (x @ params["wq"]["w"].astype(c.dtype)).reshape(B, 1, nh, hd)
            k = (memory @ params["wk"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)
            v = (memory @ params["wv"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)
            y = self._decode_attend(q, k, v, jnp.ones((S,), bool))
            y = (y.reshape(B, 1, nh * hd) @ params["wo"]["w"].astype(c.dtype))
            return shard(y, *batch_spec(None, None)), cache

        idx = decode_positions(index, B)
        q, k1, v1 = self._qkv(params, x, idx[:, None])
        kpos = jnp.arange(cache["k"].shape[1])
        if jnp.ndim(index) == 0:
            # lockstep fast path: one dynamic_update_slice, shared (S,) mask
            k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k1.astype(cache["k"].dtype), index, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v1.astype(cache["v"].dtype), index, axis=1)
            valid = kpos <= index
            if window is not None:
                valid &= kpos > index - window
        else:
            # per-row scatter: row b writes its own position idx[b]
            hit = kpos[None, :] == idx[:, None]                    # (B, S)
            k = jnp.where(hit[..., None, None], k1.astype(cache["k"].dtype), cache["k"])
            v = jnp.where(hit[..., None, None], v1.astype(cache["v"].dtype), cache["v"])
            valid = kpos[None, :] <= idx[:, None]
            if window is not None:
                valid &= kpos[None, :] > idx[:, None] - window
        y = self._decode_attend(q, k, v, valid)
        y = y.reshape(B, 1, nh * hd) @ params["wo"]["w"].astype(c.dtype)
        return shard(y, *batch_spec(None, None)), {"k": k, "v": v}

    def build_memory_cache(self, params, memory):
        """Precompute cross-attention k/v from encoder output (B, S_enc, d)."""
        c = self.cfg
        _, nkv, hd = self.dims
        B, S, _ = memory.shape
        k = (memory @ params["wk"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)
        v = (memory @ params["wv"]["w"].astype(c.dtype)).reshape(B, S, nkv, hd)
        return {"k": k, "v": v}

    def decode_memory(self, params, x, mem_cache):
        """Single-token cross-attention against a prebuilt memory cache."""
        c = self.cfg
        nh, nkv, hd = self.dims
        B = x.shape[0]
        S = mem_cache["k"].shape[1]
        q = (x @ params["wq"]["w"].astype(c.dtype)).reshape(B, 1, nh, hd)
        y = self._decode_attend(q, mem_cache["k"], mem_cache["v"], jnp.ones((S,), bool))
        y = y.reshape(B, 1, nh * hd) @ params["wo"]["w"].astype(c.dtype)
        return shard(y, *batch_spec(None, None))

    def decode_ring(self, params, x, cache, index):
        """Sliding-window decode on a ring-buffer cache of width W — the
        cache read is O(W), not O(S): the structural win of windowed layers
        for long-context serving.  cache: {k,v: (B,W,nkv,hd), pos: (B,W) i32,
        positions initialised to -1}.  ``index`` may be scalar (lockstep) or
        (B,) per-row positions (continuous batching)."""
        c = self.cfg
        nh, nkv, hd = self.dims
        B = x.shape[0]
        W = cache["k"].shape[1]
        idx = decode_positions(index, B)
        q, k1, v1 = self._qkv(params, x, idx[:, None])
        hit = jnp.arange(W)[None, :] == jnp.mod(idx, W)[:, None]   # (B, W)
        k = jnp.where(hit[..., None, None], k1.astype(cache["k"].dtype), cache["k"])
        v = jnp.where(hit[..., None, None], v1.astype(cache["v"].dtype), cache["v"])
        pos = jnp.where(hit, idx[:, None], cache["pos"])
        valid = (pos >= 0) & (pos <= idx[:, None])                 # (B, W)
        y = self._decode_attend(q, k, v, valid)
        y = y.reshape(B, 1, nh * hd) @ params["wo"]["w"].astype(c.dtype)
        return shard(y, *batch_spec(None, None)), {"k": k, "v": v, "pos": pos}

    def _decode_attend(self, q, k, v, valid):
        nh, nkv, hd = self.dims
        group = nh // max(nkv, 1)
        B = k.shape[0]
        qh = q.reshape(B, nkv, group, hd)
        logits = jnp.einsum("bkgd,bskd->bkgs", qh, k.astype(q.dtype)).astype(jnp.float32)
        logits *= 1.0 / math.sqrt(hd)
        # valid: (S,) shared mask, or (B, S) per-row (continuous batching)
        mask = valid[None, None, None] if valid.ndim == 1 else valid[:, None, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        y = jnp.einsum("bkgs,bskd->bkgd", probs, v.astype(q.dtype))
        return y.reshape(B, 1, nh, hd)

    def init_cache(self, batch: int, seq: int, dtype=None, *, ring: bool = False):
        c = self.cfg
        _, nkv, hd = self.dims
        dt = dtype or c.dtype
        cache = {
            "k": jnp.zeros((batch, seq, nkv, hd), dt),
            "v": jnp.zeros((batch, seq, nkv, hd), dt),
        }
        if ring:
            cache["pos"] = jnp.full((batch, seq), -1, jnp.int32)
        return cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SwiGLU(nn.Module):
    cfg: ArchConfig
    d_ff: int = 0

    def init(self, rng):
        c = self.cfg
        ff = self.d_ff or c.d_ff
        kg, ku, kd = jax.random.split(rng, 3)
        return {
            "w_gate": nn.Dense(c.d_model, ff, use_bias=False, dtype=c.param_dtype).init(kg),
            "w_up": nn.Dense(c.d_model, ff, use_bias=False, dtype=c.param_dtype).init(ku),
            "w_down": nn.Dense(ff, c.d_model, use_bias=False, dtype=c.param_dtype).init(kd),
        }

    def apply(self, params, x):
        c = self.cfg
        g = x @ params["w_gate"]["w"].astype(c.dtype)
        u = x @ params["w_up"]["w"].astype(c.dtype)
        h = jax.nn.silu(g) * u
        h = shard(h, *batch_spec(None, "model"))
        y = h @ params["w_down"]["w"].astype(c.dtype)
        return shard(y, *batch_spec(None, None))
