"""Mixture-of-Experts FFN with top-k routing and capacity-based einsum
dispatch (MaxText-style group-wise dispatch: tokens are routed within groups
of ``moe_group_size`` so the one-hot dispatch tensor stays VMEM/HBM-sane).

Expert weights are stacked (E, d_model, d_ff); the ``model`` mesh axis shards
d_ff inside every expert (tensor-parallel experts — uniform across E, so the
sync average of FedGAN treats expert params like any other leaf).
A load-balance auxiliary loss (Switch-style) is returned alongside the output.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.dist.sharding import batch_spec, shard
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class MoE(nn.Module):
    cfg: ArchConfig

    def init(self, rng):
        c = self.cfg
        E, d, f = c.num_experts, c.d_model, c.d_ff
        kr, kg, ku, kd = jax.random.split(rng, 4)
        lim = (6.0 / (d + f)) ** 0.5
        return {
            "router": {"w": 0.02 * jax.random.normal(kr, (d, E), c.param_dtype)},
            "experts": {
                "w_gate": jax.random.uniform(kg, (E, d, f), c.param_dtype, -lim, lim),
                "w_up": jax.random.uniform(ku, (E, d, f), c.param_dtype, -lim, lim),
                "w_down": jax.random.uniform(kd, (E, f, d), c.param_dtype, -lim, lim),
            },
        }

    def apply(self, params, x):
        """x: (B, T, d) -> (y, aux_loss)."""
        c = self.cfg
        E, k = c.num_experts, c.experts_per_token
        B, T, d = x.shape
        G = max(min(c.moe_group_size, T), 1)
        n_groups = (B * T) // G
        xt = x.reshape(n_groups, G, d)

        logits = (xt @ params["router"]["w"].astype(c.dtype)).astype(jnp.float32)  # (n,G,E)
        probs = jax.nn.softmax(logits, axis=-1)

        # top-k gating, renormalised over the chosen experts
        gate_vals, gate_idx = jax.lax.top_k(probs, k)                      # (n,G,k)
        gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

        # Switch-style load-balance loss over the group axis
        me = jnp.mean(probs, axis=1)                                       # (n,E)
        onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)            # (n,G,k,E)
        ce = jnp.mean(jnp.sum(onehot, axis=2), axis=1)                     # (n,E) fraction routed
        aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

        # capacity-based dispatch within each group
        cap = int(max(1, (k * G * c.capacity_factor) // E))
        # position of each (token, choice) in its expert's buffer
        flat_idx = gate_idx                                                # (n,G,k)
        expert_onehot = onehot                                             # (n,G,k,E)
        # cumulative count per expert along the (G*k) routing order
        flat = expert_onehot.reshape(n_groups, G * k, E)
        pos_in_expert = jnp.cumsum(flat, axis=1) - flat                    # (n,G*k,E)
        pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(n_groups, G, k)
        pos = pos.astype(jnp.int32)
        keep = pos < cap
        gate_vals = gate_vals * keep.astype(gate_vals.dtype)

        # dispatch tensor: (n, G, E, cap)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=c.dtype)
        disp = jnp.einsum("ngke,ngkc->ngec", onehot.astype(c.dtype), pos_oh)
        comb = jnp.einsum("ngk,ngke,ngkc->ngec",
                          gate_vals.astype(c.dtype), onehot.astype(c.dtype), pos_oh)

        disp = shard(disp, *batch_spec(None, None, None))
        expert_in = jnp.einsum("ngec,ngd->necd", disp, xt)                 # (n,E,cap,d)
        expert_in = shard(expert_in, *batch_spec(None, None, None))

        wg = params["experts"]["w_gate"].astype(c.dtype)
        wu = params["experts"]["w_up"].astype(c.dtype)
        wd = params["experts"]["w_down"].astype(c.dtype)
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, wg))
        h = h * jnp.einsum("necd,edf->necf", expert_in, wu)
        h = shard(h, *batch_spec(None, None, "model"))
        expert_out = jnp.einsum("necf,efd->necd", h, wd)                   # (n,E,cap,d)

        y = jnp.einsum("ngec,necd->ngd", comb, expert_out)
        y = y.reshape(B, T, d)
        return shard(y, *batch_spec(None, None)), aux.astype(jnp.float32)
