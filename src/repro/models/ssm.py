"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Full-sequence path uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk recurrent state pass); the inner chunk computation can route
through the Pallas ``ssd_scan`` kernel.  Decode path is the O(1) recurrent
update on a (heads, head_dim, state) SSM cache — this is what makes
``long_500k`` decoding feasible for mamba2/zamba2.

SPMD-friendliness (found via the dry-run HLO audit):
  * separate z/x/B/C/dt projections — a packed in_proj whose split points
    don't align with the "model"-axis shard boundaries forces
    collective-permute resharding on every layer;
  * the causal depthwise conv is implemented as k shift-and-accumulate
    steps (elementwise ops partition trivially) instead of a grouped
    lax.conv, which the SPMD partitioner handles poorly for channel-sharded
    operands.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.dist.sharding import batch_spec, shard
from repro.models.config import ArchConfig


def causal_depthwise_conv(x, w):
    """x: (B, T, C); w: (k, C) -> (B, T, C); y[t] = sum_j w[j] * x[t-k+1+j]."""
    k = w.shape[0]
    y = x * w[k - 1]
    for j in range(k - 1):
        shift = k - 1 - j
        y = y + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]] * w[j]
    return y


@dataclasses.dataclass(frozen=True)
class Mamba2Block(nn.Module):
    cfg: ArchConfig
    use_kernel: bool = False

    @property
    def dims(self):
        c = self.cfg
        d_in = c.d_inner
        nh = c.resolved_ssm_heads
        hd = d_in // nh
        return d_in, nh, hd, c.ssm_state

    def init(self, rng):
        c = self.cfg
        d_in, nh, hd, ds = self.dims
        keys = jax.random.split(rng, 10)
        dense = lambda o, k: nn.Dense(c.d_model, o, use_bias=False,
                                      dtype=c.param_dtype).init(k)
        return {
            "z_proj": dense(d_in, keys[0]),
            "x_proj": dense(d_in, keys[1]),
            "b_proj": dense(ds, keys[2]),
            "c_proj": dense(ds, keys[3]),
            "dt_proj": dense(nh, keys[4]),
            "conv": {
                "x": 0.3 * jax.random.normal(keys[5], (c.conv_kernel, d_in), c.param_dtype),
                "b": 0.3 * jax.random.normal(keys[6], (c.conv_kernel, ds), c.param_dtype),
                "c": 0.3 * jax.random.normal(keys[7], (c.conv_kernel, ds), c.param_dtype),
            },
            "ssd": {
                "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(c.param_dtype)),
                "dt_bias": jnp.zeros((nh,), c.param_dtype),
                "D": jnp.ones((nh,), c.param_dtype),
            },
            "norm": nn.RMSNorm(d_in, dtype=c.param_dtype).init(keys[8]),
            "out_proj": nn.Dense(d_in, c.d_model, use_bias=False,
                                 dtype=c.param_dtype).init(keys[9]),
        }

    # ------------------------------------------------------------------
    def _project(self, params, u):
        c = self.cfg
        dt_ = c.dtype
        z = u @ params["z_proj"]["w"].astype(dt_)
        x = u @ params["x_proj"]["w"].astype(dt_)
        Bm = u @ params["b_proj"]["w"].astype(dt_)
        Cm = u @ params["c_proj"]["w"].astype(dt_)
        dt = u @ params["dt_proj"]["w"].astype(dt_)
        return z, x, Bm, Cm, dt

    # ------------------------------------------------------------------
    def apply(self, params, u, *, return_state: bool = False):
        """Full-sequence forward.  u: (B, T, d_model) -> (B, T, d_model).
        ``return_state=True`` additionally returns the decode cache."""
        c = self.cfg
        d_in, nh, hd, ds = self.dims
        Bsz, T, _ = u.shape
        z, x_raw, B_raw, C_raw, dt = self._project(params, u)
        x = jax.nn.silu(causal_depthwise_conv(x_raw, params["conv"]["x"].astype(c.dtype)))
        Bm = jax.nn.silu(causal_depthwise_conv(B_raw, params["conv"]["b"].astype(c.dtype)))
        Cm = jax.nn.silu(causal_depthwise_conv(C_raw, params["conv"]["c"].astype(c.dtype)))
        x = x.reshape(Bsz, T, nh, hd)
        x = shard(x, *batch_spec(None, "model", None))

        A = -jnp.exp(params["ssd"]["A_log"].astype(jnp.float32))           # (nh,)
        dt = jax.nn.softplus(dt.astype(jnp.float32)
                             + params["ssd"]["dt_bias"].astype(jnp.float32))  # (B,T,nh)

        from repro.kernels.ssd_scan.ref import ssd_ref
        state = None
        if return_state:
            y, state = ssd_ref(x, dt, A, Bm, Cm, chunk=c.ssm_chunk,
                               return_final_state=True)
        elif self.use_kernel:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk=c.ssm_chunk)
        else:
            y = ssd_ref(x, dt, A, Bm, Cm, chunk=c.ssm_chunk)

        y = y + x * params["ssd"]["D"].astype(c.dtype)[None, None, :, None]
        y = y.reshape(Bsz, T, d_in)
        y = nn.RMSNorm(d_in).apply(params["norm"], y) * jax.nn.silu(z)
        out = y @ params["out_proj"]["w"].astype(c.dtype)
        out = shard(out, *batch_spec(None, None))
        if return_state:
            k = c.conv_kernel
            cache = {
                "ssm": state,
                "conv_x": _tail_window(x_raw, k - 1),
                "conv_b": _tail_window(B_raw, k - 1),
                "conv_c": _tail_window(C_raw, k - 1),
            }
            return out, cache
        return out

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, dtype=None):
        c = self.cfg
        d_in, nh, hd, ds = self.dims
        k = c.conv_kernel - 1
        return {
            "ssm": jnp.zeros((batch, nh, hd, ds), jnp.float32),
            "conv_x": jnp.zeros((batch, k, d_in), c.dtype),
            "conv_b": jnp.zeros((batch, k, ds), c.dtype),
            "conv_c": jnp.zeros((batch, k, ds), c.dtype),
        }

    def decode(self, params, u, cache):
        """Single-token recurrent step.  u: (B, 1, d_model)."""
        c = self.cfg
        d_in, nh, hd, ds = self.dims
        Bsz = u.shape[0]
        z, x_raw, B_raw, C_raw, dt = self._project(params, u)

        def conv_step(raw, window, w):
            win = jnp.concatenate([window, raw], axis=1)        # (B, k, C)
            y = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, w.astype(c.dtype)))
            return y[:, None, :], win[:, 1:, :]

        x1, new_cx = conv_step(x_raw, cache["conv_x"], params["conv"]["x"])
        B1, new_cb = conv_step(B_raw, cache["conv_b"], params["conv"]["b"])
        C1, new_cc = conv_step(C_raw, cache["conv_c"], params["conv"]["c"])

        x = x1.reshape(Bsz, nh, hd)
        A = -jnp.exp(params["ssd"]["A_log"].astype(jnp.float32))
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                              + params["ssd"]["dt_bias"].astype(jnp.float32))  # (B,nh)
        from repro.kernels.ssd_scan.ref import ssd_decode_ref
        y, state = ssd_decode_ref(cache["ssm"], x, dtv, A, B1[:, 0, :], C1[:, 0, :])
        y = y + x * params["ssd"]["D"].astype(c.dtype)[None, :, None]
        y = y.reshape(Bsz, 1, d_in)
        y = nn.RMSNorm(d_in).apply(params["norm"], y) * jax.nn.silu(z)
        out = y @ params["out_proj"]["w"].astype(c.dtype)
        return out, {"ssm": state, "conv_x": new_cx, "conv_b": new_cb,
                     "conv_c": new_cc}


def _tail_window(x, k: int):
    """Last k steps of (B, T, C), zero-padded on the left if T < k."""
    T = x.shape[1]
    if T >= k:
        return x[:, T - k:, :]
    return jnp.pad(x, ((0, 0), (k - T, 0), (0, 0)))
