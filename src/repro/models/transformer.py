"""Backbone model: one composable definition covering every assigned family.

Families
  dense / vlm : scanned decoder blocks (uniform window, or grouped
                local:global pattern à la gemma3)
  moe         : decoder blocks with MoE FFN (+ router aux loss)
  ssm         : scanned Mamba2 blocks (attention-free)
  hybrid      : zamba2-style — Mamba2 stacks with a *shared* transformer
                block applied every ``hybrid_period`` blocks
  audio       : whisper-style enc-dec; conv/mel frontend is a stub — the
                encoder consumes precomputed frame embeddings

Entry points (all pure):
  init(rng) -> params
  apply(params, tokens, ...)            # full-sequence train forward
  prefill(params, tokens, ...)          # forward + decode-cache build
  init_cache(batch, seq)                # zeroed decode cache
  decode(params, token, cache, index)   # ONE-token serve step

Layer stacks are `lax.scan`ned over stacked params so the lowered HLO stays
compact for the 512-device dry-run; `cfg.remat` wraps scan bodies in
jax.checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import nn
from repro.dist.sharding import batch_spec, shard
from repro.models.config import ArchConfig
from repro.models.layers import Attention, SwiGLU, make_norm
from repro.models.moe import MoE
from repro.models.ssm import Mamba2Block


def _pad_attn_cache(cache, extra: int):
    """Right-pad the sequence axis (-3) of attention k/v buffers; cross-attn
    memory caches and SSM/conv state are untouched."""

    def walk(tree, under_cross=False):
        if isinstance(tree, dict):
            return {
                k: (walk(v, under_cross or k == "cross")
                    if isinstance(v, dict)
                    else (_pad_leaf(k, v, extra) if not under_cross else v))
                for k, v in tree.items()
            }
        return tree

    def _pad_leaf(key, leaf, n):
        if key in ("k", "v") and leaf.ndim >= 3:
            pad = [(0, 0)] * leaf.ndim
            pad[-3] = (0, n)
            return jnp.pad(leaf, pad)
        return leaf

    return walk(cache)


def stack_init(module: nn.Module, rng, n: int):
    """Stack n independent inits along a leading layer axis (for lax.scan)."""
    keys = jax.random.split(rng, max(n, 1))
    return jax.vmap(module.init)(keys)


def stack_init2(module: nn.Module, rng, n_outer: int, n_inner: int):
    keys = jax.random.split(rng, max(n_outer * n_inner, 1)).reshape(n_outer, n_inner)
    return jax.vmap(jax.vmap(module.init))(keys)


# ---------------------------------------------------------------------------
# Decoder block: attention + (SwiGLU | MoE), optional cross-attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecoderBlock(nn.Module):
    cfg: ArchConfig
    use_moe: bool = False
    cross: bool = False
    causal: bool = True
    use_flash: bool = False

    @property
    def attn(self):
        return Attention(self.cfg, causal=self.causal, use_flash=self.use_flash)

    @property
    def mlp(self):
        return MoE(self.cfg) if self.use_moe else SwiGLU(self.cfg)

    def init(self, rng):
        keys = jax.random.split(rng, 6)
        c = self.cfg
        p = {
            "ln1": make_norm(c, c.d_model).init(keys[0]),
            "attn": self.attn.init(keys[1]),
            "ln2": make_norm(c, c.d_model).init(keys[2]),
            "mlp": self.mlp.init(keys[3]),
        }
        if self.cross:
            p["lnx"] = make_norm(c, c.d_model).init(keys[4])
            p["xattn"] = Attention(self.cfg, causal=False).init(keys[5])
        return p

    def _norm(self):
        return make_norm(self.cfg, self.cfg.d_model)

    def apply(self, params, h, *, window=None, memory=None, return_kv=False):
        norm = self._norm()
        a = self.attn.apply(params["attn"], norm.apply(params["ln1"], h),
                            window=window, return_kv=return_kv)
        if return_kv:
            a, kv = a
        h = h + a
        if self.cross:
            x = Attention(self.cfg, causal=False).apply(
                params["xattn"], norm.apply(params["lnx"], h), memory=memory)
            h = h + x
        m = self.mlp.apply(params["mlp"], norm.apply(params["ln2"], h))
        aux = jnp.float32(0.0)
        if self.use_moe:
            m, aux = m
        h = h + m
        if return_kv:
            return h, aux, kv
        return h, aux

    def decode(self, params, h, cache, index, *, window=None, ring=False,
               mem_cache=None):
        norm = self._norm()
        x = norm.apply(params["ln1"], h)
        if ring:
            a, new_cache = self.attn.decode_ring(params["attn"], x, cache, index)
        else:
            a, new_cache = self.attn.decode(params["attn"], x, cache, index,
                                            window=window)
        h = h + a
        if self.cross and mem_cache is not None:
            xq = norm.apply(params["lnx"], h)
            h = h + Attention(self.cfg, causal=False).decode_memory(
                params["xattn"], xq, mem_cache)
        m = self.mlp.apply(params["mlp"], norm.apply(params["ln2"], h))
        if self.use_moe:
            m, _ = m
        return h + m, new_cache


@dataclasses.dataclass(frozen=True)
class MambaLayer(nn.Module):
    """Pre-norm residual wrapper around Mamba2Block."""

    cfg: ArchConfig
    use_kernel: bool = False

    @property
    def inner(self):
        return Mamba2Block(self.cfg, use_kernel=self.use_kernel)

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"ln": make_norm(self.cfg, self.cfg.d_model).init(k1),
                "mixer": self.inner.init(k2)}

    def apply(self, params, h, *, return_state=False):
        norm = make_norm(self.cfg, self.cfg.d_model)
        y = self.inner.apply(params["mixer"], norm.apply(params["ln"], h),
                             return_state=return_state)
        if return_state:
            y, state = y
            return h + y, state
        return h + y

    def decode(self, params, h, cache):
        norm = make_norm(self.cfg, self.cfg.d_model)
        y, new_cache = self.inner.decode(params["mixer"],
                                         norm.apply(params["ln"], h), cache)
        return h + y, new_cache


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Backbone(nn.Module):
    cfg: ArchConfig
    use_flash: bool = False
    use_ssd_kernel: bool = False
    ring_cache: bool = False  # sliding-window layers use O(W) ring buffers

    # ---- structure helpers ----
    @property
    def grouped(self) -> bool:
        return self.cfg.local_global_ratio > 0

    @property
    def n_groups(self) -> int:
        c = self.cfg
        if c.family == "hybrid":
            return c.num_layers // c.hybrid_period
        if self.grouped:
            return c.num_layers // (c.local_global_ratio + 1)
        return 0

    @property
    def n_tail(self) -> int:
        c = self.cfg
        if c.family == "hybrid":
            return c.num_layers % c.hybrid_period
        if self.grouped:
            return c.num_layers % (c.local_global_ratio + 1)
        return 0

    def _block(self, causal=True, cross=False):
        return DecoderBlock(self.cfg, use_moe=self.cfg.num_experts > 0,
                            cross=cross, causal=causal, use_flash=self.use_flash)

    def _mamba(self):
        return MambaLayer(self.cfg, use_kernel=self.use_ssd_kernel)

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.cfg.remat else fn

    # ---- init ----
    def init(self, rng):
        c = self.cfg
        k_embed, k_layers, k_norm, k_enc, k_shared, k_head = jax.random.split(rng, 6)
        p: dict[str, Any] = {
            "embed": nn.Embedding(c.padded_vocab, c.d_model, dtype=c.param_dtype).init(k_embed),
            "final_norm": make_norm(c, c.d_model).init(k_norm),
        }
        if not c.tie_embeddings:
            p["lm_head"] = nn.Dense(c.d_model, c.padded_vocab, use_bias=False,
                                    dtype=c.param_dtype).init(k_head)
        if c.family == "ssm":
            p["blocks"] = stack_init(self._mamba(), k_layers, c.num_layers)
        elif c.family == "hybrid":
            per = c.hybrid_period - 1
            k_a, k_b = jax.random.split(k_layers)
            p["shared_attn"] = self._block().init(k_shared)
            p["mamba"] = stack_init2(self._mamba(), k_a, self.n_groups, per)
            if self.n_tail:
                p["mamba_tail"] = stack_init(self._mamba(), k_b, self.n_tail)
        elif c.family == "audio":
            k_d, k_e = jax.random.split(k_layers)
            p["enc_blocks"] = stack_init(self._block(causal=False), k_e, c.encoder_layers)
            p["enc_norm"] = make_norm(c, c.d_model).init(k_enc)
            p["blocks"] = stack_init(self._block(cross=True), k_d, c.num_layers)
        elif self.grouped:
            ratio = c.local_global_ratio
            k_l, k_g, k_t = jax.random.split(k_layers, 3)
            p["local"] = stack_init2(self._block(), k_l, self.n_groups, ratio)
            p["global"] = stack_init(self._block(), k_g, self.n_groups)
            if self.n_tail:
                p["tail"] = stack_init(self._block(), k_t, self.n_tail)
        else:
            p["blocks"] = stack_init(self._block(), k_layers, c.num_layers)
        return p

    # ---- embedding / head ----
    def _embed(self, params, tokens):
        c = self.cfg
        h = nn.Embedding(c.padded_vocab, c.d_model).apply(params["embed"], tokens)
        return shard(h.astype(c.dtype), *batch_spec(None, None))

    def _head(self, params, h, *, logits_mode: str = "full"):
        c = self.cfg
        h = make_norm(c, c.d_model).apply(params["final_norm"], h)
        if logits_mode == "none":
            return h, None
        hh = h[:, -1:] if logits_mode == "last" else h
        return h, self.project_logits(params, hh)

    def project_logits(self, params, h):
        """Head matmul on already-final-normed hidden states (B, T, d) ->
        (B, T, padded_vocab) f32.  Public so the serve engine can gather the
        last *real* token of a right-padded prefill before projecting,
        instead of paying full-sequence logits."""
        c = self.cfg
        if c.tie_embeddings:
            logits = h @ params["embed"]["table"].T.astype(c.dtype)
        else:
            logits = h @ params["lm_head"]["w"].astype(c.dtype)
        return shard(logits.astype(jnp.float32), *batch_spec(None, "model"))

    # ---- full-sequence forward ----
    def apply(self, params, tokens=None, *, embeddings=None, encoder_frames=None,
              collect_cache: bool = False, logits_mode: str = "full"):
        """Returns dict(hidden, logits, aux[, cache]).  ``logits_mode``:
        "full" (training), "last" (prefill — only the next-token logits), or
        "none"."""
        c = self.cfg
        h = embeddings if embeddings is not None else self._embed(params, tokens)
        aux0 = jnp.float32(0.0)
        caches: dict[str, Any] = {}

        memory = None
        if c.family == "audio":
            memory = self.encode(params, encoder_frames)

        if c.family == "ssm":
            layer = self._mamba()

            if collect_cache:
                def body(carry, bp):
                    hh, ssm_state = layer.apply(bp, carry, return_state=True)
                    return hh, ssm_state
            else:
                def body(carry, bp):
                    return layer.apply(bp, carry), None

            h, states = jax.lax.scan(self._maybe_remat(body), h, params["blocks"])
            if collect_cache:
                caches["blocks"] = states
        elif c.family == "hybrid":
            h, aux0, hcaches = self._hybrid_forward(params, h, collect_cache)
            if collect_cache:
                g = hcaches.pop("groups")
                caches.update({"attn": g["attn"], "mamba": g["mamba"], **hcaches})
        elif c.family == "audio":
            block = self._block(cross=True)

            def body(carry, bp):
                hh, aux = carry
                out = block.apply(bp, hh, memory=memory, return_kv=collect_cache)
                if collect_cache:
                    hh, a, kv = out
                    mem_kv = block.attn.build_memory_cache(bp["xattn"], memory)
                    return (hh, aux + a), {"self": kv, "cross": mem_kv}
                hh, a = out
                return (hh, aux + a), None

            (h, aux0), kvs = jax.lax.scan(self._maybe_remat(body), (h, aux0),
                                          params["blocks"])
            if collect_cache:
                caches["self"] = kvs["self"]
                caches["cross"] = kvs["cross"]
        elif self.grouped:
            h, aux0, gcaches = self._grouped_forward(params, h, collect_cache)
            if collect_cache:
                g = gcaches.pop("groups")
                caches.update({"local": g["local"], "global": g["global"], **gcaches})
        else:
            block = self._block()
            window = c.sliding_window if c.sliding_window > 0 else None

            def body(carry, bp):
                hh, aux = carry
                out = block.apply(bp, hh, window=window, return_kv=collect_cache)
                if collect_cache:
                    hh, a, kv = out
                    return (hh, aux + a), kv
                hh, a = out
                return (hh, aux + a), None

            (h, aux0), kvs = jax.lax.scan(self._maybe_remat(body), (h, aux0),
                                          params["blocks"])
            if collect_cache:
                caches["blocks"] = kvs

        hidden, logits = self._head(params, h, logits_mode=logits_mode)
        out = {"hidden": hidden, "logits": logits, "aux": aux0}
        if collect_cache:
            out["cache"] = caches
            if memory is not None:
                out["memory"] = memory
        return out

    def _grouped_forward(self, params, h, collect_cache):
        """gemma3-style [ratio local + 1 global] groups + local tail."""
        c = self.cfg
        block = self._block()
        W = c.sliding_window
        gw = W if c.global_uses_window else None

        def local_body(carry, bp):
            hh, aux = carry
            out = block.apply(bp, hh, window=W, return_kv=collect_cache)
            if collect_cache:
                hh, a, kv = out
                return (hh, aux + a), kv
            hh, a = out
            return (hh, aux + a), None

        def group_body(carry, xs):
            lp, gp = xs
            carry, lkv = jax.lax.scan(self._maybe_remat(local_body), carry, lp)
            hh, aux = carry
            out = block.apply(gp, hh, window=gw, return_kv=collect_cache)
            if collect_cache:
                hh, a, gkv = out
                return (hh, aux + a), {"local": lkv, "global": gkv}
            hh, a = out
            return (hh, aux + a), None

        carry = (h, jnp.float32(0.0))
        carry, kvs = jax.lax.scan(group_body, carry,
                                  (params["local"], params["global"]))
        caches = {}
        if collect_cache:
            caches["groups"] = kvs
        if self.n_tail:
            carry, tkv = jax.lax.scan(self._maybe_remat(local_body), carry,
                                      params["tail"])
            if collect_cache:
                caches["tail"] = tkv
        h, aux = carry
        return h, aux, caches

    def _hybrid_forward(self, params, h, collect_cache):
        """zamba2-style: every group = 1 shared-attn block + (period-1) mamba."""
        c = self.cfg
        block = self._block()
        mamba = self._mamba()
        shared = params["shared_attn"]

        def mamba_body(carry, bp):
            hh, aux = carry
            if collect_cache:
                hh, st = mamba.apply(bp, hh, return_state=True)
                return (hh, aux), st
            return (mamba.apply(bp, hh), aux), None

        def group_body(carry, mp):
            hh, aux = carry
            out = block.apply(shared, hh, window=None, return_kv=collect_cache)
            if collect_cache:
                hh, a, kv = out
            else:
                hh, a = out
                kv = None
            carry, mstates = jax.lax.scan(self._maybe_remat(mamba_body),
                                          (hh, aux + a), mp)
            if collect_cache:
                return carry, {"attn": kv, "mamba": mstates}
            return carry, None

        carry = (h, jnp.float32(0.0))
        carry, kvs = jax.lax.scan(group_body, carry, params["mamba"])
        caches = {}
        if collect_cache:
            caches["groups"] = kvs
        if self.n_tail:
            carry, tst = jax.lax.scan(self._maybe_remat(mamba_body), carry,
                                      params["mamba_tail"])
            if collect_cache:
                caches["tail"] = tst
        h, aux = carry
        return h, aux, caches

    # ---- encoder (audio) ----
    def encode(self, params, frames):
        """frames: (B, S_enc, d_model) — stubbed frontend embeddings."""
        c = self.cfg
        h = shard(frames.astype(c.dtype), *batch_spec(None, None))
        block = self._block(causal=False)

        def body(carry, bp):
            hh, _ = block.apply(bp, carry, window=None)
            return hh, None

        h, _ = jax.lax.scan(self._maybe_remat(body), h, params["enc_blocks"])
        return make_norm(c, c.d_model).apply(params["enc_norm"], h)

    # ---- prefill ----
    def prefill(self, params, tokens, *, encoder_frames=None, max_seq: int = 0,
                logits_mode: str = "last"):
        """Full forward + decode-cache build.  ``max_seq > T`` right-pads the
        attention caches so `decode` can continue writing at index >= T."""
        out = self.apply(params, tokens, encoder_frames=encoder_frames,
                         collect_cache=True, logits_mode=logits_mode)
        T = tokens.shape[1]
        if max_seq and max_seq > T:
            out["cache"] = _pad_attn_cache(out["cache"], max_seq - T)
        return out

    # ---- cross-attention cache (audio) ----
    def build_cross_cache(self, params, memory):
        """Per-layer cross-attention K/V from encoder output (B, S_enc, d).

        Returns the {"k", "v"} tree stacked over decoder layers, shaped
        (L, B, S_enc, n_kv, head_dim) — exactly the ``cache["cross"]`` layout
        that ``init_cache``/``prefill`` use.  This is the public replacement
        for the old ``bb._block(cross=True)`` reach-in."""
        if self.cfg.family != "audio":
            raise ValueError("build_cross_cache: only the audio (enc-dec) "
                             f"family has cross-attention, got {self.cfg.family!r}")
        blk = self._block(cross=True)
        return jax.vmap(
            lambda bp: blk.attn.build_memory_cache(bp["xattn"], memory)
        )(params["blocks"])

    # ---- decode cache ----
    def init_cache(self, batch: int, seq: int):
        c = self.cfg
        attn = Attention(c)
        mamba = Mamba2Block(c)
        W = min(c.sliding_window, seq) if c.sliding_window > 0 else seq
        use_ring = self.ring_cache and c.sliding_window > 0

        def kv(n_extra_dims_shape, width, ring):
            base = attn.init_cache(batch, width, ring=ring)
            for n in reversed(n_extra_dims_shape):
                base = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (n,) + x.shape), base)
            return base

        if c.family == "ssm":
            base = mamba.init_cache(batch)
            return {"blocks": jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (c.num_layers,) + x.shape), base)}
        if c.family == "hybrid":
            per = c.hybrid_period - 1
            base = mamba.init_cache(batch)
            cache = {
                "attn": kv((self.n_groups,), seq, False),
                "mamba": jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (self.n_groups, per) + x.shape), base),
            }
            if self.n_tail:
                cache["tail"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (self.n_tail,) + x.shape), base)
            return cache
        if c.family == "audio":
            enc_S = c.encoder_seq
            nkv, hd = c.num_kv_heads, c.resolved_head_dim
            return {
                "self": kv((c.num_layers,), seq, False),
                "cross": {
                    "k": jnp.zeros((c.num_layers, batch, enc_S, nkv, hd), c.dtype),
                    "v": jnp.zeros((c.num_layers, batch, enc_S, nkv, hd), c.dtype),
                },
            }
        if self.grouped:
            cache = {
                "local": kv((self.n_groups, c.local_global_ratio),
                            W if use_ring else seq, use_ring),
                "global": kv((self.n_groups,),
                             W if (use_ring and c.global_uses_window) else seq,
                             use_ring and c.global_uses_window),
            }
            if self.n_tail:
                cache["tail"] = kv((self.n_tail,), W if use_ring else seq, use_ring)
            return cache
        return {"blocks": kv((c.num_layers,), W if use_ring else seq, use_ring)}

    # ---- one-token decode ----
    def decode(self, params, token, cache, index):
        """token: (B, 1) int32; index: the position being generated — a
        scalar int32 (lockstep batch) or a (B,) vector of per-row positions
        (continuous batching, where every slot is mid-way through its own
        request).  Returns (logits (B,1,V), new_cache)."""
        c = self.cfg
        h = self._embed(params, token)
        use_ring = self.ring_cache and c.sliding_window > 0
        window = c.sliding_window if c.sliding_window > 0 else None

        if c.family == "ssm":
            mamba = self._mamba()

            def body(carry, xs):
                bp, lc = xs
                hh, nc = mamba.decode(bp, carry, lc)
                return hh, nc

            h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache["blocks"]))
            new_cache = {"blocks": new_cache}
        elif c.family == "hybrid":
            h, new_cache = self._hybrid_decode(params, h, cache, index)
        elif c.family == "audio":
            block = self._block(cross=True)

            def body(carry, xs):
                bp, sc, cc = xs
                hh, nc = block.decode(bp, carry, sc, index, mem_cache=cc)
                return hh, nc

            h, new_self = jax.lax.scan(
                body, h, (params["blocks"], cache["self"], cache["cross"]))
            new_cache = {"self": new_self, "cross": cache["cross"]}
        elif self.grouped:
            h, new_cache = self._grouped_decode(params, h, cache, index)
        else:
            block = self._block()

            def body(carry, xs):
                bp, lc = xs
                if use_ring:
                    hh, nc = block.decode(bp, carry, lc, index, ring=True)
                else:
                    hh, nc = block.decode(bp, carry, lc, index, window=window)
                return hh, nc

            h, new_blocks = jax.lax.scan(body, h, (params["blocks"], cache["blocks"]))
            new_cache = {"blocks": new_blocks}

        _, logits = self._head(params, h)
        return logits, new_cache

    def _grouped_decode(self, params, h, cache, index):
        c = self.cfg
        block = self._block()
        use_ring = self.ring_cache
        gw = c.sliding_window if c.global_uses_window else None
        g_ring = use_ring and c.global_uses_window

        def local_body(carry, xs):
            bp, lc = xs
            if use_ring:
                hh, nc = block.decode(bp, carry, lc, index, ring=True)
            else:
                hh, nc = block.decode(bp, carry, lc, index, window=c.sliding_window)
            return hh, nc

        def group_body(carry, xs):
            lp, gp, lcache, gcache = xs
            hh, lnew = jax.lax.scan(local_body, carry, (lp, lcache))
            if g_ring:
                hh, gnew = block.decode(gp, hh, gcache, index, ring=True)
            else:
                hh, gnew = block.decode(gp, hh, gcache, index, window=gw)
            return hh, {"local": lnew, "global": gnew}

        h, gnew = jax.lax.scan(group_body, h,
                               (params["local"], params["global"],
                                cache["local"], cache["global"]))
        new_cache = {"local": gnew["local"], "global": gnew["global"]}
        if self.n_tail:
            h, tnew = jax.lax.scan(local_body, h, (params["tail"], cache["tail"]))
            new_cache["tail"] = tnew
        return h, new_cache

    def _hybrid_decode(self, params, h, cache, index):
        c = self.cfg
        block = self._block()
        mamba = self._mamba()
        shared = params["shared_attn"]

        def mamba_body(carry, xs):
            bp, lc = xs
            hh, nc = mamba.decode(bp, carry, lc)
            return hh, nc

        def group_body(carry, xs):
            mp, acache, mcache = xs
            hh, anew = block.decode(shared, carry, acache, index)
            hh, mnew = jax.lax.scan(mamba_body, hh, (mp, mcache))
            return hh, {"attn": anew, "mamba": mnew}

        h, gnew = jax.lax.scan(group_body, h,
                               (params["mamba"], cache["attn"], cache["mamba"]))
        new_cache = {"attn": gnew["attn"], "mamba": gnew["mamba"]}
        if self.n_tail:
            h, tnew = jax.lax.scan(mamba_body, h, (params["mamba_tail"], cache["tail"]))
            new_cache["tail"] = tnew
        return h, new_cache
