from repro.nn.module import (
    BatchNorm,
    Conv1D,
    Conv2D,
    ConvTranspose2D,
    Dense,
    Embedding,
    LayerNorm,
    Module,
    RMSNorm,
    Sequential,
    fan_in_init,
    glorot_uniform,
    leaky_relu,
    normal_init,
    param_bytes,
    param_count,
    truncated_normal_init,
)

__all__ = [
    "BatchNorm", "Conv1D", "Conv2D", "ConvTranspose2D", "Dense", "Embedding",
    "LayerNorm", "Module", "RMSNorm", "Sequential", "fan_in_init",
    "glorot_uniform", "leaky_relu", "normal_init", "param_bytes",
    "param_count", "truncated_normal_init",
]
