"""Minimal functional module system.

No flax/haiku in this container, so we build a tiny, explicit library:
a Module is a pair of pure functions

    params = module.init(rng)            # pytree of jnp arrays
    out    = module.apply(params, *xs)   # pure function of (params, inputs)

Modules compose structurally: ``Sequential``, dict-of-children, etc.  All
state (batch-norm running stats are deliberately avoided -- we use
batch statistics in training mode like the reference ACGAN code and a
``train`` flag) lives in ``params`` so that FedGAN's weighted parameter
averaging (the paper's eq. (2)) is a plain pytree map.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp arrays
Array = jax.Array


def _split(rng, n):
    return jax.random.split(rng, n)


@dataclasses.dataclass(frozen=True)
class Module:
    """Base class: subclasses provide init(rng) -> Params and apply(params, x)."""

    def init(self, rng: Array) -> Params:  # pragma: no cover - abstract
        raise NotImplementedError

    def apply(self, params: Params, *args, **kwargs):  # pragma: no cover
        raise NotImplementedError

    def __call__(self, params: Params, *args, **kwargs):
        return self.apply(params, *args, **kwargs)


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(p.size * p.dtype.itemsize) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def glorot_uniform(rng, shape, dtype=jnp.float32, in_axis=-2, out_axis=-1):
    fan_in = shape[in_axis]
    fan_out = shape[out_axis]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(rng, shape, dtype, -limit, limit)


def normal_init(stddev: float = 0.02):
    def init(rng, shape, dtype=jnp.float32):
        return stddev * jax.random.normal(rng, shape, dtype)

    return init


def truncated_normal_init(stddev: float = 0.02):
    def init(rng, shape, dtype=jnp.float32):
        return stddev * jax.random.truncated_normal(rng, -2.0, 2.0, shape, dtype)

    return init


def fan_in_init(rng, shape, dtype=jnp.float32):
    """LeCun-normal: stddev = 1/sqrt(fan_in) with fan_in = prod(shape[:-1])."""
    fan_in = 1
    for s in shape[:-1]:
        fan_in *= s
    return jax.random.normal(rng, shape, dtype) / math.sqrt(max(fan_in, 1))


# ---------------------------------------------------------------------------
# core layers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Dense(Module):
    in_dim: int
    out_dim: int
    use_bias: bool = True
    dtype: Any = jnp.float32
    init_fn: Callable = glorot_uniform

    def init(self, rng):
        kw, kb = _split(rng, 2)
        p = {"w": self.init_fn(kw, (self.in_dim, self.out_dim), self.dtype)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_dim,), self.dtype)
        return p

    def apply(self, params, x):
        y = x @ params["w"]
        if self.use_bias:
            y = y + params["b"]
        return y


@dataclasses.dataclass(frozen=True)
class Embedding(Module):
    vocab: int
    dim: int
    dtype: Any = jnp.float32
    stddev: float = 0.02

    def init(self, rng):
        return {"table": self.stddev * jax.random.normal(rng, (self.vocab, self.dim), self.dtype)}

    def apply(self, params, ids):
        return jnp.take(params["table"], ids, axis=0)

    def attend(self, params, x):
        """Tied-output logits: x @ table^T."""
        return x @ params["table"].T


@dataclasses.dataclass(frozen=True)
class LayerNorm(Module):
    dim: int
    eps: float = 1e-5
    use_bias: bool = True
    dtype: Any = jnp.float32

    def init(self, rng):
        p = {"scale": jnp.ones((self.dim,), self.dtype)}
        if self.use_bias:
            p["bias"] = jnp.zeros((self.dim,), self.dtype)
        return p

    def apply(self, params, x):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps) * params["scale"].astype(jnp.float32)
        if self.use_bias:
            y = y + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class RMSNorm(Module):
    dim: int
    eps: float = 1e-6
    dtype: Any = jnp.float32

    def init(self, rng):
        return {"scale": jnp.ones((self.dim,), self.dtype)}

    def apply(self, params, x):
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps).astype(x.dtype)
        return y * params["scale"].astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class BatchNorm(Module):
    """Batch-statistics norm (training-mode BN, as in the paper's ACGAN nets).

    We intentionally use per-batch statistics in both train and eval: FedGAN
    averages *parameters*; carrying per-agent running stats would leak a
    second state channel the paper does not model.
    """

    dim: int
    eps: float = 1e-5
    axis_name: str | None = None

    def init(self, rng):
        return {"scale": jnp.ones((self.dim,)), "bias": jnp.zeros((self.dim,))}

    def apply(self, params, x):
        axes = tuple(range(x.ndim - 1))
        mu = jnp.mean(x, axis=axes, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=axes, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + self.eps)
        return y * params["scale"] + params["bias"]


@dataclasses.dataclass(frozen=True)
class Conv2D(Module):
    in_ch: int
    out_ch: int
    kernel: tuple[int, int] = (4, 4)
    stride: tuple[int, int] = (2, 2)
    padding: str = "SAME"
    use_bias: bool = True

    def init(self, rng):
        kw, _ = _split(rng, 2)
        shape = (*self.kernel, self.in_ch, self.out_ch)
        p = {"w": fan_in_init(kw, shape)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_ch,))
        return p

    def apply(self, params, x):
        # x: (B, H, W, C)
        y = jax.lax.conv_general_dilated(
            x, params["w"], window_strides=self.stride, padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        if self.use_bias:
            y = y + params["b"]
        return y


@dataclasses.dataclass(frozen=True)
class ConvTranspose2D(Module):
    in_ch: int
    out_ch: int
    kernel: tuple[int, int] = (4, 4)
    stride: tuple[int, int] = (2, 2)
    padding: str = "SAME"
    use_bias: bool = True

    def init(self, rng):
        kw, _ = _split(rng, 2)
        shape = (*self.kernel, self.out_ch, self.in_ch)  # HWOI for transpose
        p = {"w": fan_in_init(kw, shape)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_ch,))
        return p

    def apply(self, params, x):
        y = jax.lax.conv_transpose(
            x, params["w"], strides=self.stride, padding=self.padding,
            dimension_numbers=("NHWC", "HWOI", "NHWC"))
        if self.use_bias:
            y = y + params["b"]
        return y


@dataclasses.dataclass(frozen=True)
class Conv1D(Module):
    in_ch: int
    out_ch: int
    kernel: int = 5
    stride: int = 1
    padding: str = "SAME"
    use_bias: bool = True

    def init(self, rng):
        kw, _ = _split(rng, 2)
        shape = (self.kernel, self.in_ch, self.out_ch)
        p = {"w": fan_in_init(kw, shape)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_ch,))
        return p

    def apply(self, params, x):
        # x: (B, T, C)
        y = jax.lax.conv_general_dilated(
            x, params["w"], window_strides=(self.stride,), padding=self.padding,
            dimension_numbers=("NWC", "WIO", "NWC"))
        if self.use_bias:
            y = y + params["b"]
        return y


@dataclasses.dataclass(frozen=True)
class Sequential(Module):
    layers: Sequence[Any]  # mix of Modules and bare callables (activations)

    def init(self, rng):
        params = []
        mods = [l for l in self.layers if isinstance(l, Module)]
        keys = _split(rng, max(len(mods), 1))
        ki = 0
        for layer in self.layers:
            if isinstance(layer, Module):
                params.append(layer.init(keys[ki]))
                ki += 1
            else:
                params.append({})
        return params

    def apply(self, params, x):
        for layer, p in zip(self.layers, params):
            x = layer.apply(p, x) if isinstance(layer, Module) else layer(x)
        return x


def leaky_relu(slope: float = 0.2):
    return lambda x: jax.nn.leaky_relu(x, slope)
