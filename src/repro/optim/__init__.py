from repro.optim.optimizer import SGD, Adam, AdamW, Optimizer, clip_by_global_norm, global_norm
from repro.optim.schedules import (
    TimeScales,
    constant,
    constant_ttur,
    equal_timescale,
    inverse_time,
    power_decay,
    ttur_pair,
    warmup_cosine,
)

__all__ = [
    "SGD", "Adam", "AdamW", "Optimizer", "clip_by_global_norm", "global_norm",
    "TimeScales", "constant", "constant_ttur", "equal_timescale",
    "inverse_time", "power_decay", "ttur_pair", "warmup_cosine",
]
