"""Optimizers as pure pytree transforms (no optax in this container).

An optimizer is an ``Optimizer`` dataclass with

    state = opt.init(params)
    new_params, new_state = opt.update(params, grads, state, lr)

``lr`` is passed per-call so the FedGAN driver can feed the paper's
time-decaying a(n), b(n) schedules (assumption (A2)) and the two-time-scale
pairs of Appendix A (assumption (A6): b(n) = o(a(n))).

Sign convention: ``update`` performs gradient *descent* on the supplied
grads.  GAN ascent (the paper writes w_{n} = w_{n-1} + a g~) is handled by
the loss layer handing us the gradient of the loss to minimise.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    def init(self, params):  # pragma: no cover - abstract
        raise NotImplementedError

    def update(self, params, grads, state, lr):  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class SGD(Optimizer):
    """Plain SGD, optionally with heavy-ball momentum."""

    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {
            "count": jnp.zeros((), jnp.int32),
            "velocity": _tree_map(jnp.zeros_like, params),
        }

    def update(self, params, grads, state, lr):
        if self.momentum == 0.0:
            new_params = _tree_map(lambda p, g: p - lr * g, params, grads)
            return new_params, {"count": state["count"] + 1}
        vel = _tree_map(lambda v, g: self.momentum * v + g, state["velocity"], grads)
        new_params = _tree_map(lambda p, v: p - lr * v, params, vel)
        return new_params, {"count": state["count"] + 1, "velocity": vel}


@dataclasses.dataclass(frozen=True)
class Adam(Optimizer):
    """Adam; the paper's image/TS experiments use Adam(beta1=0.5, beta2=0.999)."""

    b1: float = 0.5
    b2: float = 0.999
    eps: float = 1e-8

    def init(self, params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": _tree_map(jnp.zeros_like, params),
            "nu": _tree_map(jnp.zeros_like, params),
        }

    def update(self, params, grads, state, lr):
        count = state["count"] + 1
        mu = _tree_map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state["mu"], grads)
        nu = _tree_map(lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g),
                       state["nu"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** c
        bc2 = 1.0 - self.b2 ** c

        def step(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            return p - lr * mhat / (jnp.sqrt(vhat) + self.eps)

        new_params = _tree_map(step, params, mu, nu)
        return new_params, {"count": count, "mu": mu, "nu": nu}


@dataclasses.dataclass(frozen=True)
class AdamW(Optimizer):
    """Adam with decoupled weight decay — used by the LM-backbone examples."""

    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        return Adam(self.b1, self.b2, self.eps).init(params)

    def update(self, params, grads, state, lr):
        inner = Adam(self.b1, self.b2, self.eps)
        new_params, new_state = inner.update(params, grads, state, lr)
        if self.weight_decay:
            new_params = _tree_map(
                lambda np_, p: np_ - lr * self.weight_decay * p, new_params, params)
        return new_params, new_state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    """Scale ``grads`` so their global norm is at most ``max_norm``.

    Exact at norm=0: an all-zero tree passes through with scale 1.0 (the
    old ``max_norm / (norm + eps)`` guard produced ~1e12·max_norm there,
    which is still clamped to 1.0 by the min — unless max_norm < 1e-12 —
    but more importantly it divides 0/eps inside the unclamped branch,
    wrecking gradients *through* the clip).  The ``where`` keeps both the
    value and its gradient finite on the zero branch."""
    norm = global_norm(grads)
    safe = jnp.where(norm > 0, norm, 1.0)
    scale = jnp.where(norm > 0, jnp.minimum(1.0, max_norm / safe), 1.0)
    return _tree_map(lambda g: g * scale, grads), norm
