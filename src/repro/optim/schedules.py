"""Learning-rate schedules.

The paper's convergence theory needs (A2):

    sum_n a(n) = inf,   sum_n a(n)^2 < inf     (likewise for b)

satisfied by power decays a(n) = a0 / (1 + n/tau)^p with p in (1/2, 1].
Two-time-scale updates (Appendix A) additionally need (A6): b(n) = o(a(n)),
e.g. a(n) ~ n^{-0.6} (fast discriminator) with b(n) ~ n^{-0.9} (slow
generator).  ``ttur_pair`` builds such a pair.

Constant schedules are offered for the experiment sections, which (like the
paper's own experiments) run constant-LR Adam even though the theory is
stated for decaying SGD.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


def constant(lr: float) -> Schedule:
    return lambda n: jnp.asarray(lr, jnp.float32)


def power_decay(a0: float, tau: float = 100.0, p: float = 0.75) -> Schedule:
    """a(n) = a0 / (1 + n/tau)^p.  (A2) holds iff 1/2 < p <= 1."""
    if not (0.5 < p <= 1.0):
        raise ValueError(f"power_decay exponent p={p} violates (A2); need 1/2 < p <= 1")

    def sched(n):
        return jnp.asarray(a0, jnp.float32) / (1.0 + n / tau) ** p

    return sched


def inverse_time(a0: float, tau: float = 100.0) -> Schedule:
    """a(n) = a0 / (1 + n/tau)  — the p=1 corner of (A2)."""
    return power_decay(a0, tau, 1.0)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0) -> Schedule:
    """Standard LM-pretraining schedule for the backbone examples."""

    def sched(n):
        n = jnp.asarray(n, jnp.float32)
        warm = peak * jnp.minimum(n / max(warmup, 1), 1.0)
        t = jnp.clip((n - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(n < warmup, warm, cos)

    return sched


@dataclasses.dataclass(frozen=True)
class TimeScales:
    """The (a(n), b(n)) pair for discriminator / generator updates."""

    a: Schedule  # discriminator lr a(n)
    b: Schedule  # generator lr b(n)
    equal: bool  # True -> single time-scale analysis (Theorem 1) applies


def equal_timescale(sched: Schedule) -> TimeScales:
    return TimeScales(a=sched, b=sched, equal=True)


def ttur_pair(a0: float, b0: float, tau: float = 100.0,
              pa: float = 0.6, pb: float = 0.9) -> TimeScales:
    """Two-time-scale pair with b(n) = o(a(n))  (A6): pb > pa.

    Both components satisfy (A2) individually.
    """
    if not pb > pa:
        raise ValueError("(A6) b(n)=o(a(n)) requires pb > pa")
    return TimeScales(a=power_decay(a0, tau, pa), b=power_decay(b0, tau, pb), equal=False)


def constant_ttur(a0: float, b0: float) -> TimeScales:
    """Heusel-et-al-style constant TTUR (paper Table 2 uses lr_D = 2 lr_G)."""
    return TimeScales(a=constant(a0), b=constant(b0), equal=a0 == b0)
