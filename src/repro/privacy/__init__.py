"""repro.privacy — the privacy/robustness axis of the FedGAN runtime.

Three mechanisms, three threat models (docs/privacy.md):

  * :class:`DPSGD` — per-agent DP-SGD (per-example clip + Gaussian noise
    inside the jitted step) with the closed-form RDP accountant in
    :mod:`repro.privacy.accountant`; plugs into ``FedGANConfig(dp=...)``.
  * :class:`SecureAgg` — pairwise-mask secure summing at the intermediary
    (``FedAvgSync(secure_agg=...)``); mechanism in
    ``repro.dist.collectives.masked_sync``.
  * Byzantine-robust aggregation — ``TrimmedMeanSync`` / ``CoordinateMedianSync``
    in :mod:`repro.core.strategies`, exercised by the attack simulators in
    :mod:`repro.privacy.attacks`.
"""
from repro.privacy import accountant
from repro.privacy.attacks import ATTACKS, WithByzantine, corrupt
from repro.privacy.dpsgd import DPSGD, dp_grads, noise_like, per_example_grads
from repro.privacy.secure import SecureAgg

__all__ = [
    "ATTACKS",
    "DPSGD",
    "SecureAgg",
    "WithByzantine",
    "accountant",
    "corrupt",
    "dp_grads",
    "noise_like",
    "per_example_grads",
]
