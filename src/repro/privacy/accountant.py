"""RDP (moments) accountant for the sampled Gaussian mechanism.

One DP-SGD step on one agent is the sampled Gaussian mechanism: each
example participates with probability q (the sampling rate), the clipped
per-example gradients are summed, and Gaussian noise with standard
deviation sigma·C is added (C the clip norm).  Its Renyi differential
privacy at order alpha composes additively over steps, and converts to an
(epsilon, delta) guarantee via

    epsilon(delta) = min_alpha  T·rdp(alpha) + log(1/delta) / (alpha - 1)

(the standard RDP->DP conversion of Mironov 2017; we deliberately use the
basic conversion so the closed-form tests have an analytic target).

``rdp_order`` implements the two regimes exactly:

  * q = 1 (every example every step — the deterministic Gaussian
    mechanism): rdp(alpha) = alpha / (2 sigma^2) for any real alpha > 1.
    The continuous minimiser alpha* = 1 + sigma·sqrt(2·log(1/delta)/T)
    gives the analytic bound

        epsilon = T / (2 sigma^2) + sqrt(2·T·log(1/delta)) / sigma

    which ``epsilon`` matches to float64 precision (the closed-form test
    fixture of tests/test_privacy.py).
  * q < 1, integer alpha (Mironov-Talwar-Zhang 2019, Poisson subsampling):

        rdp(alpha) = log( sum_{k=0..alpha} C(alpha,k) (1-q)^(alpha-k) q^k
                          · exp(k(k-1) / (2 sigma^2)) ) / (alpha - 1)

    evaluated in log space (float64) so large orders do not overflow.

Everything here is host-side closed-form math on static config — the
device-side cost of DP-SGD is in ``repro.privacy.dpsgd``; the accountant
is what ``RoundDriver`` surfaces as ``dp_epsilon`` next to the round
metrics and in the sweep JSONL histories.
"""
from __future__ import annotations

import math

DEFAULT_ORDERS = tuple(range(2, 129))


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def _logsumexp(vals) -> float:
    m = max(vals)
    if m == -math.inf:
        return -math.inf
    return m + math.log(sum(math.exp(v - m) for v in vals))


def rdp_order(alpha: float, *, noise_multiplier: float,
              sample_rate: float = 1.0) -> float:
    """Per-step RDP of the sampled Gaussian mechanism at order ``alpha``.

    ``alpha`` may be any real > 1 when ``sample_rate`` is 1; subsampled
    rates require integer orders (the binomial expansion above).
    """
    sigma, q = float(noise_multiplier), float(sample_rate)
    if sigma <= 0:
        return math.inf
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sample_rate must be in (0, 1], got {q}")
    if alpha <= 1:
        raise ValueError(f"RDP order must exceed 1, got {alpha}")
    if q == 1.0:
        return alpha / (2.0 * sigma * sigma)
    if int(alpha) != alpha:
        raise ValueError(
            f"subsampled RDP (q={q}) needs integer orders, got {alpha}")
    a = int(alpha)
    terms = [
        _log_comb(a, k) + (a - k) * math.log1p(-q)
        + (k * math.log(q) if k else 0.0)
        + k * (k - 1) / (2.0 * sigma * sigma)
        for k in range(a + 1)
    ]
    return _logsumexp(terms) / (a - 1)


def epsilon(*, noise_multiplier: float, steps: int, sample_rate: float = 1.0,
            delta: float = 1e-5, orders=None) -> float:
    """(epsilon, delta)-DP spent after ``steps`` compositions.

    Minimises the RDP->DP conversion over ``orders`` (default: integer
    2..128, plus — when q = 1 — the continuous optimum, so the q = 1
    answer IS the analytic Gaussian-mechanism bound, not a grid
    approximation)."""
    if steps <= 0 or noise_multiplier <= 0:
        return math.inf if noise_multiplier <= 0 and steps > 0 else 0.0
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    sigma, q, T = float(noise_multiplier), float(sample_rate), int(steps)
    L = math.log(1.0 / delta)
    cands = list(orders if orders is not None else DEFAULT_ORDERS)
    if q == 1.0:
        # continuous minimiser of T·a/(2s^2) + L/(a-1)
        cands.append(1.0 + sigma * math.sqrt(2.0 * L / T))
    best = math.inf
    for a in cands:
        if a <= 1:
            continue
        eps = T * rdp_order(a, noise_multiplier=sigma, sample_rate=q) \
            + L / (a - 1)
        best = min(best, eps)
    return best
