"""Byzantine attack simulation — the adversaries the robust aggregators
are tested against.

:class:`WithByzantine` wraps any ``FedAvgSync``-family strategy: at sync
time the first ``num_byzantine`` agents of the flattened (P, A) grid ship
corrupted parameters instead of their honest ones (the corruption models
what a malicious agent PUTS ON THE WIRE — its local training is
irrelevant, it can send anything).  The wrapped strategy then aggregates
the poisoned fleet exactly as it would the honest one, so

    WithByzantine(FedAvgSync(), ...)      shows the damage (one scaled
                                          agent moves the plain average
                                          arbitrarily far),
    WithByzantine(TrimmedMeanSync(), ...) shows the defence (f <= trim
                                          attackers are order statistics
                                          in the trimmed tail).

Attacks:

  ``sign_flip``  ship -x (the classic model-replacement direction)
  ``scale``      ship scale·x (default x100 — a magnitude outlier)
  ``nan``        ship NaN everywhere (a crash-the-fleet griefer)

This is test/bench scaffolding, not a training feature: it lives in
``repro.privacy`` so the adversarial suite and ``bench_privacy`` share
one implementation, but it is deliberately not registered in the
``--strategy`` CLI registry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

tmap = jax.tree_util.tree_map

ATTACKS = ("sign_flip", "scale", "nan")


def corrupt(tree, *, attack: str, num_byzantine: int, scale: float = 100.0):
    """Corrupt the first ``num_byzantine`` agents' slices of every inexact
    agent-stacked (P, A, ...) leaf."""
    if attack not in ATTACKS:
        raise ValueError(f"unknown attack {attack!r}; known: {list(ATTACKS)}")

    def poison(x):
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        P, A = x.shape[:2]
        flat = x.reshape((P * A,) + x.shape[2:])
        if attack == "sign_flip":
            bad = -flat
        elif attack == "scale":
            bad = scale * flat
        else:
            bad = jnp.full_like(flat, jnp.nan)
        mask = (jnp.arange(P * A) < num_byzantine).reshape(
            (P * A,) + (1,) * (flat.ndim - 1))
        return jnp.where(mask, bad, flat).reshape(x.shape)

    return tmap(poison, tree)


@dataclasses.dataclass(frozen=True)
class WithByzantine:
    """Strategy wrapper planting Byzantine agents at sync time (see module
    docstring).  Delegates every hook to ``inner``; only the parameters
    the attackers ship are corrupted."""

    inner: Any
    attack: str = "sign_flip"
    num_byzantine: int = 1
    scale: float = 100.0

    @property
    def name(self):
        return f"{self.inner.name}+byz_{self.attack}x{self.num_byzantine}"

    @property
    def intra_interval(self):
        return self.inner.intra_interval

    def validate(self, cfg):
        if self.attack not in ATTACKS:
            raise ValueError(f"unknown attack {self.attack!r}; "
                             f"known: {list(ATTACKS)}")
        if not 0 <= self.num_byzantine <= cfg.num_agents:
            raise ValueError(
                f"num_byzantine must be in [0, {cfg.num_agents}], "
                f"got {self.num_byzantine}")
        self.inner.validate(cfg)

    def init_round_state(self, fed, state):
        return self.inner.init_round_state(fed, state)

    def grad_hook(self, fed, grad_disc, grad_gen, state):
        return self.inner.grad_hook(fed, grad_disc, grad_gen, state)

    def segment_sync(self, fed, state):
        return self.inner.segment_sync(fed, state)

    def round_sync(self, fed, state):
        poisoned = dict(state)
        poisoned["params"] = corrupt(state["params"], attack=self.attack,
                                     num_byzantine=self.num_byzantine,
                                     scale=self.scale)
        return self.inner.round_sync(fed, poisoned)

    def bytes_per_round(self, cfg, params, opt=None) -> int:
        return self.inner.bytes_per_round(cfg, params, opt)
