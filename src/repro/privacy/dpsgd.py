"""Per-agent DP-SGD: per-example clipping + Gaussian noise inside the
jitted ``FedGAN._step``.

Each agent's minibatch gradient is replaced by the Gaussian mechanism:

    g = mean_i( clip_C(grad_i) ) + N(0, (sigma·C / n)^2)

where grad_i is the gradient of example i ALONE (a vmap over the batch
axis, reusing ``repro.optim.clip_by_global_norm`` per sample), C is the
clip norm, sigma the noise multiplier and n the per-agent batch size.
Each step releases BOTH players' gradients computed on the same batch —
the discriminator is the privacy-critical player (it touches real data),
but the generator update is not a free post-processing in general, so
the pair is treated as ONE release: the concatenated (G, D) per-example
gradient is clipped JOINTLY to C (one ``clip_by_global_norm`` over both
trees), making the per-example sensitivity of the released pair exactly
C, and independent N(0, (sigma·C/n)^2) noise on every coordinate of the
joint vector is then a single Gaussian mechanism at multiplier sigma.
That is what lets :meth:`DPSGD.epsilon` compose ``steps`` single
mechanisms — per-player clipping at C would have joint sensitivity
sqrt(2)·C and silently understate the spend.

Noise is keyed off the typed per-agent PRNG keys the runtime threads
through ``_step`` (PR 4): every (agent, step, leaf) triple draws from its
own fold of the round key — bit-reproducible from the round key, never
shared across agents.

The privacy spend is tracked by the closed-form RDP accountant
(``repro.privacy.accountant``) — :meth:`DPSGD.epsilon` is what
``RoundDriver`` surfaces next to the round metrics.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.optim import clip_by_global_norm, global_norm
from repro.privacy import accountant

tmap = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class DPSGD:
    """Per-agent DP-SGD config — the privacy axis of ``FedGANConfig``.

    ``clip``: per-example global-norm bound C on the JOINT (G, D) gradient.
    ``noise_multiplier``: sigma; the noise std is sigma·C/n per coordinate
    of the MEAN gradient.  0 disables noise (clip-only — no epsilon).
    ``delta``: the delta at which :meth:`epsilon` reports the spend.
    ``sample_rate``: the accountant's subsampling rate q (the fraction of
    an agent's examples in each step's batch).  The mechanism itself sees
    whatever batch the data pipeline delivers, so the run path
    (``repro.run.driver.check_dp_sample_rate``) refuses any q below the
    pipeline's actual ``batch_size / min_i |R_i|`` — an optimistic q would
    report an epsilon the mechanism does not deliver.  The default q = 1
    is always conservative.
    """

    clip: float = 1.0
    noise_multiplier: float = 0.0
    delta: float = 1e-5
    sample_rate: float = 1.0

    def validate(self):
        if self.clip <= 0:
            raise ValueError(f"DPSGD clip must be > 0, got {self.clip}")
        if self.noise_multiplier < 0:
            raise ValueError(f"DPSGD noise_multiplier must be >= 0, "
                             f"got {self.noise_multiplier}")
        if not 0.0 < self.sample_rate <= 1.0:
            raise ValueError(f"DPSGD sample_rate must be in (0, 1], "
                             f"got {self.sample_rate}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"DPSGD delta must be in (0, 1), "
                             f"got {self.delta}")

    def epsilon(self, steps: int) -> float:
        """Privacy spent after ``steps`` local steps (inf when sigma=0)."""
        return accountant.epsilon(noise_multiplier=self.noise_multiplier,
                                  steps=steps, sample_rate=self.sample_rate,
                                  delta=self.delta)


def per_example_grads(grad_fn, params, batch, rng, clip: float):
    """Per-example clipped gradients for ONE agent.

    ``grad_fn(params, batch, rng) -> (grad_disc, grad_gen, metrics)`` is
    the agent's ordinary minibatch gradient function; it is re-run per
    example (a vmap over the leading batch axis, each example wrapped back
    into a batch of one so batch-mean losses are unchanged).  Returns
    ``(gd, gg, norms_d, norms_g, metrics)`` with a leading example axis on
    everything.  The clip is applied to the CONCATENATED (gd, gg) tree —
    one ``clip_by_global_norm`` over both players — so each example's
    joint released gradient has global norm <= clip EXACTLY (the single-
    mechanism sensitivity the accountant assumes); ``norms_d``/``norms_g``
    are the pre-clip per-player norms (the signal for tuning C).
    """
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    ex_keys = jax.random.split(rng, n)

    def one(ex, k):
        gd, gg, m = grad_fn(params, tmap(lambda v: v[None], ex), k)
        nd, ng = global_norm(gd), global_norm(gg)
        (gd, gg), _ = clip_by_global_norm((gd, gg), clip)
        return gd, gg, nd, ng, m

    return jax.vmap(one)(batch, ex_keys)


def noise_like(tree, rng, std):
    """Gaussian noise shaped like ``tree``; one key fold per leaf so the
    draw is bit-reproducible from ``rng`` and leaf-order stable."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    noised = [std * jax.random.normal(jax.random.fold_in(rng, i),
                                      l.shape, l.dtype)
              for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, noised)


def dp_grads(grad_fn, params, batch, rng, dp: DPSGD):
    """The full per-agent DP-SGD gradient: per-example clip, mean, noise.

    ``rng`` is the agent's typed step key; it is split into the loss keys
    (one per example) and the noise key, so the noise differs across
    agents exactly as the step keys do.  Returns ``(gd, gg, metrics)``
    matching the un-private ``grad_fn`` contract, with the mean pre-clip
    per-example norms added to the metrics (``dp_grad_norm_d/g`` — the
    device-side signal for tuning C)."""
    r_loss, r_noise = jax.random.split(rng)
    gd, gg, nd, ng, m = per_example_grads(grad_fn, params, batch, r_loss,
                                          dp.clip)
    n = jax.tree_util.tree_leaves(batch)[0].shape[0]
    gd = tmap(lambda g: jnp.mean(g, axis=0), gd)
    gg = tmap(lambda g: jnp.mean(g, axis=0), gg)
    if dp.noise_multiplier:
        std = dp.noise_multiplier * dp.clip / n
        kd, kg = jax.random.split(r_noise)
        gd = tmap(jnp.add, gd, noise_like(gd, kd, std))
        gg = tmap(jnp.add, gg, noise_like(gg, kg, std))
    metrics = tmap(lambda v: jnp.mean(v, axis=0), m)
    metrics = {**metrics, "dp_grad_norm_d": jnp.mean(nd),
               "dp_grad_norm_g": jnp.mean(ng)}
    return gd, gg, metrics
