"""Secure-aggregation-style masked summing — the strategy-facing config.

The mechanism itself lives in ``repro.dist.collectives.masked_sync``:
every agent one-time-pads the uint32 bit pattern of its uplink payload
with net pairwise PRG masks, and the masks telescope to exactly zero
(modular integer arithmetic) at the reduce — so the intermediary learns
the weighted average and nothing else, while the recovered values (and
therefore the training trajectory) are bit-identical to the plain
``average_agents`` sync.

:class:`SecureAgg` is the knob ``FedAvgSync(secure_agg=...)`` takes: a
static fleet seed from which the per-round mask key is derived via the
(checkpointed) step counter — a restored run regenerates the same masks,
and no round ever reuses a pad.

What it refuses to stack with (loud errors, mirroring the PR 5
sync_dtype+codec refusal pattern — see ``FedAvgSync.validate``):

  * ``codec=`` / ``sync_dtype=`` — a lossy re-encoding happens per agent
    and must be decoded per agent at the server, which reveals exactly the
    individual updates the masking exists to hide;
  * ``SubsampledFedAvg`` — pairwise masks only cancel when every pair's
    both halves hit the wire; per-round dropouts need the full SecAgg
    seed-recovery protocol this simulation does not model;
  * Byzantine-robust reduces (trimmed mean / median) — order statistics
    need the individual per-agent values the secure sum hides.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class SecureAgg:
    """Pairwise-mask secure summing config (see module docstring)."""

    seed: int = 0

    def validate(self):
        pass

    def round_key(self, step):
        """The per-round mask PRG key; ``step`` is the (traced) step
        counter at sync time — checkpointed state, so save/restore
        reproduces the masks exactly."""
        from repro.dist import collectives
        return collectives.mask_pair_key(jax.random.key(self.seed), step)
