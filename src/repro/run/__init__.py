# Pillar four: the streaming training runtime.  A donated, chunked round
# driver over the FederatedData pipelines, an eval harness on the
# intermediary's averaged params, the paper-figure K-sweep runner, and
# the virtual-client scheduler (A_total clients on A_active device slots).
from repro.run.driver import RoundDriver, RunResult, train
from repro.run.evals import EvalSuite, eval_hook, evaluate, final_fd
from repro.run.virtual import (ClientStore, StragglerPolicy,
                               VirtualClientDriver, load_fleet_checkpoint,
                               staleness_scale, staleness_weights)

__all__ = [
    "AsyncAggDriver", "ClientStore", "EvalSuite", "EventJournal",
    "LatencyModel", "RoundDriver", "RunResult", "SimClock",
    "StragglerPolicy", "VirtualClientDriver", "eval_hook", "evaluate",
    "final_fd", "load_fleet_checkpoint", "modeled_sync_makespan",
    "params_digest", "run_sweep", "staleness_scale", "staleness_weights",
    "summary_table", "train",
]


def __getattr__(name):
    # lazy: keeps `python -m repro.run.experiments` / `-m repro.run.simclock`
    # free of the runpy double-import warning
    if name in ("run_sweep", "summary_table"):
        from repro.run import experiments
        return getattr(experiments, name)
    if name in ("AsyncAggDriver", "modeled_sync_makespan"):
        from repro.run import async_agg
        return getattr(async_agg, name)
    if name in ("EventJournal", "LatencyModel", "SimClock", "params_digest"):
        from repro.run import simclock
        return getattr(simclock, name)
    raise AttributeError(name)
