# Pillar four: the streaming training runtime.  A donated, chunked round
# driver over the FederatedData pipelines, an eval harness on the
# intermediary's averaged params, and the paper-figure K-sweep runner.
from repro.run.driver import RoundDriver, RunResult, train
from repro.run.evals import EvalSuite, eval_hook, evaluate, final_fd

__all__ = [
    "EvalSuite", "RoundDriver", "RunResult", "eval_hook", "evaluate",
    "final_fd", "run_sweep", "summary_table", "train",
]


def __getattr__(name):
    # lazy: keeps `python -m repro.run.experiments` free of the runpy
    # double-import warning
    if name in ("run_sweep", "summary_table"):
        from repro.run import experiments
        return getattr(experiments, name)
    raise AttributeError(name)
