# Pillar four: the streaming training runtime.  A donated, chunked round
# driver over the FederatedData pipelines, an eval harness on the
# intermediary's averaged params, the paper-figure K-sweep runner, and
# the virtual-client scheduler (A_total clients on A_active device slots).
from repro.run.driver import RoundDriver, RunResult, train
from repro.run.evals import EvalSuite, eval_hook, evaluate, final_fd
from repro.run.virtual import (ClientStore, StragglerPolicy,
                               VirtualClientDriver, load_fleet_checkpoint)

__all__ = [
    "ClientStore", "EvalSuite", "RoundDriver", "RunResult",
    "StragglerPolicy", "VirtualClientDriver", "eval_hook", "evaluate",
    "final_fd", "load_fleet_checkpoint", "run_sweep", "summary_table",
    "train",
]


def __getattr__(name):
    # lazy: keeps `python -m repro.run.experiments` free of the runpy
    # double-import warning
    if name in ("run_sweep", "summary_table"):
        from repro.run import experiments
        return getattr(experiments, name)
    raise AttributeError(name)
