"""FedBuff-style async delta aggregation over the virtual-client runtime.

PR 8's ``VirtualClientDriver`` still aggregates per round: the server
blocks until the whole cohort reports.  This module converts that contract
into an event-driven one (buffered async aggregation, arXiv 2106.06639):

  * the server keeps ``cohort`` clients in flight; each dispatch trains a
    *single* client from the current server parameters (the same jitted
    ``FedGAN.round`` body, LocalOnly twin, compiled once for a ``(1, 1)``
    grid) and its delta ``theta_post - theta_dispatch`` arrives after a
    seeded simulated latency (:class:`repro.run.simclock.LatencyModel`);
  * arrivals land in a bounded buffer; the moment ``buffer_goal`` deltas
    are in, the flush merges them through one jitted staleness-weighted
    sum — weights ``decay ** staleness`` from the existing
    :class:`repro.run.virtual.StragglerPolicy`, normalized per flush
    (``repro.run.virtual.staleness_weights``), deltas older than
    ``max_staleness`` dropped at arrival and counted;
  * slow clients stop blocking anyone: a dispatch whose latency exceeds
    the timeout budget is retried with a fresh latency draw and an
    exponentially backed-off budget (``timeout * backoff**attempt``),
    then dropped loudly after ``max_retries``.

Everything runs on the :class:`repro.run.simclock.SimClock` virtual
clock, so a seeded run replays bit-exactly — event journal and final
parameters — which is what ``tests/test_async_agg.py`` and the CI
determinism gate hold.

**Degenerate case**: with no latency model, no timeout, and
``buffer_goal == cohort`` the schedule collapses to synchronous rounds,
and the driver runs the actual fused per-round path
(:class:`VirtualClientDriver`) — bit-identical to the dense
``RoundDriver``, params, optimizer state and EF residuals included.  The
buffered path supports plain FedAvg/PartialSharing only and refuses
anything else loudly (``repro.core.strategies.check_async_mergeable``;
docs/scaling.md has the refusal rows).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import strategies as sync_strategies
from repro.core.participation import ParticipationSchedule
from repro.data.federated import FleetRounds
from repro.run.driver import RunResult
from repro.run.simclock import (EventJournal, LatencyModel, SimClock,
                                params_digest)
from repro.run.virtual import (ClientStore, StragglerPolicy,
                               VirtualClientDriver, staleness_weights)

tmap = jax.tree_util.tree_map


def modeled_sync_makespan(schedule: ParticipationSchedule,
                          latency: LatencyModel, n_rounds: int,
                          n_total: int, m: int) -> float:
    """Virtual-time cost of the *blocking* per-round schedule under the
    same latency model: every round waits for its slowest cohort member.
    The async-vs-sync benchmark's deterministic baseline (dispatch keys
    are the round index — a model of the sync driver, not a replay of the
    async one)."""
    t = 0.0
    for r in range(n_rounds):
        cohort = schedule.cohort(r, n_total, m)
        t += max(latency.draw(schedule, r, int(c), n_total) for c in cohort)
    return t


@dataclasses.dataclass
class _InFlight:
    """One outstanding dispatch."""
    client: int
    seq: int            # global dispatch counter (keys batches + latency)
    attempt: int        # retry attempt, 0-based
    version: int        # server version the client trained from
    delta: Any = None   # host numpy delta over the synced subtrees
    metrics: Any = None
    row: Any = None     # the client's post-training store row


@dataclasses.dataclass
class AsyncAggDriver:
    """Event-driven buffered-async server over ``fleet.num_clients``
    virtual clients, keeping ``fleet.cohort_size`` dispatches in flight.

    ``n_rounds`` counts buffer *flushes* (server versions) — the async
    analog of the per-round drivers' round count.  ``straggler`` supplies
    the staleness algebra (``decay``, ``max_staleness``); its ``mode`` is
    ignored here (there is no blocking to defer from).  ``latency=None``
    with ``timeout=None`` and a full-cohort ``buffer_goal`` selects the
    sync-equivalent fused path; anything else runs the buffered loop.
    """

    fed: Any
    fleet: FleetRounds
    n_rounds: int
    schedule: ParticipationSchedule = ParticipationSchedule()
    straggler: StragglerPolicy = StragglerPolicy(mode="defer")
    buffer_goal: int | None = None     # None -> cohort size
    latency: LatencyModel | None = None
    timeout: float | None = None
    max_retries: int = 2
    backoff: float = 2.0
    weighting: str = "uniform"
    log_every: int = 1
    verbose: bool = False

    def __post_init__(self):
        P, A = self.fed.cfg.agent_grid
        if self.fleet.slot_grid != (P, A):
            raise ValueError(f"fleet slot_grid {self.fleet.slot_grid} != "
                             f"fed agent_grid {(P, A)}")
        self.n_total = self.fleet.num_clients
        self.cohort_size = self.fleet.cohort_size
        self.schedule.validate(self.n_total)
        self.straggler.validate()
        if self.latency is not None:
            self.latency.validate()
        goal = self.cohort_size if self.buffer_goal is None else self.buffer_goal
        if not 1 <= goal <= self.cohort_size:
            raise ValueError(
                f"buffer_goal {goal} must be in [1, cohort={self.cohort_size}]"
                " — a goal above the in-flight count can never fill")
        self._goal = int(goal)
        if self.timeout is not None and self.timeout <= 0:
            raise ValueError(f"timeout must be > 0, got {self.timeout}")
        if self.backoff < 1.0:
            raise ValueError(f"backoff must be >= 1, got {self.backoff}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.weighting not in ("uniform", "dataset"):
            raise ValueError(f"weighting must be 'uniform' or 'dataset', "
                             f"got {self.weighting!r}")
        self.sync_equivalent = (self.latency is None and self.timeout is None
                                and self._goal == self.cohort_size)
        strat = self.fed.cfg.resolve_strategy()
        if not self.sync_equivalent:
            # the buffered merge is a weighted delta sum; refuse loudly
            # anything whose sync that algebra cannot replay
            sync_strategies.check_async_mergeable(strat)
        self.journal = EventJournal()
        self.clock = SimClock()
        self.store: ClientStore | None = None
        # memoized executables + trace counter (compile-once assertions)
        self._local1_jit = None
        self._flush_jit = None
        self.n_traces = 0

    # ------------------------------------------------------------------
    # degenerate path: the fused synchronous rounds, plus a journal
    # ------------------------------------------------------------------

    def _run_sync_equivalent(self, rng) -> RunResult:
        inner = VirtualClientDriver(self.fed, self.fleet, self.n_rounds,
                                    schedule=self.schedule,
                                    straggler=StragglerPolicy(),
                                    weighting=self.weighting,
                                    log_every=self.log_every,
                                    verbose=self.verbose)
        result = inner.run(rng)
        self.store = inner.store
        self.n_traces = inner.n_traces
        # synthesize the event journal the buffered loop would have
        # produced at zero latency: round r dispatches, arrives and
        # flushes at t = r
        for r in range(self.n_rounds):
            cohort = [int(c) for c in self.schedule.cohort(
                r, self.n_total, self.cohort_size)]
            for c in cohort:
                self.journal.append("dispatch", float(r), client=c,
                                    seq=r * self.cohort_size + cohort.index(c),
                                    attempt=0, version=r, latency=0.0)
            for c in cohort:
                self.journal.append("arrival", float(r), client=c,
                                    version=r, staleness=0)
            self.journal.append("flush", float(r), version=r,
                                merged=len(cohort))
        digest = params_digest(result.state["params"])
        self.journal.append("end", float(self.n_rounds - 1),
                            params_digest=digest)
        timings = dict(result.timings)
        timings.update(mode="sync_equivalent", makespan=0.0,
                       flushes=self.n_rounds, buffer_goal=self._goal,
                       timeouts=0, retries=0, gave_up=0,
                       data_kind="async")
        return RunResult(result.fed, result.state, result.history,
                         result.evals, timings)

    # ------------------------------------------------------------------
    # buffered path: per-client training on a (1, 1) LocalOnly twin
    # ------------------------------------------------------------------

    def _local1(self):
        if self._local1_jit is None:
            cfg = dataclasses.replace(
                self.fed.cfg, agent_grid=(1, 1),
                strategy=sync_strategies.LocalOnly(), mode="",
                sync_dtype=None, average_opt_state=False)
            fed1 = dataclasses.replace(self.fed, cfg=cfg, weights=None)

            def fn(st, b, s):
                self.n_traces += 1
                return fed1.round(st, b, s)

            self._local1_jit = jax.jit(fn)
        return self._local1_jit

    def _flush_fn(self):
        """One jitted merge per flush: ``theta += sum_i w_i * delta_i``
        per synced subtree, over a fixed-size ``(goal, ...)`` delta stack
        — compiled once, like the round executables."""
        if self._flush_jit is None:
            def fn(params, deltas, w):
                return {k: tmap(lambda p, d: p + jnp.einsum(
                    "b,b...->...", w.astype(d.dtype), d).astype(p.dtype),
                    params[k], deltas[k]) for k in params}
            self._flush_jit = jax.jit(fn)
        return self._flush_jit

    def _train(self, cid: int, seq: int, version: int):
        """Train one client from the current server params: returns its
        post-training store row, host delta over the synced subtrees, and
        scalar metrics.  Batches are salted by global client id and keyed
        by the dispatch sequence — replay-deterministic."""
        row = self.store.row(cid)
        params = dict(row["params"])
        for k in self._subtrees:
            params[k] = self._server[k]
        lift = lambda t: tmap(lambda x: jnp.asarray(x)[None, None], t)
        state1 = {"params": lift(params), "opt_g": lift(row["opt_g"]),
                  "opt_d": lift(row["opt_d"]),
                  "step": self._step0 + version * self.fed.cfg.sync_interval}
        key = jax.random.fold_in(self._data_rng, seq)
        b, s = self._fleet1.round_batches(key, [cid])
        state1, metrics = self._local1()(state1, b, s)
        # one host fetch per dispatch — the simulator is host-side by design
        fetched = jax.device_get({  # analysis: allow(host-sync)
            "state": {k: state1[k] for k in ("params", "opt_g", "opt_d")},
            "metrics": tmap(jnp.mean, metrics)})
        drop = lambda t: tmap(lambda x: x[0, 0], t)
        row_post = {k: drop(fetched["state"][k])
                    for k in ("params", "opt_g", "opt_d")}
        delta = {k: tmap(np.subtract, row_post["params"][k], self._server[k])
                 for k in self._subtrees}
        metrics = {k: float(v) for k, v in fetched["metrics"].items()}
        return row_post, delta, metrics

    def _next_client(self):
        """The next dispatchable client id from the schedule's wave
        stream, skipping ids already in flight."""
        scanned = 0
        while True:
            if self._wave_queue:
                cid = self._wave_queue.pop(0)
                if cid in self._in_flight_ids:
                    self._stats["skipped_busy"] += 1
                    scanned += 1
                    if scanned > 4 * self.n_total + self.cohort_size:
                        raise RuntimeError(
                            "dispatch stream scan did not find a free "
                            "client — in-flight bookkeeping is corrupt")
                    continue
                return cid
            wave = self.schedule.cohort(self._wave, self.n_total,
                                        self.cohort_size)
            self._wave += 1
            self._wave_queue = [int(c) for c in wave]

    def _dispatch(self, cid: int, attempt: int) -> None:
        seq = self._seq
        self._seq += 1
        self._in_flight_ids.add(cid)
        lat = (self.latency or LatencyModel()).draw(
            self.schedule, seq, cid, self.n_total, attempt)
        t = self.clock.now
        self._stats["dispatches"] += 1
        self.journal.append("dispatch", t, client=cid, seq=seq,
                            attempt=attempt, version=self._version,
                            latency=lat)
        budget = (None if self.timeout is None
                  else self.timeout * self.backoff ** attempt)
        if budget is not None and lat > budget:
            # the reply will not make the budget: schedule the timeout
            # instead of the (discarded) arrival — the retry restarts the
            # client from whatever the server holds *then*
            self.clock.push(t + budget, "timeout",
                            _InFlight(cid, seq, attempt, self._version))
            return
        fl = _InFlight(cid, seq, attempt, self._version)
        fl.row, fl.delta, fl.metrics = self._train(cid, seq, self._version)
        self.clock.push(t + lat, "arrival", fl)

    def _flush(self) -> None:
        entries = sorted(self._buffer, key=lambda e: e.seq)
        self._buffer = []
        stal = [self._version - e.version for e in entries]
        base = None
        if self.weighting == "dataset":
            base = self._sizes[[e.client for e in entries]]
        w = staleness_weights(stal, self.straggler, base)
        deltas = {k: tmap(lambda *xs: np.stack(xs),
                          *[e.delta[k] for e in entries])
                  for k in self._subtrees}
        merged = self._flush_fn()(self._server_dev, deltas, jnp.asarray(w))
        self._server_dev = merged
        self._server = jax.device_get(merged)  # analysis: allow(host-sync)
        self._stats["merged_deltas"] += len(entries)
        self.journal.append(
            "flush", self.clock.now, version=self._version,
            merged=len(entries),
            clients=[e.client for e in entries],
            staleness=[int(s) for s in stal],
            weights=[float(x) for x in w],
            params_digest=params_digest(self._server))
        self._history.append(
            {k: float(np.mean([e.metrics[k] for e in entries]))
             for k in entries[0].metrics})
        self._version += 1
        if self.verbose and self.log_every and \
                (self._version % self.log_every == 0):
            m = self._history[-1]
            print(f"flush {self._version:4d}/{self.n_rounds} "
                  f"t={self.clock.now:8.2f} "
                  f"d_loss={m.get('d_loss', float('nan')):.4f} "
                  f"g_loss={m.get('g_loss', float('nan')):.4f}", flush=True)

    def _run_buffered(self, rng) -> RunResult:
        t0 = time.perf_counter()
        self._data_rng, init_rng = jax.random.split(rng)
        self.store = ClientStore.from_fed(self.fed, init_rng, self.n_total)
        strat = self.fed.cfg.resolve_strategy()
        self._subtrees = tuple(strat.subtrees)
        self._server = {k: tmap(np.copy, self.store.template["params"][k])
                        for k in self._subtrees}
        self._server_dev = jax.device_put(self._server)
        tiny = self.fed.init_state(init_rng, agent_grid=(1, 1))
        self._step0 = tiny["step"]
        self._fleet1 = dataclasses.replace(self.fleet, slot_grid=(1, 1))
        self._sizes = self.fleet.client_sizes().astype(np.float64)

        self._history, self._buffer = [], []
        self._version, self._seq, self._wave = 0, 0, 0
        self._wave_queue: list[int] = []
        self._in_flight_ids: set[int] = set()
        self._stats = {"dispatches": 0, "merged_deltas": 0,
                       "expired_deltas": 0, "timeouts": 0, "retries": 0,
                       "gave_up": 0, "skipped_busy": 0}
        # a full fleet cycle of consecutive give-ups with zero arrivals
        # means no reply can ever make the budget — refuse, don't spin
        consecutive_gave_up = 0

        for _ in range(self.cohort_size):
            self._dispatch(self._next_client(), attempt=0)

        while self._version < self.n_rounds:
            if not len(self.clock):
                raise RuntimeError("event queue drained before the flush "
                                   "target — dispatch bookkeeping is corrupt")
            t, kind, fl = self.clock.pop()
            if kind == "timeout":
                self._in_flight_ids.discard(fl.client)
                self._stats["timeouts"] += 1
                self.journal.append("timeout", t, client=fl.client,
                                    seq=fl.seq, attempt=fl.attempt)
                if fl.attempt + 1 <= self.max_retries:
                    self._stats["retries"] += 1
                    self.journal.append("retry", t, client=fl.client,
                                        attempt=fl.attempt + 1)
                    self._dispatch(fl.client, fl.attempt + 1)
                else:
                    self._stats["gave_up"] += 1
                    consecutive_gave_up += 1
                    self.journal.append("gave_up", t, client=fl.client,
                                        attempts=fl.attempt + 1)
                    if consecutive_gave_up >= self.n_total:
                        raise ValueError(
                            f"async run starved: {consecutive_gave_up} "
                            "consecutive dispatches exhausted their retry "
                            "budgets with no arrival — the timeout "
                            f"({self.timeout}) is below every achievable "
                            "latency; raise it, the backoff, or max_retries")
                    self._dispatch(self._next_client(), attempt=0)
                continue
            # arrival
            consecutive_gave_up = 0
            self._in_flight_ids.discard(fl.client)
            self.store.put(fl.client, fl.row)
            staleness = self._version - fl.version
            if staleness > self.straggler.max_staleness:
                self._stats["expired_deltas"] += 1
                self.journal.append("expired", t, client=fl.client,
                                    seq=fl.seq, staleness=staleness)
            else:
                self._buffer.append(fl)
                self.journal.append("arrival", t, client=fl.client,
                                    seq=fl.seq, version=fl.version,
                                    staleness=staleness)
                if len(self._buffer) >= self._goal:
                    self._flush()
            if self._version < self.n_rounds:
                self._dispatch(self._next_client(), attempt=0)

        makespan = self.clock.now
        self.journal.append("end", makespan, in_flight=len(self.clock),
                            buffered=len(self._buffer),
                            params_digest=params_digest(self._server))
        total = time.perf_counter() - t0
        timings = {
            "total_s": total,
            "rounds_per_s": self.n_rounds / max(total, 1e-9),
            "makespan": makespan,
            "flushes": self._version,
            "buffer_goal": self._goal,
            "mode": "buffered",
            "data_kind": "async",
            "a_total": self.n_total,
            "a_active": self.cohort_size,
            "store_rows": self.store.materialized,
            **self._stats,
        }
        state = {"params": self._server, "version": self._version}
        return RunResult(self.fed, state, self._history, [], timings)

    # ------------------------------------------------------------------
    def run(self, rng) -> RunResult:
        # fresh journal/clock per run: re-running the same driver (bench
        # warmup + timed repeats) must not accumulate events
        self.journal = EventJournal()
        self.clock = SimClock()
        if self.sync_equivalent:
            return self._run_sync_equivalent(rng)
        return self._run_buffered(rng)
