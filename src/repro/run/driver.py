"""The streaming round driver — pillar four's hot loop.

Replaces the seed-era blocking loop (host-assembled round tensors, no
donation, a forced device sync per round for metrics) with:

  * **donated round execution** — ``jax.jit(..., donate_argnums=0)`` on the
    FedGAN state, so the (params, Adam moments) buffers are reused in place
    instead of reallocated every round;
  * **device-resident sampling** — with a ``DeviceFederatedData`` the K
    minibatches are gathered inside the jitted round
    (``FedGAN.round_from_data``), eliminating the K× host→device transfer
    and the per-agent Python assembly loop;
  * **multi-round scan chunking** — for small models (the paper's GANs)
    ``rounds_per_chunk`` rounds run as ONE ``lax.scan`` dispatch, hiding
    per-round dispatch + Python overhead entirely;
  * **non-blocking metrics** — per-round metrics are reduced device-side
    and fetched only at ``log_every`` boundaries (and once at the end);
    no round ever blocks on a host float() just to fill the history;
  * **hooks** — periodic evals on the intermediary's averaged params
    (``repro.run.evals``) and checkpointing, both at round granularity.

Streaming datasets (too large for device memory) run the same driver
through ``StreamingFederatedData``: double-buffered host assembly +
async ``device_put``, bit-identical trajectories to the legacy loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.data.federated import (DeviceFederatedData, FederatedData,
                                  FederatedRounds, StreamingFederatedData,
                                  round_key_schedule)

tmap = jax.tree_util.tree_map


@dataclasses.dataclass
class RunResult:
    """What a driver run returns.  ``history`` is one dict of float metrics
    per round (same contract as the legacy ``RunSpec.run`` history);
    ``evals`` one dict per eval point; ``timings`` wall-clock accounting
    including the round-gap: per-round host work between round dispatches
    (blocking data assembly on the stream path; key/bookkeeping/hook time
    on the device path) — an upper bound on device idle time."""

    fed: Any
    state: Any
    history: list
    evals: list
    timings: dict

    def legacy_tuple(self):
        return self.fed, self.state, self.history


def _chunk_sizes(n_rounds: int, per_chunk: int, *cadences: int) -> list[int]:
    """Split ``n_rounds`` into scan chunks of at most ``per_chunk`` that
    never cross a nonzero cadence boundary (evals/checkpoints must observe
    the state at exactly their round)."""
    per_chunk = max(per_chunk, 1)
    sizes, r = [], 0
    while r < n_rounds:
        c = min(per_chunk, n_rounds - r)
        for cad in cadences:
            if cad:
                c = min(c, cad - r % cad)
        sizes.append(c)
        r += c
    return sizes


def _dp_data_shape(data):
    """(batch_size, min per-agent dataset size) of the pipeline, or None
    when the data object does not expose them."""
    if isinstance(data, DeviceFederatedData):
        # one-time setup fetch (before the round loop starts), not per-round
        return data.batch_size, int(np.asarray(data.sizes).min())  # analysis: allow(host-sync)
    rounds = data.rounds if isinstance(data, StreamingFederatedData) else data
    if isinstance(rounds, FederatedRounds):
        n_min = min(jax.tree_util.tree_leaves(d)[0].shape[0]
                    for d in rounds.agent_data)
        return rounds.batch_size, n_min
    return None


def check_dp_sample_rate(dp, data):
    """Refuse an accountant ``sample_rate`` the pipeline does not deliver.

    Every step samples ``batch_size`` examples from each agent's local
    dataset, so the worst-case per-example participation rate is
    ``min(1, batch_size / min_i |R_i|)``.  A configured q below that makes
    :meth:`DPSGD.epsilon` report a spend the mechanism does not achieve —
    a silent privacy accounting failure, so this raises instead of
    warning (mirroring the strategy refusal matrix)."""
    shape = _dp_data_shape(data)
    if shape is None:
        return
    batch_size, n_min = shape
    q_actual = min(1.0, batch_size / max(n_min, 1))
    if dp.sample_rate < q_actual - 1e-9:
        raise ValueError(
            f"DPSGD sample_rate={dp.sample_rate} understates the pipeline's "
            f"participation rate: batch_size={batch_size} from a smallest "
            f"agent dataset of {n_min} examples samples at rate "
            f"{q_actual:.6g} per step, so the accountant's epsilon would "
            "not be delivered — set sample_rate >= batch_size / min |R_i| "
            "(or leave the conservative default of 1.0)")


@dataclasses.dataclass
class RoundDriver:
    """Drives ``n_rounds`` FedGAN rounds over a :class:`FederatedData`.

    ``data`` may be a ``DeviceFederatedData`` (device-resident fast path),
    a ``StreamingFederatedData``, or a bare ``FederatedRounds`` (wrapped
    into a streaming pipeline).  ``eval_hooks`` entries are callables
    ``(fed, state, round_idx) -> dict`` (see ``repro.run.evals``).
    """

    fed: Any
    data: Any
    n_rounds: int
    log_every: int = 1
    eval_every: int = 0
    eval_hooks: Sequence[Callable] = ()
    ckpt_every: int = 0
    ckpt_dir: str = ""
    rounds_per_chunk: int = 1
    donate: bool = True
    verbose: bool = True

    def __post_init__(self):
        if isinstance(self.data, FederatedRounds):
            self.data = StreamingFederatedData(self.data)
        if self.eval_every and not self.eval_hooks:
            raise ValueError("eval_every is set but eval_hooks is empty")
        # memoized jitted executables: repeated .run() calls (resumed or
        # repeated training, benchmarking) must not recompile
        self._round_jit = None
        self._chunk_jit = None

    # ------------------------------------------------------------------
    def run(self, rng, state=None) -> RunResult:
        """Execute the round loop.  ``rng`` seeds the data/step keys (the
        legacy per-round split schedule); ``state`` defaults to a fresh
        init from an independent split of ``rng`` — pass one explicitly to
        continue a run (or to control the init key separately, as the
        RunSpec shim does for legacy parity)."""
        dp = getattr(self.fed.cfg, "dp", None)
        if dp is not None:
            check_dp_sample_rate(dp, self.data)
        if state is None:
            rng, init_rng = jax.random.split(rng)
            state = self.fed.init_state(init_rng)
        kind = getattr(self.data, "kind", "stream")
        self._evals = []
        t0 = time.perf_counter()
        if kind == "device":
            state, raw, gap = self._run_device(rng, state)
        else:
            state, raw, gap = self._run_stream(rng, state)
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        total = time.perf_counter() - t0
        history = [tmap(float, m) for m in raw]
        K = self.fed.cfg.sync_interval
        timings = {
            "total_s": total,
            "steps_per_s": self.n_rounds * K / max(total, 1e-9),
            "round_gap_s": gap / max(self.n_rounds, 1),
            "data_kind": kind,
        }
        dp = getattr(self.fed.cfg, "dp", None)
        if dp is not None:
            timings["dp_epsilon"] = dp.epsilon(self.n_rounds * K)
        return RunResult(self.fed, state, history, self._evals, timings)

    # ------------------------------------------------------------------
    def _jit(self, fn):
        return jax.jit(fn, donate_argnums=0) if self.donate else jax.jit(fn)

    def _run_stream(self, rng, state):
        if self._round_jit is None:
            self._round_jit = self._jit(self.fed.round)
        round_fn = self._round_jit
        history = []
        gap = 0.0
        it = self.data.iter_rounds(rng, self.n_rounds)
        for r in range(self.n_rounds):
            t_gap = time.perf_counter()
            batches, seeds = next(it)
            gap += time.perf_counter() - t_gap
            state, metrics = round_fn(state, batches, seeds)
            # device-side reduction; no host sync on the round path
            history.append(tmap(jnp.mean, metrics))
            state = self._boundaries(state, r, lambda: history[r])
        return state, history, gap

    def _run_device(self, rng, state):
        data = self.data

        if self._chunk_jit is None:
            def chunk_fn(st, d, keys):
                def body(st, k):
                    st, m = self.fed.round_from_data(st, d, k)
                    return st, tmap(jnp.mean, m)
                return jax.lax.scan(body, st, keys)

            self._chunk_jit = self._jit(chunk_fn)
        chunk_jit = self._chunk_jit
        chunks = []       # (start_round, length, stacked metrics tree)
        gap = 0.0
        r = 0
        # gap: ALL host work between dispatches (key prep, boundary hooks)
        # — an upper bound on device idle time, comparable to the stream
        # path's blocking-assembly measurement.  Per-round metric slicing
        # is deferred to the end of the run: eagerly chaining ops onto the
        # in-flight chunk backs up the dispatch queue and stalls the loop.
        t_host = time.perf_counter()
        keys = jnp.stack(round_key_schedule(rng, self.n_rounds))
        for c in _chunk_sizes(self.n_rounds, self.rounds_per_chunk,
                              self.eval_every, self.ckpt_every):
            chunk_keys = keys[r:r + c]
            gap += time.perf_counter() - t_host
            state, metrics = chunk_jit(state, data, chunk_keys)
            t_host = time.perf_counter()
            chunks.append((r, c, metrics))
            for rr in range(r, r + c):
                state = self._boundaries(
                    state, rr,
                    lambda rr=rr, m=metrics, base=r: tmap(
                        lambda x: x[rr - base], m))
            r += c
        gap += time.perf_counter() - t_host
        history = []
        for base, c, metrics in chunks:   # one fetch per chunk, at the end
            # deliberate batched fetch AFTER all rounds dispatched — this is
            # the fix for the eager per-round fetch, not a regression of it
            arr = jax.device_get(metrics)  # analysis: allow(host-sync)
            for i in range(c):
                history.append(tmap(lambda x: x[i], arr))
        return state, history, gap

    # ------------------------------------------------------------------
    def _boundaries(self, state, r, get_metrics):
        """Per-round host work: logging (the only place round metrics are
        fetched mid-run — ``get_metrics`` materializes them on demand),
        periodic evals, periodic checkpoints."""
        K = self.fed.cfg.sync_interval
        last = r == self.n_rounds - 1
        if self.log_every and (r % self.log_every == 0 or last):
            m = tmap(float, get_metrics())
            if self.verbose:
                d, g = m.get("d_loss"), m.get("g_loss")
                print(f"round {r:5d}/{self.n_rounds} step {(r + 1) * K:6d} "
                      f"d_loss={d:.4f} g_loss={g:.4f}", flush=True)
        if self.eval_every and ((r + 1) % self.eval_every == 0 or last):
            scores = {}
            for hook in self.eval_hooks:
                scores.update(hook(self.fed, state, r))
            dp = getattr(self.fed.cfg, "dp", None)
            if dp is not None:
                # closed-form RDP accountant (host-side, cheap): the privacy
                # spent by the (r+1)*K local steps so far
                scores["dp_epsilon"] = dp.epsilon((r + 1) * K)
            self._evals.append({"round": r, "step": (r + 1) * K, **scores})
            if self.verbose:
                pretty = " ".join(f"{k}={v:.4g}" for k, v in scores.items())
                print(f"eval  round {r} step {(r + 1) * K}: {pretty}",
                      flush=True)
        if self.ckpt_dir and self.ckpt_every and (r + 1) % self.ckpt_every == 0:
            save_checkpoint(self.ckpt_dir, state, step=(r + 1) * K,
                            metadata={"round": r, "K": K})
        return state


def train(fed, data, n_rounds: int, rng, **kwargs) -> RunResult:
    """One-call convenience over :class:`RoundDriver`."""
    return RoundDriver(fed, data, n_rounds, **kwargs).run(rng)
