"""Eval harness for the training runtime — wires ``repro.evals`` into the
driver as periodic hooks on the intermediary's averaged parameters.

An :class:`EvalSuite` describes how to score one experiment: the pooled
real samples, how to draw generated samples from the averaged generator,
and which metrics apply (the FD stand-in always; mode coverage when the
reference modes are known; centroid matching for the time-series
experiments).  :func:`evaluate` runs it once; :func:`eval_hook` packages
it for ``RoundDriver(eval_hooks=...)``.

Evaluation always scores the *intermediary's* parameters (the weighted
average of eq. (2), no broadcast) — the object the paper's figures track —
never any single agent's copy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.evals import centroid_match_score, fd_score, mode_stats


@dataclasses.dataclass(frozen=True)
class EvalSuite:
    """One experiment's evaluation recipe.

    ``sample_fake(gen_params, rng, n)`` draws n samples from the averaged
    generator; ``real`` holds pooled (cross-agent) real samples of the same
    shape.  ``modes`` enables mode-coverage stats; ``kind="timeseries"``
    additionally reports the centroid-matching RMSE of Fig. 3/4.
    """

    real: Any
    sample_fake: Callable[[Any, jax.Array, int], Any]
    modes: Any = None
    kind: str = "fd"           # "fd" | "timeseries"
    feat_dim: int = 64
    mode_radius: float = 0.5


def evaluate(suite: EvalSuite, fed, state, rng, *, n: int = 1024) -> dict:
    """Score the intermediary's generator: always the FD stand-in (the
    fixed-random-feature Fréchet distance of ``repro.evals.fd``), plus the
    suite's extra metrics.  Returns a flat dict of floats."""
    k_fake, k_feat = jax.random.split(rng)
    gen = fed.averaged_params(state)["gen"]
    n_real = int(jax.tree_util.tree_leaves(suite.real)[0].shape[0])
    n = min(n, n_real)
    fake = np.asarray(suite.sample_fake(gen, k_fake, n))
    real = np.asarray(suite.real[:n])
    if not np.isfinite(fake).all():
        return {"fd": float("inf"), "nonfinite": 1.0}
    out = {"fd": fd_score(k_feat, real, fake, feat_dim=suite.feat_dim)}
    if suite.modes is not None:
        covered, hq, _ = mode_stats(fake.reshape(n, -1), suite.modes,
                                    radius=suite.mode_radius)
        out["modes_covered"] = float(covered)
        out["high_quality_frac"] = hq
    if suite.kind == "timeseries":
        cm = centroid_match_score(real.reshape(n, -1), fake.reshape(n, -1))
        out["centroid_rmse"] = cm["matched_rmse"]
        out["centroid_rmse_random"] = cm["random_rmse"]
    return out


def eval_hook(suite: EvalSuite, *, seed: int = 0, n: int = 1024) -> Callable:
    """An ``eval_hooks`` entry for the driver: ``fn(fed, state, round_idx)
    -> dict``.  The PRNG key is folded from the round index so repeated
    evaluations are comparable but not identical draws."""

    def hook(fed, state, round_idx: int) -> dict:
        rng = jax.random.fold_in(jax.random.key(seed), round_idx)
        return evaluate(suite, fed, state, rng, n=n)

    return hook


def final_fd(suite: EvalSuite, fed, state, *, seed: int = 0,
             n: int = 2048) -> dict:
    """End-of-run evaluation at a larger sample budget (sweep summaries)."""
    return evaluate(suite, fed, state, jax.random.key(seed), n=n)
