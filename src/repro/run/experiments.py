"""K-sweep experiment runner — the paper's Fig. 1–4 driver, extended to a
K×codec communication surface.

Reproduces the robustness-to-reduced-communication curves (metric vs sync
interval K, FedGAN vs the per-step distributed baseline) end to end in one
command, on the device-resident runtime:

    PYTHONPATH=src python -m repro.run.experiments \\
        --experiment mixed_gaussian --sweep K=5,20,100 --compare distributed

Adding ``--codecs none,int8,int4`` grows the grid along the wire-encoding
axis (``repro.comm`` codecs with error feedback, on the ``fedgan`` base
strategy): the summary then shows metric AND measured bytes/round per
(K, codec) cell — the paper's K-robustness claim extended to a full
K×compression surface (see docs/communication.md).

``--privacy none,dp,secure,trimmed_mean,median`` adds the privacy axis
(``repro.privacy``, docs/privacy.md) on the same base: per-agent DP-SGD
(the final row then carries the accountant's ``dp_epsilon``), pairwise-
mask secure summing (bit-identical — a free column), and the
Byzantine-robust reduces — the K×codec×privacy cost surface of PR 6.

Every run streams a structured JSONL history (one line per round + one
``"final"`` line with the ``repro.evals`` scores) into
``<out_dir>/sweep_<experiment>.jsonl`` and the command ends with a summary
table of the FID stand-in (and the suite's extra metrics) vs K — the
paper's qualitative claim is that the FedGAN column barely moves as K
grows while the wire bytes drop by K× (and by another codec-factor along
the compression axis).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Sequence

from repro.core import strategies as sync_strategies
from repro.run.evals import final_fd


PRIVACY_AXES = ("none", "dp", "secure", "trimmed_mean", "median")


@dataclasses.dataclass
class SweepCell:
    """One (K, strategy, codec, privacy) run of the sweep."""

    experiment: str
    K: int
    strategy: str
    history: list
    evals: list
    final: dict
    timings: dict
    codec: str = "none"
    privacy: str = "none"
    bytes_per_round: int = 0

    @property
    def label(self) -> str:
        parts = [self.strategy]
        if self.codec != "none":
            parts.append(self.codec)
        if self.privacy != "none":
            parts.append(self.privacy)
        return "+".join(parts)

    def rows(self):
        base = {"experiment": self.experiment, "K": self.K,
                "strategy": self.strategy, "codec": self.codec,
                "privacy": self.privacy}
        for r, m in enumerate(self.history):
            yield {**base, "round": r, "step": (r + 1) * self.K,
                   **{k: v for k, v in m.items()
                      if isinstance(v, (int, float))}}
        for e in self.evals:
            yield {**base, "eval": True, **e}
        extra = {}
        if "dp_epsilon" in self.timings:
            extra["dp_epsilon"] = self.timings["dp_epsilon"]
        yield {**base, "final": True, **self.final,
               "bytes_per_round": self.bytes_per_round,
               "steps_per_s": round(self.timings["steps_per_s"], 2), **extra}


def _strategy_for(name: str, codec: str = "none", privacy: str = "none"):
    """Sweep-cell (strategy, dp) pair: 'fedgan' keeps the library default
    (FedAvgSync), anything else resolves through the registry; a codec spec
    wraps the fedgan base in a compressed-sync FedAvgSync (error feedback
    on).  The privacy axis rides the fedgan base too: 'dp' turns on
    per-agent DP-SGD (returned as the dp config, not a strategy), 'secure'
    the pairwise-mask sum, 'trimmed_mean'/'median' the robust reduces
    (these compose with a codec; secure does not — loud error)."""
    if privacy not in PRIVACY_AXES:
        raise ValueError(f"unknown privacy axis {privacy!r}; "
                         f"known: {list(PRIVACY_AXES)}")
    dp = None
    kwargs = {}
    if codec != "none":
        from repro.comm import get_codec
        kwargs["codec"] = get_codec(codec)
    if privacy == "dp":
        from repro.privacy import DPSGD
        dp = DPSGD(clip=1.0, noise_multiplier=0.8)
    elif privacy == "secure":
        if codec != "none":
            raise ValueError(
                "privacy='secure' cannot ride a lossy codec wire (per-agent "
                "decode at the server reveals the updates the masking "
                "hides); drop the codec or the secure axis")
        from repro.privacy import SecureAgg
        kwargs["secure_agg"] = SecureAgg()
    if privacy == "trimmed_mean":
        return sync_strategies.TrimmedMeanSync(**kwargs), dp
    if privacy == "median":
        return sync_strategies.CoordinateMedianSync(**kwargs), dp
    if kwargs:
        return sync_strategies.FedAvgSync(**kwargs), dp
    return (None if name == "fedgan"
            else sync_strategies.get_strategy(name)), dp


def run_sweep(experiment: str, Ks: Sequence[int], *,
              strategy_names: Sequence[str] = ("fedgan",),
              codec_names: Sequence[str] = ("none",),
              privacy_names: Sequence[str] = ("none",),
              steps: int | None = None, seed: int = 0, out_dir: str = ".",
              eval_every: int = 0, eval_n: int = 2048,
              rounds_per_chunk: int = 8, verbose: bool = True
              ) -> list[SweepCell]:
    """Run the (K × strategy × codec × privacy) grid on the device-resident
    runtime and persist JSONL histories.  Codecs and privacy axes apply to
    the ``fedgan`` base strategy only (the comparison strategies run
    uncompressed/unprotected).  Returns the grid cells for programmatic
    use (tests, benchmarks)."""
    from repro.launch.train import experiment_spec
    cells = []
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"sweep_{experiment}.jsonl")
    with open(path, "w") as f:
        for K in Ks:
            for sname in strategy_names:
                specs_c = codec_names if sname == "fedgan" else ("none",)
                specs_p = privacy_names if sname == "fedgan" else ("none",)
                for cname in specs_c:
                    for pname in specs_p:
                        strat, dp = _strategy_for(sname, cname, pname)
                        spec, suite = experiment_spec(
                            experiment, K=K, steps=steps, seed=seed,
                            strategy=strat, dp=dp, log_every=0,
                            eval_every=eval_every, data_mode="device",
                            rounds_per_chunk=rounds_per_chunk)
                        if verbose:
                            print(f"[sweep] {experiment} K={K} "
                                  f"strategy={sname} codec={cname} "
                                  f"privacy={pname} ({spec.n_rounds} rounds "
                                  f"x {K} steps)", flush=True)
                        res = spec.run_result()
                        final = final_fd(suite, res.fed, res.state,
                                         seed=seed, n=eval_n)
                        acct = res.fed.comm_bytes_per_round(res.state)
                        cell = SweepCell(experiment, K, sname, res.history,
                                         res.evals, final, res.timings,
                                         codec=cname, privacy=pname,
                                         bytes_per_round=int(
                                             acct["strategy_bytes_per_round"]))
                        for row in cell.rows():
                            f.write(json.dumps(row) + "\n")
                        f.flush()
                        cells.append(cell)
    if verbose:
        print(f"[sweep] wrote {path}")
        print(summary_table(cells))
    return cells


def summary_table(cells: Sequence[SweepCell]) -> str:
    """Fixed-width (K × strategy × codec) table of the final metrics plus
    bytes/round — the robustness-to-reduced-communication surface in text
    form."""
    labels = list(dict.fromkeys(c.label for c in cells))
    metrics = list(dict.fromkeys(k for c in cells for k in c.final))
    metrics.append("B/round")
    by = {(c.K, c.label): c for c in cells}
    cols = [f"{s}:{m}" for s in labels for m in metrics]
    lines = ["  ".join(["K".rjust(6)] + [c.rjust(18) for c in cols])]
    for K in sorted(dict.fromkeys(c.K for c in cells)):
        row = [str(K).rjust(6)]
        for s in labels:
            cell = by.get((K, s))
            for m in metrics:
                if cell is None:
                    v = None
                elif m == "B/round":
                    v = cell.bytes_per_round
                else:
                    v = cell.final.get(m)
                row.append(("-" if v is None else f"{v:.4g}").rjust(18))
        lines.append("  ".join(row))
    return "\n".join(lines)


def parse_sweep(arg: str) -> list[int]:
    """'K=10,20,100' (or bare '10,20,100') -> [10, 20, 100]."""
    body = arg.split("=", 1)[1] if "=" in arg else arg
    try:
        Ks = [int(x) for x in body.split(",") if x]
    except ValueError:
        raise ValueError(f"bad --sweep {arg!r}; expected K=10,20,100") from None
    if not Ks or any(k < 1 for k in Ks):
        raise ValueError(f"bad --sweep {arg!r}; need positive K values")
    return Ks


def main(argv: Any = None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--experiment", default="toy_2d")
    ap.add_argument("--sweep", default="K=1,5,20,50",
                    help="sync intervals, e.g. K=10,20,100,500")
    ap.add_argument("--compare", default="",
                    help="comma-separated extra strategies to run beside "
                         "fedgan at every K (e.g. 'distributed')")
    ap.add_argument("--codecs", default="",
                    help="comma-separated wire codec specs to run on the "
                         "fedgan base at every K (e.g. 'none,int8,int4'; "
                         "'none' = uncompressed)")
    ap.add_argument("--privacy", default="",
                    help="comma-separated privacy axes to run on the fedgan "
                         "base at every K: none | dp | secure | "
                         "trimmed_mean | median")
    ap.add_argument("--steps", type=int, default=0,
                    help="local steps per run (0 = experiment default)")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="rounds between mid-run evals (0 = final only)")
    ap.add_argument("--eval-n", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--rounds-per-chunk", type=int, default=8)
    args = ap.parse_args(argv)

    names = ["fedgan"] + [s for s in args.compare.split(",") if s]
    for s in names[1:]:
        if s not in sync_strategies.STRATEGIES:
            ap.error(f"unknown --compare strategy {s!r}; known: "
                     f"{sorted(sync_strategies.STRATEGIES)}")
    codecs = [c for c in args.codecs.split(",") if c] or ["none"]
    from repro.comm import get_codec
    for c in codecs:
        if c != "none":
            try:
                get_codec(c)
            except ValueError as e:
                ap.error(str(e))
    privacy = [p for p in args.privacy.split(",") if p] or ["none"]
    for p in privacy:
        if p not in PRIVACY_AXES:
            ap.error(f"unknown --privacy axis {p!r}; "
                     f"known: {list(PRIVACY_AXES)}")
        if p == "secure" and any(c != "none" for c in codecs):
            ap.error("--privacy secure cannot ride a lossy --codecs wire "
                     "(per-agent decode reveals the updates the masking "
                     "hides); drop one")
    run_sweep(args.experiment, parse_sweep(args.sweep), strategy_names=names,
              codec_names=codecs, privacy_names=privacy,
              steps=args.steps or None, seed=args.seed,
              out_dir=args.out_dir, eval_every=args.eval_every,
              eval_n=args.eval_n, rounds_per_chunk=args.rounds_per_chunk)


if __name__ == "__main__":
    main()
