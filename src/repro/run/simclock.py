"""Deterministic virtual-time simulation for the async runtime.

Every scheduling decision the async server makes (``repro.run.async_agg``)
is driven by *virtual* time, never the wall clock, so an async schedule is
a pure function of its seeds and replays bit-exactly:

  * :class:`SimClock` — a heapq event queue ordered by ``(time, seq)``;
    the push sequence number breaks ties, so simultaneous events fire in
    a deterministic order with no reliance on heap internals;
  * :class:`LatencyModel` — client round-trip latency as a pure function
    of ``(schedule.seed, dispatch_seq, client, attempt)``; the uniforms
    come from ``ParticipationSchedule.arrival_uniforms`` so the cohort
    draw and the latency draw share one seeding discipline but disjoint
    streams;
  * :class:`EventJournal` — an append-only record of every dispatch /
    arrival / timeout / retry / flush, serialized canonically (sorted
    keys, shortest-round-trip floats) so two runs of the same seed are
    **byte-identical** — the CI determinism gate diffs the files raw.

``python -m repro.run.simclock --seed 7 --out journal.jsonl`` runs a
self-contained straggler simulation (a tiny quadratic GAN fleet) and
writes the journal plus a final-params digest — run it twice, ``cmp`` the
outputs: that is the whole gate.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import zlib
from typing import Any

import numpy as np

from repro.core.participation import ParticipationSchedule


class SimClock:
    """Virtual-time event queue.  Events are ``(time, seq, kind, payload)``
    tuples; ``seq`` is the push order, which makes pop order total and
    deterministic even for equal-time events.  Time never flows backward:
    pushing before ``now`` refuses (a scheduling bug, not a policy)."""

    def __init__(self):
        self._q: list = []
        self._pushes = 0
        self.now = 0.0

    def __len__(self) -> int:
        return len(self._q)

    def push(self, at: float, kind: str, payload: Any = None) -> None:
        at = float(at)
        if at < self.now:
            raise ValueError(f"cannot schedule {kind!r} at t={at} before "
                             f"now={self.now}")
        heapq.heappush(self._q, (at, self._pushes, kind, payload))
        self._pushes += 1

    def pop(self):
        """Advance to and return the earliest event: ``(t, kind, payload)``."""
        t, _, kind, payload = heapq.heappop(self._q)
        self.now = t
        return t, kind, payload


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Seeded client latency: ``base + jitter * U1``, multiplied by
    ``straggler_factor`` when the straggler coin (``U2 < straggler_frac``)
    lands.  Both uniforms are ``ParticipationSchedule.arrival_uniforms``
    draws keyed by ``(schedule.seed, dispatch_seq, attempt)`` and indexed
    by client id — a retry (``attempt > 0``) gets a *fresh* draw, which is
    what makes retrying a straggler worthwhile."""

    base: float = 1.0
    jitter: float = 0.0
    straggler_frac: float = 0.0
    straggler_factor: float = 10.0

    def validate(self) -> None:
        if self.base < 0 or self.jitter < 0:
            raise ValueError(f"latency base/jitter must be >= 0, got "
                             f"base={self.base} jitter={self.jitter}")
        if not 0.0 <= self.straggler_frac <= 1.0:
            raise ValueError(f"straggler_frac must be in [0, 1], got "
                             f"{self.straggler_frac}")
        if self.straggler_factor < 1.0:
            raise ValueError(f"straggler_factor must be >= 1, got "
                             f"{self.straggler_factor}")

    def draw(self, schedule: ParticipationSchedule, dispatch_seq: int,
             client: int, n_total: int, attempt: int = 0) -> float:
        """Latency for one dispatch — a pure function of every argument."""
        u1 = schedule.arrival_uniforms(dispatch_seq, n_total,
                                       salt=2 * attempt)[client]
        lat = self.base + self.jitter * float(u1)
        if self.straggler_frac > 0.0:
            u2 = schedule.arrival_uniforms(dispatch_seq, n_total,
                                           salt=2 * attempt + 1)[client]
            if float(u2) < self.straggler_frac:
                lat *= self.straggler_factor
        return float(lat)


class EventJournal:
    """Append-only event log with a canonical byte serialization.

    Records are plain dicts; ``append`` stamps each with its index so the
    journal is totally ordered by construction.  ``canonical_bytes``
    serializes with sorted keys, no whitespace, and Python's
    shortest-round-trip float repr — two runs producing the same events
    produce the same *bytes*, which is the contract the determinism gate
    (``make determinism-gate``) enforces with a raw file diff."""

    def __init__(self):
        self.records: list[dict] = []

    def __len__(self) -> int:
        return len(self.records)

    def append(self, ev: str, t: float, **fields) -> None:
        rec = {"i": len(self.records), "ev": str(ev), "t": float(t)}
        for k, v in fields.items():
            if isinstance(v, (np.integer,)):
                v = int(v)
            elif isinstance(v, (np.floating,)):
                v = float(v)
            rec[k] = v
        self.records.append(rec)

    def select(self, ev: str) -> list[dict]:
        return [r for r in self.records if r["ev"] == ev]

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for r in self.records:
            out[r["ev"]] = out.get(r["ev"], 0) + 1
        return out

    def canonical_bytes(self) -> bytes:
        lines = [json.dumps(r, sort_keys=True, separators=(",", ":"))
                 for r in self.records]
        return ("\n".join(lines) + "\n").encode("utf-8") if lines else b""

    def write(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.canonical_bytes())


def params_digest(tree) -> str:
    """crc32 over every leaf's bytes in sorted-path order — a cheap,
    deterministic fingerprint for journals and replay assertions."""
    import jax
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    crc = 0
    for path, leaf in sorted(leaves_with_paths, key=lambda kv: str(kv[0])):
        arr = np.ascontiguousarray(leaf)  # analysis: allow(host-sync)
        crc = zlib.crc32(str(path).encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    return f"{crc:08x}"


# ---------------------------------------------------------------------------
# self-contained demo fleet + CLI (the determinism gate's workload)
# ---------------------------------------------------------------------------


def demo_driver(*, seed: int = 7, n_clients: int = 8, cohort: int = 4,
                n_rounds: int = 6, buffer_goal: int = 2,
                timeout: float | None = 6.0):
    """A small quadratic-GAN async run with planted stragglers — the
    workload behind ``python -m repro.run.simclock`` and the CI
    determinism gate.  Everything is seeded from ``seed``."""
    import jax
    import jax.numpy as jnp

    from repro.core import FedGAN, FedGANConfig, GANTask
    from repro.data.federated import FleetRounds
    from repro.optim import SGD, constant, equal_timescale
    from repro.run.async_agg import AsyncAggDriver
    from repro.run.virtual import StragglerPolicy

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    task = GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)
    key = jax.random.key(seed)
    data = [{"x": jax.random.normal(jax.random.fold_in(key, i), (32, 3)) + i}
            for i in range(n_clients)]
    grid = (1, cohort)
    fed = FedGAN(task, FedGANConfig(agent_grid=grid, sync_interval=3),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(constant(0.05)))
    fleet = FleetRounds(data, grid, batch_size=8, sync_interval=3)
    return AsyncAggDriver(
        fed, fleet, n_rounds,
        schedule=ParticipationSchedule(seed=seed),
        straggler=StragglerPolicy(mode="defer", decay=0.5, max_staleness=2),
        buffer_goal=buffer_goal,
        latency=LatencyModel(base=1.0, jitter=0.5, straggler_frac=0.25,
                             straggler_factor=8.0),
        timeout=timeout, max_retries=2, backoff=2.0)


def main(argv=None) -> int:
    import argparse

    import jax

    ap = argparse.ArgumentParser(
        description="deterministic async-aggregation simulation; run twice "
                    "with the same seed and diff the journals byte-for-byte")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--cohort", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--buffer-goal", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=6.0)
    ap.add_argument("--out", default="", help="journal path (.jsonl)")
    args = ap.parse_args(argv)

    driver = demo_driver(seed=args.seed, n_clients=args.clients,
                         cohort=args.cohort, n_rounds=args.rounds,
                         buffer_goal=args.buffer_goal, timeout=args.timeout)
    result = driver.run(jax.random.key(args.seed))
    if args.out:
        driver.journal.write(args.out)
    digest = params_digest(result.state["params"])
    counts = driver.journal.counts()
    print(f"events={len(driver.journal)} flushes={counts.get('flush', 0)} "
          f"timeouts={counts.get('timeout', 0)} "
          f"makespan={result.timings['makespan']} params_digest={digest}",
          flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
