"""Virtual-client runtime — `A_total` clients on `A_active` device slots.

Pillar four's dense driver keeps every agent on device simultaneously as a
stacked ``(P, A)`` leaf, which caps the fleet at what HBM holds.  Real
cross-device fleets are orders of magnitude larger than any per-round
cohort, so this module decouples the two sizes:

  * :class:`ClientStore` keeps inactive clients' state host-side (numpy
    rows: params, Adam moments, per-client error-feedback residuals) with
    copy-on-write over the shared Algorithm-1 init template — a
    million-client fleet that has touched k clients materializes k rows;
  * a ``repro.core.participation.ParticipationSchedule`` picks each
    round's cohort (seeded and stateless, so a resumed run replays the
    same sequence), and ``repro.data.federated.FleetRounds`` assembles
    that cohort's round tensor salted by *global* client id;
  * :class:`VirtualClientDriver` runs the same jitted ``FedGAN.round`` the
    dense driver runs — compiled once for ``(P, A_active)``, never for
    ``A_total`` — and pages cohort state between store and slots around
    it.  Swaps are diff-based (a client keeps its slot while it stays in
    the cohort; the identity schedule swaps nothing), and the next
    cohort's rows and batches are uploaded with async ``jax.device_put``
    while the current round computes, extending
    ``StreamingFederatedData``'s double-buffered prefetch to *state*;
  * :class:`StragglerPolicy` ``mode="defer"`` lets a planted-late cohort
    member's delta merge into a *later* round's average with a staleness
    decay ``gamma**s`` instead of blocking, and planted drops revert to
    their pre-round row untouched (see docs/scaling.md for the merge
    algebra).

With ``A_total == A_active`` and the identity schedule the virtual path
is bit-identical to the dense ``RoundDriver`` stream path — params, opt
state, EF residuals and metrics — held by ``tests/test_virtual_clients.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core import strategies as sync_strategies
from repro.core.participation import ParticipationSchedule
from repro.data.federated import FleetRounds, round_key_schedule
from repro.run.driver import RunResult

tmap = jax.tree_util.tree_map

# entries every FedGAN state carries; strategies declare the rest via
# SyncStrategy.state_axes()
_BASE_AXES = {"params": "client", "opt_g": "client", "opt_d": "client",
              "step": "shared"}


def state_axes(fed, state) -> dict:
    """Per-entry paging axis ("client" vs "shared") for a round state."""
    axes = dict(_BASE_AXES)
    axes.update(fed.cfg.resolve_strategy().state_axes())
    unknown = sorted(set(state) - set(axes))
    if unknown:
        raise ValueError(
            f"strategy {fed.cfg.resolve_strategy().name!r} carries round-"
            f"state entries {unknown} without declaring them per-client or "
            "shared in SyncStrategy.state_axes(); the ClientStore cannot "
            "page state it cannot classify")
    bad = sorted(k for k, v in axes.items() if v not in ("client", "shared"))
    if bad:
        raise ValueError(f"state_axes() values must be 'client' or "
                         f"'shared'; got {[axes[k] for k in bad]} for {bad}")
    return axes


class ClientStore:
    """Host-side fleet state: one numpy row per *materialized* client,
    copy-on-write over the shared init template.

    A row is the client-axis slice of the round state — ``{"params": ...,
    "opt_g": ..., "opt_d": ...}`` (plus per-client strategy entries like
    the uplink EF residual) with the leading ``(P, A)`` dims stripped.
    Algorithm 1 starts every client from the same point, so clients that
    have never participated share ``template`` and cost no memory; the
    store materializes a private row only on first write-back.
    """

    def __init__(self, template, n_total: int):
        self.template = template
        self.n_total = int(n_total)
        self._rows: dict[int, Any] = {}

    @classmethod
    def from_fed(cls, fed, rng, n_total: int) -> "ClientStore":
        """Build the template from a (1, 1) slot-view init — the same
        ``task.init(rng)`` the dense init broadcasts, so template rows are
        bit-identical to a fresh ``fed.init_state(rng)`` slot."""
        tiny = fed.init_state(rng, agent_grid=(1, 1))
        axes = state_axes(fed, tiny)
        client = {k: tiny[k] for k, ax in axes.items() if ax == "client"}
        # one-time init fetch, before any round is dispatched
        template = jax.device_get(tmap(lambda x: x[0, 0], client))  # analysis: allow(host-sync)
        return cls(template, n_total)

    @property
    def materialized(self) -> int:
        """Rows holding private state (the copy-on-write high-water mark)."""
        return len(self._rows)

    def client_ids(self):
        return sorted(self._rows)

    def row(self, cid: int):
        return self._rows.get(int(cid), self.template)

    def put(self, cid: int, row) -> None:
        if not 0 <= int(cid) < self.n_total:
            raise ValueError(f"client id {cid} outside fleet [0, {self.n_total})")
        self._rows[int(cid)] = row

    def gather(self, cids):
        """Stack rows for ``cids`` into a ``(len(cids), ...)`` numpy
        pytree — the host half of a swap-in.  Flattens each row once and
        stacks leaf-wise (a per-leaf ``tmap`` over dozens of leaves costs
        more Python time than the byte copies themselves)."""
        rows = [self.row(c) for c in cids]
        treedef = jax.tree.structure(rows[0])
        cols = zip(*(jax.tree.leaves(r) for r in rows))
        return jax.tree.unflatten(treedef, [np.stack(c) for c in cols])

    def scatter(self, cids, stacked) -> None:
        """Write back one row per client from a ``(len(cids), ...)``
        stacked pytree — the host half of a swap-out."""
        leaves, treedef = jax.tree.flatten(stacked)  # host numpy by contract
        for j, c in enumerate(cids):
            self.put(c, jax.tree.unflatten(
                treedef, [x[j].copy() for x in leaves]))


def plan_swap(slot_clients, next_cohort):
    """Diff-based slot assignment: clients staying in the cohort keep
    their slot; leavers' slots are handed to entrants in order.  Returns
    ``(new_slot_clients, evicted_slots, entering_ids)`` — both lists empty
    when the cohort is unchanged (the identity-schedule fast path)."""
    nxt = set(int(c) for c in next_cohort)
    cur = set(int(c) for c in slot_clients)
    evicted = [j for j, c in enumerate(slot_clients) if int(c) not in nxt]
    entering = [int(c) for c in next_cohort if int(c) not in cur]
    new = [int(c) for c in slot_clients]
    for j, c in zip(evicted, entering):
        new[j] = c
    return new, evicted, entering


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    """What to do with planted-late cohort members.

    ``"block"`` (default): the round waits for everyone — late is just
    slow, only explicit ``"drop"`` faults are excluded (and renormalized
    away).  ``"defer"``: a late member's delta ``theta_post - theta_pre``
    is held host-side and merged into the round it arrives in with weight
    ``decay ** staleness`` (staleness in rounds, >= 1); deltas older than
    ``max_staleness`` are discarded.  See docs/scaling.md.
    """

    mode: str = "block"
    decay: float = 0.5
    max_staleness: int = 2

    def validate(self) -> None:
        if self.mode not in ("block", "defer"):
            raise ValueError(f"straggler mode must be 'block' or 'defer', "
                             f"got {self.mode!r}")
        if not 0.0 <= self.decay <= 1.0:
            raise ValueError(f"staleness decay must be in [0, 1], got {self.decay}")
        if self.max_staleness < 1:
            raise ValueError(f"max_staleness must be >= 1, got {self.max_staleness}")


def staleness_scale(staleness: int, policy: StragglerPolicy) -> float:
    """The staleness discount for one delta: ``decay ** staleness``,
    exactly zero past ``max_staleness`` (an expired delta never leaks a
    sub-epsilon contribution).  Shared by the per-round deferred merge
    (:meth:`VirtualClientDriver._fault_round`) and the async buffer
    (``repro.run.async_agg``) so the two paths can never disagree on the
    discount algebra."""
    if staleness < 0:
        raise ValueError(f"staleness must be >= 0, got {staleness}")
    if staleness > policy.max_staleness:
        return 0.0
    # pure host floats: policy fields are Python scalars, never traced
    return float(policy.decay ** staleness)  # analysis: allow(host-sync)


def staleness_weights(staleness, policy: StragglerPolicy,
                      base=None) -> np.ndarray:
    """Normalized merge weights for one async buffer flush.

    ``staleness[i]`` is delta *i*'s age in server versions; ``base``
    (optional, same length) carries the §3.1 dataset-size shares.  Raw
    weight = ``base_i * decay**staleness_i`` (zero past ``max_staleness``),
    normalized to sum to 1 over the surviving deltas — the invariants the
    property suite in tests/test_async_agg.py holds.  All-expired buffers
    normalize to all-zeros (the flush is then a no-op), never to NaN."""
    s = [int(x) for x in staleness]
    raw = np.array([staleness_scale(x, policy) for x in s], np.float64)
    if base is not None:
        b = np.asarray(base, np.float64)  # analysis: allow(host-sync)
        if b.shape != raw.shape:
            raise ValueError(f"base weights shape {b.shape} != "
                             f"staleness shape {raw.shape}")
        if not np.isfinite(b).all() or (b < 0).any():
            raise ValueError("base weights must be finite and >= 0")
        raw = raw * b
    tot = raw.sum()
    if tot > 0:
        raw = raw / tot
    return raw.astype(np.float32)


def _pad_bucket(items):
    """Round a swap list up to the next power-of-two length by repeating
    its first element.  Duplicate gathers read the same row twice and
    duplicate scatters write the same value twice — both no-ops — while
    the jit cache behind the paging ops stays O(log slots) deep instead of
    re-specializing for every distinct swap size."""
    if not items:
        return items
    n = 1
    while n < len(items):
        n *= 2
    return list(items) + [items[0]] * (n - len(items))


def _slot_coords(slots, grid):
    P, A = grid
    idx = np.asarray(slots, np.int32)  # analysis: allow(host-sync) — python slot list, host planning
    return idx // A, idx % A


@dataclasses.dataclass
class VirtualClientDriver:
    """Drives ``n_rounds`` FedGAN rounds over a fleet of
    ``fleet.num_clients`` virtual clients on ``P * A_active`` device slots
    (``fed.cfg.agent_grid == (P, A_active)``).

    ``faults`` is the fault-injection hook for the straggler tests:
    ``faults(round_idx, slot_clients) -> {client_id: "drop" | "late" |
    "late:<k>"}``.  Fault handling (and any deferred-merge accounting)
    runs on a split local-train/host-merge path — when ``faults`` is None
    every round is the same single jitted ``FedGAN.round`` call the dense
    driver makes, which is what the bit-parity and compile-once tests
    hold.  ``weighting`` is ``"uniform"`` (the dense default) or
    ``"dataset"`` (§3.1 ``|R_i| / sum_cohort |R_j|`` from the fleet's true
    shard sizes, passed as a traced argument so cohorts never retrace).
    """

    fed: Any
    fleet: FleetRounds
    n_rounds: int
    schedule: ParticipationSchedule = ParticipationSchedule()
    straggler: StragglerPolicy = StragglerPolicy()
    faults: Callable | None = None
    weighting: str = "uniform"
    log_every: int = 1
    eval_every: int = 0
    eval_hooks: Sequence[Callable] = ()
    ckpt_every: int = 0
    ckpt_dir: str = ""
    verbose: bool = False
    donate: bool = True

    def __post_init__(self):
        P, A = self.fed.cfg.agent_grid
        self._grid = (P, A)
        self._slots = P * A
        if self.fleet.slot_grid != (P, A):
            raise ValueError(f"fleet slot_grid {self.fleet.slot_grid} != "
                             f"fed agent_grid {(P, A)}")
        self.n_total = self.fleet.num_clients
        self.schedule.validate(self.n_total)
        self.straggler.validate()
        if self.weighting not in ("uniform", "dataset"):
            raise ValueError(f"weighting must be 'uniform' or 'dataset', "
                             f"got {self.weighting!r}")
        if self.fed.weights is not None:
            raise ValueError(
                "FedGAN.weights is shaped for a fixed (P, A) grid; under "
                "the virtual scheduler per-round cohort weights come from "
                "weighting='uniform'|'dataset' instead")
        strat = self.fed.cfg.resolve_strategy()
        if getattr(strat, "secure_agg", None) is not None \
                and self.n_total > self._slots:
            raise ValueError(
                "secure_agg= needs every pair's both mask halves on the "
                "wire; a sampled cohort (A_active < A_total) leaves the "
                "absent clients' pad halves uncancelled — run the full "
                "fleet on device (A_total == A_active) or drop secure_agg")
        if self.faults is not None or self.straggler.mode == "defer":
            self._check_mergeable(strat)
        if self.faults is not None and self.ckpt_every:
            raise ValueError(
                "checkpointing a fault-injection run is not supported: "
                "in-flight late deltas are host-side driver state a "
                "checkpoint does not capture")
        if self.eval_every and not self.eval_hooks:
            raise ValueError("eval_every is set but eval_hooks is empty")
        # memoized executables + a trace counter the compile-once test reads
        self._round_jit = None
        self._local_jit = None
        self._merge_jit = None
        self._gather_jit = None
        self._scatter_jit = None
        self.n_traces = 0
        self.store: ClientStore | None = None
        self.slot_clients: list[int] | None = None

    def _check_mergeable(self, strat):
        """The deferred/fault merge recomputes the round average host-side
        with per-round weights; that algebra only matches plain weighted
        FedAvg.  Anything whose sync is not a plain weighted mean of the
        declared subtrees is refused loudly rather than merged wrongly."""
        ok = type(strat) in (sync_strategies.FedAvgSync,
                             sync_strategies.PartialSharing)
        if not ok or strat.codec is not None or strat.sync_dtype is not None \
                or strat.secure_agg is not None \
                or strat.sync_reduce() is not None or strat.average_opt_state:
            raise ValueError(
                f"straggler-tolerant merge supports plain FedAvgSync/"
                f"PartialSharing only (no codec/sync_dtype/secure_agg/"
                f"robust reduce/average_opt_state): a deferred delta "
                f"cannot be replayed through {strat.name!r}'s sync — use "
                f"StragglerPolicy(mode='block') without faults, or "
                f"simplify the strategy")

    # ------------------------------------------------------------------
    def cohort(self, round_idx: int) -> np.ndarray:
        return self.schedule.cohort(round_idx, self.n_total, self._slots)

    def _weights_row(self, slot_clients) -> np.ndarray:
        """Nominal per-slot weight shares (sum 1) for this cohort."""
        if self.weighting == "uniform":
            return np.full(self._slots, 1.0 / self._slots, np.float32)
        sizes = self.fleet.client_sizes()[np.asarray(slot_clients, np.int64)]  # analysis: allow(host-sync)
        return (sizes / sizes.sum()).astype(np.float32)

    # -- jitted executables --------------------------------------------
    def _jit(self, fn, donate=True):
        if donate and self.donate:
            return jax.jit(fn, donate_argnums=0)
        return jax.jit(fn)

    def _round_fn(self):
        if self._round_jit is None:
            if self.weighting == "uniform":
                def fn(st, b, s):
                    self.n_traces += 1
                    return self.fed.round(st, b, s)
            else:
                def fn(st, b, s, w):
                    self.n_traces += 1
                    fed_w = dataclasses.replace(self.fed, weights=w)
                    return fed_w.round(st, b, s)
            self._round_jit = self._jit(fn)
        return self._round_jit

    def _local_fn(self):
        """The LocalOnly twin: K local steps, no sync — the training half
        of the split fault/merge path."""
        if self._local_jit is None:
            cfg = dataclasses.replace(
                self.fed.cfg, strategy=sync_strategies.LocalOnly(), mode="",
                sync_dtype=None, average_opt_state=False)
            fed_local = dataclasses.replace(self.fed, cfg=cfg)

            def fn(st, b, s):
                self.n_traces += 1
                return fed_local.round(st, b, s)

            self._local_jit = self._jit(fn)
        return self._local_jit

    def _merge_fn(self):
        """The aggregation half: theta_bar = sum_i w_on[i] * theta_i +
        extra (the decayed late-delta contribution), broadcast to the
        slots in ``recv`` (on-time participants); everyone else keeps
        local values.  ``w_on``/``extra``/``recv`` are traced, so fault
        patterns never retrace."""
        if self._merge_jit is None:
            subtrees = self.fed.cfg.resolve_strategy().subtrees

            def fn(st, w_on, extra, recv):
                new = dict(st)
                params = dict(st["params"])
                for k in subtrees:
                    def avg1(x, e):
                        row = jnp.einsum("pa,pa...->...",
                                         w_on.astype(x.dtype), x)
                        row = row + e.astype(x.dtype)
                        return jnp.broadcast_to(row, x.shape)
                    merged = tmap(avg1, st["params"][k], extra[k])
                    params[k] = sync_strategies._select(
                        recv, merged, st["params"][k])
                new["params"] = params
                return new

            self._merge_jit = self._jit(fn)
        return self._merge_jit

    # -- paging --------------------------------------------------------
    # The gather/scatter pytrees have dozens of leaves; dispatching them as
    # eager per-leaf ops costs more host time than the round itself, so
    # both directions run as ONE memoized jit (jax's cache re-specializes
    # per row-count; `_pad_bucket` in the run loop rounds swap sizes up to
    # powers of two so that cache stays O(log slots) deep).

    def _fetch_slots(self, state, slots, axes):
        """Device->host: the client-axis rows currently in ``slots``
        (stacked pytree, leading len(slots))."""
        pp, aa = _slot_coords(slots, self._grid)
        if self._gather_jit is None:
            def gather(st, pp, aa, keys):
                return {k: tmap(lambda x: x[pp, aa], st[k]) for k in keys}
            self._gather_jit = jax.jit(gather, static_argnames=("keys",))
        keys = tuple(sorted(k for k, ax in axes.items() if ax == "client"))
        gathered = self._gather_jit(state, pp, aa, keys=keys)
        # swap-out: synchronizes on the in-flight round's result, which is
        # exactly the dependency — the evicted rows must be post-round
        return jax.device_get(gathered)  # analysis: allow(host-sync)

    def _stage_rows(self, entering):
        """Host->device upload of entering clients' rows (async — overlaps
        the in-flight round's compute)."""
        return jax.device_put(self.store.gather(entering))

    def _apply_swap(self, state, slots, staged, axes):
        """Scatter staged rows into their device slots."""
        pp, aa = _slot_coords(slots, self._grid)
        if self._scatter_jit is None:
            def scatter(st, pp, aa, staged, keys):
                new = dict(st)
                for k in keys:
                    new[k] = tmap(
                        lambda x, r: x.at[pp, aa].set(r.astype(x.dtype)),
                        st[k], staged[k])
                return new
            self._scatter_jit = jax.jit(scatter, static_argnames=("keys",))
        keys = tuple(sorted(k for k, ax in axes.items()
                            if ax == "client" and k in staged))
        return self._scatter_jit(state, pp, aa,
                                 {k: staged[k] for k in keys}, keys=keys)

    def flush(self, state) -> None:
        """Persist every resident slot row into the store (end of run /
        checkpoint boundary) so the host fleet view is complete."""
        axes = state_axes(self.fed, state)
        rows = self._fetch_slots(state, list(range(self._slots)), axes)
        self.store.scatter(self.slot_clients, rows)

    # ------------------------------------------------------------------
    def run(self, rng, state=None, *, start_round: int = 0,
            store=None, slot_clients=None) -> RunResult:
        """Run rounds ``start_round .. n_rounds-1``.  ``rng`` is the run's
        root key: the data-key schedule is derived from ``split(rng)[0]``
        and the init from ``split(rng)[1]`` (the dense driver's exact
        derivation), so a resumed run — same root ``rng``, restored
        ``state``/``store``/``slot_clients``, ``start_round`` from the
        checkpoint — replays the uninterrupted run's cohorts and batches
        identically."""
        if not 0 <= start_round < self.n_rounds:
            raise ValueError(f"start_round {start_round} outside "
                             f"[0, {self.n_rounds})")
        data_rng, init_rng = jax.random.split(rng)
        if state is None:
            state = self.fed.init_state(init_rng)
            store = ClientStore.from_fed(self.fed, init_rng, self.n_total)
        if store is not None:
            self.store = store
        if self.store is None:
            raise ValueError("pass store= (a ClientStore) when resuming "
                             "from an explicit state")
        axes = state_axes(self.fed, state)
        keys = round_key_schedule(data_rng, self.n_rounds)[start_round:]

        # initial cohort: fresh slots are interchangeable (every client is
        # still the init template), so assignment is free; a resumed run
        # swaps from the checkpointed assignment to this round's cohort
        first = self.cohort(start_round)
        if slot_clients is None:
            self.slot_clients = [int(c) for c in first]
        else:
            self.slot_clients, evicted, entering = plan_swap(slot_clients,
                                                             first)
            if evicted:
                ev, en = _pad_bucket(evicted), _pad_bucket(entering)
                rows = self._fetch_slots(state, ev, axes)
                self.store.scatter([slot_clients[j] for j in ev], rows)
                state = self._apply_swap(state, ev,
                                         self._stage_rows(en), axes)

        self._evals = []
        history = []
        pending = []   # (client_id, delta_row, submit_round, arrival_round, w_share)
        stats = {"swapped_rows": 0, "late": 0, "dropped": 0,
                 "merged_deltas": 0, "expired_deltas": 0}
        gap = 0.0
        t0 = time.perf_counter()
        t_host = time.perf_counter()

        batches = self.fleet.round_batches(keys[0], self.slot_clients)
        staged = None
        for i, r in enumerate(range(start_round, self.n_rounds)):
            b, s = batches
            if self.faults is None:
                gap += time.perf_counter() - t_host
                if self.weighting == "uniform":
                    state, metrics = self._round_fn()(state, b, s)
                else:
                    w = jnp.asarray(
                        self._weights_row(self.slot_clients)).reshape(self._grid)
                    state, metrics = self._round_fn()(state, b, s, w)
                t_host = time.perf_counter()
            else:
                state, metrics, pending = self._fault_round(
                    r, state, b, s, pending, axes, stats)
            history.append(tmap(jnp.mean, metrics))

            # overlap: stage next round's batches + entering rows while
            # this round's result is still in flight
            nxt = None
            if r + 1 < self.n_rounds:
                nxt_cohort = self.cohort(r + 1)
                new_slots, evicted, entering = plan_swap(self.slot_clients,
                                                         nxt_cohort)
                staged = (self._stage_rows(_pad_bucket(entering))
                          if entering else None)
                batches = self.fleet.round_batches(keys[i + 1], new_slots)
                nxt = (new_slots, evicted, entering)

            state = self._boundaries(state, r, history[-1])

            if nxt is not None:
                new_slots, evicted, entering = nxt
                if evicted:
                    ev = _pad_bucket(evicted)
                    rows = self._fetch_slots(state, ev, axes)
                    self.store.scatter(
                        [self.slot_clients[j] for j in ev], rows)
                    state = self._apply_swap(state, ev, staged, axes)
                    stats["swapped_rows"] += len(evicted)
                self.slot_clients = new_slots

        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        gap += time.perf_counter() - t_host
        total = time.perf_counter() - t0
        self.flush(state)
        n_run = self.n_rounds - start_round
        K = self.fed.cfg.sync_interval
        timings = {
            "total_s": total,
            "steps_per_s": n_run * K / max(total, 1e-9),
            "rounds_per_s": n_run / max(total, 1e-9),
            "round_gap_s": gap / max(n_run, 1),
            "data_kind": "virtual",
            "a_total": self.n_total,
            "a_active": self._slots,
            "store_rows": self.store.materialized,
            **stats,
        }
        history = [tmap(float, m) for m in history]
        return RunResult(self.fed, state, history, self._evals, timings)

    # -- straggler / fault path ----------------------------------------
    def _parse_fault(self, kind: str) -> tuple[str, int]:
        if kind == "drop":
            return "drop", 0
        if kind == "late":
            return "late", 1
        if kind.startswith("late:"):
            return "late", int(kind.split(":", 1)[1])
        raise ValueError(f"unknown fault {kind!r}; use 'drop', 'late' or "
                         "'late:<rounds>'")

    def _fault_round(self, r, state, b, s, pending, axes, stats):
        """One round on the split path: K local steps (no sync), then the
        host-orchestrated merge that excludes drops, defers late deltas
        and folds in pending ones — docs/scaling.md gives the algebra."""
        faults = {int(c): self._parse_fault(k)
                  for c, k in (self.faults(r, list(self.slot_clients)) or {}).items()}
        unknown = sorted(set(faults) - set(self.slot_clients))
        if unknown:
            raise ValueError(f"faults for clients {unknown} not in this "
                             f"round's cohort {self.slot_clients}")
        if faults and self.straggler.mode == "block":
            # blocking mode waits for the late — only drops are excluded
            faults = {c: (m, d) for c, (m, d) in faults.items() if m == "drop"}
        slot_of = {c: j for j, c in enumerate(self.slot_clients)}
        fault_slots = [slot_of[c] for c in sorted(faults)]
        pre = (self._fetch_slots(state, fault_slots, axes)
               if fault_slots else None)

        state, metrics = self._local_fn()(state, b, s)

        w_row = self._weights_row(self.slot_clients)
        on_time = np.ones(self._slots, bool)
        post_fault = (self._fetch_slots(state, fault_slots, axes)
                      if fault_slots else None)
        revert_slots = []
        for j, c in enumerate(sorted(faults)):
            mode, delay = faults[c]
            slot = fault_slots[j]
            on_time[slot] = False
            pre_row = tmap(lambda x: x[j], pre)
            post_row = tmap(lambda x: x[j], post_fault)
            if mode == "drop":
                stats["dropped"] += 1
                # never completed the round: state unchanged, on host and
                # in its device slot
                self.store.put(c, pre_row)
                revert_slots.append((slot, pre_row))
            else:
                stats["late"] += 1
                # trained but the delta arrives `delay` rounds from now;
                # the client itself keeps its local trained state (it
                # never receives this round's broadcast)
                self.store.put(c, post_row)
                delta = tmap(np.subtract, post_row["params"],
                             pre_row["params"])
                # w_row is host numpy (never traced) — no device sync here
                pending.append((c, delta, r, r + delay, float(w_row[slot])))  # analysis: allow(host-sync)

        # drain pending deltas that arrive this round
        strat = self.fed.cfg.resolve_strategy()
        extra = {k: tmap(lambda x: np.zeros(x.shape[2:], np.float32),
                         state["params"][k]) for k in strat.subtrees}
        still = []
        for (c, delta, submitted, arrival, w_share) in pending:
            if arrival > r:
                still.append((c, delta, submitted, arrival, w_share))
                continue
            staleness = r - submitted
            if staleness > self.straggler.max_staleness:
                stats["expired_deltas"] += 1
                continue
            stats["merged_deltas"] += 1
            scale = w_share * staleness_scale(staleness, self.straggler)
            for k in strat.subtrees:
                extra[k] = tmap(lambda e, d: e + scale * d,
                                extra[k], delta[k])

        if not on_time.any():
            raise ValueError(f"round {r}: every cohort member faulted — "
                             "no on-time participants to average")
        w_on = w_row * on_time
        w_on = (w_on / w_on.sum()).reshape(self._grid)
        recv = jnp.asarray(on_time.reshape(self._grid))
        state = self._merge_fn()(state, jnp.asarray(w_on),
                                 jax.device_put(extra), recv)
        for slot, row in revert_slots:
            staged = tmap(lambda x: x[None], row)
            state = self._apply_swap(state, [slot], staged, axes)
        return state, metrics, still

    # -- boundaries ----------------------------------------------------
    def _boundaries(self, state, r, metrics_dev):
        K = self.fed.cfg.sync_interval
        last = r == self.n_rounds - 1
        if self.log_every and self.verbose and (r % self.log_every == 0 or last):
            m = tmap(float, metrics_dev)  # analysis: allow(host-sync)
            d, g = m.get("d_loss"), m.get("g_loss")
            head = self.slot_clients[:8]
            tail = "" if len(self.slot_clients) <= 8 else \
                f" +{len(self.slot_clients) - 8}"
            print(f"round {r:5d}/{self.n_rounds} step {(r + 1) * K:6d} "
                  f"d_loss={d:.4f} g_loss={g:.4f} "
                  f"cohort={head}{tail}", flush=True)
        if self.eval_every and ((r + 1) % self.eval_every == 0 or last):
            scores = {}
            for hook in self.eval_hooks:
                scores.update(hook(self.fed, state, r))
            self._evals.append({"round": r, "step": (r + 1) * K, **scores})
        if self.ckpt_dir and self.ckpt_every and (r + 1) % self.ckpt_every == 0:
            self.save_fleet_checkpoint(self.ckpt_dir, state, r)
        return state

    # -- checkpointing -------------------------------------------------
    def save_fleet_checkpoint(self, directory: str, state, r: int) -> str:
        """One checkpoint = the device slot state + the *whole* host fleet
        (materialized rows + template).  The participation RNG needs no
        state beyond (seed, round): the schedule is stateless, which is
        what makes resume replay the exact cohort sequence."""
        self.flush(state)
        payload = {
            "device": state,
            "template": self.store.template,
            "fleet": {str(c): self.store._rows[c]
                      for c in self.store.client_ids()},
        }
        meta = {
            "round": r,
            "K": self.fed.cfg.sync_interval,
            "virtual": True,
            "a_total": self.n_total,
            "slot_clients": [int(c) for c in self.slot_clients],
            "participation_seed": self.schedule.seed,
        }
        return save_checkpoint(directory, payload,
                               step=(r + 1) * self.fed.cfg.sync_interval,
                               metadata=meta)


def load_fleet_checkpoint(directory: str, *, step: int | None = None):
    """Restore a virtual-client checkpoint: ``(state, store, slot_clients,
    next_round, metadata)``.  Fleet rows stay host-side numpy; only the
    ``(P, A_active)`` slot state goes back to device."""
    payload, manifest = restore_checkpoint(directory, step=step,
                                           to_device=False)
    meta = manifest["metadata"]
    if not meta.get("virtual"):
        raise ValueError(f"{directory} is not a virtual-client checkpoint "
                         "(no fleet state); use restore_checkpoint")
    state = tmap(jnp.asarray, payload["device"])
    store = ClientStore(payload["template"], meta["a_total"])
    for cid, row in payload["fleet"].items():
        store.put(int(cid), row)
    return (state, store, list(meta["slot_clients"]),
            int(meta["round"]) + 1, meta)
