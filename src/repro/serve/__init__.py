"""repro.serve — continuous-batching generator serving.

The repo's third pillar (dist → strategies → serve): the FedGAN end product
is the synced generator, and this package is what actually serves it —
a :class:`ServeEngine` with bounded compiled executables, a continuous
:class:`Batcher`, formalized KV-cache layouts (:mod:`repro.serve.cache`)
and hot-reload of training checkpoints (:mod:`repro.serve.reload`).
Operator guide: docs/serving.md.
"""
from repro.serve.batcher import Batcher, Request
from repro.serve.cache import (CacheLayout, insert_slot, make_buckets,
                               plan_layout, prefill_bucket, ring_index_map)
from repro.serve.engine import EngineStats, ServeEngine
from repro.serve.reload import CheckpointWatcher, generator_from_state

__all__ = [
    "Batcher", "CacheLayout", "CheckpointWatcher", "EngineStats", "Request",
    "ServeEngine", "generator_from_state", "insert_slot", "make_buckets",
    "plan_layout", "prefill_bucket", "ring_index_map",
]
