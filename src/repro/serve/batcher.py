"""Continuous-batching request queue.

The engine owns a fixed grid of ``max_slots`` batch slots (one decode cache
row each).  Requests queue FIFO; every tick the engine

  1. evicts finished requests (freeing their slots),
  2. admits queued requests into free slots (one bucketed prefill each),
  3. runs ONE decode step for all active slots at their own positions.

The batcher is pure bookkeeping — no jax — so its invariants (a request is
admitted exactly once, occupancy never exceeds ``max_slots``, eviction
frees exactly the finished slots, FIFO admission order) are testable
without compiling anything.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Optional


@dataclasses.dataclass
class Request:
    """One generation request and its runtime state."""

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    temperature: float = 0.0
    frames: Any = None                 # audio family: (S_enc, d_model) frames
    stop_tokens: frozenset = frozenset()

    # runtime state, owned by the batcher/engine
    generated: list = dataclasses.field(default_factory=list)
    pending: list = dataclasses.field(default_factory=list)
    # ^ prompt tokens not yet consumed — chunked prefill for exact-length
    #   families feeds these through the shared decode step
    slot: int = -1
    position: int = -1                 # next cache index this request writes
    status: str = "queued"             # queued | active | done
    stopped: bool = False              # hit a stop token

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.stopped or len(self.generated) >= self.max_new_tokens


class Batcher:
    """Slot allocator + FIFO queue for continuous batching."""

    def __init__(self, max_slots: int):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self.slots: list[Optional[Request]] = [None] * max_slots
        self.queue: collections.deque[Request] = collections.deque()
        self._rids = itertools.count()

    def submit(self, req: Request) -> int:
        req.rid = next(self._rids)
        req.status = "queued"
        self.queue.append(req)
        return req.rid

    def evict(self) -> list[Request]:
        """Free the slots of finished requests; returns them."""
        out = []
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                r.status = "done"
                self.slots[i] = None
                out.append(r)
        return out

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue (FIFO); returns (slot, request)
        pairs for the engine to prefill."""
        out = []
        for i in range(self.max_slots):
            if self.slots[i] is None and self.queue:
                r = self.queue.popleft()
                r.slot, r.status = i, "active"
                self.slots[i] = r
                out.append((i, r))
        return out

    def active(self) -> list[tuple[int, Request]]:
        """Slots that should take part in the next decode step."""
        return [(i, r) for i, r in enumerate(self.slots)
                if r is not None and not r.done]

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def occupancy(self) -> float:
        return sum(r is not None for r in self.slots) / self.max_slots
