"""KV/SSM-cache layouts for serving, and per-slot cache surgery.

``Backbone.init_cache`` allocates one batch-wide decode cache whose leaves
come in four kinds (all with arbitrary leading layer-stack dims):

  k/v    attention keys/values  (..., B, S, n_kv, head_dim)
         S = max_seq ("full" layout) or the sliding window W ("ring")
  pos    ring-buffer positions  (..., B, W) int32, -1 = empty slot
  ssm    Mamba2 recurrent state (..., B, n_heads, head_dim, d_state)
  conv_* causal-conv tail       (..., B, conv_kernel-1, channels)

This module formalizes those layouts (:class:`CacheLayout`), the bucketing
policy that keeps the number of compiled prefill executables bounded
(:func:`make_buckets` / :func:`prefill_bucket`), and the one mutation the
continuous batcher needs: :func:`insert_slot`, which writes a single
request's batch-1 prefill cache into slot ``b`` of the live batch cache —
including the full→ring conversion for windowed layers.  The old
``examples/serve_generator.py`` did all of this ad hoc (and reached into
``Backbone._block``); the engine now goes exclusively through this module
and the public ``Backbone`` cache API.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

# Where the batch dim sits in each cache-leaf kind (negative = from the end).
BATCH_AXIS = {"k": -4, "v": -4, "pos": -2, "ssm": -4,
              "conv_x": -3, "conv_b": -3, "conv_c": -3}
SEQ_AXIS = -3  # k/v only

# Families whose prefill carries recurrent state (SSM/conv tails) or
# capacity-limited routing: right-padding the prompt would corrupt the state
# (pad tokens flow through the recurrence) or perturb expert capacity, so
# these prefill at the exact prompt length instead of a padded bucket.
EXACT_PREFILL_FAMILIES = ("ssm", "hybrid", "moe")


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """What the batch cache holds per attention layer."""

    kind: str             # "full" | "ring"
    max_seq: int          # decode-cache capacity per slot (full layout)
    window: int = 0       # ring width for windowed layers (ring layout)

    @property
    def ring(self) -> bool:
        return self.kind == "ring"


def plan_layout(cfg: ArchConfig, max_seq: int, *, ring: bool = False) -> CacheLayout:
    """The layout ``Backbone(cfg, ring_cache=ring).init_cache(B, max_seq)``
    allocates.  Ring caches require sliding-window attention (a full-context
    layer cannot be O(W))."""
    if ring:
        if cfg.sliding_window <= 0:
            raise ValueError(
                f"{cfg.name}: ring caches need sliding_window > 0 "
                "(a full-attention layer cannot be window-bounded)")
        return CacheLayout("ring", max_seq, min(cfg.sliding_window, max_seq))
    return CacheLayout("full", max_seq)


def make_buckets(min_bucket: int, max_seq: int) -> tuple[int, ...]:
    """Power-of-two prompt-length ladder: min_bucket, 2·min_bucket, ...,
    capped at max_seq.  |buckets| prefill compiles bound the engine's total
    executable count."""
    if min_bucket < 1 or max_seq < min_bucket:
        raise ValueError(f"bad bucket range [{min_bucket}, {max_seq}]")
    out = []
    b = min_bucket
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


def prefill_bucket(cfg: ArchConfig, prompt_len: int, buckets: tuple[int, ...]) -> int:
    """Padded prefill length for a prompt.  Attention-cache families pad up
    to the next bucket (decode masks the padded positions, and the first
    real decode write lands on top of the first pad slot); recurrent-state
    families must prefill exact-length — see EXACT_PREFILL_FAMILIES."""
    if cfg.family in EXACT_PREFILL_FAMILIES:
        return prefill_prefix(cfg, prompt_len)
    for b in buckets:
        if b >= prompt_len:
            return b
    raise ValueError(f"prompt of {prompt_len} tokens exceeds the largest "
                     f"bucket {buckets[-1]}")


def prefill_prefix(cfg: ArchConfig, prompt_len: int) -> int:
    """Longest prompt prefix an exact-length family can prefill in one shot.

    SSM/hybrid forwards run the chunked SSD scan, so the prefix must be a
    multiple of ``ssm_chunk``; MoE dispatch reshapes tokens into
    ``moe_group_size`` groups, so ditto (and padding would perturb expert
    capacity for the real tokens anyway).  Either way the prefix can be 0
    for very short prompts; the engine feeds the remaining prompt tokens
    through the shared decode step ("chunked prefill"), which threads the
    recurrent state / routing exactly."""
    if cfg.family in ("ssm", "hybrid"):
        return (prompt_len // cfg.ssm_chunk) * cfg.ssm_chunk
    if cfg.family == "moe":
        return (prompt_len // cfg.moe_group_size) * cfg.moe_group_size
    return prompt_len


def ring_index_map(prompt_len: int, window: int):
    """(gather, pos) mapping a full-layout prefill cache into ring order.

    Ring slot ``s`` holds position ``p ≡ s (mod W)``; after a T-token
    prefill the live window is positions [max(T-W, 0), T).  ``gather`` are
    the source sequence indices to read from the full cache (clipped in
    range; dead slots re-read position T-1 and are masked by ``pos``), and
    ``pos`` is the per-slot position row (-1 = empty)."""
    base = max(prompt_len - window, 0)
    s = jnp.arange(window)
    src = base + jnp.mod(s - base, window)
    pos = jnp.where(src < prompt_len, src, -1)
    return jnp.minimum(src, prompt_len - 1), pos


def _slot_write(dst, src, slot, key):
    """Write ``src`` (batch dim of size 1) into batch index ``slot`` of
    ``dst``; all other dims write from offset 0 (so a Tb-long prefill k/v
    fills the [0, Tb) prefix of a max_seq-long destination)."""
    axis = dst.ndim + BATCH_AXIS[key]
    starts = [0] * dst.ndim
    starts[axis] = slot
    return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), tuple(starts))


def _insert_attn_node(dst, src, slot, prompt_len):
    """One attention cache node ({"k","v"} or ring {"k","v","pos"}).  The
    source is always the full-layout batch-1 cache prefill produced; a ring
    destination consumes its last-window suffix."""
    out = dict(dst)
    if "pos" in dst:
        if "pos" in src:
            # same (ring) layout on both sides — e.g. a fresh init_cache row
            # resetting the slot: write the rows straight through
            return {key: _slot_write(dst[key], src[key], slot, key)
                    for key in dst}
        W = dst["k"].shape[SEQ_AXIS]
        gather, pos = ring_index_map(prompt_len, W)
        for key in ("k", "v"):
            row = jnp.take(src[key], gather, axis=SEQ_AXIS)
            out[key] = _slot_write(dst[key], row, slot, key)
        posrow = jnp.broadcast_to(pos, dst["pos"].shape[:-2] + (1, W))
        out["pos"] = _slot_write(dst["pos"], posrow, slot, "pos")
        return out
    for key in ("k", "v"):
        if src[key].shape[SEQ_AXIS] > dst[key].shape[SEQ_AXIS]:
            raise ValueError(
                f"prefill cache seq {src[key].shape[SEQ_AXIS]} exceeds the "
                f"batch cache capacity {dst[key].shape[SEQ_AXIS]}")
        out[key] = _slot_write(dst[key], src[key], slot, key)
    return out


def insert_slot(cache, request_cache, slot: int, *, prompt_len: int):
    """Write one request's batch-1 prefill cache into batch slot ``slot`` of
    the live cache.  Attention nodes are handled as a unit (full→ring
    conversion needs k, v and pos together); ssm/conv state rows are written
    whole.  Everything the previous occupant (or idle decode garbage) left
    in positions the new request will attend to is overwritten; positions
    beyond the prompt stay masked until decode writes reach them."""
    def walk(d, s, key=""):
        if isinstance(d, dict):
            if "k" in d and "v" in d:
                return _insert_attn_node(d, s, slot, prompt_len)
            return {k2: walk(d[k2], s[k2], k2) for k2 in d}
        if isinstance(d, (list, tuple)):
            return type(d)(walk(a, b, key) for a, b in zip(d, s))
        return _slot_write(d, s, slot, key)

    return walk(cache, request_cache)
