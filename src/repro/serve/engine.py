"""ServeEngine: continuous-batching generation over a ``Backbone``.

One engine owns

  * a fixed decode cache of ``max_batch`` slots x ``max_seq`` positions
    (ring-width for windowed layers under ``ring=True``),
  * compiled executables, keyed by (backbone, bucketed input shape): ONE
    decode executable at (max_batch, 1), and one prefill executable per
    prompt-length bucket — the executable cache is module-level, so two
    engines over the same arch share compilations,
  * a :class:`~repro.serve.batcher.Batcher` admitting queued requests into
    free slots each tick and evicting finished ones,
  * optionally a :class:`~repro.serve.reload.CheckpointWatcher` that swaps
    in newer generator params between ticks (same shapes — no recompile).

Every slot decodes at its *own* sequence position (``Backbone.decode``
takes a (B,) index vector), which is what lets a new request start while
its neighbours are mid-generation.  See docs/serving.md for the operator
view: lifecycle, bucketing model, hot-reload semantics, capacity planning.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.transformer import Backbone
from repro.serve.batcher import Batcher, Request
from repro.serve.cache import (insert_slot, make_buckets, plan_layout,
                               prefill_bucket)
from repro.serve.reload import CheckpointWatcher


@functools.lru_cache(maxsize=None)
def _decode_exec(bb: Backbone):
    # Donate the cache so XLA updates it in place instead of copying the
    # dominant serving buffer every tick (the engine drops its reference on
    # reassignment).  CPU lacks donation support and would warn every call.
    donate = (2,) if jax.default_backend() != "cpu" else ()
    return jax.jit(bb.decode, donate_argnums=donate)


@functools.lru_cache(maxsize=None)
def _prefill_exec(bb: Backbone):
    """Bucketed prefill: forward the padded prompt, gather the hidden state
    at the last REAL token (``last``), project only that row to logits."""

    def fn(params, toks, last, frames=None):
        out = bb.prefill(params, toks, encoder_frames=frames,
                         logits_mode="none")
        h = jax.lax.dynamic_index_in_dim(out["hidden"], last, axis=1,
                                         keepdims=True)
        return bb.project_logits(params, h), out["cache"]

    return jax.jit(fn)


@dataclasses.dataclass
class EngineStats:
    """Operational counters a bench or operator dashboard reads.

    Per-tick samples live in bounded deques (recent-window percentiles);
    throughput/occupancy come from running aggregates, so a server ticking
    indefinitely holds O(1) memory."""

    WINDOW = 4096

    ticks: int = 0
    prefills: int = 0
    reloads: int = 0
    decode_tokens: int = 0
    decode_ticks: int = 0
    total_tick_seconds: float = 0.0
    total_active: int = 0
    prefill_buckets: set = dataclasses.field(default_factory=set)
    tick_seconds: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=EngineStats.WINDOW))
    tick_active: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=EngineStats.WINDOW))
    prefill_seconds: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=EngineStats.WINDOW))

    def record_decode(self, seconds: float, active: int) -> None:
        self.decode_tokens += active
        self.decode_ticks += 1
        self.total_tick_seconds += seconds
        self.total_active += active
        self.tick_seconds.append(seconds)
        self.tick_active.append(active)

    def tick_ms(self, q: float) -> float:
        """q-th percentile decode-tick latency in ms (q in [0, 100]), over
        the last WINDOW ticks."""
        if not self.tick_seconds:
            return 0.0
        xs = sorted(self.tick_seconds)
        i = min(int(round(q / 100 * (len(xs) - 1))), len(xs) - 1)
        return xs[i] * 1e3

    def tokens_per_sec(self) -> float:
        if self.total_tick_seconds <= 0:
            return 0.0
        return self.decode_tokens / self.total_tick_seconds

    def mean_occupancy(self, max_batch: int) -> float:
        if not self.decode_ticks:
            return 0.0
        return self.total_active / (self.decode_ticks * max_batch)


class ServeEngine:
    """Continuous-batching serving of one generator architecture."""

    def __init__(self, cfg: ArchConfig, *, max_batch: int = 4,
                 max_seq: int = 256, ring: bool = False,
                 params=None, rng_seed: int = 0, min_bucket: int = 16,
                 ckpt_dir: str = "", ckpt_extract=None, reload_every: int = 1,
                 mesh=None):
        self.cfg = cfg
        self.bb = Backbone(cfg, ring_cache=ring)
        self.layout = plan_layout(cfg, max_seq, ring=ring)
        self.max_batch, self.max_seq = max_batch, max_seq
        self.buckets = make_buckets(min(min_bucket, max_seq), max_seq)
        self.batcher = Batcher(max_batch)
        self.mesh = mesh
        self.stats = EngineStats()
        self.reload_every = max(reload_every, 1)
        self.loaded_step: Optional[int] = None
        self._rng = np.random.default_rng(rng_seed)
        self._tokens = np.zeros((max_batch,), np.int32)
        self._indices = np.zeros((max_batch,), np.int32)

        self.watcher = None
        if ckpt_dir:
            self.watcher = CheckpointWatcher(ckpt_dir, extract=ckpt_extract)

        with self._on_mesh():
            if params is None and self.watcher is not None:
                got = self.watcher.poll()
                if got is not None:
                    params, self.loaded_step = got
            if params is None:
                params = self.bb.init(jax.random.key(rng_seed))
            self.params = self._place_params(params)
            self.cache = self._place_cache(self.bb.init_cache(max_batch, max_seq))
        self._param_shapes = jax.tree_util.tree_map(jnp.shape, self.params)

    # ---- sharded-serving plumbing -----------------------------------------
    @contextlib.contextmanager
    def _on_mesh(self):
        if self.mesh is None:
            yield
        else:
            with jax.set_mesh(self.mesh):
                yield

    def _place_params(self, params):
        if self.mesh is None:
            return params
        from repro.dist.sharding import named_shardings, param_specs
        specs = param_specs(params, self.mesh)
        return jax.device_put(params, named_shardings(self.mesh, specs))

    def _place_cache(self, cache):
        if self.mesh is None:
            return cache
        from repro.dist.sharding import named_shardings
        from repro.launch.steps import cache_specs
        specs = cache_specs(cache, self.mesh, batch=self.max_batch)
        return jax.device_put(cache, named_shardings(self.mesh, specs))

    # ---- request intake ----------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, temperature: float = 0.0,
               frames=None, stop_tokens=()) -> int:
        prompt = tuple(int(t) for t in np.asarray(prompt).reshape(-1))
        if not prompt:
            raise ValueError("empty prompt")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the engine's max_seq {self.max_seq}")
        if self.cfg.family == "audio" and frames is None:
            raise ValueError("audio family requests need encoder frames")
        req = Request(rid=-1, prompt=prompt, max_new_tokens=max_new_tokens,
                      temperature=temperature, frames=frames,
                      stop_tokens=frozenset(stop_tokens))
        return self.batcher.submit(req)

    # ---- hot reload --------------------------------------------------------
    def maybe_reload(self) -> bool:
        if self.watcher is None or self.stats.ticks % self.reload_every:
            return False
        got = self.watcher.poll()
        if got is None:
            return False
        params, step = got
        try:
            same = (jax.tree_util.tree_map(jnp.shape, params)
                    == self._param_shapes)
        except ValueError:  # tree structures differ
            same = False
        if not same:
            raise RuntimeError(
                f"checkpoint step {step} params tree does not match the "
                f"serving arch {self.cfg.name} — wrong --ckpt-dir or config?")
        with self._on_mesh():
            self.params = self._place_params(params)
        self.loaded_step = step
        self.stats.reloads += 1
        return True

    # ---- one tick ----------------------------------------------------------
    def tick(self) -> list[Request]:
        """Evict finished requests, admit queued ones (prefill), run one
        decode step for all active slots.  Returns the evicted requests."""
        self.maybe_reload()
        finished = self.batcher.evict()
        self.stats.ticks += 1
        with self._on_mesh():
            for slot, req in self.batcher.admit():
                self._prefill_into(slot, req)
            active = self.batcher.active()
            if active:
                self._decode_tick(active)
        return finished

    def run(self, *, max_ticks: int = 1_000_000) -> dict[int, Request]:
        """Tick until every submitted request is finished; returns
        {rid: request} for all evicted requests."""
        done: dict[int, Request] = {}
        ticks = 0
        while self.batcher.has_work:
            if ticks >= max_ticks:
                raise RuntimeError(f"not drained after {max_ticks} ticks")
            ticks += 1
            for req in self.tick():
                done[req.rid] = req
        return done

    # ---- internals ---------------------------------------------------------
    def _prefill_into(self, slot: int, req: Request) -> None:
        """Bucketed (attention families) or exact-prefix (recurrent-state
        families) prefill, written into the request's batch slot.  Any prompt
        tokens beyond the prefix land in ``req.pending`` and are fed through
        the shared decode step — chunked prefill, which threads SSM state
        exactly instead of corrupting it with pad tokens."""
        t0 = time.perf_counter()
        T = req.prompt_len
        Tb = prefill_bucket(self.cfg, T, self.buckets)
        req.pending = list(req.prompt[Tb:])  # empty for bucketed families
        if Tb == 0:
            # prompt shorter than one SSD chunk: reset the slot to fresh
            # state and feed the whole prompt through decode
            fresh = self.bb.init_cache(1, self.max_seq)
            self.cache = insert_slot(self.cache, fresh, slot, prompt_len=0)
            req.position = 0
            self._tokens[slot] = req.pending.pop(0)
            self._indices[slot] = 0
        else:
            toks = np.zeros((1, Tb), np.int32)
            n = min(T, Tb)
            toks[0, :n] = req.prompt[:n]
            args = [self.params, jnp.asarray(toks), jnp.int32(n - 1)]
            if req.frames is not None:
                args.append(jnp.asarray(req.frames)[None])
            logits, req_cache = _prefill_exec(self.bb)(*args)
            self.cache = insert_slot(self.cache, req_cache, slot, prompt_len=n)
            req.position = n
            self._indices[slot] = n
            if req.pending:
                self._tokens[slot] = req.pending.pop(0)
            else:
                tok = self._sample(logits[0, 0], req)
                req.generated.append(tok)
                self._tokens[slot] = tok
        self.stats.prefills += 1
        self.stats.prefill_buckets.add(Tb)
        self.stats.prefill_seconds.append(time.perf_counter() - t0)

    def _decode_tick(self, active) -> None:
        t0 = time.perf_counter()
        logits, self.cache = _decode_exec(self.bb)(
            self.params, jnp.asarray(self._tokens)[:, None], self.cache,
            jnp.asarray(self._indices))
        logits = jax.device_get(logits)
        for slot, req in active:
            req.position += 1
            self._indices[slot] += 1
            if req.pending:
                # still consuming the prompt (chunked prefill): feed the
                # next known token, ignore the logits
                self._tokens[slot] = req.pending.pop(0)
                continue
            tok = self._sample(logits[slot, 0], req)
            req.generated.append(tok)
            if tok in req.stop_tokens:
                req.stopped = True
            self._tokens[slot] = tok
        self.stats.record_decode(time.perf_counter() - t0, len(active))

    def _sample(self, row, req: Request) -> int:
        """Host-side sampling on the already-fetched logits row — no device
        round-trips on the per-slot decode hot loop."""
        row = np.asarray(row)[: self.cfg.vocab_size]  # mask vocab padding
        if req.temperature <= 0:
            return int(row.argmax())
        g = self._rng.gumbel(size=row.shape)  # Gumbel-max == categorical
        return int((row / req.temperature + g).argmax())
