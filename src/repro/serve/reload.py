"""Hot-reload: pick up newer generator checkpoints between decode ticks.

A FedGAN run training in one process (``launch/train.py --ckpt-dir ...``)
is servable live from another: the trainer's ``save_checkpoint`` writes the
step directory first and atomically repoints ``LATEST`` last (temp file +
``os.replace``), so a poll here either sees the previous complete
checkpoint or the new complete one.  ``CheckpointWatcher.poll`` is cheap
(one small file read) when nothing changed; array IO only happens when a
newer step appears.  In-flight requests keep their KV caches — only the
weights swap, which is exactly the FedGAN semantics: the synced generator
is a drop-in replacement of the same shapes, so nothing recompiles.
"""
from __future__ import annotations

import warnings

import jax

from repro.checkpoint import read_latest_step, restore_checkpoint


def generator_from_state(state, agent: tuple[int, int] = (0, 0)):
    """Extract one agent's generator params from a FedGAN train state.

    Train checkpoints hold every leaf with a leading (P, A) agent grid;
    after a sync all agents are identical, so serving reads agent (0, 0) by
    default."""
    gen = state["params"]["gen"]
    return jax.tree_util.tree_map(lambda x: x[agent], gen)


class CheckpointWatcher:
    """Polls a checkpoint directory for steps newer than the last one seen.

    ``extract`` maps the restored state to the params tree the engine
    serves (default: :func:`generator_from_state` for FedGAN train states;
    pass ``lambda s: s`` for raw Backbone params checkpoints).
    """

    def __init__(self, directory: str, *, extract=None, start_step: int = -1):
        self.directory = directory
        self.extract = extract if extract is not None else generator_from_state
        self.seen_step = start_step
        self._bad_step = None  # step whose extract failed deterministically

    def poll(self):
        """(params, step) when a newer complete checkpoint exists, else
        None.  A checkpoint mid-write never surfaces: LATEST only points at
        complete step dirs; transient filesystem errors just defer to the
        next tick, while a deterministic extract/structure failure (e.g.
        the wrong ``extract`` for the checkpoint's layout) warns once and
        stops re-reading that step — a newer step gets a fresh attempt."""
        try:
            step = read_latest_step(self.directory)
        except OSError:
            return None
        if step is None or step <= self.seen_step or step == self._bad_step:
            return None
        try:
            state, _ = restore_checkpoint(self.directory, step=step)
        except OSError:
            return None  # likely a filesystem race — retry next poll
        except (KeyError, ValueError) as e:  # corrupt step dir: don't loop on it
            self._bad_step = step
            warnings.warn(f"CheckpointWatcher: step {step} in "
                          f"{self.directory} is unreadable ({e!r})",
                          stacklevel=2)
            return None
        try:
            params = self.extract(state)
        except (KeyError, ValueError, TypeError, IndexError) as e:
            self._bad_step = step
            warnings.warn(
                f"CheckpointWatcher: extracting step {step} from "
                f"{self.directory} failed ({e!r}); still serving the "
                f"previous params — wrong extract= for this checkpoint "
                f"layout?", stacklevel=2)
            return None
        self.seen_step = step
        return params, step
