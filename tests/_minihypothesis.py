"""Deterministic stand-in for the `hypothesis` API surface this suite uses.

The container has no hypothesis wheel and installing one is off-limits, so
conftest registers this module as ``hypothesis`` ONLY when the real package
is missing (real hypothesis wins whenever present).  It keeps the
property-based tests meaningful: each ``@given`` test runs ``max_examples``
times over seeded pseudo-random draws (seed = example index, so failures
reproduce exactly), with min/max boundary draws front-loaded.

Supported: given(**kwargs), settings(max_examples=, deadline=),
strategies.integers / floats / lists / permutations.
"""
from __future__ import annotations

import types

import numpy as np


class _Strategy:
    def __init__(self, sample, boundary=None):
        self._sample = sample
        self._boundary = boundary or []

    def example(self, rng, index):
        if index < len(self._boundary):
            return self._boundary[index]
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: int(r.randint(min_value, max_value + 1)),
                     boundary=[min_value, max_value])


def floats(min_value, max_value, allow_nan=True, allow_infinity=None,
           width=64):
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda r: float(r.uniform(lo, hi)), boundary=[lo, hi])


def lists(elements, min_size=0, max_size=10):
    def sample(r):
        n = int(r.randint(min_size, max_size + 1))
        return [elements._sample(r) for _ in range(n)]

    return _Strategy(sample)


def permutations(values):
    vals = list(values)
    return _Strategy(lambda r: [vals[i] for i in r.permutation(len(vals))],
                     boundary=[list(vals)])


def given(**strategies_kw):
    def deco(fn):
        # no functools.wraps: pytest would follow __wrapped__ and mistake the
        # strategy parameters for fixtures; the wrapper must look zero-arg
        def wrapper():
            # @settings may sit above (annotating wrapper) or below
            # (annotating fn) the @given decorator; honour both orders
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", 10))
            for i in range(n):
                rng = np.random.RandomState(i)
                drawn = {k: s.example(rng, i) for k, s in strategies_kw.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # reproduce like hypothesis does
                    raise AssertionError(
                        f"falsifying example (draw #{i}): {drawn!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._minihypothesis = True
        return wrapper

    return deco


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def build_module():
    """Assemble module objects registrable as hypothesis / h.strategies."""
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.permutations = permutations
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    return hyp, st
