import os
import sys

# Tests run single-device CPU (the dry-run sets its own 512-device flag in a
# subprocess).  Keep any preexisting XLA_FLAGS but never force device count
# here — smoke tests and benches must see 1 device.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The planted-violation trees under fixtures/ contain deliberately broken
# "tests" (lint fodder for repro.analysis) — never collect them.
collect_ignore_glob = ["fixtures/*"]

# The container ships no hypothesis wheel (and installing one is off-limits);
# fall back to the deterministic stub.  Real hypothesis wins when present.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _minihypothesis

    _hyp, _st = _minihypothesis.build_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
