"""Fixture codec registry."""


class IntQuant:
    def __init__(self, bits=8):
        self.bits = bits


CODECS = {
    "int8": lambda: IntQuant(bits=8),
    "int4": lambda: IntQuant(bits=4),
}
