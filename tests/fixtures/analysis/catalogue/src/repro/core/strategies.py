"""Fixture registry: GhostSync is registered but undocumented."""


class FedAvgSync:
    pass


class GhostSync:
    pass


STRATEGIES = {
    "fedgan": FedAvgSync,
    "ghost": GhostSync,
}
