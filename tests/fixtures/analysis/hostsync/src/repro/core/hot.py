"""Planted host-sync violations (fixture for tests/test_analysis.py).

Line numbers matter — the test asserts exact anchors."""
import jax
import numpy as np


def leaky_round(state, metrics):
    loss = float(metrics["loss"])                      # line 9: float() sync
    count = metrics["count"].item()                    # line 10: .item() sync
    host = np.asarray(metrics["grad_norm"])            # line 11: np.asarray sync
    fetched = jax.device_get(state)                    # line 12: device_get sync
    return loss, count, host, fetched


def waived_round(state, metrics):
    # one-time fetch at the very end of the run, after all dispatches
    fetched = jax.device_get(state)  # analysis: allow(host-sync)
    return fetched


def fine_round(state):
    scale = float(1e-3)          # constant: no sync, must NOT be flagged
    import jax.numpy as jnp
    return jnp.asarray(state)    # jnp stays on device, must NOT be flagged
