"""Documented host-side module (in LintContext.host_side_modules) —
syncs here must be skipped wholesale."""
import jax


def evaluate(state):
    return jax.device_get(state)   # exempt: whole module is host-side
