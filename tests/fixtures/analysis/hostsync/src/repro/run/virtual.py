"""Planted violations in a virtual-client paging loop (fixture for
tests/test_analysis.py).

The real ``repro.run.virtual`` pages rows between device slots and the
host store; every legitimate sync there sits behind an
``analysis: allow(host-sync)`` waiver.  This twin plants the two bugs
the lint exists to catch in that loop: an unwaivered per-round
``device_get`` (blocks the in-flight round instead of overlapping) and
a ``float()`` on a traced weight row."""
import jax


def leaky_swap_out(state, slots, w_row, slot):
    rows = jax.device_get(state)                # line 14: per-round D2H sync
    share = float(w_row[slot])                  # line 15: traced-scalar sync
    return rows, share


def overlapped_swap_out(state):
    # the sanctioned pattern: one fetch, after the round result is in
    rows = jax.device_get(state)  # analysis: allow(host-sync)
    return rows
