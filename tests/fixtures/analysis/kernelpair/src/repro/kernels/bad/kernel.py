"""Fixture kernel with NO sibling ref.py — must be flagged at line 1."""
def op(x):
    return x * 3
