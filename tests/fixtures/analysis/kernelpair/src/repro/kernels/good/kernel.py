"""Fixture kernel WITH a ref oracle and a parity test — must not be flagged."""
def op(x):
    return x * 2
