def op(x):
    return x * 2
