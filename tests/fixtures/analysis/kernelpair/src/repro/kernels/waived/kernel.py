# analysis: allow(kernel-ref-pair) — fixture: waived missing-ref kernel
def op(x):
    return x * 4
