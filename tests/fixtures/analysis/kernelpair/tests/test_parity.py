"""References kernels.good and its ref oracle (satisfies kernel-ref-pair)."""
# from repro.kernels.good import ops, ref   (pattern match is textual)


def test_parity():
    from repro.kernels.good import kernel, ref
    assert kernel.op(3) == ref.op(3)
