"""Fixture: refusal guards for the refusal-matrix rule."""


class FedAvgSync:
    def validate(self, cfg):
        if self.codec is not None and self.sync_dtype is not None:
            raise ValueError("codec= and sync_dtype= are both wire "
                             "compressions; pick one")


class TrimmedMeanSync(FedAvgSync):
    def validate(self, cfg):
        if self.secure_agg is not None:
            raise ValueError("robust aggregation needs the per-agent values "
                             "a secure sum hides")
