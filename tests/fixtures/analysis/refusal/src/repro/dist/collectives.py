"""Fixture: no refusal guards live here."""
