"""Fixture: async-buffer refusal guards for the refusal-matrix rule.

One guard per knob, mirroring ``check_async_mergeable``: the codec guard
has a matching docs row (no finding), the sync_dtype guard is the
planted code-side hole (docs row missing), and the docs table plants a
robust+async row with no guard behind it.
"""


def check_async_mergeable(strategy):
    if strategy.codec is not None:
        raise ValueError("codec= residuals cannot ride an async buffer")
    if strategy.sync_dtype is not None:
        raise ValueError("sync_dtype= has no wire cast point under async "
                         "buffering")
