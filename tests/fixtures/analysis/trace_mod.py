"""Planted jaxpr-level violations (fixture for the trace auditor tests).

Each violation has a waived twin carrying the ``analysis: allow`` marker;
line numbers are asserted by tests/test_analysis.py."""
import jax


def callback_in_scan(state, xs):
    def body(c, x):
        jax.debug.print("c={}", c)          # line 10: host callback in scan
        return c + x, c
    return jax.lax.scan(body, state, xs)[0]


def callback_in_scan_waived(state, xs):
    def body(c, x):
        # deliberate per-step debug hook (fixture)
        jax.debug.print("c={}", c)  # analysis: allow(host-callback-in-scan)
        return c + x, c
    return jax.lax.scan(body, state, xs)[0]


def raw_seed_in_loop(state, xs):
    def body(c, x):
        k = jax.random.key(0)               # line 25: raw seed in loop body
        return c + x + jax.random.uniform(k, ()), c
    return jax.lax.scan(body, state, xs)[0]


def raw_seed_in_loop_waived(state, xs):
    def body(c, x):
        k = jax.random.key(0)  # analysis: allow(raw-fold-in)
        return c + x + jax.random.uniform(k, ()), c
    return jax.lax.scan(body, state, xs)[0]


def pad_reuse(key):
    a = jax.random.uniform(jax.random.fold_in(key, 7), ())
    b = jax.random.uniform(jax.random.fold_in(key, 7), ())  # line 38: reuse
    return a + b


def pad_reuse_waived(key):
    a = jax.random.uniform(jax.random.fold_in(key, 7), ())
    b = jax.random.uniform(jax.random.fold_in(key, 7), ())  # analysis: allow(pad-reuse)
    return a + b
