"""repro.analysis: every rule caught red-handed on planted fixtures, every
suppression honoured, and the real repo clean against the committed
baseline.  The wire matrix (strategy x codec on 8 devices) runs in a
subprocess at the end."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.findings import (Finding, allowed_rules_on_line,
                                     filter_suppressed, load_baseline,
                                     new_findings)
from repro.analysis.lint import LintContext, run_lint

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIX = os.path.join(HERE, "fixtures", "analysis")
SRC = os.path.join(ROOT, "src")


def ctx_for(name: str) -> LintContext:
    return LintContext.for_repo(os.path.join(FIX, name))


def line_of(root: str, rel: str, needle: str, nth: int = 0) -> int:
    """1-based line number of the nth line containing ``needle``."""
    with open(os.path.join(root, rel), encoding="utf-8") as f:
        hits = [i + 1 for i, ln in enumerate(f.read().splitlines())
                if needle in ln]
    return hits[nth]


# ---------------------------------------------------------------------------
# Layer 2: lint rules on planted fixtures
# ---------------------------------------------------------------------------


def test_host_sync_catches_each_call_form_at_its_line():
    root = os.path.join(FIX, "hostsync")
    findings = run_lint(ctx_for("hostsync"), rules=["host-sync"])
    got = {(f.file, f.line) for f in findings}
    rel = "src/repro/core/hot.py"
    virt = "src/repro/run/virtual.py"
    expected = {
        (rel, line_of(root, rel, "float(metrics")),
        (rel, line_of(root, rel, '.item()')),
        (rel, line_of(root, rel, "np.asarray(metrics")),
        (rel, line_of(root, rel, "jax.device_get(state)                ")),
        (virt, line_of(root, virt, "per-round D2H sync")),
        (virt, line_of(root, virt, "traced-scalar sync")),
    }
    assert got == expected, findings
    assert all(f.rule == "host-sync" for f in findings)
    # the waived twin (allow comment) and the documented host-side module
    # (run/evals.py) produced nothing — by construction of `expected` above
    assert not any("evals" in f.file for f in findings)


def test_host_sync_ignores_constants_and_jnp():
    findings = run_lint(ctx_for("hostsync"), rules=["host-sync"])
    fine_line = line_of(os.path.join(FIX, "hostsync"),
                        "src/repro/core/hot.py", "float(1e-3)")
    assert not any(f.line == fine_line for f in findings)


def test_kernel_ref_pair_flags_only_the_unpaired_kernel():
    findings = run_lint(ctx_for("kernelpair"), rules=["kernel-ref-pair"])
    assert len(findings) == 1, findings
    f = findings[0]
    assert f.file == "src/repro/kernels/bad/kernel.py"
    assert f.line == 1
    assert "ref.py" in f.message
    # good/ has ref + parity test; waived/ carries the allow marker on line 1


def test_refusal_matrix_both_directions_with_suppression():
    root = os.path.join(FIX, "refusal")
    findings = run_lint(ctx_for("refusal"), rules=["refusal-matrix"])
    assert len(findings) == 2, findings
    docs_hole = [f for f in findings if f.file == "docs/privacy.md"]
    code_hole = [f for f in findings if f.file.endswith("strategies.py")]
    assert len(docs_hole) == 1 and len(code_hole) == 1
    assert docs_hole[0].line == line_of(root, "docs/privacy.md",
                                        "`secure_agg` + `codec=`")
    assert "no matching ValueError guard" in docs_hole[0].message
    assert code_hole[0].line == line_of(root, "src/repro/core/strategies.py",
                                        "raise ValueError", nth=1)
    assert "no docs refusal-matrix row" in code_hole[0].message
    # the secure_agg+sync_dtype docs row carries the inline allow marker


def test_refusal_matrix_async_rows():
    """The async-buffer vocabulary ('async' + knob tokens): a guarded and
    documented pair is silent; the planted undocumented sync_dtype guard
    and the planted guard-less robust docs row are each one finding."""
    root = os.path.join(FIX, "refusal_async")
    findings = run_lint(ctx_for("refusal_async"), rules=["refusal-matrix"])
    assert len(findings) == 2, findings
    docs_hole = [f for f in findings if f.file == "docs/scaling.md"]
    code_hole = [f for f in findings if f.file.endswith("strategies.py")]
    assert len(docs_hole) == 1 and len(code_hole) == 1
    assert "async + robust" in docs_hole[0].message
    assert docs_hole[0].line == line_of(root, "docs/scaling.md",
                                        "robust reduce + async")
    assert "async + sync_dtype" in code_hole[0].message
    assert code_hole[0].line == line_of(root, "src/repro/core/strategies.py",
                                        "raise ValueError", nth=1)


def test_catalogue_drift_stale_missing_and_suppressed():
    root = os.path.join(FIX, "catalogue")
    findings = run_lint(ctx_for("catalogue"), rules=["catalogue-drift"])
    by_msg = {f.message: f for f in findings}
    assert len(findings) == 4, findings

    stale = [f for f in findings if "StaleSync" in f.message]
    assert stale and stale[0].line == line_of(root, "docs/strategies.md",
                                              "StaleSync")
    assert not any("WaivedStale" in f.message for f in findings)  # suppressed

    ghost = [f for f in findings if "GhostSync" in f.message]
    assert ghost and ghost[0].file == "docs/strategies.md"
    assert ghost[0].line == line_of(root, "docs/strategies.md", "| strategy |")

    assert any("int9" in m for m in by_msg)                 # stale codec row
    missing_codec = [f for f in findings if "`int4`" in f.message]
    assert missing_codec and missing_codec[0].file == "docs/communication.md"


# ---------------------------------------------------------------------------
# Layer 1: trace auditor on planted fixtures
# ---------------------------------------------------------------------------


def _trace_mod():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "analysis_trace_fixture", os.path.join(FIX, "trace_mod.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("fn_name,rule,needle", [
    ("callback_in_scan", "host-callback-in-scan", 'jax.debug.print("c={}", c)          #'),
    ("raw_seed_in_loop", "raw-fold-in", "jax.random.key(0)               #"),
    ("pad_reuse", "pad-reuse", "fold_in(key, 7), ())  # line"),
])
def test_trace_rule_fires_at_line_and_waived_twin_is_silent(fn_name, rule, needle):
    import jax
    import jax.numpy as jnp

    from repro.analysis.trace import TracedFn, audit_traced
    mod = _trace_mod()
    if fn_name == "pad_reuse":
        args = (jax.random.key(3),)
    else:
        args = (jnp.float32(0.0), jnp.zeros((3,)))

    findings = filter_suppressed(
        audit_traced(TracedFn(fn_name, getattr(mod, fn_name), args), FIX), FIX)
    hits = [f for f in findings if f.rule == rule]
    assert hits, findings
    assert hits[0].file == "trace_mod.py"
    assert hits[0].line == line_of(FIX, "trace_mod.py", needle)

    waived = filter_suppressed(
        audit_traced(TracedFn(fn_name, getattr(mod, fn_name + "_waived"),
                              args), FIX), FIX)
    assert not [f for f in waived if f.rule == rule], waived


def test_donation_miss_and_round_donation_helper():
    import jax.numpy as jnp

    from repro.analysis.trace import audit_built
    from repro.launch.steps import BuiltStep, round_donation

    built = BuiltStep(fn=lambda s, x: ({"p": s["p"] + x}, x),
                      input_sds=({"p": jnp.zeros(())}, jnp.zeros(())),
                      in_shardings=None, out_shardings=None,
                      meta={"kind": "train"})
    assert round_donation(built) == (0,)
    assert round_donation(BuiltStep(None, (), None, None,
                                    meta={"kind": "prefill"})) == ()

    missed = audit_built(built, donate_argnums=())
    assert any(f.rule == "donation-miss" for f in missed), missed
    fixed = audit_built(built, donate_argnums=round_donation(built))
    assert not [f for f in fixed if f.rule == "donation-miss"], fixed


# ---------------------------------------------------------------------------
# Baseline + suppression machinery
# ---------------------------------------------------------------------------


def test_suppression_marker_forms():
    assert allowed_rules_on_line("x = 1  # analysis: allow(host-sync)") == \
        {"host-sync"}
    assert allowed_rules_on_line("<!-- analysis: allow(a-rule, b-rule) -->") \
        == {"a-rule", "b-rule"}
    assert allowed_rules_on_line("# analysis allow host-sync") == set()


def test_baseline_refuses_entries_without_reason(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "host-sync", "file": "a.py", "message": "m"}]}))
    with pytest.raises(ValueError, match="reason"):
        load_baseline(str(p))
    p.write_text(json.dumps({"findings": [
        {"rule": "host-sync", "file": "a.py", "message": "m",
         "reason": "documented false positive"}]}))
    assert load_baseline(str(p)) == {("host-sync", "a.py", "m")}


def test_baseline_matching_is_line_independent():
    f = Finding(rule="r", file="a.py", line=10, message="m")
    g = Finding(rule="r", file="a.py", line=99, message="m")
    assert f.key == g.key
    assert new_findings([g], {f.key}) == []


def test_update_baseline_output_needs_human_reasons(tmp_path):
    """--update-baseline writes reason-less entries that the gate refuses
    until a human fills them in — updating the baseline is a reviewed act."""
    out = tmp_path / "b.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "catalogue-drift",
         "--root", os.path.join(FIX, "catalogue"),
         "--update-baseline", "--baseline", str(out)],
        capture_output=True, text=True, env=dict(os.environ, PYTHONPATH=SRC),
        timeout=120)
    assert res.returncode == 0, res.stderr[-2000:]
    assert out.exists()
    with pytest.raises(ValueError, match="reason"):
        load_baseline(str(out))


# ---------------------------------------------------------------------------
# The real repo is clean vs the committed (empty) baseline
# ---------------------------------------------------------------------------


def test_repo_lint_clean_vs_baseline():
    assert new_findings(run_lint(), load_baseline()) == []


def test_repo_trace_clean_vs_baseline():
    """The canonical typed-key round targets trace with zero findings —
    in particular NO random_seed in the K-scan (the legacy uint32 shim is
    only reachable from raw seeds) and no host callbacks."""
    from repro.analysis.trace import run_trace
    assert new_findings(run_trace(), load_baseline()) == []


def test_cli_gate_exits_zero_on_clean_lint(tmp_path):
    report = tmp_path / "report.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "lint",
         "--json", "--out", str(report)],
        capture_output=True, text=True, env=dict(os.environ, PYTHONPATH=SRC),
        timeout=300)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    data = json.loads(report.read_text())
    assert data["new"] == []
    assert set(data["rules"]) == {"host-sync", "kernel-ref-pair",
                                  "refusal-matrix", "catalogue-drift"}


def test_cli_rejects_unknown_rule():
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "no-such-rule"],
        capture_output=True, text=True, env=dict(os.environ, PYTHONPATH=SRC),
        timeout=60)
    assert res.returncode != 0
    assert "unknown rule" in (res.stdout + res.stderr)


# ---------------------------------------------------------------------------
# Layer 1b: the wire matrix
# ---------------------------------------------------------------------------


def _wire_rec(dtypes, nbytes=100, in_loop=False):
    from repro.launch.hlo_analysis import CollectiveRecord
    return CollectiveRecord(op="all-reduce", bytes=nbytes,
                            group_signature="4T",
                            operand_dtypes=tuple(dtypes),
                            in_loop=in_loop, computation="entry")


class _FakeStrategy:
    pass


def test_wire_cell_findings_logic():
    """Every wire-dtype check on hand-built collective records — the
    compiled matrix itself is exercised by the subprocess test below."""
    from repro.analysis.hotpath import WireCell, _cell_findings

    def cell(codec, records, billed, status="ok"):
        return WireCell("s", "_FakeStrategy", codec, status,
                        agent_bytes_once=sum(r.bytes for r in records),
                        billed=billed, agent_records=tuple(records))

    none = cell("none", [_wire_rec(["f32"])], 1000)

    wide = _cell_findings({"none": none,
                           "int8": cell("int8", [_wire_rec(["f64"])], 500)},
                          _FakeStrategy, ROOT)
    assert any("wider than" in f.message for f in wide), wide

    leak = _cell_findings({"none": none,
                           "int8": cell("int8", [_wire_rec(["u8"])], 500)},
                          _FakeStrategy, ROOT)
    assert any("crossed the agent axis" in f.message for f in leak), leak

    # narrow traffic the none cell ALSO carries is the strategy's own
    # wire (e.g. a pred subsampling mask), not a codec leak
    none_pred = cell("none", [_wire_rec(["f32", "pred"])], 1000)
    ok = _cell_findings({"none": none_pred,
                         "int8": cell("int8", [_wire_rec(["f32", "pred"])],
                                      500)}, _FakeStrategy, ROOT)
    assert ok == [], ok

    lazy = _cell_findings({"none": none,
                           "int4": cell("int4", [_wire_rec(["f32"])], 1000)},
                          _FakeStrategy, ROOT)
    assert any("silently ignored" in f.message for f in lazy), lazy

    good16 = _cell_findings({"none": none,
                             "bf16": cell("bf16", [_wire_rec(["bf16"])], 500)},
                            _FakeStrategy, ROOT)
    assert good16 == [], good16
    bad16 = _cell_findings({"none": none,
                            "bf16": cell("bf16", [_wire_rec(["f32"])], 500)},
                           _FakeStrategy, ROOT)
    assert any("never reached the wire" in f.message for f in bad16), bad16

    refused = _cell_findings(
        {"none": none,
         "int8": cell("int8", [], 0, status="refused")},
        _FakeStrategy, ROOT)
    assert refused == [], refused


def test_wire_matrix_full_strategy_by_codec(tmp_path):
    """The acceptance matrix: every registered strategy x {none, int8,
    int4} (+ fedgan bf16) compiled on the 8-device mesh, zero findings
    beyond the committed baseline.  Slow: ~22 compiles in a subprocess
    (the CLI sets the 8-device XLA flag itself before importing jax)."""
    report = tmp_path / "wire.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "wire",
         "--json", "--out", str(report)],
        capture_output=True, text=True, env=dict(os.environ, PYTHONPATH=SRC),
        timeout=1800)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    data = json.loads(report.read_text())
    assert data["new"] == []
    # the one baselined finding: fedgan+bf16 legalized to f32 by the CPU
    # backend's bf16 normalization (see baseline.json reason)
    assert data["baselined"] == 1, data["findings"]

    cells = {(c["strategy"], c["codec"]): c for c in data["wire_cells"]}
    from repro.core.strategies import STRATEGIES
    canonical = []
    seen = set()
    for name, cls in STRATEGIES.items():
        if cls not in seen:
            seen.add(cls)
            canonical.append(name)
    for name in canonical:
        for codec in ("none", "int8", "int4"):
            assert (name, codec) in cells, (name, codec)
    assert cells[("fedgan", "bf16")]["status"] == "ok"

    # the int8/int4 cells audit the FUSED pipeline (coded_sync auto-fuses
    # when the codec has a fused_sync_spec); fedgan's explicit *_composed
    # cells keep the per-leaf composed pipeline audited, and both variants
    # must bill identically — the fusion changes dispatch structure, never
    # the §3.2 budget
    for codec in ("int8", "int4"):
        fused_cell = cells[("fedgan", codec)]
        comp_cell = cells[("fedgan", f"{codec}_composed")]
        assert fused_cell["status"] == "ok", fused_cell
        assert comp_cell["status"] == "ok", comp_cell
        assert fused_cell["billed"] == comp_cell["billed"], \
            (fused_cell, comp_cell)

    # strategies without a codec field REFUSE the codec cells loudly
    for name in ("local_only", "distributed"):
        for codec in ("int8", "int4"):
            c = cells[(name, codec)]
            assert c["status"] == "refused" and "TypeError" in c["reason"], c
    # every accepted codec cell bills strictly less than its none cell
    for name in canonical:
        none_cell = cells[(name, "none")]
        for codec in ("int8", "int4"):
            c = cells[(name, codec)]
            if c["status"] == "ok" and none_cell["billed"]:
                assert c["billed"] < none_cell["billed"], c
