"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures is instantiated as a REDUCED same-family
variant (<=2-3 layers, d_model<=512, <=4 experts) and runs one forward and
one federated adversarial train step on CPU, asserting output shapes and
the absence of NaNs.  The FULL configs are exercised by the dry-run only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import FedGAN, FedGANConfig
from repro.launch.steps import make_lm_gan_task
from repro.models.transformer import Backbone
from repro.optim import SGD, constant, equal_timescale

ARCHS = list_archs()


def test_registry_has_all_ten():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.source  # provenance recorded


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).smoke()
    assert cfg.d_model <= 512 and cfg.num_layers <= 3
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        kw["encoder_frames"] = 0.1 * jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    out = bb.apply(params, toks, **kw)
    assert out["logits"].shape == (B, T, cfg.padded_vocab)
    assert not jnp.isnan(out["logits"]).any()
    assert not jnp.isnan(out["hidden"]).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_fedgan_train_step(arch):
    """One FedGAN round (2 local steps + sync) on the reduced variant."""
    cfg = get_config(arch).smoke()
    task = make_lm_gan_task(cfg)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, 2), sync_interval=2),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(0))
    K, P, A, b, T = 2, 1, 2, 2, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (K, P, A, b, T),
                                          0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.key(2), (K, P, A, b, cfg.encoder_seq, cfg.d_model))
    seeds = jax.random.randint(jax.random.key(3), (K, P, A), 0,
                               2 ** 31 - 1).astype(jnp.uint32)
    state2, metrics = jax.jit(fed.round)(state, batch, seeds)
    assert np.isfinite(float(jnp.mean(metrics["d_loss"])))
    assert np.isfinite(float(jnp.mean(metrics["g_loss"])))
    # params moved and are agent-synced after the round
    th0 = jax.tree_util.tree_leaves(state["params"]["gen"])[0]
    th1 = jax.tree_util.tree_leaves(state2["params"]["gen"])[0]
    assert not np.allclose(np.asarray(th0), np.asarray(th1))
    for leaf in jax.tree_util.tree_leaves(state2["params"]):
        np.testing.assert_allclose(np.asarray(leaf[0, 0]), np.asarray(leaf[0, 1]),
                                   rtol=1e-5, atol=1e-6)
        assert not jnp.isnan(leaf).any()


@pytest.mark.parametrize("arch", ["gemma3-4b", "mamba2-2.7b", "zamba2-7b",
                                  "whisper-medium"])
def test_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    B, S = 2, 16
    cache = bb.init_cache(B, S)
    if cfg.family == "audio":
        frames = 0.1 * jax.random.normal(jax.random.key(2),
                                         (B, cfg.encoder_seq, cfg.d_model))
        mem = bb.encode(params, frames)
        blk = bb._block(cross=True)
        cache["cross"] = jax.vmap(
            lambda bp: blk.attn.build_memory_cache(bp["xattn"], mem))(params["blocks"])
    tok = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab_size)
    logits, cache2 = bb.decode(params, tok, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()


def test_long_decode_support_flags():
    from repro.configs import pair_supported
    runs = {a: pair_supported(a, "long_500k")[0] for a in ARCHS}
    assert runs == {
        "gemma3-4b": True, "mixtral-8x22b": True, "qwen3-8b": False,
        "phi4-mini-3.8b": False, "whisper-medium": False, "glm4-9b": False,
        "zamba2-7b": True, "granite-moe-3b-a800m": False,
        "chameleon-34b": False, "mamba2-2.7b": True,
    }
