"""Async buffered aggregation (repro.run.async_agg) + the virtual-clock
simulator (repro.run.simclock).

Two load-bearing contracts:

* **degenerate parity** — with no latency model, no timeout and a
  full-cohort buffer goal the async driver IS the synchronous per-round
  path: bit-identical params, optimizer state, EF residuals and metrics
  against the dense ``RoundDriver``;
* **replay determinism** — a seeded straggler simulation replays
  bit-exactly: byte-identical event journals and identical final
  parameters across runs (the CI determinism gate diffs the files raw).

Plus the buffered-mode semantics (flush at goal, staleness weighting,
expiry, timeout/retry/backoff), the loud strategy refusals, and
property-based invariants for the staleness-weight algebra.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import codec_from_flags
from repro.core import strategies
from repro.core.participation import ParticipationSchedule
from repro.core.strategies import (AdaptiveK, FedAvgSync, PartialSharing,
                                   SubsampledFedAvg, TrimmedMeanSync,
                                   check_async_mergeable)
from repro.data import FleetRounds
from repro.optim import Adam
from repro.run.async_agg import AsyncAggDriver, modeled_sync_makespan
from repro.run.simclock import (EventJournal, LatencyModel, SimClock,
                                demo_driver, params_digest)
from repro.run.virtual import (StragglerPolicy, staleness_scale,
                               staleness_weights)
from test_virtual_clients import (assert_trees_equal, client_shards,
                                  dense_result, make_fed, virtual_result)

tmap = jax.tree_util.tree_map


def async_driver(strategy, agent_data, grid=(1, 4), K=3, n_rounds=5,
                 opt=None, **kw):
    fed = make_fed(strategy, grid, K, opt)
    fleet = FleetRounds(agent_data, grid, batch_size=8, sync_interval=K)
    return AsyncAggDriver(fed, fleet, n_rounds, log_every=0, **kw)


def in_flight_trace(journal):
    """Reconstruct the in-flight count after each event from the journal."""
    n, trace = 0, []
    for r in journal.records:
        if r["ev"] == "dispatch":
            n += 1
        elif r["ev"] in ("arrival", "expired", "timeout"):
            n -= 1
        trace.append(n)
    return trace


# ---------------------------------------------------------------------------
# degenerate parity: async(B=cohort, zero latency) == synchronous rounds
# ---------------------------------------------------------------------------

DEGENERATE_STRATEGIES = [
    ("fedavg", None),
    ("partial_sharing", PartialSharing()),
    ("codec_ef", FedAvgSync(codec=codec_from_flags("int8"))),
]


@pytest.mark.parametrize("name,strategy", DEGENERATE_STRATEGIES,
                         ids=[p[0] for p in DEGENERATE_STRATEGIES])
def test_degenerate_parity_bit_identical(name, strategy):
    """No latency, no timeout, full-cohort goal -> the dense per-round
    trajectory, bit for bit: params, opt moments, EF residuals, metrics."""
    data = client_shards(4)
    dense = dense_result(strategy, data, opt=Adam())
    drv = async_driver(strategy, data, opt=Adam())
    res = drv.run(jax.random.key(7))
    assert set(dense.state) == set(res.state)
    assert_trees_equal(dense.state, res.state)
    assert dense.history == res.history
    assert res.timings["mode"] == "sync_equivalent"


def test_degenerate_journal_shape_and_digest():
    data = client_shards(4)
    drv = async_driver(None, data, n_rounds=5)
    res = drv.run(jax.random.key(7))
    counts = drv.journal.counts()
    assert counts["flush"] == 5
    assert counts["dispatch"] == counts["arrival"] == 5 * 4
    end = drv.journal.select("end")[-1]
    assert end["params_digest"] == params_digest(res.state["params"])


def test_degenerate_matches_virtual_driver_exactly():
    data = client_shards(6)
    sched = ParticipationSchedule(seed=9)
    _, virt = virtual_result(None, data, n_rounds=4, schedule=sched)
    drv = async_driver(None, data, n_rounds=4, schedule=sched)
    res = drv.run(jax.random.key(7))
    assert_trees_equal(virt.state, res.state)
    assert virt.history == res.history


# ---------------------------------------------------------------------------
# replay determinism: same seed -> byte-identical journal + params
# ---------------------------------------------------------------------------


def _demo_run(seed=7, **kw):
    drv = demo_driver(seed=seed, n_rounds=4, **kw)
    res = drv.run(jax.random.key(seed))
    return drv, res


def test_buffered_replay_bit_exact():
    d1, r1 = _demo_run()
    d2, r2 = _demo_run()
    assert d1.journal.canonical_bytes() == d2.journal.canonical_bytes()
    assert_trees_equal(r1.state["params"], r2.state["params"])
    assert r1.timings["makespan"] == r2.timings["makespan"]


def test_buffered_other_seed_differs():
    d1, _ = _demo_run(seed=7)
    d2, _ = _demo_run(seed=8)
    assert d1.journal.canonical_bytes() != d2.journal.canonical_bytes()


def test_journal_end_digest_matches_final_params():
    drv, res = _demo_run()
    assert drv.journal.select("end")[-1]["params_digest"] == \
        params_digest(res.state["params"])


def test_cli_main_writes_identical_journals(tmp_path, capsys):
    from repro.run import simclock
    a, b = str(tmp_path / "a.jsonl"), str(tmp_path / "b.jsonl")
    assert simclock.main(["--seed", "5", "--rounds", "3", "--out", a]) == 0
    assert simclock.main(["--seed", "5", "--rounds", "3", "--out", b]) == 0
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()
    out = capsys.readouterr().out
    assert "params_digest=" in out and "makespan=" in out


# ---------------------------------------------------------------------------
# buffered semantics: goal, staleness weights, expiry, concurrency
# ---------------------------------------------------------------------------


def test_flush_fires_exactly_at_goal():
    drv, res = _demo_run(buffer_goal=2)
    flushes = drv.journal.select("flush")
    assert len(flushes) == 4 == res.timings["flushes"]
    assert all(f["merged"] == 2 for f in flushes)
    assert res.timings["merged_deltas"] == 8


def test_buffer_goal_one_merges_singletons():
    drv, _ = _demo_run(buffer_goal=1)
    assert all(f["merged"] == 1 and f["weights"] == [1.0]
               for f in drv.journal.select("flush"))


def test_in_flight_never_exceeds_cohort():
    drv, _ = _demo_run(cohort=4)
    assert max(in_flight_trace(drv.journal)) <= 4


def test_flush_weights_are_the_staleness_closed_form():
    """Every journalled flush weight vector is exactly
    ``normalize(decay**staleness)`` — decay 0.5 keeps the arithmetic in
    powers of two, so 'exactly' means bitwise."""
    drv, _ = _demo_run()
    policy = drv.straggler
    saw_stale = False
    for f in drv.journal.select("flush"):
        expect = staleness_weights(f["staleness"], policy)
        np.testing.assert_array_equal(np.float32(f["weights"]), expect)
        assert all(0 <= s <= policy.max_staleness for s in f["staleness"])
        saw_stale |= any(s > 0 for s in f["staleness"])
    assert saw_stale, "workload never produced a stale delta — vacuous"


def test_expired_deltas_are_dropped_and_counted():
    data = client_shards(8)
    drv = async_driver(
        None, data, n_rounds=6, buffer_goal=1,
        schedule=ParticipationSchedule(seed=7),
        straggler=StragglerPolicy(mode="defer", decay=0.5, max_staleness=1),
        latency=LatencyModel(base=1.0, jitter=0.5, straggler_frac=0.4,
                             straggler_factor=16.0))
    res = drv.run(jax.random.key(7))
    expired = drv.journal.select("expired")
    assert res.timings["expired_deltas"] == len(expired) > 0
    assert all(e["staleness"] > 1 for e in expired)
    # and everything that DID merge was within the staleness bound
    assert all(s <= 1 for f in drv.journal.select("flush")
               for s in f["staleness"])


def test_constant_latency_makespan_closed_form():
    """base-only latency, full-cohort goal: the loop degenerates to lock
    step — flush k lands at exactly (k+1) * base virtual seconds."""
    data = client_shards(4)
    drv = async_driver(None, data, n_rounds=3,
                       latency=LatencyModel(base=2.0))
    res = drv.run(jax.random.key(7))
    assert res.timings["mode"] == "buffered"
    assert res.timings["makespan"] == 3 * 2.0
    assert [f["t"] for f in drv.journal.select("flush")] == [2.0, 4.0, 6.0]
    assert all(np.isfinite(m["d_loss"]) for m in res.history)


def test_partial_sharing_buffered_leaves_disc_local():
    data = client_shards(4)
    drv = async_driver(PartialSharing(), data, n_rounds=3,
                       latency=LatencyModel(base=1.0))
    res = drv.run(jax.random.key(7))
    # the server only ever owns the shared subtree
    assert set(res.state["params"]) == {"gen"}
    rows = {cid: drv.store.row(cid) for cid in drv.store.client_ids()}
    discs = [np.asarray(r["params"]["disc"]["w"]) for r in rows.values()]
    assert len(discs) >= 2
    assert any(not np.array_equal(discs[0], d) for d in discs[1:])


def test_dataset_weighting_scales_flush_weights():
    data = client_shards(4, size=16) + client_shards(4, size=48, seed=1)
    fed = make_fed(None, (1, 4), 3)
    fleet = FleetRounds(data, (1, 4), batch_size=8, sync_interval=3)
    drv = AsyncAggDriver(fed, fleet, 3, log_every=0, weighting="dataset",
                         latency=LatencyModel(base=1.0), buffer_goal=2)
    drv.run(jax.random.key(7))
    for f in drv.journal.select("flush"):
        sizes = np.array([16.0 if c < 4 else 48.0 for c in f["clients"]])
        expect = staleness_weights(f["staleness"], drv.straggler, sizes)
        np.testing.assert_allclose(np.float32(f["weights"]), expect,
                                   rtol=1e-6)


def test_buffered_compiles_one_local_trace():
    drv, res = _demo_run()
    assert drv.n_traces == 1
    assert res.timings["data_kind"] == "async"
    assert res.timings["store_rows"] <= 8


# ---------------------------------------------------------------------------
# timeout / retry / backoff
# ---------------------------------------------------------------------------


def test_timeouts_retry_with_backed_off_budget():
    drv, _ = _demo_run()   # timeout=6, backoff=2, planted stragglers
    timeouts = drv.journal.select("timeout")
    assert timeouts, "workload planted stragglers but nothing timed out"
    dispatches = {r["seq"]: r for r in drv.journal.select("dispatch")}
    for ev in timeouts:
        d = dispatches[ev["seq"]]
        budget = drv.timeout * drv.backoff ** ev["attempt"]
        assert d["latency"] > budget
        np.testing.assert_allclose(ev["t"] - d["t"], budget)
    retries = drv.journal.select("retry")
    assert retries and all(r["attempt"] >= 1 for r in retries)


def test_retry_draws_fresh_latency():
    lm = LatencyModel(base=1.0, jitter=1.0)
    sched = ParticipationSchedule(seed=3)
    a = lm.draw(sched, dispatch_seq=5, client=2, n_total=8, attempt=0)
    b = lm.draw(sched, dispatch_seq=5, client=2, n_total=8, attempt=1)
    assert a != b
    assert a == lm.draw(sched, 5, 2, 8, attempt=0)   # pure function


def test_gave_up_is_loud_but_run_completes():
    """Some dispatches exhaust their retries; the run still reaches the
    flush target because replacements keep the pipeline full."""
    data = client_shards(8)
    drv = async_driver(
        None, data, grid=(1, 4), n_rounds=4, buffer_goal=2,
        schedule=ParticipationSchedule(seed=5),
        latency=LatencyModel(base=1.0, straggler_frac=0.5,
                             straggler_factor=50.0),
        timeout=2.0, max_retries=1, backoff=1.0)
    res = drv.run(jax.random.key(5))
    assert res.timings["flushes"] == 4
    assert res.timings["gave_up"] > 0
    assert drv.journal.counts()["gave_up"] == res.timings["gave_up"]


def test_starvation_raises_loudly():
    """timeout below every achievable latency + no retries: the driver
    must refuse with a diagnosis, not spin forever."""
    data = client_shards(6)
    drv = async_driver(None, data, n_rounds=2,
                       latency=LatencyModel(base=5.0),
                       timeout=1.0, max_retries=0)
    with pytest.raises(ValueError, match="starved"):
        drv.run(jax.random.key(7))


def test_modeled_sync_makespan_is_the_blocking_cost():
    sched = ParticipationSchedule(seed=7)
    lm = LatencyModel(base=1.0, jitter=0.5, straggler_frac=0.25,
                      straggler_factor=8.0)
    got = modeled_sync_makespan(sched, lm, n_rounds=3, n_total=8, m=4)
    expect = sum(max(lm.draw(sched, r, int(c), 8)
                     for c in sched.cohort(r, 8, 4)) for r in range(3))
    assert got == expect > 3.0   # at least base per round, stragglers more


# ---------------------------------------------------------------------------
# refusals: what the buffered merge cannot replay, it must refuse loudly
# ---------------------------------------------------------------------------

REFUSED = [
    ("subsampled", SubsampledFedAvg(fraction=0.5,
                                    schedule=ParticipationSchedule(seed=3)),
     "subsampled"),
    ("robust", TrimmedMeanSync(trim=1), "order statistic"),
    ("secure_agg", FedAvgSync(secure_agg="pairwise"), "uncancelled"),
    ("codec", FedAvgSync(codec=codec_from_flags("int8")), "stale payloads"),
    ("sync_dtype", FedAvgSync(sync_dtype=jnp.bfloat16), "wire cast"),
    ("avg_opt", FedAvgSync(average_opt_state=True), "moments stay local"),
    ("adaptive_k", AdaptiveK(), "per-round driver"),
]


@pytest.mark.parametrize("name,strategy,msg", REFUSED,
                         ids=[r[0] for r in REFUSED])
def test_check_async_mergeable_refuses(name, strategy, msg):
    with pytest.raises(ValueError, match=msg):
        check_async_mergeable(strategy)


def test_plain_strategies_are_async_mergeable():
    check_async_mergeable(FedAvgSync())
    check_async_mergeable(PartialSharing())


def test_buffered_construction_refuses_codec_but_degenerate_allows():
    data = client_shards(4)
    strat = FedAvgSync(codec=codec_from_flags("int8"))
    async_driver(strat, data)   # degenerate: fused sync path, codecs fine
    with pytest.raises(ValueError, match="codec"):
        async_driver(strat, data, latency=LatencyModel(base=1.0))


@pytest.mark.parametrize("kw,msg", [
    (dict(buffer_goal=0), "buffer_goal"),
    (dict(buffer_goal=5), "buffer_goal"),
    (dict(timeout=0.0), "timeout"),
    (dict(latency=LatencyModel(base=1.0), backoff=0.5), "backoff"),
    (dict(latency=LatencyModel(base=1.0), max_retries=-1), "max_retries"),
    (dict(weighting="nope"), "weighting"),
    (dict(latency=LatencyModel(base=-1.0)), "base/jitter"),
], ids=["goal_zero", "goal_over_cohort", "timeout_zero", "backoff_lt_one",
        "neg_retries", "bad_weighting", "neg_latency"])
def test_constructor_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        async_driver(None, client_shards(4), **kw)


# ---------------------------------------------------------------------------
# staleness-weight algebra: property-based invariants
# ---------------------------------------------------------------------------

_POLICY = StragglerPolicy(mode="defer", decay=0.5, max_staleness=3)


@settings(max_examples=25)
@given(stal=st.lists(st.integers(0, 6), min_size=1, max_size=8))
def test_weights_normalize_to_one(stal):
    w = staleness_weights(stal, _POLICY)
    if any(s <= _POLICY.max_staleness for s in stal):
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    else:
        assert w.sum() == 0.0


@settings(max_examples=25)
@given(s=st.integers(0, 10), decay=st.floats(0.05, 1.0))
def test_scale_monotone_nonincreasing(s, decay):
    pol = StragglerPolicy(mode="defer", decay=decay, max_staleness=5)
    assert staleness_scale(s, pol) >= staleness_scale(s + 1, pol)


@settings(max_examples=25)
@given(s=st.integers(4, 20))
def test_past_max_staleness_is_exactly_zero(s):
    assert staleness_scale(s, _POLICY) == 0.0
    w = staleness_weights([0, 1, s], _POLICY)
    assert w[2] == 0.0 and w.sum() > 0


@settings(max_examples=20)
@given(perm=st.permutations(list(range(6))))
def test_weights_commute_with_permutation(perm):
    """Merge-order invariance: permuting the buffer permutes the weights
    elementwise — decay 1/2 keeps every sum exact in binary, so this is
    bitwise, which is exactly what the canonical-sort flush relies on."""
    stal = [0, 1, 1, 2, 3, 0]
    base = staleness_weights(stal, _POLICY)
    permuted = staleness_weights([stal[i] for i in perm], _POLICY)
    np.testing.assert_array_equal(permuted, base[np.asarray(perm)])


def test_negative_staleness_refused():
    with pytest.raises(ValueError, match=">= 0"):
        staleness_scale(-1, _POLICY)


# ---------------------------------------------------------------------------
# simulator primitives
# ---------------------------------------------------------------------------


def test_simclock_orders_ties_by_push_sequence():
    clk = SimClock()
    clk.push(2.0, "b")
    clk.push(1.0, "a1", payload=1)
    clk.push(1.0, "a2", payload=2)
    assert clk.pop() == (1.0, "a1", 1)
    assert clk.pop() == (1.0, "a2", 2)
    assert clk.now == 1.0
    with pytest.raises(ValueError, match="before"):
        clk.push(0.5, "late")
    assert clk.pop()[1] == "b" and clk.now == 2.0


def test_journal_canonical_bytes_round_trip():
    j = EventJournal()
    j.append("flush", np.float64(1.5), merged=np.int64(3), w=[0.5, 0.5])
    j.append("end", 2.0)
    lines = j.canonical_bytes().decode().splitlines()
    assert lines[0] == '{"ev":"flush","i":0,"merged":3,"t":1.5,"w":[0.5,0.5]}'
    assert j.counts() == {"flush": 1, "end": 1}
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "j.jsonl")
        j.write(p)
        with open(p, "rb") as f:
            assert f.read() == j.canonical_bytes()


def test_arrival_uniforms_seeded_and_disjoint():
    sched = ParticipationSchedule(seed=11)
    u = sched.arrival_uniforms(3, 16)
    np.testing.assert_array_equal(u, sched.arrival_uniforms(3, 16))
    assert u.shape == (16,) and (u >= 0).all() and (u < 1).all()
    assert not np.array_equal(u, sched.arrival_uniforms(3, 16, salt=1))
    assert not np.array_equal(u, sched.arrival_uniforms(4, 16))


def test_params_digest_detects_any_leaf_change():
    tree = {"gen": {"theta": np.arange(3.0)}, "disc": {"w": np.ones(3)}}
    d0 = params_digest(tree)
    assert d0 == params_digest(tmap(np.copy, tree))
    bumped = {"gen": {"theta": np.arange(3.0)},
              "disc": {"w": np.ones(3) + 1e-9}}
    assert d0 != params_digest(bumped)
