"""repro.comm — codec round-trip properties (qpack kernel ↔ ref parity,
quantization error bounds, honest wire accounting), error-feedback
accumulation closed form, strategy/CLI integration, and the int8+EF
mixed-Gaussian convergence claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import (IntQuant, Sequential, TopK, codec_from_flags,
                        get_codec)
from repro.core import FedGAN, FedGANConfig, GANTask, losses
from repro.core.strategies import (FedAvgSync, LocalOnly, PartialSharing,
                                   SubsampledFedAvg)
from repro.dist import collectives
from repro.kernels.qpack import ops, ref
from repro.optim import Adam, SGD, constant, equal_timescale

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# qpack: Pallas pack/unpack vs ref oracle, bit-identical
# ---------------------------------------------------------------------------


@settings(max_examples=10)
@given(n=st.integers(1, 700), rows=st.integers(1, 5),
       bits=st.integers(0, 1), block=st.integers(0, 2), seed=st.integers(0, 99))
def test_qpack_kernel_matches_ref(n, rows, bits, block, seed):
    """Kernel (interpret) and ref must agree exactly — codes, scales and
    dequantized values — across shapes, bit widths and block sizes."""
    bits = (8, 4)[bits % 2]
    block = (64, 128, 512)[block % 3]
    x = 3.0 * jax.random.normal(jax.random.key(seed), (rows, n))
    qk, sk = ops.quantize_blocks(x, bits=bits, block=block, use_kernel=True)
    qr, sr = ops.quantize_blocks(x, bits=bits, block=block, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(sr))
    dk = ops.dequantize_blocks(qk, sk, n=n, bits=bits, block=block,
                               use_kernel=True)
    dr = ops.dequantize_blocks(qr, sr, n=n, bits=bits, block=block,
                               use_kernel=False)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


@settings(max_examples=10)
@given(n=st.integers(1, 900), bits=st.integers(0, 1), seed=st.integers(0, 99))
def test_quantize_roundtrip_error_bounded(n, bits, seed):
    """Per-block reconstruction error <= scale/2 (round-to-nearest) and the
    padded lanes never leak into the output."""
    bits = (8, 4)[bits % 2]
    block = 128
    x = jax.random.normal(jax.random.key(seed), (2, n))
    q, s = ops.quantize_blocks(x, bits=bits, block=block)
    out = ops.dequantize_blocks(q, s, n=n, bits=bits, block=block)
    assert out.shape == x.shape
    err = np.abs(np.asarray(out) - np.asarray(x))
    per_block_scale = np.repeat(np.asarray(s, np.float32), block,
                                axis=-1)[:, :n]
    assert (err <= 0.5 * per_block_scale + 1e-7).all()


def test_int4_pack_is_two_codes_per_byte():
    q = jnp.arange(-7, 8, dtype=jnp.int8).reshape(1, 15)
    q = jnp.pad(q, ((0, 0), (0, 1)))  # even length
    packed = ref.pack4_ref(q)
    assert packed.shape == (1, 8) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(ref.unpack4_ref(packed)),
                                  np.asarray(q))


def test_overflow_block_clips_instead_of_nan():
    """A block whose max-abs overflows the f16 scale must clip hard (EF
    absorbs the error) — never ship inf and decode 0*inf = NaN."""
    for bits in (8, 4):
        codec = IntQuant(bits=bits)
        x = jnp.full((256,), 9e6, jnp.float32)
        out = np.asarray(codec.roundtrip(x))
        assert np.isfinite(out).all(), bits
        qmax = 2 ** (bits - 1) - 1
        np.testing.assert_allclose(out, 65504.0 * qmax, rtol=1e-3)


def test_zero_block_roundtrips_to_zero():
    """A tile whose max-abs underflows f16 must decode to exact zeros, not
    NaN/inf from a zero-division."""
    x = jnp.concatenate([jnp.zeros((1, 128)),
                         1e-9 * jnp.ones((1, 128)),
                         jnp.ones((1, 128))], axis=1)
    q, s = ops.quantize_blocks(x, bits=8, block=128)
    out = np.asarray(ops.dequantize_blocks(q, s, n=384, bits=8, block=128))
    assert np.isfinite(out).all()
    np.testing.assert_array_equal(out[0, :256], 0.0)
    assert abs(out[0, 300] - 1.0) < 1e-2


# ---------------------------------------------------------------------------
# codec layer: wire accounting is honest, top-k keeps the right entries
# ---------------------------------------------------------------------------


@settings(max_examples=8)
@given(n=st.integers(1, 2000), frac=st.floats(0.01, 1.0),
       seed=st.integers(0, 99))
def test_topk_keeps_largest_and_bills_indices(n, frac, seed):
    codec = TopK(fraction=frac)
    x = jax.random.normal(jax.random.key(seed), (n,))
    like = jax.ShapeDtypeStruct((n,), jnp.float32)
    k = codec._k(n)
    out = np.asarray(codec.roundtrip(x))
    xs = np.asarray(x)
    kept = np.flatnonzero(out)
    assert len(kept) <= k
    # every surviving entry is exact, and no dropped |x| beats a kept one
    np.testing.assert_array_equal(out[kept], xs[kept])
    if k < n:
        thresh = np.sort(np.abs(xs))[-k]
        dropped = np.setdiff1d(np.arange(n), kept)
        assert (np.abs(xs[dropped]) <= thresh + 1e-7).all()
    # indices billed at 4 bytes, values at the leaf dtype
    assert codec.wire_bytes(like) == k * 4 + k * 4


@settings(max_examples=8)
@given(n=st.integers(1, 4000), bits=st.integers(0, 1))
def test_wire_bytes_match_materialized_arrays(n, bits):
    """wire_bytes must equal the trimmed payload + every meta array — the
    accounting can never drift from what encode actually produces."""
    bits = (8, 4)[bits % 2]
    codec = IntQuant(bits=bits)
    x = jax.random.normal(jax.random.key(0), (n,))
    like = jax.ShapeDtypeStruct((n,), jnp.float32)
    payload, meta = codec.encode(x)
    trimmed = (n * bits + 7) // 8  # padding lanes are never shipped
    meta_b = sum(int(m.size) * m.dtype.itemsize
                 for m in jax.tree_util.tree_leaves(meta))
    assert codec.wire_bytes(like) == trimmed + meta_b
    # padded payload only ever exceeds the billed bytes by < one block
    assert 0 <= payload.size * payload.dtype.itemsize - trimmed \
        < codec.block * bits // 8


def test_roundtrip_override_matches_encode_decode():
    """IntQuant.roundtrip skips the int4 nibble pack/unpack (a bit-exact
    identity) — the values must match the real wire path exactly."""
    for bits in (8, 4):
        codec = IntQuant(bits=bits, block=64)
        x = jax.random.normal(jax.random.key(5), (2, 3, 333))
        like = jax.ShapeDtypeStruct((333,), jnp.float32)
        payload, meta = codec.encode(x, 2)
        via_wire = codec.decode(payload, meta, like, 2)
        np.testing.assert_array_equal(np.asarray(codec.roundtrip(x, 2)),
                                      np.asarray(via_wire))


def test_sequential_chains_and_bills_every_stage():
    n = 1000
    like = jax.ShapeDtypeStruct((n,), jnp.float32)
    chain = Sequential((TopK(fraction=0.1), IntQuant(bits=8)))
    chain.validate()
    k = TopK(fraction=0.1)._k(n)
    want = (k * 4                                    # indices
            + IntQuant(bits=8).wire_bytes(jax.ShapeDtypeStruct((k,),
                                                               jnp.float32)))
    assert chain.wire_bytes(like) == want
    x = jax.random.normal(jax.random.key(1), (2, 2, n))
    out = np.asarray(chain.roundtrip(x, batch_ndims=2))
    assert out.shape == x.shape
    assert (np.count_nonzero(out, axis=-1) <= k).all()
    # quantizers are terminal: int8 codes cannot be re-encoded downstream
    with pytest.raises(ValueError, match="last stage"):
        Sequential((IntQuant(bits=8), TopK())).validate()


def test_registry_and_flag_resolution():
    assert get_codec("int8") == IntQuant(bits=8)
    assert get_codec("topk+int8", fraction=0.25, bits=8) == \
        Sequential((TopK(fraction=0.25), IntQuant(bits=8)))
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("bogus")
    assert codec_from_flags() is None
    assert codec_from_flags("int4") == IntQuant(bits=4)
    assert codec_from_flags("", bits=4) == IntQuant(bits=4)
    assert codec_from_flags("", topk=0.05) == TopK(fraction=0.05)
    # --topk beside a quantizer spec builds the sparsify-then-quantize chain
    assert codec_from_flags("int8", topk=0.25) == \
        Sequential((TopK(fraction=0.25), IntQuant(bits=8)))
    with pytest.raises(ValueError):
        IntQuant(bits=3).validate()
    with pytest.raises(ValueError):
        IntQuant(block=7).validate()
    with pytest.raises(ValueError):
        TopK(fraction=0.0).validate()


# ---------------------------------------------------------------------------
# error feedback: closed-form accumulation
# ---------------------------------------------------------------------------


def test_error_feedback_telescopes():
    """EF invariant: with y_t = x + e_{t-1}, q_t = Q(y_t), e_t = y_t - q_t,
    the transmitted sum telescopes to sum(q_1..t) = t*x - e_t exactly, and
    the residual stays bounded by one quantization step (no blow-up)."""
    codec = IntQuant(bits=4, block=16)
    x = jax.random.normal(jax.random.key(3), (64,))
    e = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    qmax = 2 ** (codec.bits - 1) - 1
    for t in range(1, 9):
        y = x + e
        q = codec.roundtrip(y)
        total = total + q
        e = y - q
        np.testing.assert_allclose(np.asarray(total), t * np.asarray(x)
                                   - np.asarray(e), rtol=0, atol=1e-5)
        # residual bound: half a step of the *current* block scales
        _, meta = codec.encode(y)
        step = np.repeat(np.asarray(meta["scale"], np.float32),
                         codec.block)[:64]
        assert (np.abs(np.asarray(e)) <= 0.5 * step + 1e-7).all()
    # time-average of what the intermediary saw converges to x
    np.testing.assert_allclose(np.asarray(total) / 8, np.asarray(x),
                               atol=float(step.max()))


# ---------------------------------------------------------------------------
# strategy integration
# ---------------------------------------------------------------------------


def quad_task():
    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)


def _fed(strategy, K=4, grid=(1, 4)):
    return FedGAN(quad_task(),
                  FedGANConfig(agent_grid=grid, sync_interval=K,
                               strategy=strategy),
                  opt_g=SGD(), opt_d=SGD(),
                  scales=equal_timescale(constant(0.05)))


def _run_rounds(fed, n_rounds=2, K=4):
    P, A = fed.cfg.agent_grid
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    for r in range(n_rounds):
        rng = jax.random.key(1 + r)
        x = (jax.random.normal(rng, (K, P, A, 8, 3))
             + jnp.arange(P * A, dtype=jnp.float32).reshape(P, A)[None, :, :,
                                                                  None, None])
        seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                                   2 ** 31 - 1).astype(jnp.uint32)
        state, metrics = round_fn(state, {"x": x}, seeds)
    return state, metrics


def test_coded_sync_state_carries_residuals():
    state, metrics = _run_rounds(_fed(FedAvgSync(codec=IntQuant(bits=8))))
    assert "ef" in state and "ef_down" in state
    assert state["ef"]["gen"]["theta"].shape == (1, 4, 3)     # per-agent
    assert state["ef_down"]["gen"]["theta"].shape == (3,)     # shared
    assert float(jnp.max(jnp.abs(state["ef"]["gen"]["theta"]))) > 0
    assert np.isfinite(np.asarray(metrics["d_loss"])).all()
    # all agents hold the same (coded) average after sync
    th = state["params"]["gen"]["theta"]
    np.testing.assert_array_equal(np.asarray(th[0, 0]), np.asarray(th[0, -1]))
    # without error feedback (or without a codec) the state stays lean
    state, _ = _run_rounds(_fed(FedAvgSync(codec=IntQuant(bits=8),
                                           error_feedback=False)))
    assert "ef" not in state and "ef_down" not in state
    state, _ = _run_rounds(_fed(FedAvgSync()))
    assert "ef" not in state and "ef_down" not in state


def test_coded_sync_matches_manual_ef_average():
    """One round of the coded path == the hand-rolled EF + decode→average→
    encode pipeline applied to the uncoded (local-only) trajectory."""
    K, grid = 4, (1, 4)
    codec = IntQuant(bits=8, block=16)
    coded, _ = _run_rounds(_fed(FedAvgSync(codec=codec), K=K, grid=grid),
                           n_rounds=1, K=K)
    local, _ = _run_rounds(_fed(LocalOnly(), K=K, grid=grid),
                           n_rounds=1, K=K)
    w = np.full((1, 4), 0.25, np.float32)
    for sub in ("gen", "disc"):
        for key, pre in local["params"][sub].items():
            pre = jnp.asarray(pre)
            q = codec.roundtrip(pre, batch_ndims=2)       # ef was zero
            m = jnp.einsum("pa,pa...->...", jnp.asarray(w), q)
            qd = codec.roundtrip(m)                       # ef_down was zero
            np.testing.assert_allclose(
                np.asarray(coded["params"][sub][key][0, 0]), np.asarray(qd),
                rtol=0, atol=1e-7)
            np.testing.assert_allclose(
                np.asarray(coded["ef"][sub][key]), np.asarray(pre - q),
                rtol=0, atol=1e-7)
            np.testing.assert_allclose(
                np.asarray(coded["ef_down"][sub][key]), np.asarray(m - qd),
                rtol=0, atol=1e-7)


def test_subsampled_coded_keeps_nonparticipant_residuals():
    K, grid = 2, (1, 4)
    strat = SubsampledFedAvg(fraction=0.5, codec=IntQuant(bits=8))
    fed = _fed(strat, K=K, grid=grid)
    state, _ = _run_rounds(fed, n_rounds=1, K=K)
    mask = np.asarray(strat.participation_mask(fed,
                                               {"step": jnp.int32(K)}))
    ef = np.asarray(state["ef"]["gen"]["theta"])
    # non-participants never encoded -> their residuals are still zero
    assert (ef[~mask] == 0).all()
    assert (np.abs(ef[mask]).max(axis=-1) > 0).all()


def test_partial_sharing_coded_bytes_and_residual_scope():
    state, _ = _run_rounds(_fed(PartialSharing(codec=IntQuant(bits=8))))
    assert set(state["ef"]) == {"gen"}  # D never hits the wire
    fed = _fed(FedAvgSync())
    params = fed.agent_params(fed.init_state(jax.random.key(0)))
    full = FedAvgSync().bytes_per_round(fed.cfg, params)
    gen_only = PartialSharing(codec=IntQuant(bits=8)).bytes_per_round(
        fed.cfg, params)
    assert gen_only < FedAvgSync(codec=IntQuant(bits=8)).bytes_per_round(
        fed.cfg, params) < full


def test_codec_bytes_reduction_on_real_params():
    """On the paper's mixed-Gaussian MLP GAN the billed wire cut is >= 3.5x
    (int8, scales included) and >= 4x (int4 / topk+int8) vs f32 FedAvg."""
    from repro.launch.train import mlp_gan_task
    task, _ = mlp_gan_task()
    params = jax.eval_shape(task.init, jax.random.key(0))
    cfg = FedGANConfig(agent_grid=(1, 4), sync_interval=20)
    full = FedAvgSync().bytes_per_round(cfg, params)
    i8 = FedAvgSync(codec=IntQuant(bits=8)).bytes_per_round(cfg, params)
    i4 = FedAvgSync(codec=IntQuant(bits=4)).bytes_per_round(cfg, params)
    tk8 = FedAvgSync(codec=Sequential((TopK(fraction=0.125),
                                       IntQuant(bits=8)))
                     ).bytes_per_round(cfg, params)
    assert full / i8 >= 3.5
    assert full / i4 >= 4.0
    assert full / tk8 >= 4.0


def test_config_validation_rejects_codec_misuse():
    cfg = FedGANConfig(agent_grid=(1, 4), sync_interval=4)
    with pytest.raises(ValueError, match="wire compressions"):
        FedAvgSync(codec=IntQuant(bits=8),
                   sync_dtype=jnp.bfloat16).validate(cfg)
    with pytest.raises(ValueError, match="wire compressions"):
        collectives.sync_bytes({"x": jnp.ones(4)},
                               sync_dtype=jnp.bfloat16,
                               codec=IntQuant(bits=8))
    # invalid codec knobs surface through strategy validation too
    with pytest.raises(ValueError, match="bits"):
        FedGANConfig(agent_grid=(1, 4), sync_interval=4,
                     strategy=FedAvgSync(codec=IntQuant(bits=3))).validate()


def test_cli_codec_flags():
    from repro.launch.train import build_parser, strategy_from_args

    def args(*argv):
        return build_parser().parse_args(["--experiment", "toy_2d",
                                          *argv])

    strat = strategy_from_args(args("--codec", "int8"))
    assert isinstance(strat, FedAvgSync) and strat.codec == IntQuant(bits=8)
    strat = strategy_from_args(args("--strategy", "partial_sharing",
                                    "--codec", "int4"))
    assert isinstance(strat, PartialSharing)
    assert strat.codec == IntQuant(bits=4)
    strat = strategy_from_args(args("--codec", "int8", "--topk", "0.25"))
    assert strat.codec == Sequential((TopK(fraction=0.25),
                                      IntQuant(bits=8)))
    # strategies that never sync (or sync per step) have no codec knob
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(args("--strategy", "local_only",
                                "--codec", "int8"))
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(args("--strategy", "distributed",
                                "--codec", "int8"))
    # double compression and legacy-mode mixes fail loudly
    with pytest.raises(ValueError, match="pick one"):
        strategy_from_args(args("--codec", "int8", "--sync-dtype", "bf16"))
    with pytest.raises(ValueError, match="requires --strategy"):
        strategy_from_args(args("--mode", "fedgan", "--codec", "int8"))
    # bare --codec implies fedgan, still through the stray-knob validation
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(args("--codec", "int8", "--participation", "0.5"))
    # a malformed chain spec is a clean error, not a traceback
    assert codec_from_flags("int8+") == IntQuant(bits=8)
    with pytest.raises(ValueError, match="empty codec spec"):
        codec_from_flags("+")


def test_checkpoint_roundtrip_carries_residuals(tmp_path):
    """EF residuals are training state: they must survive a save/load."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    fed = _fed(FedAvgSync(codec=IntQuant(bits=8)))
    state, _ = _run_rounds(fed, n_rounds=1)
    save_checkpoint(str(tmp_path), state, step=1)
    loaded, _ = restore_checkpoint(str(tmp_path))
    la = jax.tree_util.tree_leaves(loaded)
    sa = jax.tree_util.tree_leaves(state)
    assert len(la) == len(sa)
    for a, b in zip(sa, la):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mesh_round_specs_cover_residuals():
    """build_train_round must give the strategy-carried EF entries mesh
    shardings (jit would reject a state/sharding pytree mismatch): the
    agent-stacked uplink residuals shard like the params, the shared
    downlink residual is replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_train_round
    from repro.models.config import ShapeConfig
    mesh = make_test_mesh((1, 1), ("data", "model"))
    built = build_train_round(get_config("gemma3-4b").smoke(),
                              ShapeConfig("t", 1, 8, "train"), mesh, K=2,
                              strategy=FedAvgSync(codec=IntQuant(bits=8)))
    specs = built.meta["state_specs"]
    state_sds = built.input_sds[0]
    assert set(specs) == set(state_sds) >= {"ef", "ef_down"}
    assert jax.tree_util.tree_structure(
        tmap(lambda _: 0, specs["ef"], is_leaf=lambda x: isinstance(x, P))
    ) == jax.tree_util.tree_structure(tmap(lambda _: 0, state_sds["ef"]))
    down = jax.tree_util.tree_leaves(
        specs["ef_down"], is_leaf=lambda x: isinstance(x, P))
    assert down and all(s == P() for s in down)


# ---------------------------------------------------------------------------
# convergence: int8+EF holds mode coverage at matched steps
# ---------------------------------------------------------------------------


def _mixed_gaussian_coverage(strategy, steps=1500, B=4, K=5):
    from repro.data import synthetic
    from repro.evals import mode_stats
    from repro.models.gan_nets import MLPDiscriminator, MLPGenerator
    G = MLPGenerator(latent_dim=2, out_dim=2, hidden=64, depth=2)
    D = MLPDiscriminator(in_dim=2, hidden=64, depth=2)

    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": G.init(kg), "disc": D.init(kd)}

    def disc_loss(params, batch, rng):
        fake = jax.lax.stop_gradient(G.apply(params["gen"], batch["z"]))
        return losses.ns_d_loss(D.apply(params["disc"], batch["x"]),
                                D.apply(params["disc"], fake))

    def gen_loss(params, batch, rng):
        return losses.ns_g_loss(
            D.apply(params["disc"], G.apply(params["gen"], batch["z"])))

    task = GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=K,
                                    strategy=strategy),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(1e-3)))
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(1)
    n = 128
    for r in range(steps // K):
        rng, r1, r2, r3 = jax.random.split(rng, 4)
        x = jnp.stack([synthetic.sample_mixed_gaussian(
            jax.random.fold_in(r1, r * B + i), K * n,
            mode_subset=[2 * i, 2 * i + 1]).reshape(K, n, 2)
            for i in range(B)], axis=1).reshape(K, 1, B, n, 2)
        z = jax.random.normal(r2, (K, 1, B, n, 2))
        seeds = jax.random.randint(r3, (K, 1, B), 0,
                                   2 ** 31 - 1).astype(jnp.uint32)
        state, _ = round_fn(state, {"x": x, "z": z}, seeds)
    gp = fed.averaged_params(state)["gen"]
    samples = G.apply(gp, jax.random.normal(jax.random.key(9), (2000, 2)))
    assert not np.isnan(np.asarray(samples)).any()
    covered, _, _ = mode_stats(samples, synthetic.mixed_gaussian_modes(),
                               radius=0.5)
    return int(covered)


def test_int8_ef_holds_mode_coverage_at_matched_steps():
    """The acceptance claim: int8+error-feedback must keep the pooled mode
    coverage within 1 mode of the uncompressed run at equal (K, steps),
    while the billed wire shrinks 3.9x (see
    test_codec_bytes_reduction_on_real_params)."""
    base = _mixed_gaussian_coverage(None)
    coded = _mixed_gaussian_coverage(FedAvgSync(codec=IntQuant(bits=8)))
    assert coded >= base - 1, (base, coded)
    assert coded >= 5, coded
