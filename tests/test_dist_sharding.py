"""Unit tests for the repro.dist substrate that need no subprocess mesh:
the batch-axes context protocol, filter_spec's adaptation rules, the
param-spec name rules (lead/fsdp variants), dp_param_specs, shard_attn_qkv
off-mesh behaviour, and the collectives helpers' numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import collectives
from repro.dist.sharding import (DEFAULT_BATCH_AXES, batch_axes, batch_spec,
                                 current_batch_axes, dp_param_specs,
                                 filter_spec, named_shardings, param_specs,
                                 shape_of, shard, shard_attn_qkv)


class FakeMesh:
    """Duck-typed mesh: filter_spec/param_specs only read axis_names and
    devices.shape, so spec logic is testable on a single CPU device."""

    def __init__(self, dims: dict):
        self.axis_names = tuple(dims)
        self.devices = np.empty(tuple(dims.values()), dtype=object)


MESH = FakeMesh({"data": 4, "model": 2})
MESH3 = FakeMesh({"pod": 2, "data": 4, "model": 2})


# ---------------------------------------------------------------------------
# batch_axes context
# ---------------------------------------------------------------------------


def test_batch_axes_nesting_restores_on_exit():
    assert current_batch_axes() == DEFAULT_BATCH_AXES
    with batch_axes("model"):
        assert current_batch_axes() == ("model",)
        with batch_axes():
            assert current_batch_axes() == ()
            assert batch_spec(None) == (None, None)
        assert current_batch_axes() == ("model",)
    assert current_batch_axes() == DEFAULT_BATCH_AXES


def test_batch_axes_restores_on_exception():
    with pytest.raises(RuntimeError):
        with batch_axes("data"):
            raise RuntimeError("boom")
    assert current_batch_axes() == DEFAULT_BATCH_AXES


def test_batch_spec_prepends_current_axes():
    assert batch_spec(None, "model") == (("pod", "data"), None, "model")
    with batch_axes("data"):
        assert batch_spec() == (("data",),)


# ---------------------------------------------------------------------------
# filter_spec
# ---------------------------------------------------------------------------


def test_filter_spec_drops_unknown_axes():
    spec = filter_spec(MESH, (("pod", "data"), None), (8, 16))
    assert spec == P("data", None)


def test_filter_spec_divisibility_fallback():
    # 6 % 4 != 0 -> dim replicated, NOT unevenly sharded
    assert filter_spec(MESH, ("data", None), (6, 16)) == P(None, None)
    # tuple entry: (pod, data) product 8 divides 16
    assert filter_spec(MESH3, (("pod", "data"), None), (16, 3)) == \
        P(("pod", "data"), None)
    # product 8 does not divide 12 -> whole entry replicated
    assert filter_spec(MESH3, (("pod", "data"), None), (12, 3)) == P(None, None)


def test_filter_spec_axis_reuse_first_dim_wins():
    # "model" consumed by dim 0 (the DP-plan batch) is dropped from dim 2
    spec = filter_spec(MESH, (("model",), None, "model"), (8, 4, 16))
    assert spec == P("model", None, None)


def test_filter_spec_rejects_excess_entries():
    with pytest.raises(ValueError):
        filter_spec(MESH, (None, None, None), (4, 4))


# ---------------------------------------------------------------------------
# param_specs name rules
# ---------------------------------------------------------------------------


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


PARAMS = {
    "embed": {"table": _sds(512, 64)},
    "lm_head": {"w": _sds(64, 512)},
    "blocks": {
        "attn": {"wq": {"w": _sds(8, 64, 128)},
                 "wo": {"w": _sds(8, 128, 64)}},
        "mlp": {"w_up": {"w": _sds(8, 64, 128)},
                "w_down": {"w": _sds(8, 128, 64)}},
        "ln1": {"scale": _sds(8, 64)},
    },
}


def test_param_specs_col_row_and_replicated():
    specs = param_specs(PARAMS, MESH)
    assert specs["embed"]["table"] == P(None, "model")
    assert specs["lm_head"]["w"] == P(None, "model")
    assert specs["blocks"]["attn"]["wq"]["w"] == P(None, None, "model")
    assert specs["blocks"]["attn"]["wo"]["w"] == P(None, "model", None)
    assert specs["blocks"]["mlp"]["w_down"]["w"] == P(None, "model", None)
    assert specs["blocks"]["ln1"]["scale"] == P(None, None)


def test_param_specs_lead_consumes_leading_dims():
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((2, 4) + s.shape, s.dtype), PARAMS)
    specs = param_specs(stacked, MESH3, lead=("pod", "data"))
    assert specs["embed"]["table"] == P("pod", "data", None, "model")
    assert specs["blocks"]["attn"]["wq"]["w"] == \
        P("pod", "data", None, None, "model")
    # lead axes missing from the mesh are dropped, not errors
    specs2 = param_specs(stacked, MESH, lead=("pod", "data"))
    assert specs2["embed"]["table"] == P(None, "data", None, "model")


def test_param_specs_fsdp_axis_shards_complement_dim():
    specs = param_specs(PARAMS, MESH, fsdp_axis="data")
    # column-parallel: model on -1, fsdp on -2
    assert specs["embed"]["table"] == P("data", "model")
    assert specs["blocks"]["attn"]["wq"]["w"] == P(None, "data", "model")
    # row-parallel: model on -2, fsdp on -1
    assert specs["blocks"]["attn"]["wo"]["w"] == P(None, "model", "data")
    # unmatched leaves get plain trailing-dim FSDP
    assert specs["blocks"]["ln1"]["scale"] == P(None, "data")


def test_param_specs_divisibility_falls_back_per_dim():
    odd = {"wq": {"w": _sds(64, 3)}, "w_down": {"w": _sds(3, 64)}}
    specs = param_specs(odd, MESH)
    assert specs["wq"]["w"] == P(None, None)        # 3 % model(2) != 0
    assert specs["w_down"]["w"] == P(None, None)


def test_dp_param_specs_shards_innermost_divisible_dim():
    specs = dp_param_specs(PARAMS, MESH, lead=())
    assert specs["embed"]["table"] == P(None, "model")
    assert specs["blocks"]["ln1"]["scale"] == P(None, "model")  # 64 % 2 == 0
    odd = {"x": _sds(8, 3)}
    assert dp_param_specs(odd, MESH)["x"] == P("model", None)   # falls inward
    assert dp_param_specs({"x": _sds(3, 3)}, MESH)["x"] == P(None, None)


def test_dp_param_specs_respects_lead():
    stacked = {"w": _sds(2, 4, 64)}
    specs = dp_param_specs(stacked, MESH3, lead=("pod", "data"))
    assert specs["w"] == P("pod", "data", "model")
    # lead dims are never candidates for the model shard
    scalarish = {"count": _sds(2, 4)}
    assert dp_param_specs(scalarish, MESH3, lead=("pod", "data"))["count"] == \
        P("pod", "data")


# ---------------------------------------------------------------------------
# off-mesh behaviour + utilities
# ---------------------------------------------------------------------------


def test_shard_is_identity_without_mesh_context():
    x = jnp.ones((4, 8))
    assert shard(x, "data", "model") is x
    q = k = v = jnp.ones((2, 4, 4, 8))
    q2, k2, v2 = shard_attn_qkv(q, k, v)
    assert q2 is q and k2 is k and v2 is v


def test_named_shardings_passthrough_and_shape_of():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    tree = {"a": P("data"), "b": None}
    mapped = named_shardings(mesh, tree)
    assert isinstance(mapped["a"], jax.sharding.NamedSharding)
    assert mapped["a"].spec == P("data")
    assert mapped["b"] is None  # non-spec leaves pass through untouched
    assert shape_of(jax.ShapeDtypeStruct((3, 5), jnp.float32)) == (3, 5)


# ---------------------------------------------------------------------------
# collectives numerics (CPU, no mesh)
# ---------------------------------------------------------------------------


def test_average_agents_matches_manual_weighted_mean():
    k = jax.random.key(0)
    x = jax.random.normal(k, (2, 3, 5))
    w = jnp.array([[0.1, 0.2, 0.1], [0.2, 0.3, 0.1]], jnp.float32)
    out = collectives.average_agents({"x": x}, w)["x"]
    want = jnp.einsum("pa,pa...->...", w, x)
    np.testing.assert_allclose(out[0, 0], want, rtol=1e-6)
    np.testing.assert_allclose(out[1, 2], want, rtol=1e-6)  # broadcast back


def test_average_agents_sync_dtype_quantises():
    x = jnp.full((1, 2, 4), 1.0 + 2 ** -12, jnp.float32)
    w = jnp.full((1, 2), 0.5, jnp.float32)
    out = collectives.average_agents({"x": x}, w, sync_dtype=jnp.bfloat16)["x"]
    assert out.dtype == jnp.float32           # master copy stays f32
    np.testing.assert_allclose(out, 1.0)      # but the wire word dropped 2^-12


def test_average_intra_pod_is_per_pod():
    x = jnp.stack([jnp.zeros((2, 3)), jnp.ones((2, 3))])  # (P=2, A=2, 3)
    w = jnp.full((2, 2), 0.25, jnp.float32)
    out = collectives.average_intra_pod({"x": x}, w)["x"]
    np.testing.assert_allclose(out[0], 0.0)
    np.testing.assert_allclose(out[1], 1.0)


def test_sync_and_tree_bytes():
    tree = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    assert collectives.tree_bytes(tree) == (16 + 8) * 4
    assert collectives.sync_bytes(tree) == (16 + 8) * 4
    assert collectives.sync_bytes(tree, sync_dtype=jnp.bfloat16) == (16 + 8) * 2
    assert collectives.agent_axes() == ("pod", "data")
