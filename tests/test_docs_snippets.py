"""Executable documentation: every fenced ``python`` block tagged
``runnable`` in docs/*.md must actually run.

The serving guide (and the older docs before it) can only stay truthful if
their code executes against the current API — this is the CI gate that
stops docs drifting from the code, which is exactly how the pre-PR-3 docs
rotted.  ``make docs-check`` runs just this module.

Convention: tag a fence as ```` ```python runnable ```` to opt it in.
Untagged python fences are illustrative (may reference undefined names,
heavy meshes, ...) and are not executed.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
FENCE = re.compile(r"```python([^\n]*)\n(.*?)\n```", re.DOTALL)


def _snippets():
    out = []
    for doc in sorted((ROOT / "docs").glob("*.md")):
        for i, m in enumerate(FENCE.finditer(doc.read_text())):
            if "runnable" in m.group(1):
                out.append(pytest.param(doc.name, m.group(2),
                                        id=f"{doc.stem}-{i}"))
    return out


SNIPPETS = _snippets()


def test_docs_carry_runnable_snippets():
    """The tag convention is load-bearing: if a refactor renames it (or the
    docs lose their snippets), this fails rather than silently running
    nothing."""
    docs = {p.values[0] for p in SNIPPETS}
    assert "serving.md" in docs and "sharding.md" in docs
    assert len(SNIPPETS) >= 3


@pytest.mark.parametrize("doc,code", SNIPPETS)
def test_snippet_executes(doc, code):
    exec(compile(code, f"<{doc} snippet>", "exec"),
         {"__name__": "__docs_snippet__"})
