"""Unit tests for repro.evals — the metric substrate the run-time eval
harness (repro.run.evals) and the K-sweep figures stand on.

Each metric is checked against hand-computable fixtures: exact zeros /
known closed forms for the Fréchet distance, planted clusters for k-means,
and hand-placed samples for the mode-coverage stats.
"""
import jax
import numpy as np
import pytest

from repro.evals import (centroid_match_score, fd_score, frechet_distance,
                         kmeans, mode_stats, random_feature_fn,
                         wasserstein_1d_proj)


# ---------------------------------------------------------------------------
# Fréchet distance (the FID stand-in)
# ---------------------------------------------------------------------------


def _gauss(rng, n, mean, scale=1.0, d=4):
    return rng.randn(n, d) * scale + np.asarray(mean)


def test_frechet_identical_distributions_is_zero():
    x = np.random.RandomState(0).randn(512, 6)
    assert frechet_distance(x, x) == pytest.approx(0.0, abs=1e-6)


def test_frechet_symmetry():
    rng = np.random.RandomState(1)
    a, b = _gauss(rng, 400, [0, 0, 0, 0]), _gauss(rng, 400, [1, 0, -1, 2])
    assert frechet_distance(a, b) == pytest.approx(frechet_distance(b, a),
                                                   rel=1e-6)


def test_frechet_mean_shift_closed_form():
    """For equal covariances the distance reduces to ||mu_r - mu_f||^2."""
    rng = np.random.RandomState(2)
    base = rng.randn(20000, 3)
    shift = np.asarray([1.5, -0.5, 2.0])
    d2 = frechet_distance(base, base + shift)
    assert d2 == pytest.approx(float(shift @ shift), rel=0.05)


def test_frechet_common_translation_invariance():
    """Shifting BOTH sets by one vector must not move the score."""
    rng = np.random.RandomState(3)
    a, b = _gauss(rng, 600, [0, 0, 0, 0]), _gauss(rng, 600, [2, 0, 0, 0])
    t = np.asarray([10.0, -3.0, 7.0, 1.0])
    assert frechet_distance(a + t, b + t) == pytest.approx(
        frechet_distance(a, b), rel=1e-4)


def test_frechet_common_rotation_invariance():
    """The Gaussian-Fréchet form is invariant under a shared orthogonal
    transform (means rotate together, covariances conjugate together)."""
    rng = np.random.RandomState(4)
    a = _gauss(rng, 800, [1, 0, 0, 0], scale=1.3)
    b = _gauss(rng, 800, [0, 2, 0, 0], scale=0.7)
    q, _ = np.linalg.qr(rng.randn(4, 4))
    assert frechet_distance(a @ q, b @ q) == pytest.approx(
        frechet_distance(a, b), rel=1e-3)


def test_frechet_orders_increasing_separation():
    rng = np.random.RandomState(5)
    base = _gauss(rng, 500, [0, 0, 0, 0])
    prev = -1.0
    for shift in (0.5, 1.0, 2.0, 4.0):
        d = frechet_distance(base, _gauss(rng, 500, [shift, 0, 0, 0]))
        assert d > prev
        prev = d


def test_fd_score_end_to_end_separates():
    """fd_score (random-feature pipeline) must score same-distribution far
    below different-distribution, with the same shared feature map."""
    rng = np.random.RandomState(6)
    key = jax.random.key(0)
    real = rng.randn(800, 2)
    same = rng.randn(800, 2)
    far = rng.randn(800, 2) + 5.0
    assert fd_score(key, real, same) * 10 < fd_score(key, real, far)


def test_random_feature_fn_deterministic_given_key():
    f1 = random_feature_fn(jax.random.key(7), in_dim=3)
    f2 = random_feature_fn(jax.random.key(7), in_dim=3)
    x = np.random.RandomState(0).randn(10, 3).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(f1(x)), np.asarray(f2(x)))


# ---------------------------------------------------------------------------
# k-means + centroid matching (time-series figures)
# ---------------------------------------------------------------------------


def _planted_clusters(rng, centers, per=100, noise=0.02):
    return np.concatenate([c + noise * rng.randn(per, len(c))
                           for c in centers])


def test_kmeans_recovers_planted_centroids():
    centers = np.asarray([[0.0, 0.0], [5.0, 5.0], [-5.0, 5.0]])
    x = _planted_clusters(np.random.RandomState(0), centers)
    cent, assign, sizes = kmeans(x, 3, seed=1)
    # each planted center is within noise of one recovered centroid
    d = np.linalg.norm(centers[:, None] - cent[None], axis=-1)
    assert d.min(axis=1).max() < 0.1
    assert sizes.sum() == len(x)
    # sizes sorted descending, assignments consistent with the remap
    assert (np.diff(sizes) <= 0).all()
    for j in range(3):
        pts = x[assign == j]
        np.testing.assert_allclose(pts.mean(0), cent[j], atol=0.1)


def test_kmeans_unequal_cluster_sizes_order():
    rng = np.random.RandomState(2)
    x = np.concatenate([_planted_clusters(rng, [[0.0, 0.0]], per=300),
                        _planted_clusters(rng, [[8.0, 8.0]], per=50)])
    cent, _, sizes = kmeans(x, 2, seed=0)
    assert sizes[0] == 300 and sizes[1] == 50
    np.testing.assert_allclose(cent[0], [0, 0], atol=0.1)


def test_centroid_match_identical_data_beats_random():
    rng = np.random.RandomState(3)
    centers = rng.randn(5, 8) * 3
    x = _planted_clusters(rng, centers, per=80)
    out = centroid_match_score(x, x, k=5, top=5, seed=0)
    assert out["matched_rmse"] == pytest.approx(0.0, abs=0.05)
    assert out["matched_rmse"] < out["random_rmse"]
    assert out["real_centroids"].shape == (5, 8)


def test_centroid_match_detects_distribution_shift():
    rng = np.random.RandomState(4)
    centers = rng.randn(4, 6)
    x = _planted_clusters(rng, centers, per=60)
    y = _planted_clusters(rng, centers + 3.0, per=60)
    near = centroid_match_score(x, x, k=4, top=4)["matched_rmse"]
    far = centroid_match_score(x, y, k=4, top=4)["matched_rmse"]
    assert far > near + 1.0


# ---------------------------------------------------------------------------
# mode coverage (mixed-Gaussian figure)
# ---------------------------------------------------------------------------


def test_mode_stats_hand_fixture():
    modes = np.asarray([[0.0, 0.0], [4.0, 0.0], [0.0, 4.0], [4.0, 4.0]])
    # 60 samples at mode0, 40 at mode1, nothing near modes 2/3, 10 garbage
    samples = np.concatenate([
        np.tile(modes[0], (60, 1)), np.tile(modes[1], (40, 1)),
        np.full((10, 2), 100.0)])
    covered, hq, counts = mode_stats(samples, modes, radius=0.3)
    assert covered == 2
    assert hq == pytest.approx(100 / 110)
    np.testing.assert_array_equal(counts, [60, 40, 0, 0])


def test_mode_stats_one_percent_threshold():
    """A mode needs >= 1% of ALL samples to count as covered."""
    modes = np.asarray([[0.0, 0.0], [4.0, 0.0]])
    samples = np.concatenate([np.tile(modes[0], (995, 1)),
                              np.tile(modes[1], (5, 1))])
    covered, _, _ = mode_stats(samples, modes, radius=0.3)
    assert covered == 1  # 5/1000 < 1% -> mode1 not covered
    samples = np.concatenate([np.tile(modes[0], (990, 1)),
                              np.tile(modes[1], (10, 1))])
    covered, _, _ = mode_stats(samples, modes, radius=0.3)
    assert covered == 2


def test_mode_stats_radius_gates_quality():
    modes = np.asarray([[0.0, 0.0]])
    samples = np.asarray([[0.1, 0.0], [0.0, 0.25], [1.0, 1.0]])
    covered, hq, _ = mode_stats(samples, modes, radius=0.3)
    assert hq == pytest.approx(2 / 3)


def test_wasserstein_1d_proj_zero_and_shift():
    rng = np.random.RandomState(5)
    a = rng.randn(2000, 2)
    assert wasserstein_1d_proj(a, a) == pytest.approx(0.0, abs=1e-9)
    shift = wasserstein_1d_proj(a, a + np.asarray([3.0, 0.0]))
    # sliced-W of a pure translation ~ E|<t, v>| over random unit v < |t|
    assert 0.5 < shift < 3.0


# ---------------------------------------------------------------------------
# the run-time eval harness on top
# ---------------------------------------------------------------------------


def test_evaluate_scores_averaged_generator():
    """repro.run.evals.evaluate: perfect generator -> near-zero FD and full
    mode coverage; collapsed generator -> worse FD, fewer modes."""
    import jax.numpy as jnp

    from repro.core import FedGAN, FedGANConfig, GANTask
    from repro.run.evals import EvalSuite, evaluate

    modes = np.asarray([[0.0, 0.0], [3.0, 0.0]])
    rng = np.random.RandomState(0)
    real = modes[rng.randint(0, 2, 2000)] + 0.05 * rng.randn(2000, 2)

    def init(r):
        return {"gen": {"w": jnp.zeros(())}, "disc": {"w": jnp.zeros(())}}

    task = GANTask(init=init, disc_loss=lambda p, b, r: 0.0,
                   gen_loss=lambda p, b, r: 0.0)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, 2), sync_interval=1))
    state = fed.init_state(jax.random.key(0))

    def perfect(gp, r, n):
        k1, k2 = jax.random.split(r)
        idx = jax.random.randint(k1, (n,), 0, 2)
        return jnp.asarray(modes)[idx] + 0.05 * jax.random.normal(k2, (n, 2))

    def collapsed(gp, r, n):
        return jnp.zeros((n, 2)) + 0.05 * jax.random.normal(r, (n, 2))

    good = evaluate(EvalSuite(real=real, sample_fake=perfect, modes=modes),
                    fed, state, jax.random.key(1), n=1000)
    bad = evaluate(EvalSuite(real=real, sample_fake=collapsed, modes=modes),
                   fed, state, jax.random.key(1), n=1000)
    assert good["fd"] < bad["fd"]
    assert good["modes_covered"] == 2.0 and bad["modes_covered"] == 1.0
    assert good["high_quality_frac"] > 0.95
