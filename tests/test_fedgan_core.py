"""Core FedGAN algorithm: unit + hypothesis property tests (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import FedGAN, FedGANConfig, GANTask, dataset_weights, losses
from repro.core.fedgan import uniform_weights
from repro.optim import SGD, Adam, constant, equal_timescale

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# A tiny quadratic GAN task for exact reasoning
# ---------------------------------------------------------------------------


def quad_task():
    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        # simple saddle: L_D = -w.(x_mean - theta) + |w|^2/2
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)


def _round_inputs(rng, K, P, A, n=8, d=3):
    x = jax.random.normal(rng, (K, P, A, n, d))
    seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                               2 ** 31 - 1).astype(jnp.uint32)
    return {"x": x}, seeds


def _fed(task, K=4, grid=(1, 4), mode="fedgan", **kw):
    return FedGAN(task, FedGANConfig(agent_grid=grid, sync_interval=K,
                                     mode=mode, **kw),
                  opt_g=SGD(), opt_d=SGD(),
                  scales=equal_timescale(constant(0.05)))


def test_init_state_identical_across_agents():
    fed = _fed(quad_task())
    state = fed.init_state(jax.random.key(0))
    th = state["params"]["gen"]["theta"]
    assert th.shape[:2] == (1, 4)
    for a in range(4):
        np.testing.assert_array_equal(np.asarray(th[0, a]), np.asarray(th[0, 0]))


def test_sync_makes_agents_equal_and_weighted():
    fed = _fed(quad_task(), K=2)
    state = fed.init_state(jax.random.key(0))
    # de-synchronise params manually
    state["params"]["gen"]["theta"] = jnp.arange(12.0).reshape(1, 4, 3)
    synced = fed._sync(state)
    th = synced["params"]["gen"]["theta"]
    want = jnp.mean(jnp.arange(12.0).reshape(4, 3), axis=0)
    for a in range(4):
        np.testing.assert_allclose(np.asarray(th[0, a]), np.asarray(want), rtol=1e-6)


def test_round_fedgan_ends_synced_local_only_does_not():
    rng = jax.random.key(1)
    batches, seeds = _round_inputs(rng, 4, 1, 4)
    # make agent data non-iid so local runs diverge
    batches = {"x": batches["x"] + jnp.arange(4.0)[None, None, :, None, None]}
    for mode, expect_equal in [("fedgan", True), ("local_only", False),
                               ("distributed", True)]:
        fed = _fed(quad_task(), K=4, mode=mode)
        state = fed.init_state(jax.random.key(0))
        state, _ = jax.jit(fed.round)(state, batches, seeds)
        th = state["params"]["gen"]["theta"][0]
        equal = bool(jnp.allclose(th[0], th[1], atol=1e-6) and
                     jnp.allclose(th[0], th[3], atol=1e-6))
        assert equal == expect_equal, mode


def test_distributed_equals_fedgan_k1_for_sgd():
    """With K=1 and plain SGD, parameter averaging after the step equals
    averaging the gradients (linearity) -> the two modes coincide."""
    rng = jax.random.key(2)
    batches, seeds = _round_inputs(rng, 1, 1, 4)
    batches = {"x": batches["x"] + jnp.arange(4.0)[None, None, :, None, None]}
    out = {}
    for mode in ("fedgan", "distributed"):
        fed = _fed(quad_task(), K=1, mode=mode)
        state = fed.init_state(jax.random.key(0))
        state, _ = jax.jit(fed.round)(state, batches, seeds)
        out[mode] = fed.averaged_params(state)
    for a, b in zip(jax.tree_util.tree_leaves(out["fedgan"]),
                    jax.tree_util.tree_leaves(out["distributed"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_hierarchical_matches_fedgan_when_single_pod():
    """With P=1, intra-pod sync == full sync, so hierarchical(K1) just syncs
    more often; with K1=K it must equal plain fedgan exactly."""
    rng = jax.random.key(3)
    batches, seeds = _round_inputs(rng, 4, 1, 4)
    fed_h = _fed(quad_task(), K=4, mode="hierarchical", intra_interval=4)
    fed_f = _fed(quad_task(), K=4, mode="fedgan")
    s_h, _ = jax.jit(fed_h.round)(fed_h.init_state(jax.random.key(0)), batches, seeds)
    s_f, _ = jax.jit(fed_f.round)(fed_f.init_state(jax.random.key(0)), batches, seeds)
    for a, b in zip(jax.tree_util.tree_leaves(s_h["params"]),
                    jax.tree_util.tree_leaves(s_f["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_validate_rejects_bad_configs():
    with pytest.raises(ValueError):
        FedGANConfig(mode="hierarchical", sync_interval=4, intra_interval=3).validate()
    with pytest.raises(ValueError):
        FedGANConfig(mode="nonsense").validate()


def test_comm_accounting_matches_paper_ratio():
    fed = _fed(quad_task(), K=20)
    state = fed.init_state(jax.random.key(0))
    acc = fed.comm_bytes_per_round(state)
    assert acc["per_agent_per_round"]["distributed"] == \
        20 * acc["per_agent_per_round"]["fedgan"]
    assert acc["ratio"] == 20


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(sizes=st.lists(st.integers(1, 1000), min_size=2, max_size=8))
def test_dataset_weights_normalised(sizes):
    w = dataset_weights(sizes)
    assert abs(float(jnp.sum(w)) - 1.0) < 1e-5
    assert float(jnp.min(w)) >= 0.0
    # proportionality (paper §3.1)
    ratio = np.asarray(w) * sum(sizes) / np.asarray(sizes, np.float32)
    np.testing.assert_allclose(ratio, 1.0, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    vals=st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=4),
    w_raw=st.lists(st.floats(0.01, 10, allow_nan=False), min_size=4, max_size=4),
)
def test_weighted_average_is_convex_combination(vals, w_raw):
    """The sync average must stay inside the convex hull of agent params."""
    task = quad_task()
    w = jnp.asarray(w_raw) / sum(w_raw)
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, 4), sync_interval=1),
                 weights=w.reshape(1, 4),
                 scales=equal_timescale(constant(0.1)))
    state = fed.init_state(jax.random.key(0))
    v = jnp.asarray(vals, jnp.float32)
    state["params"]["gen"]["theta"] = v.reshape(1, 4, 1) * jnp.ones((1, 4, 3))
    synced = fed._sync(state)
    th = np.asarray(synced["params"]["gen"]["theta"])
    assert th.min() >= min(vals) - 1e-3
    assert th.max() <= max(vals) + 1e-3
    np.testing.assert_allclose(th[0, 0, 0], float(jnp.dot(w, v)), rtol=1e-4,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(perm=st.permutations(range(4)))
def test_sync_permutation_equivariance(perm):
    """Uniform-weight averaging is invariant to agent permutation."""
    task = quad_task()
    fed = _fed(task, K=1)
    state = fed.init_state(jax.random.key(0))
    base = jnp.arange(12.0).reshape(1, 4, 3)
    state["params"]["gen"]["theta"] = base
    a = fed._sync(state)["params"]["gen"]["theta"][0, 0]
    state["params"]["gen"]["theta"] = base[:, list(perm)]
    b = fed._sync(state)["params"]["gen"]["theta"][0, 0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sync_fixed_point(seed):
    """If all agents already share identical params, sync is a no-op."""
    fed = _fed(quad_task(), K=1)
    state = fed.init_state(jax.random.key(seed))
    synced = fed._sync(state)
    for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                    jax.tree_util.tree_leaves(synced["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_sync_dtype_compression_close_to_exact():
    fed_c = _fed(quad_task(), K=1, sync_dtype=jnp.bfloat16)
    fed_e = _fed(quad_task(), K=1)
    state = fed_e.init_state(jax.random.key(0))
    state["params"]["gen"]["theta"] = jax.random.normal(jax.random.key(1), (1, 4, 3))
    exact = fed_e._sync(state)["params"]["gen"]["theta"]
    comp = fed_c._sync(state)["params"]["gen"]["theta"]
    np.testing.assert_allclose(np.asarray(comp), np.asarray(exact), atol=0.05)


def test_uniform_weights_shape():
    cfg = FedGANConfig(agent_grid=(2, 3))
    w = uniform_weights(cfg)
    assert w.shape == (2, 3)
    assert abs(float(jnp.sum(w)) - 1.0) < 1e-6
