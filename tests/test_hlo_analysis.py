"""Loop-aware HLO analyzer: calibration against known-FLOP programs
(the dry-run's roofline terms depend on this being exact)."""
import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import collective_bytes, program_costs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_program_costs_counts_scan_trips():
    """A 10-trip scanned matmul must report 10x one trip's FLOPs (XLA's own
    cost_analysis reports 1x — the bug this module exists to fix)."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import program_costs
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
N = 512
def f(x, w):
    def body(h, _):
        return jnp.tanh(h @ w), None
    return jax.lax.scan(body, x, None, length=10)[0]
with jax.set_mesh(mesh):
    comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                    NamedSharding(mesh, P(None, "model")))
                   ).lower(jax.ShapeDtypeStruct((64, N), jnp.float32),
                           jax.ShapeDtypeStruct((N, N), jnp.float32)).compile()
pc = program_costs(comp.as_text())
ca = comp.cost_analysis()
# per-device per-trip: 2 * (64/4) * 512 * (512/2) = 4.19e6; x10 trips
assert abs(pc["flops"] - 10 * 2 * 16 * 512 * 256) < 1e4, pc["flops"]
assert ca["flops"] < pc["flops"] / 5  # cost_analysis undercounts
assert pc["hbm_bytes"] > 10 * 512 * 256 * 4  # at least the weight reads
print("CALIBRATED")
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "CALIBRATED" in res.stdout


def test_group_signature_distinguishes_axes():
    hlo = """
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar1 = f32[8,8]{1,0} all-reduce(%a), replica_groups=[4,2]<=[8], to_apply=%add
  %ar2 = f32[8,8]{1,0} all-reduce(%ar1), replica_groups=[2,4]<=[8]T(1,0), to_apply=%add
  ROOT %r = f32[8,8]{1,0} all-reduce(%ar2), replica_groups=[4,2]<=[8]T(1,0), to_apply=%add
}
"""
    st = collective_bytes(hlo)
    ax = st.bytes_by_axis({"data": 4, "model": 2})
    b = 8 * 8 * 4
    assert ax["model"] == b          # size-2 minor-most
    assert ax["agent"] == b          # size-4 transposed == data axis
    assert ax["other"] == b          # size-2 transposed: partial/other


def test_fusion_flops_counted_once():
    hlo = """
%fused_dot (p0: f32[4,8], p1: f32[8,4]) -> f32[4,4] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,4]{1,0} parameter(1)
  ROOT %d = f32[4,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[4,8], b: f32[8,4]) -> f32[4,4] {
  %a = f32[4,8]{1,0} parameter(0)
  %b = f32[8,4]{1,0} parameter(1)
  ROOT %f = f32[4,4]{1,0} fusion(%a, %b), kind=kOutput, calls=%fused_dot
}
"""
    pc = program_costs(hlo)
    assert pc["flops"] == 2 * 4 * 4 * 8
