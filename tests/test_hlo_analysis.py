"""Loop-aware HLO analyzer: calibration against known-FLOP programs
(the dry-run's roofline terms depend on this being exact)."""
import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import collective_bytes, program_costs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_program_costs_counts_scan_trips():
    """A 10-trip scanned matmul must report 10x one trip's FLOPs (XLA's own
    cost_analysis reports 1x — the bug this module exists to fix)."""
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import program_costs
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
N = 512
def f(x, w):
    def body(h, _):
        return jnp.tanh(h @ w), None
    return jax.lax.scan(body, x, None, length=10)[0]
with jax.set_mesh(mesh):
    comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                    NamedSharding(mesh, P(None, "model")))
                   ).lower(jax.ShapeDtypeStruct((64, N), jnp.float32),
                           jax.ShapeDtypeStruct((N, N), jnp.float32)).compile()
pc = program_costs(comp.as_text())
ca = comp.cost_analysis()
# per-device per-trip: 2 * (64/4) * 512 * (512/2) = 4.19e6; x10 trips
assert abs(pc["flops"] - 10 * 2 * 16 * 512 * 256) < 1e4, pc["flops"]
assert ca["flops"] < pc["flops"] / 5  # cost_analysis undercounts
assert pc["hbm_bytes"] > 10 * 512 * 256 * 4  # at least the weight reads
print("CALIBRATED")
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "CALIBRATED" in res.stdout


def test_group_signature_distinguishes_axes():
    hlo = """
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %ar1 = f32[8,8]{1,0} all-reduce(%a), replica_groups=[4,2]<=[8], to_apply=%add
  %ar2 = f32[8,8]{1,0} all-reduce(%ar1), replica_groups=[2,4]<=[8]T(1,0), to_apply=%add
  ROOT %r = f32[8,8]{1,0} all-reduce(%ar2), replica_groups=[4,2]<=[8]T(1,0), to_apply=%add
}
"""
    st = collective_bytes(hlo)
    ax = st.bytes_by_axis({"data": 4, "model": 2})
    b = 8 * 8 * 4
    assert ax["model"] == b          # size-2 minor-most
    assert ax["agent"] == b          # size-4 transposed == data axis
    assert ax["other"] == b          # size-2 transposed: partial/other


def test_fusion_flops_counted_once():
    hlo = """
%fused_dot (p0: f32[4,8], p1: f32[8,4]) -> f32[4,4] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,4]{1,0} parameter(1)
  ROOT %d = f32[4,4]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}

ENTRY %main (a: f32[4,8], b: f32[8,4]) -> f32[4,4] {
  %a = f32[4,8]{1,0} parameter(0)
  %b = f32[8,4]{1,0} parameter(1)
  ROOT %f = f32[4,4]{1,0} fusion(%a, %b), kind=kOutput, calls=%fused_dot
}
"""
    pc = program_costs(hlo)
    assert pc["flops"] == 2 * 4 * 4 * 8


def test_sub_byte_types_half_byte_per_elem():
    """u4/s4 buffers are ceil(n/2) bytes — the old table fell through to the
    4-byte unknown-dtype default and overstated int4 wire traffic 8x."""
    hlo = """
ENTRY %main (a: u4[1000]) -> u4[1000] {
  ROOT %ag = u4[1000]{0} all-gather(%a), replica_groups=[2,4]<=[8]T(1,0), dimensions={0}
}
"""
    st = collective_bytes(hlo)
    assert st.total_bytes == 500, st.total_bytes
    odd = collective_bytes("""
ENTRY %main (a: s4[7]) -> s4[7] {
  ROOT %ag = s4[7]{0} all-gather(%a), replica_groups=[2,4]<=[8]T(1,0), dimensions={0}
}
""")
    assert odd.total_bytes == 4, odd.total_bytes  # ceil(7/2), integer math


def test_collective_records_loop_context_and_metadata():
    from repro.launch.hlo_analysis import collective_records
    hlo = """
%body (t: (f32[8])) -> (f32[8]) {
  %t = (f32[8]{0}) parameter(0)
  %g = f32[8]{0} get-tuple-element(%t), index=0
  %ar = f32[8]{0} all-reduce(f32[8]{0} %g), replica_groups=[2,4]<=[8]T(1,0), to_apply=%add
  ROOT %out = (f32[8]{0}) tuple(%ar)
}

%cond (t: (f32[8])) -> pred[] {
  ROOT %c = pred[] constant(false)
}

ENTRY %main (a: bf16[16]) -> bf16[16] {
  %a = bf16[16]{0} parameter(0)
  %once = bf16[16]{0} all-reduce(bf16[16]{0} %a), replica_groups=[2,4]<=[8]T(1,0), to_apply=%add, metadata={op_name="jit(round)/sync" source_file="/root/repo/src/repro/dist/collectives.py" source_line=42}
  %t = (f32[8]{0}) tuple(%f)
  %w = (f32[8]{0}) while(%t), condition=%cond, body=%body
  ROOT %r = bf16[16]{0} copy(%once)
}
"""
    recs = collective_records(hlo)
    by_comp = {r.computation: r for r in recs}
    once = by_comp["main"]
    assert not once.in_loop
    assert once.operand_dtypes == ("bf16",)
    assert once.bytes == 16 * 2
    assert once.source_file.endswith("dist/collectives.py")
    assert once.source_line == 42
    looped = by_comp["body"]
    assert looped.in_loop
    assert looped.operand_dtypes == ("f32",)
    assert looped.group_signature == "4T"
