"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracles, with
shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedavg.ops import fedavg_tree
from repro.kernels.fedavg.ref import fedavg_flat_ref, fedavg_tree_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_decode_ref, ssd_ref


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,N", [(2, 64), (5, 1037), (8, 4096), (16, 513)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_flat_matches_ref(B, N, dtype):
    w = jax.random.dirichlet(jax.random.key(0), jnp.ones(B))
    x = jax.random.normal(jax.random.key(1), (B, N)).astype(dtype)
    got = fedavg_tree(w, {"x": x}, interpret=True)["x"]
    want = fedavg_flat_ref(w, x)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_fedavg_tree_multi_leaf_and_2d_agent_grid():
    w = jnp.full((2, 3), 1.0 / 6)
    tree = {"a": jax.random.normal(jax.random.key(0), (2, 3, 7, 5)),
            "b": [jax.random.normal(jax.random.key(1), (2, 3, 11))]}
    got = fedavg_tree(w, tree, interpret=True)
    want = fedavg_tree_ref(w.reshape(-1), jax.tree_util.tree_map(
        lambda x: x.reshape((6,) + x.shape[2:]), tree))
    for g, r in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=1e-5)


def test_fedavg_block_sizes():
    w = jnp.ones(4) / 4
    x = jax.random.normal(jax.random.key(2), (4, 777))
    for block in (64, 128, 512, 1024):
        got = fedavg_tree(w, {"x": x}, block=block, interpret=True)["x"]
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(fedavg_flat_ref(w, x)), atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (T, S, nh, nkv, hd, causal, window)
    (128, 128, 4, 4, 64, True, 0),
    (256, 256, 4, 2, 64, True, 0),
    (256, 256, 8, 1, 32, True, 0),     # MQA
    (128, 128, 4, 2, 64, False, 0),    # bidirectional (encoder)
    (256, 256, 4, 2, 64, True, 64),    # sliding window
    (192, 192, 4, 4, 32, True, 50),    # non-multiple window + padded T
    (96, 96, 2, 2, 128, True, 0),      # T < block
]


@pytest.mark.parametrize("T,S,nh,nkv,hd,causal,window", FLASH_CASES)
def test_flash_attention_matches_ref(T, S, nh, nkv, hd, causal, window):
    q = jax.random.normal(jax.random.key(0), (2, T, nh, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (2, S, nkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (2, S, nkv, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    want = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=causal, window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.key(0), (1, 128, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, 128, 2, 64)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = jnp.swapaxes(attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_flash_attention_block_shapes():
    q = jax.random.normal(jax.random.key(0), (1, 256, 2, 64))
    k = jax.random.normal(jax.random.key(1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.key(2), (1, 256, 2, 64))
    want = flash_attention(q, k, v, causal=True, interpret=True)
    for bq, bk in [(64, 64), (64, 128), (128, 64)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (T, nh, hd, ds, chunk, head_block)
    (64, 4, 16, 8, 16, 4),
    (128, 8, 32, 16, 32, 4),
    (128, 8, 32, 16, 32, 8),
    (96, 2, 64, 32, 32, 1),
    (256, 4, 16, 64, 128, 2),
]


def _ssd_inputs(T, nh, hd, ds, dtype=jnp.float32):
    x = 0.5 * jax.random.normal(jax.random.key(0), (2, T, nh, hd)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (2, T, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.key(2), (nh,)))
    B = 0.5 * jax.random.normal(jax.random.key(3), (2, T, ds))
    C = 0.5 * jax.random.normal(jax.random.key(4), (2, T, ds))
    return x, dt, A, B, C


@pytest.mark.parametrize("T,nh,hd,ds,chunk,head_block", SSD_CASES)
def test_ssd_kernel_matches_ref(T, nh, hd, ds, chunk, head_block):
    x, dt, A, B, C = _ssd_inputs(T, nh, hd, ds)
    got = ssd(x, dt, A, B, C, chunk=chunk, head_block=head_block, interpret=True)
    want = ssd_ref(x, dt, A, B, C, chunk=chunk)
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(want) / scale,
                               atol=3e-6)


def test_ssd_chunk_invariance():
    """The chunked algorithm must be exact: answers identical across chunk
    sizes (up to float assoc)."""
    x, dt, A, B, C = _ssd_inputs(128, 4, 16, 8)
    outs = [ssd_ref(x, dt, A, B, C, chunk=c) for c in (8, 16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]), atol=1e-4)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == literal per-step recurrence."""
    T, nh, hd, ds = 32, 2, 8, 4
    x, dt, A, B, C = _ssd_inputs(T, nh, hd, ds)
    want = ssd_ref(x, dt, A, B, C, chunk=8)
    state = jnp.zeros((2, nh, hd, ds))
    ys = []
    for t in range(T):
        y, state = ssd_decode_ref(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(want), atol=1e-5)
