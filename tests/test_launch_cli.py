"""Launcher plumbing: the CLI must reach every strategy knob.

Regression for the pre-strategy gap where ``--mode hierarchical`` always
raised ValueError because ``intra_interval`` (and ``sync_dtype`` /
``average_opt_state``) were not exposed by ``repro.launch.train``.
"""
import jax.numpy as jnp
import pytest

from repro.core.strategies import (AdaptiveK, FedAvgSync, Hierarchical,
                                   PartialSharing, SubsampledFedAvg)
from repro.launch.train import (RunSpec, build_parser, run_experiment,
                                strategy_from_args, toy2d_task)


def _args(*argv):
    return build_parser().parse_args(list(argv))


def test_mode_hierarchical_cli_plumbing():
    """--mode hierarchical + --intra-interval must resolve (the old
    launcher dropped intra_interval on the floor)."""
    args = _args("--experiment", "toy_2d", "--mode", "hierarchical",
                 "--intra-interval", "2")
    strat = strategy_from_args(args)
    assert isinstance(strat, Hierarchical) and strat.intra_interval == 2


def test_legacy_sync_knobs_reach_strategy():
    args = _args("--experiment", "toy_2d", "--mode", "fedgan",
                 "--sync-dtype", "bf16", "--average-opt-state")
    strat = strategy_from_args(args)
    assert isinstance(strat, FedAvgSync)
    assert strat.sync_dtype == jnp.bfloat16 and strat.average_opt_state


def test_strategy_flag_selects_and_parameterises():
    cases = [
        (("--strategy", "partial_sharing"), PartialSharing, {}),
        (("--strategy", "subsampled", "--participation", "0.25"),
         SubsampledFedAvg, {"fraction": 0.25}),
        (("--strategy", "adaptive_k", "--warmup-rounds", "3",
          "--sync-every", "4"), AdaptiveK,
         {"warmup_rounds": 3, "sync_every": 4}),
        (("--strategy", "hierarchical", "--intra-interval", "5"),
         Hierarchical, {"intra_interval": 5}),
    ]
    for argv, cls, want in cases:
        strat = strategy_from_args(_args("--experiment", "toy_2d", *argv))
        assert isinstance(strat, cls)
        for k, v in want.items():
            assert getattr(strat, k) == v, (argv, k)


def test_no_flags_keeps_library_default():
    assert strategy_from_args(_args("--experiment", "toy_2d")) is None


def test_stray_knob_for_strategy_is_an_error():
    """A knob the chosen strategy doesn't declare must fail loudly."""
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(_args("--experiment", "toy_2d",
                                 "--strategy", "fedgan",
                                 "--intra-interval", "5"))
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(_args("--experiment", "toy_2d",
                                 "--strategy", "subsampled",
                                 "--warmup-rounds", "3"))


def test_run_experiment_hierarchical_end_to_end():
    """The crash repro: a hierarchical toy_2d run must train, not raise."""
    fed, state, hist = run_experiment(
        "toy_2d", K=2, steps=4, seed=0,
        strategy=Hierarchical(intra_interval=1))
    assert len(hist) == 2
    assert fed.cfg.resolve_strategy().name == "hierarchical"


def test_runspec_builder_round_trip():
    import jax
    task, _ = toy2d_task()
    from repro.data import synthetic
    B = 3
    rng = jax.random.key(0)
    data = [{"x": synthetic.sample_2d_segment(jax.random.fold_in(rng, i),
                                              256, i, B)} for i in range(B)]
    spec = RunSpec(task=task, agent_data=data, agent_grid=(1, B), K=2,
                   steps=4, batch_size=16, strategy=PartialSharing(),
                   sample_extra=lambda r, s: {
                       "z": jax.random.uniform(r, s, minval=-1, maxval=1)},
                   log_every=0)
    fed, rounds = spec.build()
    assert fed.cfg.sync_interval == 2
    assert fed.cfg.resolve_strategy() == PartialSharing()
    _, state, hist = spec.run()
    assert len(hist) == 2 and "d_loss" in hist[0]
