"""Launcher plumbing: the CLI must reach every strategy knob.

Regression for the pre-strategy gap where ``--mode hierarchical`` always
raised ValueError because ``intra_interval`` (and ``sync_dtype`` /
``average_opt_state``) were not exposed by ``repro.launch.train``.
"""
import jax.numpy as jnp
import pytest

from repro.core.strategies import (AdaptiveK, FedAvgSync, Hierarchical,
                                   PartialSharing, SubsampledFedAvg)
from repro.launch.train import (RunSpec, build_parser, experiment_spec,
                                run_experiment, strategy_from_args,
                                toy2d_task)


def _args(*argv):
    return build_parser().parse_args(list(argv))


def test_mode_hierarchical_cli_plumbing():
    """--mode hierarchical + --intra-interval must resolve (the old
    launcher dropped intra_interval on the floor)."""
    args = _args("--experiment", "toy_2d", "--mode", "hierarchical",
                 "--intra-interval", "2")
    strat = strategy_from_args(args)
    assert isinstance(strat, Hierarchical) and strat.intra_interval == 2


def test_legacy_sync_knobs_reach_strategy():
    args = _args("--experiment", "toy_2d", "--mode", "fedgan",
                 "--sync-dtype", "bf16", "--average-opt-state")
    strat = strategy_from_args(args)
    assert isinstance(strat, FedAvgSync)
    assert strat.sync_dtype == jnp.bfloat16 and strat.average_opt_state


def test_strategy_flag_selects_and_parameterises():
    cases = [
        (("--strategy", "partial_sharing"), PartialSharing, {}),
        (("--strategy", "subsampled", "--participation", "0.25"),
         SubsampledFedAvg, {"fraction": 0.25}),
        (("--strategy", "adaptive_k", "--warmup-rounds", "3",
          "--sync-every", "4"), AdaptiveK,
         {"warmup_rounds": 3, "sync_every": 4}),
        (("--strategy", "hierarchical", "--intra-interval", "5"),
         Hierarchical, {"intra_interval": 5}),
    ]
    for argv, cls, want in cases:
        strat = strategy_from_args(_args("--experiment", "toy_2d", *argv))
        assert isinstance(strat, cls)
        for k, v in want.items():
            assert getattr(strat, k) == v, (argv, k)


def test_no_flags_keeps_library_default():
    assert strategy_from_args(_args("--experiment", "toy_2d")) is None


def test_stray_knob_for_strategy_is_an_error():
    """A knob the chosen strategy doesn't declare must fail loudly."""
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(_args("--experiment", "toy_2d",
                                 "--strategy", "fedgan",
                                 "--intra-interval", "5"))
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(_args("--experiment", "toy_2d",
                                 "--strategy", "subsampled",
                                 "--warmup-rounds", "3"))


def test_run_experiment_hierarchical_end_to_end():
    """The crash repro: a hierarchical toy_2d run must train, not raise."""
    fed, state, hist = run_experiment(
        "toy_2d", K=2, steps=4, seed=0,
        strategy=Hierarchical(intra_interval=1))
    assert len(hist) == 2
    assert fed.cfg.resolve_strategy().name == "hierarchical"


def test_run_overrides_reach_the_spec():
    """--batch-size / --agents / --log-every were previously fixed by the
    experiment config with no CLI override; they must plumb through to the
    RunSpec (and reshape the agent fleet/data accordingly)."""
    spec, suite = experiment_spec("toy_2d", K=4, steps=8, batch_size=16,
                                  agents=3, log_every=7, eval_every=2)
    assert spec.batch_size == 16
    assert spec.agent_grid == (1, 3) and len(spec.agent_data) == 3
    assert spec.log_every == 7
    assert spec.eval_every == 2 and len(spec.eval_hooks) == 1
    # defaults stay when not overridden
    spec2, _ = experiment_spec("toy_2d", K=4, steps=8)
    from repro.configs.paper_gans import ALL_EXPERIMENTS
    exp = ALL_EXPERIMENTS["toy_2d"]
    assert spec2.batch_size == exp.batch_size
    assert spec2.agent_grid == (1, exp.num_agents)
    assert spec2.eval_every == 0 and spec2.eval_hooks == ()


def test_cli_exposes_run_overrides():
    args = _args("--experiment", "toy_2d", "--batch-size", "32",
                 "--agents", "3", "--log-every", "0", "--eval-every", "5",
                 "--data-mode", "device")
    assert args.batch_size == 32 and args.agents == 3
    assert args.log_every == 0 and args.eval_every == 5
    assert args.data_mode == "device"
    # defaults: sentinel values that mean "keep the experiment config"
    d = _args("--experiment", "toy_2d")
    assert d.batch_size == 0 and d.agents == 0 and d.log_every == -1
    assert d.eval_every == 0 and d.data_mode == "stream"
    with pytest.raises(SystemExit):
        _args("--experiment", "toy_2d", "--data-mode", "bogus")


def test_agent_override_wraps_class_assignments():
    """--agents beyond the experiment's natural fleet must wrap mode/class
    assignments, not clamp out of range (jnp indexing silently clamps, so
    agent 4 of mixed_gaussian used to get mode 7 twice instead of 0+1)."""
    import numpy as np

    from repro.data import synthetic
    spec, _ = experiment_spec("mixed_gaussian", K=2, steps=4, agents=5)
    modes = np.asarray(synthetic.mixed_gaussian_modes())
    x4 = np.asarray(spec.agent_data[4]["x"])
    nearest = np.linalg.norm(x4[:, None] - modes[None], axis=-1).argmin(1)
    assert set(np.unique(nearest)) == {0, 1}  # wrapped, not clamped to 7
    # image_acgan: randint bounds must stay valid when B > num classes
    spec, _ = experiment_spec("image_acgan", K=2, steps=4, agents=12,
                              batch_size=8)
    labs = np.concatenate([np.asarray(d["y"]) for d in spec.agent_data])
    assert labs.min() >= 0 and labs.max() < 10
    # timeseries: climate zone stays in [0, 5)
    spec, _ = experiment_spec("timeseries_cgan", K=2, steps=4, agents=7,
                              batch_size=8)
    for d in spec.agent_data:
        y = np.asarray(d["y"])
        assert y.sum(axis=-1).min() == 1.0  # one-hot stays valid


def test_run_experiment_with_overrides_and_evals():
    fed, state, hist = run_experiment(
        "toy_2d", K=2, steps=8, seed=0, batch_size=8, agents=2,
        log_every=0, eval_every=2, data_mode="device")
    assert fed.cfg.agent_grid == (1, 2)
    assert len(hist) == 4


def test_eval_every_with_arch_is_rejected():
    """No eval suite exists for backbone smoke runs — the CLI must say so
    instead of silently dropping the flag."""
    import sys
    from unittest import mock

    import repro.launch.train as train_mod
    argv = ["train", "--arch", "gemma3-4b", "--eval-every", "2"]
    with mock.patch.object(sys, "argv", argv):
        with pytest.raises(SystemExit):
            train_mod.main()


def test_runspec_builder_round_trip():
    import jax
    task, _ = toy2d_task()
    from repro.data import synthetic
    B = 3
    rng = jax.random.key(0)
    data = [{"x": synthetic.sample_2d_segment(jax.random.fold_in(rng, i),
                                              256, i, B)} for i in range(B)]
    spec = RunSpec(task=task, agent_data=data, agent_grid=(1, B), K=2,
                   steps=4, batch_size=16, strategy=PartialSharing(),
                   sample_extra=lambda r, s: {
                       "z": jax.random.uniform(r, s, minval=-1, maxval=1)},
                   log_every=0)
    fed, rounds = spec.build()
    assert fed.cfg.sync_interval == 2
    assert fed.cfg.resolve_strategy() == PartialSharing()
    _, state, hist = spec.run()
    assert len(hist) == 2 and "d_loss" in hist[0]


def test_dryrun_exposes_analyze_flag():
    """--analyze must reach run_pair (the per-cell trace audit hook).

    Runs in a subprocess: importing repro.launch.dryrun in-process would
    append the 512-device XLA flag to this process's environment, which
    every later subprocess test would inherit."""
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = (
        "import inspect\n"
        "from repro.launch.dryrun import main, run_pair\n"
        "assert 'analyze' in inspect.signature(run_pair).parameters\n"
        "import sys; sys.argv = ['dryrun', '--help']\n"
        "try:\n"
        "    main()\n"
        "except SystemExit as e:\n"
        "    assert e.code in (0, None)\n"
    )
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=src),
                         timeout=120)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "--analyze" in res.stdout
