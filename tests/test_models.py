"""Backbone model behaviour: decode/forward consistency, prefill handoff,
GQA/window masks, adversarial pair losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ArchConfig
from repro.models.transformer import Backbone

F32 = dict(dtype=jnp.float32, remat=False)


def _dense(**kw):
    base = dict(name="d", family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=128, **F32)
    base.update(kw)
    return ArchConfig(**base)


CFGS = {
    "dense": _dense(),
    "dense_window": _dense(name="w", sliding_window=4),
    "grouped": _dense(name="g", local_global_ratio=1, sliding_window=4),
    "moe": _dense(name="m", family="moe", num_experts=4, experts_per_token=2,
                  moe_group_size=4, capacity_factor=2.0, d_ff=64),
    "ssm": ArchConfig(name="s", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=128,
                      ssm_state=16, ssm_heads=2, ssm_chunk=4, **F32),
    "hybrid": ArchConfig(name="h", family="hybrid", num_layers=3, d_model=64,
                         num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                         ssm_state=16, ssm_heads=2, ssm_chunk=4,
                         hybrid_period=3, **F32),
    "audio": ArchConfig(name="a", family="audio", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=2, encoder_seq=8, cross_attention=True,
                        frontend_stub=True, norm="layernorm", **F32),
}


def _decode_all(bb, params, toks, cache, frames=None):
    if bb.cfg.family == "audio":
        mem = bb.encode(params, frames)
        cache["cross"] = bb.build_cross_cache(params, mem)
    outs = []
    for i in range(toks.shape[1]):
        lg, cache = bb.decode(params, toks[:, i:i + 1], cache, jnp.int32(i))
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("key", list(CFGS))
def test_decode_matches_forward(key):
    cfg = CFGS[key]
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    T, B = 12, 2
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "audio":
        kw["encoder_frames"] = 0.1 * jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq, cfg.d_model))
    full = bb.apply(params, toks, **kw)["logits"]
    assert full.shape == (B, T, cfg.padded_vocab)
    assert not jnp.isnan(full).any()
    dec = _decode_all(bb, params, toks, bb.init_cache(B, T),
                      frames=kw.get("encoder_frames"))
    tol = 5e-2 if key == "moe" else 5e-4  # MoE capacity drops differ at T=1
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=tol)


@pytest.mark.parametrize("key", ["dense_window", "grouped"])
def test_ring_cache_matches_full_cache(key):
    cfg = CFGS[key]
    T, B = 12, 2
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    full = Backbone(cfg).apply(Backbone(cfg).init(jax.random.key(0)), toks)["logits"]
    bb = Backbone(cfg, ring_cache=True)
    params = bb.init(jax.random.key(0))
    dec = _decode_all(bb, params, toks, bb.init_cache(B, T))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=5e-4)


def test_ring_cache_is_window_sized():
    cfg = CFGS["dense_window"]
    bb = Backbone(cfg, ring_cache=True)
    cache = bb.init_cache(2, 1024)
    assert cache["blocks"]["k"].shape[-3] == cfg.sliding_window
    full = Backbone(cfg, ring_cache=False).init_cache(2, 1024)
    assert full["blocks"]["k"].shape[-3] == 1024


def test_prefill_then_decode_continues_correctly():
    cfg = CFGS["dense"]
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    full = bb.apply(params, toks)["logits"]
    pre = bb.prefill(params, toks[:, :8], max_seq=12)
    np.testing.assert_allclose(np.asarray(pre["logits"][:, 0]),
                               np.asarray(full[:, 7]), atol=5e-4)
    cache = pre["cache"]
    lg, cache = bb.decode(params, toks[:, 8:9], cache, jnp.int32(8))
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 8]),
                               atol=5e-4)


def test_sliding_window_actually_masks():
    """A token far outside the window must not influence the output."""
    cfg = _dense(name="wm", sliding_window=2, num_layers=1)
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab_size)
    out1 = bb.apply(params, toks)["logits"][:, -1]
    toks2 = toks.at[:, 0].set((toks[:, 0] + 7) % cfg.vocab_size)
    out2 = bb.apply(params, toks2)["logits"][:, -1]
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_causality():
    """Future tokens must not affect past logits."""
    cfg = CFGS["dense"]
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    out1 = bb.apply(params, toks)["logits"][:, :5]
    toks2 = toks.at[:, 7].set((toks[:, 7] + 3) % cfg.vocab_size)
    out2 = bb.apply(params, toks2)["logits"][:, :5]
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_ssm_causality():
    cfg = CFGS["ssm"]
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab_size)
    out1 = bb.apply(params, toks)["logits"][:, :5]
    toks2 = toks.at[:, 9].set((toks[:, 9] + 3) % cfg.vocab_size)
    out2 = bb.apply(params, toks2)["logits"][:, :5]
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_moe_aux_loss_positive_and_finite():
    cfg = CFGS["moe"]
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    out = bb.apply(params, toks)
    aux = float(out["aux"])
    assert np.isfinite(aux) and aux >= 0.0


def test_adversarial_pair_losses_finite():
    from repro.launch.steps import make_lm_gan_task
    cfg = CFGS["dense"]
    task = make_lm_gan_task(cfg)
    params = task.init(jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                          cfg.vocab_size)}
    gd, gg, metrics = task.fused_grads(params, batch, jax.random.key(2))
    for leaf in jax.tree_util.tree_leaves((gd, gg)):
        assert not jnp.isnan(leaf).any()
    assert np.isfinite(float(metrics["d_loss"]))
    assert np.isfinite(float(metrics["g_loss"]))
    # fused grads must match the separate-loss path
    gd2 = jax.grad(lambda d: task.disc_loss({**params, "disc": d}, batch,
                                            jax.random.key(2)))(params["disc"])
    for a, b in zip(jax.tree_util.tree_leaves(gd), jax.tree_util.tree_leaves(gd2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_attention_path_matches_sdpa():
    cfg = _dense(name="fl", num_layers=1, vocab_size=64)
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    p = Backbone(cfg).init(jax.random.key(0))
    base = Backbone(cfg).apply(p, toks)["logits"]
    flash = Backbone(cfg, use_flash=True).apply(p, toks)["logits"]
    np.testing.assert_allclose(np.asarray(flash), np.asarray(base), atol=2e-4)


def test_ssd_kernel_path_matches_ref_in_model():
    cfg = CFGS["ssm"].scaled(ssm_chunk=4)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    p = Backbone(cfg).init(jax.random.key(0))
    base = Backbone(cfg).apply(p, toks)["logits"]
    kern = Backbone(cfg, use_ssd_kernel=True).apply(p, toks)["logits"]
    np.testing.assert_allclose(np.asarray(kern), np.asarray(base), atol=2e-4)
