"""Optimizers/schedules, data pipeline and checkpoint substrate tests."""
import os
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (list_checkpoints, read_latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import (FederatedRounds, dirichlet_partition,
                        label_shard_partition, partition_sizes, synthetic)
from repro.optim import (SGD, Adam, AdamW, clip_by_global_norm, constant,
                         equal_timescale, global_norm, inverse_time,
                         power_decay, ttur_pair)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_sgd_step():
    opt = SGD()
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    new, state = opt.update(params, {"w": jnp.full(3, 2.0)}, state, 0.1)
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8, rtol=1e-6)


def test_adam_matches_reference_impl():
    """Cross-check against a hand-rolled numpy Adam."""
    b1, b2, eps, lr = 0.5, 0.999, 1e-8, 1e-2
    opt = Adam(b1=b1, b2=b2, eps=eps)
    p = np.asarray([1.0, -2.0, 3.0], np.float32)
    params = {"w": jnp.asarray(p)}
    state = opt.init(params)
    m = np.zeros(3)
    v = np.zeros(3)
    rng = np.random.RandomState(0)
    for t in range(1, 6):
        g = rng.randn(3).astype(np.float32)
        params, state = opt.update(params, {"w": jnp.asarray(g)}, state, lr)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g ** 2
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        p = p - lr * mh / (np.sqrt(vh) + eps)
        np.testing.assert_allclose(np.asarray(params["w"]), p, rtol=1e-5)


def test_adamw_decay():
    opt = AdamW(weight_decay=0.1)
    params = {"w": jnp.ones(2)}
    state = opt.init(params)
    new, _ = opt.update(params, {"w": jnp.zeros(2)}, state, 0.5)
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0 - 0.5 * 0.1, rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 3.0), "b": jnp.full(9, 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


# ---------------------------------------------------------------------------
# schedules: (A2) and (A6)
# ---------------------------------------------------------------------------


def test_power_decay_satisfies_a2_numerically():
    sched = power_decay(0.1, tau=10, p=0.75)
    n = jnp.arange(100000, dtype=jnp.float32)
    a = jax.vmap(sched)(n)
    # sum a diverges (grows with horizon), sum a^2 converges
    s1a = float(jnp.sum(a[:50000]))
    s1b = float(jnp.sum(a))
    assert s1b > s1a * 1.15  # still growing
    s2_tail = float(jnp.sum(a[50000:] ** 2))
    assert s2_tail < 0.01 * float(jnp.sum(a[:100] ** 2)) + 1e-3


def test_power_decay_rejects_a2_violations():
    with pytest.raises(ValueError):
        power_decay(0.1, p=0.5)   # sum a^2 = inf
    with pytest.raises(ValueError):
        power_decay(0.1, p=1.5)   # sum a < inf


def test_ttur_pair_satisfies_a6():
    ts = ttur_pair(0.1, 0.1, pa=0.6, pb=0.9)
    assert not ts.equal
    # b(n)/a(n) -> 0: the ratio must decay monotonically toward zero
    r4 = float(ts.b(jnp.float32(1e4)) / ts.a(jnp.float32(1e4)))
    r8 = float(ts.b(jnp.float32(1e8)) / ts.a(jnp.float32(1e8)))
    assert r8 < r4 < 0.5
    assert r8 < 0.02


def test_ttur_pair_rejects_a6_violation():
    with pytest.raises(ValueError):
        ttur_pair(0.1, 0.1, pa=0.9, pb=0.6)


def test_inverse_time_and_constant():
    assert float(inverse_time(0.2, tau=1.0)(jnp.float32(1.0))) == pytest.approx(0.1)
    assert float(constant(0.3)(jnp.float32(999))) == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(num_agents=st.integers(2, 8), seed=st.integers(0, 100))
def test_label_shard_partition_covers_everything(num_agents, seed):
    labels = np.repeat(np.arange(10), 17)
    parts = label_shard_partition(labels, num_agents, seed=seed)
    all_idx = np.concatenate([np.asarray(p) for p in parts])
    assert sorted(all_idx.tolist()) == list(range(len(labels)))


def test_label_shard_partition_is_non_iid():
    labels = np.repeat(np.arange(10), 20)
    parts = label_shard_partition(labels, 5, seed=0)
    for p in parts:
        classes = np.unique(labels[np.asarray(p)])
        assert len(classes) <= 2  # paper: 2 classes per agent


def test_dirichlet_partition_covers_everything():
    labels = np.repeat(np.arange(10), 20)
    parts = dirichlet_partition(labels, 5, alpha=0.3, seed=1)
    all_idx = np.concatenate([np.asarray(p) for p in parts])
    assert sorted(all_idx.tolist()) == list(range(len(labels)))
    sizes = partition_sizes(parts)
    assert float(jnp.sum(sizes)) == len(labels)


def test_federated_rounds_shapes_and_determinism():
    agent_data = [{"x": jnp.arange(40.0) + 100 * i} for i in range(4)]
    fr = FederatedRounds(agent_data, (2, 2), batch_size=8, sync_interval=3,
                         sample_extra=lambda r, s: {"z": jax.random.normal(r, s + (2,))})
    b1, s1 = fr.round_batches(jax.random.key(5))
    b2, s2 = fr.round_batches(jax.random.key(5))
    assert b1["x"].shape == (3, 2, 2, 8)
    assert b1["z"].shape == (3, 2, 2, 8, 2)
    assert s1.shape == (3, 2, 2)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
    # agent separation: agent (p, a) samples only from its own dataset
    for p in range(2):
        for a in range(2):
            i = p * 2 + a
            vals = np.asarray(b1["x"][:, p, a])
            assert ((vals >= 100 * i) & (vals < 100 * i + 40)).all()


def test_federated_rounds_rejects_bad_grid():
    with pytest.raises(ValueError):
        FederatedRounds([{"x": jnp.zeros(4)}] * 3, (2, 2), 2, 2)


def test_synthetic_generators_shapes():
    r = jax.random.key(0)
    assert synthetic.sample_2d_segment(r, 50, 2, 5).shape == (50,)
    assert synthetic.sample_mixed_gaussian(r, 50).shape == (50, 2)
    assert synthetic.sample_swiss_roll(r, 50).shape == (50, 2)
    img = synthetic.sample_class_images(r, 4, jnp.arange(4), hw=16)
    assert img.shape == (4, 16, 16, 3)
    assert float(jnp.max(jnp.abs(img))) <= 1.0
    hl = synthetic.sample_household_load(r, 6, climate_zone=jnp.arange(6) % 5)
    assert hl.shape == (6, 24) and float(jnp.max(hl)) <= 1.0 + 1e-6
    ev = synthetic.sample_ev_sessions(r, 6, category=jnp.arange(6) % 5)
    assert ev.shape == (6, 24)
    tok = synthetic.sample_agent_tokens(r, 3, 8, 64, agent=0, num_agents=4)
    assert tok.shape == (3, 8) and int(tok.max()) < 64


def test_agent_tokens_are_non_iid():
    r = jax.random.key(0)
    a0 = synthetic.sample_agent_tokens(r, 64, 32, 1000, agent=0, num_agents=4)
    a3 = synthetic.sample_agent_tokens(r, 64, 32, 1000, agent=3, num_agents=4)
    # distributions differ: agent-specific vocabulary slices dominate
    assert abs(float(jnp.mean(a0)) - float(jnp.mean(a3))) > 50


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_structure_and_dtypes():
    state = {
        "params": {"w": jnp.ones((3, 4), jnp.bfloat16),
                   "layers": [jnp.zeros(2), jnp.arange(3.0)]},
        "opt": ({"mu": jnp.full((2, 2), 0.5)},),
        "step": jnp.int32(42),
    }
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, step=42, metadata={"K": 20, "mode": "fedgan"})
        got, man = restore_checkpoint(d)
        assert man["metadata"]["K"] == 20
        assert isinstance(got["params"]["layers"], list)
        assert isinstance(got["opt"], tuple)
        assert got["params"]["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["step"]), 42)


def test_checkpoint_multiple_steps_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30):
            save_checkpoint(d, {"x": jnp.full(2, float(s))}, step=s)
        assert list_checkpoints(d) == [10, 20, 30]
        assert read_latest_step(d) == 30
        got, man = restore_checkpoint(d)
        assert man["step"] == 30
        got15, _ = restore_checkpoint(d, step=20)
        np.testing.assert_allclose(np.asarray(got15["x"]), 20.0)


def test_read_latest_step_without_checkpoints():
    with tempfile.TemporaryDirectory() as d:
        assert read_latest_step(d) is None
        assert read_latest_step(os.path.join(d, "missing")) is None


def test_restore_while_writing_never_sees_torn_latest():
    """Regression for the non-atomic LATEST write: a serve process polling
    LATEST while the trainer saves must always see a complete pointer to a
    complete checkpoint (the old truncate-then-write could surface an empty
    LATEST or a half-written step dir mid-save)."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, {"x": jnp.zeros(64)}, step=0)
        failures = []

        def writer():
            for s in range(1, 16):
                save_checkpoint(d, {"x": jnp.full(64, float(s))}, step=s)

        t = threading.Thread(target=writer)
        t.start()
        while t.is_alive():
            step = read_latest_step(d)
            if step is None:
                failures.append("torn LATEST")
                break
            got, man = restore_checkpoint(d)  # must be a complete step dir
            if man["step"] != int(np.asarray(got["x"])[0]):
                failures.append(f"half-written step {man['step']}")
                break
        t.join()
        assert not failures, failures
        assert read_latest_step(d) == 15
        # no temp droppings left behind
        assert not [f for f in os.listdir(d) if f.startswith(".LATEST.tmp")]
