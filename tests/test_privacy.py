"""repro.privacy — the adversarial suite.

Three mechanisms, each proven against its own threat model:

  * Byzantine-robust aggregation: planted sign-flip / x100-scaled / NaN
    agents must not move trimmed-mean/median syncs outside the honest
    agents' envelope (while plain FedAvg is pulled arbitrarily far), up to
    the analytic breakdown points (f <= trim; f < B/2).
  * DP-SGD: per-example clipped gradients have global norm <= C exactly,
    noise is bit-reproducible from the round key and differs across
    agents, and the RDP accountant matches the analytic Gaussian-mechanism
    bound on closed-form fixtures to 1e-6.
  * Secure summing: the pairwise masks telescope to exactly zero, the
    masked round is bit-identical to the plain FedAvg round, mask seeds
    survive a checkpoint roundtrip, and unprotectable stacks are refused
    loudly.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FedGAN, FedGANConfig, GANTask, losses
from repro.core.strategies import (CoordinateMedianSync, FedAvgSync,
                                   SubsampledFedAvg, TrimmedMeanSync)
from repro.dist import collectives
from repro.optim import Adam, SGD, clip_by_global_norm, constant, \
    equal_timescale, global_norm
from repro.privacy import (DPSGD, SecureAgg, WithByzantine, accountant,
                           corrupt, dp_grads, noise_like, per_example_grads)

tmap = jax.tree_util.tree_map


# ---------------------------------------------------------------------------
# shared fixtures: the quadratic task of test_comm, plus a one-round runner
# ---------------------------------------------------------------------------


def quad_task():
    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)


def _fed(strategy=None, K=4, grid=(1, 4), dp=None):
    return FedGAN(quad_task(),
                  FedGANConfig(agent_grid=grid, sync_interval=K,
                               strategy=strategy, dp=dp),
                  opt_g=SGD(), opt_d=SGD(),
                  scales=equal_timescale(constant(0.05)))


def _run_rounds(fed, n_rounds=2, K=4, state=None):
    P, A = fed.cfg.agent_grid
    if state is None:
        state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    for r in range(n_rounds):
        rng = jax.random.key(1 + r)
        x = (jax.random.normal(rng, (K, P, A, 8, 3))
             + jnp.arange(P * A, dtype=jnp.float32).reshape(P, A)[None, :, :,
                                                                  None, None])
        seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                                   2 ** 31 - 1).astype(jnp.uint32)
        state, metrics = round_fn(state, {"x": x}, seeds)
    return state, metrics


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# robust reduces: the statistics themselves
# ---------------------------------------------------------------------------


def test_trimmed_mean_and_median_match_numpy():
    x = jax.random.normal(jax.random.key(0), (2, 3, 5, 7))
    w = jnp.full((2, 3), 1 / 6.0)
    flat = np.asarray(x).reshape(6, 5, 7)
    tm = collectives.make_robust_reduce("trimmed_mean", trim=1)(x, w)
    srt = np.sort(flat, axis=0)
    np.testing.assert_allclose(np.asarray(tm), srt[1:-1].mean(axis=0),
                               rtol=0, atol=1e-6)
    med = collectives.make_robust_reduce("median")(x, w)
    np.testing.assert_array_equal(np.asarray(med), srt[(6 - 1) // 2])


@settings(max_examples=10, deadline=None)
@given(perm=st.permutations(list(range(6))), seed=st.integers(0, 50))
def test_robust_reduces_are_permutation_invariant(perm, seed):
    """Order statistics cannot depend on which slot an agent occupies —
    the property that makes them robust to WHERE the attacker sits."""
    x = jax.random.normal(jax.random.key(seed), (1, 6, 4))
    w = jnp.full((1, 6), 1 / 6.0)
    xp = x[:, jnp.asarray(perm)]
    for kind in ("trimmed_mean", "median"):
        r = collectives.make_robust_reduce(kind)
        np.testing.assert_array_equal(np.asarray(r(x, w)),
                                      np.asarray(r(xp, w)))


def test_robust_reduce_is_weight_oblivious():
    """A poisoned agent must not be able to buy influence through a claimed
    dataset size: the robust reduces ignore the weights entirely."""
    x = jax.random.normal(jax.random.key(1), (1, 4, 3))
    w_uni = jnp.full((1, 4), 0.25)
    w_skew = jnp.asarray([[0.97, 0.01, 0.01, 0.01]])
    for kind in ("trimmed_mean", "median"):
        r = collectives.make_robust_reduce(kind)
        np.testing.assert_array_equal(np.asarray(r(x, w_uni)),
                                      np.asarray(r(x, w_skew)))


def test_robust_reduce_validation():
    with pytest.raises(ValueError, match="unknown robust reduce"):
        collectives.make_robust_reduce("krum")
    w = jnp.full((1, 4), 0.25)
    x = jnp.ones((1, 4, 2))
    with pytest.raises(ValueError, match="2\\*trim"):
        collectives.make_robust_reduce("trimmed_mean", trim=2)(x, w)


# ---------------------------------------------------------------------------
# attack simulation: planted Byzantine agents in real rounds
# ---------------------------------------------------------------------------


def test_corrupt_touches_only_the_first_f_agents():
    tree = {"p": jnp.ones((1, 4, 3)), "n": jnp.arange(4).reshape(1, 4)}
    out = corrupt(tree, attack="scale", num_byzantine=2, scale=-5.0)
    got = np.asarray(out["p"]).reshape(4, 3)
    np.testing.assert_array_equal(got[:2], -5.0)
    np.testing.assert_array_equal(got[2:], 1.0)
    np.testing.assert_array_equal(np.asarray(out["n"]),
                                  np.asarray(tree["n"]))  # int leaves pass


@pytest.mark.parametrize("attack", ["sign_flip", "scale", "nan"])
def test_robust_syncs_stay_in_honest_envelope_fedavg_does_not(attack):
    """One planted attacker (f=1, B=6): trimmed-mean and median syncs land
    inside the honest agents' per-coordinate envelope.  Plain FedAvg is
    measurably corrupted: dragged outside the envelope by a x100 attacker,
    to NaN by a NaN-emitter, and off its attacker-free answer by a
    sign-flipper."""
    grid, K = (1, 6), 4
    # honest pre-sync values: the local-only trajectory
    from repro.core.strategies import LocalOnly
    local, _ = _run_rounds(_fed(LocalOnly(), K=K, grid=grid), n_rounds=1, K=K)
    clean, _ = _run_rounds(_fed(FedAvgSync(), K=K, grid=grid),
                           n_rounds=1, K=K)

    def synced(strategy):
        st_, _ = _run_rounds(_fed(WithByzantine(strategy, attack=attack),
                                  K=K, grid=grid), n_rounds=1, K=K)
        return st_["params"]

    avg = synced(FedAvgSync())
    tm = synced(TrimmedMeanSync())
    med = synced(CoordinateMedianSync())
    for sub in ("gen", "disc"):
        for key in local["params"][sub]:
            # honest envelope: drop the attacker's slot (agent 0)
            vals = np.asarray(local["params"][sub][key]).reshape(-1, 3)[1:]
            lo, hi = vals.min(axis=0), vals.max(axis=0)
            for robust in (tm, med):
                got = np.asarray(robust[sub][key][0, 0])
                assert np.isfinite(got).all(), (attack, sub, key)
                assert (got >= lo - 1e-6).all() and (got <= hi + 1e-6).all(), \
                    (attack, sub, key, got, lo, hi)
            bad = np.asarray(avg[sub][key][0, 0])
            if attack == "nan":
                assert np.isnan(bad).all(), (sub, key, bad)
            elif attack == "scale":
                outside = (bad < lo - 1e-6) | (bad > hi + 1e-6)
                assert outside.any(), (sub, key, bad, lo, hi)
            else:  # sign_flip: pulled off the attacker-free answer
                ref = np.asarray(clean["params"][sub][key][0, 0])
                assert np.abs(bad - ref).max() > 1e-4, (sub, key, bad, ref)


def test_robust_sync_close_to_attacker_free_average():
    """With one x100 attacker, the trimmed-mean sync stays within the honest
    agents' spread of the attacker-free FedAvg answer; plain FedAvg's error
    is orders of magnitude larger."""
    grid, K = (1, 6), 4
    from repro.core.strategies import LocalOnly
    local, _ = _run_rounds(_fed(LocalOnly(), K=K, grid=grid), n_rounds=1, K=K)
    clean, _ = _run_rounds(_fed(FedAvgSync(), K=K, grid=grid),
                           n_rounds=1, K=K)
    atk_avg, _ = _run_rounds(_fed(WithByzantine(FedAvgSync(), attack="scale"),
                                  K=K, grid=grid), n_rounds=1, K=K)
    atk_tm, _ = _run_rounds(_fed(WithByzantine(TrimmedMeanSync(),
                                               attack="scale"),
                                 K=K, grid=grid), n_rounds=1, K=K)
    for sub in ("gen", "disc"):
        for key in clean["params"][sub]:
            ref = np.asarray(clean["params"][sub][key][0, 0])
            spread = np.ptp(np.asarray(local["params"][sub][key]).reshape(
                -1, 3), axis=0).max()
            err_tm = np.abs(np.asarray(atk_tm["params"][sub][key][0, 0])
                            - ref).max()
            err_avg = np.abs(np.asarray(atk_avg["params"][sub][key][0, 0])
                             - ref).max()
            assert err_tm <= spread + 1e-6, (sub, key, err_tm, spread)
            assert err_avg > 10 * max(err_tm, 1e-6), (sub, key, err_avg,
                                                      err_tm)


def test_breakdown_points():
    """f = trim+1 attackers defeat the trimmed mean; f >= B/2 defeats the
    median — the analytic breakdown points, demonstrated."""
    w = jnp.full((1, 6), 1 / 6.0)
    honest = jnp.broadcast_to(jnp.arange(6, dtype=jnp.float32)[None, :, None],
                              (1, 6, 3)) * 0.1

    def attacked(f, scale=-1e4):
        flat = honest.reshape(6, 3)
        bad = jnp.where((jnp.arange(6) < f)[:, None], scale, flat)
        return bad.reshape(1, 6, 3)

    tm = collectives.make_robust_reduce("trimmed_mean", trim=1)
    med = collectives.make_robust_reduce("median")
    hi = float(jnp.max(honest))
    lo = float(jnp.min(honest))
    # within budget: both stay in the honest range
    assert lo <= float(tm(attacked(1), w).min()) <= hi
    assert lo <= float(med(attacked(2), w).min()) <= hi
    # over budget: the aggregate is dragged to the attacker's value
    assert float(tm(attacked(2), w).min()) < lo - 1.0
    assert float(med(attacked(3), w).min()) < lo - 1.0


def test_trimmed_mean_validate_and_byzantine_wrapper_validate():
    cfg4 = FedGANConfig(agent_grid=(1, 4), sync_interval=4)
    with pytest.raises(ValueError, match="trim must be"):
        TrimmedMeanSync(trim=0).validate(cfg4)
    with pytest.raises(ValueError, match="num_agents > 2\\*trim"):
        TrimmedMeanSync(trim=2).validate(cfg4)
    TrimmedMeanSync(trim=1).validate(cfg4)
    with pytest.raises(ValueError, match="unknown attack"):
        WithByzantine(FedAvgSync(), attack="mimic").validate(cfg4)
    with pytest.raises(ValueError, match="num_byzantine"):
        WithByzantine(FedAvgSync(), num_byzantine=5).validate(cfg4)


# ---------------------------------------------------------------------------
# DP-SGD: clipping, noise, accountant
# ---------------------------------------------------------------------------


def test_clip_by_global_norm_zero_grads_pass_through_exactly():
    """Regression: at norm 0 the scale must be exactly 1.0 (the old
    max_norm/(norm+eps) gave a ~1e12*max_norm scale before the clamp and a
    0/0 gradient through the clip)."""
    grads = {"a": jnp.zeros((3, 4)), "b": jnp.zeros((7,))}
    clipped, norm = clip_by_global_norm(grads, 0.5)
    assert float(norm) == 0.0
    for leaf in jax.tree_util.tree_leaves(clipped):
        np.testing.assert_array_equal(np.asarray(leaf), 0.0)
    # the scale itself is finite and exactly 1 — visible through jvp
    f = lambda g: clip_by_global_norm(g, 0.5)[0]
    tangents = jax.jvp(f, (grads,), ({"a": jnp.ones((3, 4)),
                                      "b": jnp.ones((7,))},))[1]
    for leaf in jax.tree_util.tree_leaves(tangents):
        assert np.isfinite(np.asarray(leaf)).all()


def test_per_example_grads_clipped_to_c_exactly():
    fed = _fed()
    params = tmap(lambda x: x[0, 0], fed.init_state(jax.random.key(0))["params"])
    batch = {"x": 50.0 * jax.random.normal(jax.random.key(1), (8, 3))}
    C = 0.37
    gd, gg, nd, ng, _ = per_example_grads(fed._local_grads, params, batch,
                                          jax.random.key(2), C)
    for i in range(8):
        for g in (tmap(lambda v: v[i], gd), tmap(lambda v: v[i], gg)):
            assert float(global_norm(g)) <= C * (1 + 1e-6)
    # pre-clip norms are reported un-clipped (the signal for tuning C)
    assert float(jnp.max(nd)) > C


def test_per_example_joint_grad_clipped_to_c_exactly():
    """The accountant composes ONE Gaussian mechanism per step, which is
    only honest if the per-example sensitivity of the released (G, D) PAIR
    is C — i.e. the joint concatenated gradient is clipped to C, not each
    player separately (joint sensitivity sqrt(2)*C, a 2x-understated
    epsilon)."""
    fed = _fed()
    params = tmap(lambda x: x[0, 0],
                  fed.init_state(jax.random.key(0))["params"])
    batch = {"x": 50.0 * jax.random.normal(jax.random.key(1), (8, 3))}
    C = 0.37
    gd, gg, nd, ng, _ = per_example_grads(fed._local_grads, params, batch,
                                          jax.random.key(2), C)
    for i in range(8):
        joint = (tmap(lambda v: v[i], gd), tmap(lambda v: v[i], gg))
        jn = float(global_norm(joint))
        assert jn <= C * (1 + 1e-6), (i, jn)
        # pre-clip joint norm >> C here, so the clip must be TIGHT at C:
        # a per-player clip would leave the joint norm near sqrt(2)*C
        pre = math.hypot(float(nd[i]), float(ng[i]))
        if pre > C:
            assert jn == pytest.approx(C, rel=1e-5), (i, jn)


def test_dp_noise_bit_reproducible_and_distinct_across_agents():
    fed = _fed(dp=DPSGD(clip=1.0, noise_multiplier=1.0))
    params = tmap(lambda x: x[0, 0], fed.init_state(jax.random.key(0))["params"])
    batch = {"x": jax.random.normal(jax.random.key(1), (4, 3))}
    k_a, k_b = jax.random.key(10), jax.random.key(11)
    g1 = dp_grads(fed._local_grads, params, batch, k_a, fed.cfg.dp)
    g2 = dp_grads(fed._local_grads, params, batch, k_a, fed.cfg.dp)
    g3 = dp_grads(fed._local_grads, params, batch, k_b, fed.cfg.dp)
    assert _leaves_equal(g1[:2], g2[:2])            # same key -> same bits
    assert not _leaves_equal(g1[:2], g3[:2])        # agent keys differ
    # and the noise actually moved the gradient
    plain = per_example_grads(fed._local_grads, params, batch,
                              jax.random.split(k_a)[0], 1.0)
    mean_gd = tmap(lambda g: jnp.mean(g, axis=0), plain[0])
    assert not _leaves_equal(g1[0], mean_gd)


def test_noise_like_is_leaf_order_stable():
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((5,))}
    n1 = noise_like(tree, jax.random.key(3), 1.0)
    n2 = noise_like(tree, jax.random.key(3), 1.0)
    assert _leaves_equal(n1, n2)
    assert not _leaves_equal(n1["a"], jnp.zeros((2, 3)))


def test_dp_round_runs_finite_and_carries_dp_metrics():
    state, metrics = _run_rounds(_fed(dp=DPSGD(clip=0.5,
                                               noise_multiplier=0.5)))
    assert {"dp_grad_norm_d", "dp_grad_norm_g"} <= set(metrics)
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all()
    # clip-only DP (sigma=0) also runs, and spends infinite epsilon
    _run_rounds(_fed(dp=DPSGD(clip=0.5)))
    assert DPSGD(clip=0.5).epsilon(10) == math.inf


@pytest.mark.parametrize("sigma,T,delta", [(1.5, 200, 1e-5),
                                           (4.0, 1000, 1e-6),
                                           (0.8, 50, 1e-5)])
def test_accountant_matches_analytic_gaussian_bound(sigma, T, delta):
    """At q=1 the accountant must equal the closed-form optimum of the
    RDP->DP conversion, eps = T/(2 sigma^2) + sqrt(2 T ln(1/delta))/sigma,
    to 1e-6 — not a grid approximation of it."""
    L = math.log(1.0 / delta)
    analytic = T / (2 * sigma ** 2) + math.sqrt(2 * T * L) / sigma
    got = accountant.epsilon(noise_multiplier=sigma, steps=T, delta=delta)
    assert abs(got - analytic) < 1e-6, (got, analytic)
    # DPSGD.epsilon delegates to the same math
    assert abs(DPSGD(noise_multiplier=sigma, delta=delta).epsilon(T)
               - analytic) < 1e-6


def test_accountant_monotonicity_and_subsampling_gain():
    e = lambda **kw: accountant.epsilon(delta=1e-5, **kw)
    assert e(noise_multiplier=1.0, steps=100) \
        > e(noise_multiplier=2.0, steps=100)        # more noise, less eps
    assert e(noise_multiplier=1.0, steps=400) \
        > e(noise_multiplier=1.0, steps=100)        # more steps, more eps
    assert e(noise_multiplier=1.0, steps=100, sample_rate=0.05) \
        < e(noise_multiplier=1.0, steps=100)        # subsampling amplifies


def test_accountant_edges_and_validation():
    assert accountant.epsilon(noise_multiplier=0.0, steps=10) == math.inf
    assert accountant.epsilon(noise_multiplier=1.0, steps=0) == 0.0
    with pytest.raises(ValueError, match="delta"):
        accountant.epsilon(noise_multiplier=1.0, steps=1, delta=2.0)
    with pytest.raises(ValueError, match="order"):
        accountant.rdp_order(1.0, noise_multiplier=1.0)
    with pytest.raises(ValueError, match="integer orders"):
        accountant.rdp_order(2.5, noise_multiplier=1.0, sample_rate=0.5)
    with pytest.raises(ValueError, match="sample_rate"):
        accountant.rdp_order(2, noise_multiplier=1.0, sample_rate=0.0)
    for bad in (DPSGD(clip=0.0), DPSGD(noise_multiplier=-1.0),
                DPSGD(sample_rate=0.0), DPSGD(delta=0.0)):
        with pytest.raises(ValueError):
            bad.validate()
    with pytest.raises(ValueError, match="clip"):
        FedGANConfig(agent_grid=(1, 4), sync_interval=4,
                     dp=DPSGD(clip=-1.0)).validate()


def test_driver_refuses_understated_sample_rate():
    """The accountant's q is only honest if it covers the participation
    rate the pipeline actually delivers (batch_size / |R_i|): a smaller q
    reports an epsilon the mechanism does not achieve, so the run path
    refuses it loudly instead of relying on a docstring caveat."""
    from repro.data.federated import (DeviceFederatedData,
                                      StreamingFederatedData)
    from repro.run.driver import RoundDriver, check_dp_sample_rate

    agent_data = [{"x": jax.random.normal(jax.random.key(i), (16, 3))}
                  for i in range(4)]
    data = StreamingFederatedData.from_agent_data(agent_data, (1, 4),
                                                  batch_size=8,
                                                  sync_interval=4)
    # pipeline rate is 8/16 = 0.5: q below that must refuse...
    bad = _fed(dp=DPSGD(noise_multiplier=1.0, sample_rate=0.1))
    with pytest.raises(ValueError, match="understates"):
        RoundDriver(bad, data, n_rounds=1, log_every=0,
                    verbose=False).run(jax.random.key(0))
    # ...while an honest (or conservative) q runs
    ok = _fed(dp=DPSGD(noise_multiplier=1.0, sample_rate=0.5))
    res = RoundDriver(ok, data, n_rounds=1, log_every=0,
                      verbose=False).run(jax.random.key(0))
    assert np.isfinite(res.timings["dp_epsilon"])
    # the device-resident pipeline is checked through its true shard sizes
    dev = DeviceFederatedData.from_agent_data(agent_data, (1, 4),
                                              batch_size=8)
    with pytest.raises(ValueError, match="understates"):
        check_dp_sample_rate(DPSGD(sample_rate=0.25), dev)
    check_dp_sample_rate(DPSGD(sample_rate=1.0), dev)


def test_driver_surfaces_dp_epsilon():
    from repro.launch.train import experiment_spec
    spec, _ = experiment_spec("toy_2d", K=5, steps=10, eval_every=1,
                              log_every=0, data_mode="device",
                              dp=DPSGD(clip=1.0, noise_multiplier=2.0))
    res = spec.run_result()
    assert res.evals and all("dp_epsilon" in e for e in res.evals)
    assert res.timings["dp_epsilon"] == pytest.approx(
        DPSGD(clip=1.0, noise_multiplier=2.0).epsilon(10))
    # epsilon grows with the step count across eval points
    eps = [e["dp_epsilon"] for e in res.evals]
    assert eps == sorted(eps) and eps[0] > 0


# ---------------------------------------------------------------------------
# secure summing
# ---------------------------------------------------------------------------


def test_masked_sync_bit_identical_to_average_agents():
    tree = {"a": jax.random.normal(jax.random.key(1), (2, 3, 4, 5)),
            "b": jax.random.normal(jax.random.key(2), (2, 3, 7)),
            "count": jnp.zeros((2, 3), jnp.int32)}
    w = jax.random.uniform(jax.random.key(3), (2, 3))
    w = w / jnp.sum(w)
    plain = collectives.average_agents(tree, w)
    key = collectives.mask_pair_key(jax.random.key(0), 17)
    masked = collectives.masked_sync(tree, w, key)
    assert _leaves_equal(plain, masked)


def test_pairwise_masks_telescope_to_exactly_zero():
    for grid in ((1, 4), (2, 3), (1, 2)):
        m = collectives._pairwise_masks(jax.random.key(5), grid, (16,))
        total = np.zeros(16, np.uint32)
        for row in np.asarray(m).reshape(-1, 16):
            total = total + row          # uint64-free modular add
        np.testing.assert_array_equal(total.astype(np.uint32), 0)


def test_wire_image_hides_plaintext_and_rotates_per_round():
    x = jnp.ones((1, 4, 64), jnp.float32)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    k1 = collectives.mask_pair_key(jax.random.key(0), 1)
    k2 = collectives.mask_pair_key(jax.random.key(0), 2)
    m1 = collectives._pairwise_masks(jax.random.fold_in(k1, 0), (1, 4), (64,))
    m2 = collectives._pairwise_masks(jax.random.fold_in(k2, 0), (1, 4), (64,))
    wire1, wire2 = bits + m1, bits + m2
    # identical plaintext rows produce non-identical wire rows (per-agent
    # pads) and the pads rotate across rounds (fresh one-time pad)
    assert not (np.asarray(wire1) == np.asarray(bits)).all()
    assert not (np.asarray(wire1) == np.asarray(wire2)).all()
    assert len({np.asarray(wire1)[0, a].tobytes() for a in range(4)}) == 4


def test_secure_round_bit_identical_to_plain_round():
    plain, _ = _run_rounds(_fed(FedAvgSync()))
    secure, _ = _run_rounds(_fed(FedAvgSync(secure_agg=SecureAgg())))
    assert _leaves_equal(plain["params"], secure["params"])
    # ...including with opt-state averaging on (more subtrees, fresh salts)
    plain, _ = _run_rounds(_fed(FedAvgSync(average_opt_state=True)))
    secure, _ = _run_rounds(_fed(FedAvgSync(average_opt_state=True,
                                            secure_agg=SecureAgg())))
    assert _leaves_equal(plain["params"], secure["params"])
    assert _leaves_equal(plain["opt_g"], secure["opt_g"])


def test_secure_sync_survives_checkpoint_roundtrip(tmp_path):
    """The mask key is (seed, step)-derived and step is checkpointed state:
    a restored run must continue bit-identically to the uninterrupted
    one."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    strat = FedAvgSync(secure_agg=SecureAgg(seed=3))
    fed = _fed(strat)
    mid, _ = _run_rounds(fed, n_rounds=1)
    save_checkpoint(str(tmp_path), mid, step=4)
    loaded, _ = restore_checkpoint(str(tmp_path))
    # restored leaves come back 1-D-at-least; reshape to the live layout
    state = tmap(lambda l, m: jnp.asarray(l).reshape(m.shape).astype(m.dtype),
                 loaded, mid)
    assert int(state["step"]) == int(mid["step"])
    cont_mem, _ = _run_rounds(fed, n_rounds=2)  # rounds 1+2 uninterrupted
    # replay round 2 from the restored state (same data schedule)
    fed2 = _fed(strat)
    P, A, K = 1, 4, 4
    rng = jax.random.key(2)
    x = (jax.random.normal(rng, (K, P, A, 8, 3))
         + jnp.arange(P * A, dtype=jnp.float32).reshape(P, A)[None, :, :,
                                                              None, None])
    seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                               2 ** 31 - 1).astype(jnp.uint32)
    cont_ckpt, _ = jax.jit(fed2.round)(state, {"x": x}, seeds)
    assert _leaves_equal(cont_mem["params"], cont_ckpt["params"])


def test_secure_refusal_matrix():
    from repro.comm import IntQuant
    cfg = FedGANConfig(agent_grid=(1, 4), sync_interval=4)
    with pytest.raises(ValueError, match="codec"):
        FedAvgSync(secure_agg=SecureAgg(),
                   codec=IntQuant(bits=8)).validate(cfg)
    with pytest.raises(ValueError, match="32-bit wire image"):
        FedAvgSync(secure_agg=SecureAgg(),
                   sync_dtype=jnp.bfloat16).validate(cfg)
    with pytest.raises(ValueError, match="dropouts"):
        SubsampledFedAvg(secure_agg=SecureAgg()).validate(cfg)
    # the virtual-client scheduler is subsampling by other means: a sampled
    # cohort (A_active < A_total) leaves absent clients' pad halves
    # uncancelled, so the driver must refuse at construction — while the
    # full fleet on device (A_total == A_active) stays legal
    from repro.data import FleetRounds
    from repro.run import VirtualClientDriver
    shards = [{"x": jnp.ones((8, 3))} for _ in range(8)]
    fed_sec = _fed(FedAvgSync(secure_agg=SecureAgg()))
    with pytest.raises(ValueError, match="uncancelled"):
        VirtualClientDriver(fed_sec, FleetRounds(shards, (1, 4), 8, 4), 2)
    VirtualClientDriver(fed_sec, FleetRounds(shards[:4], (1, 4), 8, 4), 2)
    for robust in (TrimmedMeanSync, CoordinateMedianSync):
        with pytest.raises(ValueError, match="secure sum hides"):
            robust(secure_agg=SecureAgg()).validate(cfg)
    # the mechanism itself refuses non-4-byte leaves
    with pytest.raises(ValueError, match="32-bit wire image"):
        collectives.masked_sync({"h": jnp.ones((1, 2, 3), jnp.bfloat16)},
                                jnp.full((1, 2), 0.5), jax.random.key(0))
    # ...and the combinations the strategy layer also refuses (defense in
    # depth for callers that bypass validate): a robust reduce needs the
    # per-agent values the sum hides; a sync_dtype recast breaks the pad
    tree = {"h": jnp.ones((1, 2, 3), jnp.float32)}
    w = jnp.full((1, 2), 0.5)
    with pytest.raises(ValueError, match="secure sum hides"):
        collectives.masked_sync(
            tree, w, jax.random.key(0),
            reduce=collectives.make_robust_reduce("median"))
    with pytest.raises(ValueError, match="pad cancellation"):
        collectives.masked_sync(tree, w, jax.random.key(0),
                                sync_dtype=jnp.float32)


def test_masked_sync_weights_ride_the_payload():
    """Weight-then-mask: the uplink wire image is the masked bit pattern
    of w_i*x_i, NOT of x_i — a server that only ever sees masked payloads
    cannot apply per-agent weights, so the agents must fold them in before
    masking.  (The recovered aggregate is then a plain unweighted sum.)"""
    x = jnp.full((1, 2, 4), 2.0, jnp.float32)
    w = jnp.asarray([[0.75, 0.25]])
    key = collectives.mask_pair_key(jax.random.key(0), 3)
    k_leaf = jax.random.fold_in(key, 0)
    m = collectives._pairwise_masks(k_leaf, (1, 2), (4,))
    wire_unweighted = jax.lax.bitcast_convert_type(x, jnp.uint32) + m
    wire_weighted = jax.lax.bitcast_convert_type(
        x * w[..., None], jnp.uint32) + m
    # reconstruct what masked_sync ships by re-deriving its wire image:
    # unmasking the weighted wire gives w_i*x_i exactly
    rec = jax.lax.bitcast_convert_type(wire_weighted - m, jnp.float32)
    np.testing.assert_array_equal(np.asarray(rec),
                                  np.asarray(x * w[..., None]))
    assert not (np.asarray(wire_weighted) == np.asarray(wire_unweighted)).all()
    # and the full sync still equals the weighted average bit-exactly
    out = collectives.masked_sync({"p": x}, w, key)
    np.testing.assert_array_equal(
        np.asarray(out["p"]),
        np.asarray(collectives.average_agents({"p": x}, w)["p"]))


def test_pairwise_masks_memory_is_linear_in_agents():
    """The mask accumulator must never materialize the (B, B, leaf) pair
    tensor — the jaxpr's largest intermediate stays O(B * leaf)."""
    B, leaf = 8, 32
    jaxpr = jax.make_jaxpr(
        lambda k: collectives._pairwise_masks(k, (1, B), (leaf,)))(
            jax.random.key(0))
    biggest = max(
        (int(np.prod(v.aval.shape)) for eqn in jaxpr.jaxpr.eqns
         for v in list(eqn.outvars) + list(eqn.invars)
         if hasattr(v, "aval") and getattr(v.aval, "shape", None)),
        default=0)
    assert biggest <= 4 * B * leaf, biggest  # O(B*leaf), never B^2*leaf


# ---------------------------------------------------------------------------
# CLI + sweep integration
# ---------------------------------------------------------------------------


def test_cli_privacy_flags():
    from repro.launch.train import build_parser, dp_from_args, \
        strategy_from_args

    def args(*argv):
        return build_parser().parse_args(["--experiment", "toy_2d", *argv])

    a = args("--robust", "trimmed_mean", "--trim", "2", "--dp-noise", "0.5")
    strat, dp = strategy_from_args(a), dp_from_args(a)
    assert strat == TrimmedMeanSync(trim=2)
    assert dp == DPSGD(clip=1.0, noise_multiplier=0.5)
    assert dp_from_args(args()) is None
    a = args("--dp-clip", "0.2")
    assert dp_from_args(a) == DPSGD(clip=0.2, noise_multiplier=0.0)
    strat = strategy_from_args(args("--secure-agg", "--seed", "7"))
    assert strat == FedAvgSync(secure_agg=SecureAgg(seed=7))
    with pytest.raises(ValueError, match="conflicts"):
        strategy_from_args(args("--robust", "median", "--strategy", "fedgan"))
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(args("--strategy", "local_only", "--secure-agg"))
    with pytest.raises(ValueError, match="does not accept"):
        strategy_from_args(args("--robust", "median", "--trim", "2"))
    with pytest.raises(ValueError, match="requires --strategy"):
        strategy_from_args(args("--mode", "fedgan", "--secure-agg"))


def test_privacy_sweep_end_to_end(tmp_path):
    """A tiny K x privacy grid runs through the device-resident runtime and
    the JSONL rows carry the privacy label (and dp_epsilon on the dp
    cell)."""
    import json
    import os
    from repro.run.experiments import PRIVACY_AXES, _strategy_for, run_sweep
    cells = run_sweep("mixed_gaussian", [2, 4],
                      privacy_names=["none", "dp", "trimmed_mean"],
                      steps=8, eval_n=128, out_dir=str(tmp_path),
                      verbose=False)
    assert len(cells) == 6
    assert sorted({c.privacy for c in cells}) == ["dp", "none",
                                                  "trimmed_mean"]
    rows = [json.loads(l) for l in
            open(os.path.join(tmp_path, "sweep_mixed_gaussian.jsonl"))]
    finals = [r for r in rows if r.get("final")]
    assert all("privacy" in r for r in rows)
    for r in finals:
        if r["privacy"] == "dp":
            assert r["dp_epsilon"] > 0
        assert r["bytes_per_round"] > 0
    with pytest.raises(ValueError, match="unknown privacy axis"):
        _strategy_for("fedgan", privacy="bogus")
    with pytest.raises(ValueError, match="codec wire"):
        _strategy_for("fedgan", codec="int8", privacy="secure")
    assert set(PRIVACY_AXES) == {"none", "dp", "secure", "trimmed_mean",
                                 "median"}
