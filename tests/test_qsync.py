"""kernels.qsync — the fused sync hot path: kernel ↔ ref bit parity,
fused-vs-composed ``coded_sync`` bit-identity (synced tree, EF residuals,
wire images), O(1)-dispatch bucketing, the fused Adam+sync step against
``optim.Adam.update``, and the strategy-level ``fused_sync`` knob."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import IntQuant, TopK
from repro.core import FedGAN, FedGANConfig, GANTask
from repro.core.strategies import FedAvgSync, TrimmedMeanSync
from repro.dist import collectives
from repro.kernels.qpack import ops as qpack_ops
from repro.kernels.qsync import ops, ref
from repro.optim import Adam, SGD, constant, equal_timescale

tmap = jax.tree_util.tree_map


def _composed(leaves, weights, codec, e_leaves, ed_leaves):
    """The per-leaf composed pipeline, written out — the oracle the fused
    path must match bit for bit."""
    outs, new_e, new_ed = [], [], []
    for x, e, ed in zip(leaves, e_leaves, ed_leaves):
        y = x + e if e is not None else x
        q = codec.roundtrip(y, batch_ndims=2)
        m = collectives.weighted_mean(q, weights)
        yd = m + ed if ed is not None else m
        qd = codec.roundtrip(yd)
        outs.append(jnp.broadcast_to(qd, x.shape))
        new_e.append(y - q if e is not None else None)
        new_ed.append(yd - qd if ed is not None else None)
    return outs, new_e, new_ed


def _tree(seed, grid, shapes):
    ks = jax.random.split(jax.random.key(seed), len(shapes))
    return [3.0 * jax.random.normal(k, grid + s, jnp.float32)
            for k, s in zip(ks, shapes)]


# ---------------------------------------------------------------------------
# qsync_flat: Pallas kernel (interpret) vs pure-jnp ref, bit-identical
# ---------------------------------------------------------------------------


@settings(max_examples=8)
@given(n=st.integers(1, 700), b=st.integers(1, 3), bits=st.integers(0, 1),
       ef=st.integers(0, 1), seed=st.integers(0, 99))
def test_qsync_kernel_matches_ref(n, b, bits, ef, seed):
    """kernel.qsync_flat (interpret) and ref.qsync_flat_ref must agree
    exactly — synced stream and both residuals — across shapes, bit widths
    and EF on/off, including non-block-aligned n."""
    bits = (8, 4)[bits % 2]
    B = 2 * b
    ks = jax.random.split(jax.random.key(seed), 4)
    w = jax.random.uniform(ks[0], (2, b)) + 0.1
    w = w / jnp.sum(w)
    x = 3.0 * jax.random.normal(ks[1], (B, n))
    e = 0.05 * jax.random.normal(ks[2], (B, n)) if ef else None
    ed = 0.05 * jax.random.normal(ks[3], (n,)) if ef else None
    outs = {}
    for uk in (False, True):
        outs[uk] = ops.qsync_flat(w, x, e, ed, bits=bits, use_kernel=uk)
    for a, r in zip(outs[True], outs[False]):
        if a is None:
            assert r is None
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


@settings(max_examples=6)
@given(n=st.integers(1, 500), b=st.integers(2, 8), bits=st.integers(0, 1),
       seed=st.integers(0, 99))
def test_adam_sync_kernel_matches_ref(n, b, bits, seed):
    """The fused Adam+quantize kernel and its jitted ref agree exactly on
    params, both moments, codes and scales."""
    bits = (8, 4)[bits % 2]
    ks = jax.random.split(jax.random.key(seed), 4)
    p = jax.random.normal(ks[0], (b, n), jnp.float32)
    g = 0.1 * jax.random.normal(ks[1], (b, n), jnp.float32)
    mu = 0.2 * jax.random.normal(ks[2], (b, n), jnp.float32)
    nu = 0.1 * jnp.abs(jax.random.normal(ks[3], (b, n), jnp.float32))
    outs = {}
    for uk in (False, True):
        outs[uk] = ops.adam_sync_flat(p, g, mu, nu, lr=0.01,
                                      count=jnp.asarray(3, jnp.int32),
                                      bits=bits, use_kernel=uk)
    for a, r in zip(outs[True], outs[False]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


# ---------------------------------------------------------------------------
# fused coded_sync == composed coded_sync, bit for bit
# ---------------------------------------------------------------------------

SHAPES = [(5, 7), (130,), (), (128,), (3, 1, 2)]


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("weighted", [True, False])
@pytest.mark.parametrize("use_ef", [True, False])
def test_fused_matches_composed(bits, weighted, use_ef):
    """The bucketed fused path reproduces the composed per-leaf pipeline
    exactly: synced values (the downlink wire image), uplink residuals and
    downlink residuals — which together pin both wire images, since
    uplink_wire = (x + ef) - new_ef and downlink_wire = synced."""
    grid = (2, 2)
    leaves = _tree(0, grid, SHAPES)
    if weighted:
        w = jax.random.uniform(jax.random.key(9), grid) + 0.1
        w = w / jnp.sum(w)
    else:
        w = jnp.full(grid, 0.25)
    e_leaves = ([0.05 * l for l in _tree(1, grid, SHAPES)] if use_ef
                else [None] * len(SHAPES))
    ed_leaves = ([jnp.mean(l, axis=(0, 1)) * 0.05
                  for l in _tree(2, grid, SHAPES)] if use_ef
                 else [None] * len(SHAPES))
    codec = IntQuant(bits=bits, use_kernel=False)
    c_out, c_ne, c_ned = _composed(leaves, w, codec, e_leaves, ed_leaves)
    for uk in (False, True):  # vectorized ref AND interpret-mode kernel
        f_out, f_ne, f_ned = ops.qsync_leaves(
            leaves, w,
            e_leaves if use_ef else None,
            ed_leaves if use_ef else None, bits=bits, use_kernel=uk)
        for cs, fs in ((c_out, f_out), (c_ne, f_ne), (c_ned, f_ned)):
            for a, b in zip(cs, fs):
                if a is None:
                    assert b is None
                    continue
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_coded_sync_fused_flag_matrix():
    """coded_sync(fused=None|False|True) all land on the same bits; auto
    fuses when the codec has a spec, True raises when it cannot."""
    grid = (2, 2)
    tree = {"a": _tree(0, grid, [(5, 7)])[0], "b": _tree(3, grid, [(33,)])[0],
            "count": jnp.asarray(3, jnp.int32)}
    ef = tmap(lambda x: x * 0.01, tree)
    ed = tmap(lambda x: (x[0, 0] * 0.01 if x.ndim > 0 else x), tree)
    w = jnp.full(grid, 0.25)
    codec = IntQuant(use_kernel=False)
    ref_out = collectives.coded_sync(tree, w, codec, ef=ef, ef_down=ed,
                                     fused=False)
    for fused in (None, True):
        got = collectives.coded_sync(tree, w, codec, ef=ef, ef_down=ed,
                                     fused=fused)
        for a, b in zip(jax.tree_util.tree_leaves(ref_out),
                        jax.tree_util.tree_leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # integer leaves pass through untouched on the fused path too
    assert int(ref_out[0]["count"]) == 3
    with pytest.raises(ValueError, match="fused_sync_spec"):
        collectives.coded_sync(tree, w, TopK(), fused=True)
    with pytest.raises(ValueError, match="custom reduce"):
        collectives.coded_sync(tree, w, codec, fused=True,
                               reduce=collectives.make_robust_reduce("median"))
    # a custom reduce silently disables auto-fusion (robust stats need the
    # per-agent wire images) — same values as the explicit composed call
    red = collectives.make_robust_reduce("median")
    a = collectives.coded_sync(tree, w, codec, reduce=red)
    b = collectives.coded_sync(tree, w, codec, reduce=red, fused=False)
    for x, y in zip(jax.tree_util.tree_leaves(a[0]),
                    jax.tree_util.tree_leaves(b[0])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_non_f32_leaf_falls_back_to_composed():
    """bf16 leaves can't ride the fused kernel (it reduces in f32, which
    would widen the composed numerics) — they take the per-leaf pipeline
    and the result still matches fused=False exactly."""
    grid = (2, 2)
    tree = {"a": _tree(0, grid, [(40,)])[0],
            "h": _tree(1, grid, [(24,)])[0].astype(jnp.bfloat16)}
    w = jnp.full(grid, 0.25)
    codec = IntQuant(use_kernel=False)
    auto = collectives.coded_sync(tree, w, codec)
    composed = collectives.coded_sync(tree, w, codec, fused=False)
    for a, b in zip(jax.tree_util.tree_leaves(auto[0]),
                    jax.tree_util.tree_leaves(composed[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# O(1) dispatch: the bucketed sync quantizes twice, however many leaves
# ---------------------------------------------------------------------------


def _count_prim(jaxpr, name: str) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    v, is_leaf=lambda x: isinstance(x, jax.extend.core.Jaxpr)):
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    n += _count_prim(sub.jaxpr, name)
                elif isinstance(sub, jax.extend.core.Jaxpr):
                    n += _count_prim(sub, name)
    return n


def test_bucketed_sync_is_constant_dispatch():
    """The composed pipeline rounds 2x per leaf (uplink + downlink); the
    bucketed fused path rounds exactly twice TOTAL, independent of leaf
    count — the jaxpr-level witness of O(1) kernel launches per sync."""
    grid = (2, 2)
    w = jnp.full(grid, 0.25)
    codec = IntQuant(use_kernel=False)
    for n_leaves in (2, 5):
        tree = {f"l{i}": x
                for i, x in enumerate(_tree(0, grid, [(9,)] * n_leaves))}
        fused_jaxpr = jax.make_jaxpr(
            lambda t: collectives.coded_sync(t, w, codec)[0])(tree)
        composed_jaxpr = jax.make_jaxpr(
            lambda t: collectives.coded_sync(t, w, codec, fused=False)[0])(
                tree)
        assert _count_prim(fused_jaxpr.jaxpr, "round") == 2
        assert _count_prim(composed_jaxpr.jaxpr, "round") == 2 * n_leaves


# ---------------------------------------------------------------------------
# fused Adam + sync vs optim.Adam.update
# ---------------------------------------------------------------------------


def test_adam_sync_tree_matches_optimizer():
    """adam_sync_tree == jax.jit(Adam.update) bit for bit (jit is the form
    the trainer runs — under jit XLA contracts the moment updates into
    FMAs, a 1-ulp shift from the op-by-op eager dispatch), and its wire
    image == quantize_blocks of the bucketed new params."""
    B = 8
    ks = jax.random.split(jax.random.key(0), 2)
    params = {"wa": jax.random.normal(ks[0], (B, 33), jnp.float32),
              "wb": jax.random.normal(ks[1], (B, 4, 128), jnp.float32)}
    grads = tmap(lambda x: 0.1 * x + 0.03, params)
    state = {"count": jnp.asarray(4, jnp.int32),
             "mu": tmap(lambda x: 0.2 * x, params),
             "nu": tmap(lambda x: 0.1 * jnp.abs(x), params)}
    adam = Adam()
    p_ref, s_ref = jax.jit(
        lambda p, g, s: adam.update(p, g, s, 0.01))(params, grads, state)
    for uk in (False, True):
        p2, s2, q, s = ops.adam_sync_tree(params, grads, state, lr=0.01,
                                          use_kernel=uk)
        for k in params:
            np.testing.assert_array_equal(np.asarray(p_ref[k]),
                                          np.asarray(p2[k]))
            np.testing.assert_array_equal(np.asarray(s_ref["mu"][k]),
                                          np.asarray(s2["mu"][k]))
            np.testing.assert_array_equal(np.asarray(s_ref["nu"][k]),
                                          np.asarray(s2["nu"][k]))
        assert int(s2["count"]) == int(s_ref["count"])
        leaves, _ = jax.tree_util.tree_flatten(p2)
        buf, _ = ops._bucket(leaves, B, 128)
        q_ref, sc_ref = qpack_ops.quantize_blocks(buf, bits=8, use_kernel=uk)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sc_ref))


# ---------------------------------------------------------------------------
# strategy integration: fused_sync knob
# ---------------------------------------------------------------------------


def quad_task():
    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)


def _run_rounds(strategy, n_rounds=2, K=4, grid=(1, 4)):
    fed = FedGAN(quad_task(),
                 FedGANConfig(agent_grid=grid, sync_interval=K,
                              strategy=strategy),
                 opt_g=SGD(), opt_d=SGD(),
                 scales=equal_timescale(constant(0.05)))
    P, A = grid
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    for r in range(n_rounds):
        rng = jax.random.key(1 + r)
        x = (jax.random.normal(rng, (K, P, A, 8, 3))
             + jnp.arange(P * A, dtype=jnp.float32).reshape(P, A)[None, :, :,
                                                                  None, None])
        seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                                   2 ** 31 - 1).astype(jnp.uint32)
        state, metrics = round_fn(state, {"x": x}, seeds)
    return state, metrics


@pytest.mark.parametrize("bits", [8, 4])
def test_strategy_round_fused_matches_composed(bits):
    """Two full training rounds through FedAvgSync: the fused_sync=True and
    fused_sync=False trajectories are bit-identical — params, residuals,
    metrics."""
    base = FedAvgSync(codec=IntQuant(bits=bits, block=16, use_kernel=False),
                      average_opt_state=True)
    s_fused, m_fused = _run_rounds(dataclasses.replace(base,
                                                       fused_sync=True))
    s_comp, m_comp = _run_rounds(dataclasses.replace(base, fused_sync=False))
    for a, b in zip(jax.tree_util.tree_leaves((s_fused, m_fused)),
                    jax.tree_util.tree_leaves((s_comp, m_comp))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_sync_billed_bytes_unchanged():
    """The fused path is an execution detail: §3.2 wire accounting must not
    move by a single byte."""
    cfg = FedGANConfig(agent_grid=(1, 4), sync_interval=4)
    params = {"gen": {"w": jax.ShapeDtypeStruct((1, 4, 257), jnp.float32)},
              "disc": {"w": jax.ShapeDtypeStruct((1, 4, 64), jnp.float32)}}
    codec = IntQuant(bits=4)
    for fused in (True, False, None):
        s = FedAvgSync(codec=codec, fused_sync=fused)
        assert (s.bytes_per_round(cfg, params)
                == FedAvgSync(codec=codec).bytes_per_round(cfg, params))


def test_fused_sync_validation():
    cfg = FedGANConfig(agent_grid=(1, 4), sync_interval=4)
    with pytest.raises(ValueError, match="needs a codec"):
        FedAvgSync(fused_sync=True).validate(cfg)
    with pytest.raises(ValueError, match="fused_sync_spec"):
        FedAvgSync(fused_sync=True, codec=TopK()).validate(cfg)
    with pytest.raises(ValueError, match="robust reduce"):
        TrimmedMeanSync(fused_sync=True, codec=IntQuant()).validate(cfg)
    # the spec round-trips the codec's knobs into the fused call
    spec = IntQuant(bits=4, block=64, use_kernel=False).fused_sync_spec()
    assert spec == {"bits": 4, "block": 64, "use_kernel": False}
    assert TopK().fused_sync_spec() is None
