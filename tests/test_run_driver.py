"""repro.run runtime tests: legacy-parity of the RunSpec shim, the
device-resident data pipeline, chunked driver invariance, RNG hygiene, and
the eval/checkpoint hooks.

The parity tests replicate the PRE-refactor ``RunSpec.run()`` loop inline
(host-assembled batches, non-donated jit, per-round blocking metric
floats) and hold the new driver bit-exact against it — the contract that
makes ``RunSpec.run()`` a safe shim rather than a behavior change.
"""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedGAN, FedGANConfig, PartialSharing
from repro.data import (DeviceFederatedData, FederatedRounds,
                        StreamingFederatedData, round_key_schedule, synthetic)
from repro.launch.train import experiment_spec, toy2d_task
from repro.run.driver import RoundDriver, _chunk_sizes
from repro.run.evals import EvalSuite, eval_hook

tmap = jax.tree_util.tree_map


def _legacy_loop(spec):
    """The pre-refactor RunSpec.run() body, verbatim (minus prints/ckpt)."""
    fed, rounds = spec.build()
    state = fed.init_state(jax.random.key(spec.seed))
    round_fn = jax.jit(fed.round)
    rng = jax.random.key(spec.seed + 1)
    history = []
    for _ in range(max(spec.steps // spec.K, 1)):
        rng, rb = jax.random.split(rng)
        batches, seeds = rounds.round_batches(rb)
        state, metrics = round_fn(state, batches, seeds)
        history.append(tmap(lambda x: float(jnp.mean(x)), metrics))
    return fed, state, history


# ---------------------------------------------------------------------------
# parity: the shim must be bit-exact vs the old loop
# ---------------------------------------------------------------------------


def test_runspec_shim_parity_quickstart_settings():
    """Quickstart settings (toy_2d, K=20, 5 agents): identical history and
    final state, bit for bit."""
    spec, _ = experiment_spec("toy_2d", K=20, steps=100, seed=0, log_every=0)
    fed_old, state_old, hist_old = _legacy_loop(spec)
    fed_new, state_new, hist_new = spec.run()
    assert hist_old == hist_new
    for a, b in zip(jax.tree_util.tree_leaves(state_old),
                    jax.tree_util.tree_leaves(state_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_runspec_shim_parity_with_strategy_and_conditional_batches():
    """Parity must survive a non-default strategy and multi-field batches
    (labels + latents), not just the toy config."""
    spec, _ = experiment_spec("timeseries_cgan", K=4, steps=8, seed=3,
                              strategy=PartialSharing(), log_every=0,
                              batch_size=16)
    _, state_old, hist_old = _legacy_loop(spec)
    _, state_new, hist_new = spec.run()
    assert hist_old == hist_new
    for a, b in zip(jax.tree_util.tree_leaves(state_old),
                    jax.tree_util.tree_leaves(state_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_prefetch_preserves_batch_stream():
    """StreamingFederatedData must yield exactly the batches the blocking
    loop would assemble, in order, for any prefetch depth."""
    agent_data = [{"x": jnp.arange(40.0) + 100 * i} for i in range(4)]
    fr = FederatedRounds(agent_data, (2, 2), batch_size=8, sync_interval=3)
    rng = jax.random.key(9)
    want = [fr.round_batches(rb) for rb in round_key_schedule(rng, 5)]
    for prefetch in (1, 2, 4, 8):
        got = list(StreamingFederatedData(fr, prefetch=prefetch)
                   .iter_rounds(rng, 5))
        assert len(got) == 5
        for (gb, gs), (wb, ws) in zip(got, want):
            np.testing.assert_array_equal(np.asarray(gb["x"]), np.asarray(wb["x"]))
            np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))


# ---------------------------------------------------------------------------
# device-resident data
# ---------------------------------------------------------------------------


def test_device_data_shapes_and_agent_separation():
    agent_data = [{"x": jnp.arange(40.0) + 100 * i} for i in range(4)]
    data = DeviceFederatedData.from_agent_data(
        agent_data, (2, 2), batch_size=8,
        sample_extra=lambda r, s: {"z": jax.random.normal(r, s + (2,))})
    batch = data.sample_step(jax.random.key(0))
    assert batch["x"].shape == (2, 2, 8)
    assert batch["z"].shape == (2, 2, 8, 2)
    for p in range(2):
        for a in range(2):
            i = p * 2 + a
            vals = np.asarray(batch["x"][p, a])
            assert ((vals >= 100 * i) & (vals < 100 * i + 40)).all()


def test_device_data_unequal_shards_never_sample_padding():
    """Shards are padded to the fleet max by wrapping; sampling must stay
    within each agent's true size."""
    agent_data = [{"x": jnp.arange(5.0)}, {"x": 1000 + jnp.arange(64.0)}]
    data = DeviceFederatedData.from_agent_data(agent_data, (1, 2), 16)
    assert np.asarray(data.sizes).tolist() == [[5, 64]]
    draws = [data.sample_step(jax.random.key(s))["x"] for s in range(20)]
    a0 = np.concatenate([np.asarray(d[0, 0]) for d in draws])
    a1 = np.concatenate([np.asarray(d[0, 1]) for d in draws])
    assert set(np.unique(a0)) <= set(range(5))
    assert a1.min() >= 1000 and a1.max() < 1064


def test_device_data_is_a_pytree():
    agent_data = [{"x": jnp.arange(8.0)} for _ in range(2)]
    data = DeviceFederatedData.from_agent_data(agent_data, (1, 2), 4)
    leaves, treedef = jax.tree_util.tree_flatten(data)
    assert len(leaves) == 2  # stacked data + sizes
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.batch_size == 4 and back.agent_grid == (1, 2)

    @jax.jit
    def through_jit(d, k):
        return d.sample_step(k)

    b = through_jit(data, jax.random.key(0))
    assert b["x"].shape == (1, 2, 4)


def test_round_from_data_runs_and_is_deterministic():
    task, _ = toy2d_task()
    B = 3
    rng = jax.random.key(0)
    agent_data = [{"x": synthetic.sample_2d_segment(
        jax.random.fold_in(rng, i), 128, i, B)} for i in range(B)]
    data = DeviceFederatedData.from_agent_data(
        agent_data, (1, B), 16,
        sample_extra=lambda r, s: {"z": jax.random.uniform(r, s, minval=-1,
                                                           maxval=1)})
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=4))
    state = fed.init_state(jax.random.key(1))
    fn = jax.jit(fed.round_from_data)
    s1, m1 = fn(state, data, jax.random.key(2))
    s2, m2 = fn(state, data, jax.random.key(2))
    assert m1["d_loss"].shape == (4,)
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s3, _ = fn(state, data, jax.random.key(3))
    th2, th3 = s2["params"]["gen"]["theta"], s3["params"]["gen"]["theta"]
    assert not np.allclose(np.asarray(th2), np.asarray(th3))


def test_step_accepts_typed_keys_and_agents_decorrelate():
    """RNG hygiene: with a threaded key, agents holding IDENTICAL data and
    params must still draw different per-agent randomness (the z draws in
    sample_extra are per-agent), and the legacy uint32 seeds path keeps
    working."""
    task, _ = toy2d_task()
    B = 4
    x = jnp.linspace(-1, 1, 64)
    data = DeviceFederatedData.from_agent_data(
        [{"x": x} for _ in range(B)], (1, B), 16,
        sample_extra=lambda r, s: {"z": jax.random.uniform(r, s, minval=-1,
                                                           maxval=1)})
    fed = FedGAN(task, FedGANConfig(agent_grid=(1, B), sync_interval=2,
                                    strategy=None))
    # local_only so agent states do not get re-averaged
    from repro.core import LocalOnly
    fed = dataclasses.replace(fed, cfg=FedGANConfig(
        agent_grid=(1, B), sync_interval=2, strategy=LocalOnly()))
    state = fed.init_state(jax.random.key(0))
    out, _ = jax.jit(fed.round_from_data)(state, data, jax.random.key(5))
    thetas = np.asarray(out["params"]["gen"]["theta"][0])
    assert len(np.unique(thetas)) == B  # distinct despite identical data

    # seeds compat path: FedGAN.round with uint32 seeds still runs
    batches = {"x": jnp.zeros((2, 1, B, 16)) ,
               "z": jnp.zeros((2, 1, B, 16))}
    seeds = jnp.arange(2 * B, dtype=jnp.uint32).reshape(2, 1, B)
    st, m = jax.jit(fed.round)(state, batches, seeds)
    assert m["d_loss"].shape == (2,)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def test_chunk_sizes_respect_boundaries():
    assert _chunk_sizes(10, 4) == [4, 4, 2]
    assert _chunk_sizes(10, 4, 3) == [3, 3, 3, 1]  # never cross a %3 boundary
    assert _chunk_sizes(6, 100, 2, 3) == [2, 1, 1, 2]
    assert _chunk_sizes(5, 1) == [1] * 5
    for n, per, cads in ((17, 5, (4,)), (9, 3, (2, 5)), (8, 8, ())):
        sizes = _chunk_sizes(n, per, *cads)
        assert sum(sizes) == n and all(1 <= c <= per for c in sizes)
        r = 0
        for c in sizes:
            # a chunk starting at r must end at or before r's next cadence
            # boundary, for every active cadence
            for cad in cads:
                assert c <= cad - r % cad, (n, per, cads, sizes, r, c)
            r += c


def test_driver_chunking_is_bit_invariant():
    spec, _ = experiment_spec("toy_2d", K=5, steps=60, seed=0, log_every=0,
                              data_mode="device")
    runs = {}
    for c in (1, 4, 12):
        s = dataclasses.replace(spec, rounds_per_chunk=c)
        _, state, hist = s.run()
        runs[c] = (state, hist)
    for c in (4, 12):
        assert runs[1][1] == runs[c][1]
        for a, b in zip(jax.tree_util.tree_leaves(runs[1][0]),
                        jax.tree_util.tree_leaves(runs[c][0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_device_runtime_converges_toy2d():
    """The new pipeline trains: toy_2d to the paper fixed point (1, 0)."""
    spec, _ = experiment_spec("toy_2d", K=20, steps=3000, seed=0,
                              log_every=0, data_mode="device",
                              rounds_per_chunk=15)
    fed, state, hist = spec.run()
    avg = fed.averaged_params(state)
    assert abs(float(avg["gen"]["theta"]) - 1.0) < 0.1
    assert abs(float(avg["disc"]["psi"])) < 0.1
    assert len(hist) == 150 and np.isfinite(hist[-1]["g_loss"])


def test_driver_eval_hooks_and_checkpoints():
    spec, suite = experiment_spec("toy_2d", K=5, steps=40, seed=0,
                                  log_every=0, data_mode="device")
    fed, _ = spec.build()
    with tempfile.TemporaryDirectory() as d:
        driver = RoundDriver(
            fed, spec.build_data(), 8, log_every=0, verbose=False,
            eval_every=4, eval_hooks=(eval_hook(suite, n=256),),
            ckpt_every=4, ckpt_dir=d, rounds_per_chunk=3)
        res = driver.run(jax.random.key(1))
        assert [e["round"] for e in res.evals] == [3, 7]
        assert all("fd" in e and np.isfinite(e["fd"]) for e in res.evals)
        from repro.checkpoint import list_checkpoints
        assert list_checkpoints(d) == [20, 40]  # (r+1)*K at r=3,7
    assert res.timings["steps_per_s"] > 0
    assert res.timings["data_kind"] == "device"
    assert len(res.history) == 8
    assert all(isinstance(v, float) for m in res.history for v in m.values())


def test_driver_rejects_eval_every_without_hooks():
    spec, _ = experiment_spec("toy_2d", K=5, steps=10, log_every=0)
    fed, rounds = spec.build()
    with pytest.raises(ValueError, match="eval_hooks"):
        RoundDriver(fed, rounds, 2, eval_every=1)


def test_build_data_rejects_unknown_mode():
    spec, _ = experiment_spec("toy_2d", K=5, steps=10)
    with pytest.raises(ValueError, match="data_mode"):
        dataclasses.replace(spec, data_mode="nonsense").build_data()


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------


def test_sweep_runner_end_to_end(tmp_path):
    import json

    from repro.run.experiments import parse_sweep, run_sweep, summary_table
    assert parse_sweep("K=1,5,20") == [1, 5, 20]
    assert parse_sweep("10,20") == [10, 20]
    with pytest.raises(ValueError):
        parse_sweep("K=zero")

    cells = run_sweep("toy_2d", [2, 4], strategy_names=("fedgan", "distributed"),
                      steps=16, seed=0, out_dir=str(tmp_path), eval_n=256,
                      verbose=False)
    assert len(cells) == 4
    assert {(c.K, c.strategy) for c in cells} == {
        (2, "fedgan"), (2, "distributed"), (4, "fedgan"), (4, "distributed")}
    for c in cells:
        assert np.isfinite(c.final["fd"])
        assert len(c.history) == 16 // c.K
    rows = [json.loads(l) for l in
            (tmp_path / "sweep_toy_2d.jsonl").read_text().splitlines()]
    finals = [r for r in rows if r.get("final")]
    assert len(finals) == 4 and all("fd" in r for r in finals)
    per_round = [r for r in rows if "round" in r and not r.get("eval")]
    assert len(per_round) == sum(len(c.history) for c in cells)
    table = summary_table(cells)
    assert "fedgan:fd" in table and "distributed:fd" in table
