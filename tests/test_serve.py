"""repro.serve: batcher invariants, cache bucketing, engine output parity
vs per-request greedy decode, per-row decode indices, hot-reload."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint
from repro.models.config import ArchConfig
from repro.models.transformer import Backbone
from repro.serve import (Batcher, CheckpointWatcher, Request, ServeEngine,
                         generator_from_state, make_buckets, plan_layout,
                         prefill_bucket)

F32 = dict(dtype=jnp.float32, remat=False)


def _dense(**kw):
    base = dict(name="d", family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, d_ff=128, vocab_size=128, **F32)
    base.update(kw)
    return ArchConfig(**base)


CFGS = {
    "dense": _dense(),
    "grouped_ring": _dense(name="g", local_global_ratio=1, sliding_window=4),
    "ssm": ArchConfig(name="s", family="ssm", num_layers=2, d_model=64,
                      num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=128,
                      ssm_state=16, ssm_heads=2, ssm_chunk=4, **F32),
    "audio": ArchConfig(name="a", family="audio", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=2, encoder_seq=8, cross_attention=True,
                        frontend_stub=True, norm="layernorm", **F32),
}

# (prompt_len, max_new_tokens): mixed lengths + a queue deeper than the
# slot count exercise bucketing and mid-stream admission
WORK = [(5, 6), (3, 4), (11, 5)]


def _reference_greedy(cfg, params, prompt, gen, frames=None):
    """Batch-1 token-by-token greedy decode from scratch — exact for every
    family (threads SSM state one token at a time)."""
    bb = Backbone(cfg)
    T = len(prompt)
    cache = bb.init_cache(1, T + gen)
    if cfg.family == "audio":
        mem = bb.encode(params, jnp.asarray(frames)[None])
        cache["cross"] = bb.build_cross_cache(params, mem)
    toks = list(prompt)
    outs = []
    for i in range(T + gen - 1):
        lg, cache = bb.decode(params, jnp.asarray([[toks[i]]], jnp.int32),
                              cache, jnp.int32(i))
        if i >= T - 1:
            tok = int(jnp.argmax(lg[0, 0, :cfg.vocab_size]))
            outs.append(tok)
            toks.append(tok)
    return outs


# ---------------------------------------------------------------------------
# batcher + bucketing invariants (pure python, no jax)
# ---------------------------------------------------------------------------


def test_bucket_ladder_is_bounded_and_covering():
    assert make_buckets(8, 64) == (8, 16, 32, 64)
    assert make_buckets(16, 100) == (16, 32, 64, 100)
    cfg = _dense()
    for n in range(1, 65):
        b = prefill_bucket(cfg, n, make_buckets(8, 64))
        assert b >= n and b in make_buckets(8, 64)
    with pytest.raises(ValueError):
        prefill_bucket(cfg, 65, make_buckets(8, 64))


def test_prefill_prefix_respects_chunk_constraints():
    ssm = CFGS["ssm"]  # ssm_chunk=4
    assert prefill_bucket(ssm, 11, (8, 16)) == 8   # largest multiple of 4
    assert prefill_bucket(ssm, 3, (8, 16)) == 0    # shorter than one chunk
    assert prefill_bucket(ssm, 12, (8, 16)) == 12  # exact, never padded


def test_plan_layout_rejects_ring_without_window():
    with pytest.raises(ValueError):
        plan_layout(_dense(), 64, ring=True)
    lay = plan_layout(_dense(sliding_window=4), 64, ring=True)
    assert lay.ring and lay.window == 4


def test_batcher_admit_evict_invariants():
    b = Batcher(2)
    reqs = [Request(rid=-1, prompt=(1, 2), max_new_tokens=1) for _ in range(5)]
    rids = [b.submit(r) for r in reqs]
    assert rids == sorted(rids)  # monotone ids

    admitted = []
    while b.has_work:
        got = b.admit()
        admitted.extend(r.rid for _, r in got)
        # never over-subscribed; every occupied slot belongs to one request
        assert sum(r is not None for r in b.slots) <= b.max_slots
        occupied = [r.slot for r in b.slots if r is not None]
        assert len(set(occupied)) == len(occupied)
        for _, r in b.active():
            r.generated.append(0)  # finish everyone this tick
        evicted = b.evict()
        assert all(r.done and r.status == "done" for r in evicted)
    # FIFO, exactly once
    assert admitted == rids


# ---------------------------------------------------------------------------
# engine parity: continuous batching == per-request greedy decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key", list(CFGS))
def test_engine_matches_reference_greedy(key):
    cfg = CFGS[key]
    ring = key.endswith("_ring")
    eng = ServeEngine(cfg, max_batch=2, max_seq=32, min_bucket=8, ring=ring)
    frames = None
    if cfg.family == "audio":
        frames = 0.1 * np.random.RandomState(0).randn(
            cfg.encoder_seq, cfg.d_model).astype(np.float32)
    rids = [eng.submit(list(range(1, T + 1)), max_new_tokens=g, frames=frames)
            for T, g in WORK]
    done = eng.run()
    assert set(done) == set(rids)
    for rid, (T, g) in zip(rids, WORK):
        want = _reference_greedy(cfg, eng.params, list(range(1, T + 1)), g,
                                 frames)
        assert done[rid].generated == want, (key, rid)
    # three requests through two slots: the third was admitted mid-stream
    assert eng.stats.prefills == 3
    assert max(eng.stats.tick_active) == 2


def test_engine_on_serving_mesh_single_device():
    from repro.launch.mesh import make_serving_mesh
    cfg = CFGS["dense"]
    eng = ServeEngine(cfg, max_batch=2, max_seq=32, min_bucket=8,
                      mesh=make_serving_mesh())
    rid = eng.submit([1, 2, 3, 4], max_new_tokens=3)
    want = _reference_greedy(cfg, jax.device_get(eng.params), [1, 2, 3, 4], 3)
    assert eng.run()[rid].generated == want


def test_submit_validation():
    eng = ServeEngine(CFGS["dense"], max_batch=1, max_seq=16, min_bucket=8)
    with pytest.raises(ValueError):
        eng.submit([], max_new_tokens=2)
    with pytest.raises(ValueError):
        eng.submit(list(range(10)), max_new_tokens=10)  # 10+10 > 16


def test_vector_index_decode_matches_scalar_lockstep():
    """Backbone.decode with a (B,) index vector of equal entries must equal
    the scalar fast path bit for bit."""
    cfg = _dense(sliding_window=4)
    bb = Backbone(cfg)
    params = bb.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 6), 0, cfg.vocab_size)
    out = bb.prefill(params, toks, max_seq=8)
    lg_s, _ = bb.decode(params, toks[:, :1], out["cache"], jnp.int32(6))
    lg_v, _ = bb.decode(params, toks[:, :1], out["cache"],
                        jnp.full((2,), 6, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))


# ---------------------------------------------------------------------------
# hot reload
# ---------------------------------------------------------------------------


def _fedgan_style_state(params):
    """Wrap Backbone params as a (1, 1)-agent FedGAN train state."""
    lead = jax.tree_util.tree_map(lambda x: x[None, None], params)
    return {"params": {"gen": lead, "disc": {"w": jnp.zeros((1, 1, 3))}}}


def test_generator_from_state_strips_agent_grid():
    cfg = CFGS["dense"]
    params = Backbone(cfg).init(jax.random.key(0))
    got = generator_from_state(_fedgan_style_state(params))
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hot_reload_picks_up_newer_checkpoint_mid_stream():
    cfg = CFGS["dense"]
    bb = Backbone(cfg)
    params0 = bb.init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _fedgan_style_state(params0), step=1)
        eng = ServeEngine(cfg, max_batch=1, max_seq=32, min_bucket=8,
                          ckpt_dir=d)
        assert eng.loaded_step == 1
        rid = eng.submit([1, 2, 3, 4], max_new_tokens=8)
        for _ in range(3):
            eng.tick()
        # trainer finishes another round: zeroed generator is trivially
        # distinguishable from the step-1 weights
        params1 = jax.tree_util.tree_map(jnp.zeros_like, params0)
        save_checkpoint(d, _fedgan_style_state(params1), step=2)
        done = {}
        while eng.batcher.has_work:
            for req in eng.tick():
                done[req.rid] = req
        assert eng.loaded_step == 2 and eng.stats.reloads == 1
        np.testing.assert_array_equal(
            np.asarray(jax.tree_util.tree_leaves(eng.params)[0]), 0.0)
        assert len(done[rid].generated) == 8  # request survived the swap


def test_hot_reload_rejects_mismatched_arch():
    cfg = CFGS["dense"]
    other = Backbone(_dense(name="x", num_layers=3))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, _fedgan_style_state(other.init(jax.random.key(0))),
                        step=1)
        eng = ServeEngine(cfg, max_batch=1, max_seq=16, min_bucket=8)
        eng.watcher = CheckpointWatcher(d)
        with pytest.raises(RuntimeError):
            eng.maybe_reload()


def test_watcher_warns_once_on_wrong_layout_and_recovers():
    """A checkpoint the extractor cannot parse (e.g. raw Backbone params
    under the default FedGAN-state extractor) must warn once — not spin
    silently re-reading it every poll — and a later well-formed step must
    still load."""
    cfg = CFGS["dense"]
    params = Backbone(cfg).init(jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, step=1)  # raw params: no ["params"]["gen"]
        w = CheckpointWatcher(d)
        with pytest.warns(UserWarning, match="extract"):
            assert w.poll() is None
        assert w.poll() is None  # cached bad step: no second warning/IO
        save_checkpoint(d, _fedgan_style_state(params), step=2)
        got = w.poll()
        assert got is not None and got[1] == 2


def test_engine_waits_when_no_checkpoint_yet():
    with tempfile.TemporaryDirectory() as d:
        eng = ServeEngine(CFGS["dense"], max_batch=1, max_seq=16,
                          min_bucket=8, ckpt_dir=os.path.join(d, "empty"))
        assert eng.loaded_step is None  # falls back to init params, keeps polling
