"""Distribution substrate: param specs, cache specs, hlo analysis, and a
small-mesh lower+compile in a subprocess (device count must be set before
jax initialises, so the multi-device checks run in `python -c` children)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import batch_axes, batch_spec, current_batch_axes
from repro.launch.hlo_analysis import CollectiveStats, collective_bytes

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# subprocess lower+compile checks dominate the suite's wall clock;
# `make test-fast` excludes them via -m "not slow"
pytestmark = pytest.mark.slow


def test_batch_axes_context():
    assert current_batch_axes() == ("pod", "data")
    with batch_axes():
        assert current_batch_axes() == ()
        assert batch_spec(None)[0] is None
    with batch_axes("data"):
        assert batch_spec(None, "model") == (("data",), None, "model")
    assert current_batch_axes() == ("pod", "data")


def test_collective_parser_synthetic_hlo():
    hlo = """
HloModule test, entry_computation_layout={()->f32[]}

%body.1 (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %ar = f32[4,8]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[4,8]) tuple(%c, %ar)
}

%cond.1 (p: (s32[], f32[4,8])) -> pred[] {
  ROOT %lt = pred[] compare(%gte, %k), direction=LT
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %w = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  %ag = f32[16,8]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8]T(1,0), dimensions={0}
  ROOT %r = f32[4,8] get-tuple-element(%w), index=1
}
"""
    st = collective_bytes(hlo)
    # all-reduce: 4*8*4 bytes * 5 trips = 640; all-gather: 16*8*4 = 512
    assert st.bytes_by_op["all-reduce"] == 4 * 8 * 4 * 5
    assert st.bytes_by_op["all-gather"] == 16 * 8 * 4
    assert st.count_by_op["all-reduce"] == 5
    ax = st.bytes_by_axis({"data": 4, "model": 2})
    assert ax["model"] == 640  # group size 2
    assert ax["agent"] == 512  # group size 4


def test_param_specs_rules_and_divisibility():
    """Run on a subprocess mesh so axis sizes exist."""
    code = """
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P
from repro.dist.sharding import param_specs
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4, 2), ("data", "model"))
params = {
  "embed": {"table": jnp.zeros((512, 64))},
  "lm_head": {"w": jnp.zeros((64, 512))},
  "blocks": {"attn": {"wq": {"w": jnp.zeros((8, 64, 128))}},
             "mlp": {"w_down": {"w": jnp.zeros((8, 128, 64))}}},
  "odd": {"wq": {"w": jnp.zeros((64, 3))}},   # indivisible -> replicated
}
specs = param_specs(params, mesh)
out = {
  "embed": str(specs["embed"]["table"]),
  "head": str(specs["lm_head"]["w"]),
  "wq": str(specs["blocks"]["attn"]["wq"]["w"]),
  "down": str(specs["blocks"]["mlp"]["w_down"]["w"]),
  "odd": str(specs["odd"]["wq"]["w"]),
}
print(json.dumps(out))
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["embed"] == "PartitionSpec(None, 'model')"
    assert out["head"] == "PartitionSpec(None, 'model')"
    assert out["wq"] == "PartitionSpec(None, None, 'model')"     # layer dim replicated
    assert out["down"] == "PartitionSpec(None, 'model', None)"
    assert out["odd"] == "PartitionSpec(None, None)"             # 3 % 2 != 0


def test_dp_plan_reduces_collectives():
    """The intra-agent DP plan must cut collective bytes vs the TP baseline
    at identical FLOPs (the §Perf A/C mechanism)."""
    code = """
import jax, jax.numpy as jnp
from repro.models.config import ArchConfig, ShapeConfig
from repro.launch.steps import build_step, AGENTS_DATA, AGENTS_DATA_DP
from repro.launch.mesh import make_test_mesh
from repro.launch.hlo_analysis import collective_bytes, program_costs
mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                 num_kv_heads=2, d_ff=128, vocab_size=512, dtype=jnp.bfloat16,
                 remat=True, disc_layers=2, disc_d_model=32, disc_heads=2)
tr = ShapeConfig("train", 128, 8, "train")
out = {}
for plan in (AGENTS_DATA, AGENTS_DATA_DP):
    built = build_step(cfg, tr, mesh, K=2, plan=plan)
    with jax.set_mesh(mesh):
        comp = jax.jit(built.fn, in_shardings=built.in_shardings,
                       out_shardings=built.out_shardings).lower(*built.input_sds).compile()
    txt = comp.as_text()
    out[plan.name] = (collective_bytes(txt).total_bytes, program_costs(txt)["flops"])
base, dp = out["agents-data"], out["agents-data-dp"]
assert dp[0] < base[0] * 0.5, (dp[0], base[0])          # >=2x fewer bytes
assert abs(dp[1] - base[1]) < 0.2 * base[1]             # ~same FLOPs
print("DP_WINS", base[0] / max(dp[0], 1))
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "DP_WINS" in res.stdout


def test_partial_sharing_shrinks_agent_axis_bytes():
    """PS-FedGAN-style gen-only sync must move strictly fewer agent-axis
    all-reduce bytes than full FedAvg in the compiled round, by about the
    discriminator's share of the parameter bytes (HLO audit)."""
    code = """
import jax, jax.numpy as jnp
from repro.models.config import ArchConfig, ShapeConfig
from repro.launch.steps import build_step, make_lm_gan_task
from repro.launch.mesh import make_test_mesh
from repro.launch.hlo_analysis import collective_bytes
from repro.core.strategies import FedAvgSync, PartialSharing
from repro.dist.collectives import tree_bytes
mesh = make_test_mesh((2, 4), ("data", "model"))
cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                 num_kv_heads=2, d_ff=128, vocab_size=512, dtype=jnp.float32,
                 remat=False, disc_layers=2, disc_d_model=32, disc_heads=2)
tr = ShapeConfig("train", 128, 8, "train")
params = jax.eval_shape(make_lm_gan_task(cfg).init, jax.random.key(0))
gen_frac = tree_bytes(params["gen"]) / tree_bytes(params)
out = {}
for name, strat in (("full", FedAvgSync()), ("partial", PartialSharing())):
    built = build_step(cfg, tr, mesh, K=2, strategy=strat)
    import json as _json  # dryrun JSON-dumps meta (minus state_specs)
    _json.dumps({k: v for k, v in built.meta.items() if k != "state_specs"})
    with jax.set_mesh(mesh):
        comp = jax.jit(built.fn, in_shardings=built.in_shardings,
                       out_shardings=built.out_shardings).lower(*built.input_sds).compile()
    txt = comp.as_text()
    # skip_loops drops the per-step in-scan traffic, leaving the
    # once-per-round parameter sync this strategy choice controls
    sync = collective_bytes(txt, skip_loops=True).bytes_by_axis(
        {"data": 2, "model": 4})
    out[name] = (sync["agent"],
                 collective_bytes(txt).bytes_by_axis({"data": 2, "model": 4})["agent"])
assert 0 < out["partial"][0] < out["full"][0], out
assert out["partial"][1] < out["full"][1], out   # total shrinks too
ratio = out["partial"][0] / out["full"][0]
assert abs(ratio - gen_frac) < 0.15, (ratio, gen_frac)
print("PARTIAL_SHRINKS", ratio, gen_frac)
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "PARTIAL_SHRINKS" in res.stdout


@pytest.mark.parametrize("shape_kind", ["train", "prefill", "decode"])
def test_small_mesh_lower_compile(shape_kind):
    """The step builders must lower+compile on a (4, 2) test mesh (the
    512-device production dry-run runs via launch/dryrun.py)."""
    code = f"""
import jax, jax.numpy as jnp
from repro.models.config import ArchConfig, ShapeConfig
from repro.launch.steps import build_step
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4, 2), ("data", "model"))
cfg = ArchConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                 num_kv_heads=2, d_ff=128, vocab_size=256, dtype=jnp.float32,
                 remat=False, disc_layers=2, disc_d_model=32, disc_heads=2)
shape = ShapeConfig("x", 64, 8, "{shape_kind}")
kw = {{"K": 2}} if "{shape_kind}" == "train" else {{}}
built = build_step(cfg, shape, mesh, **kw)
with jax.set_mesh(mesh):
    comp = jax.jit(built.fn, in_shardings=built.in_shardings,
                   out_shardings=built.out_shardings).lower(*built.input_sds).compile()
print("COMPILED", comp.cost_analysis()["flops"] > 0)
"""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "COMPILED True" in res.stdout
