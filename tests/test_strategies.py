"""SyncStrategy API: legacy-mode parity (bit-identical trajectories), the
deprecation shim, and the beyond-paper strategies (partial sharing,
subsampled participation, adaptive-K) with their wire-byte accounting."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FedGAN, FedGANConfig, GANTask, strategies)
from repro.core.strategies import (AdaptiveK, FedAvgSync, Hierarchical,
                                   LocalOnly, PartialSharing, PerStepGradAvg,
                                   SubsampledFedAvg, get_strategy,
                                   strategy_from_mode)
from repro.optim import SGD, constant, equal_timescale

tmap = jax.tree_util.tree_map


def quad_task():
    def init(rng):
        kg, kd = jax.random.split(rng)
        return {"gen": {"theta": 0.1 * jax.random.normal(kg, (3,))},
                "disc": {"w": 0.1 * jax.random.normal(kd, (3,))}}

    def disc_loss(params, batch, rng):
        xm = jnp.mean(batch["x"], axis=0)
        g = jax.lax.stop_gradient(params["gen"]["theta"])
        return (-jnp.dot(params["disc"]["w"], xm - g)
                + 0.5 * jnp.sum(params["disc"]["w"] ** 2))

    def gen_loss(params, batch, rng):
        w = jax.lax.stop_gradient(params["disc"]["w"])
        return jnp.dot(w, params["gen"]["theta"])

    return GANTask(init=init, disc_loss=disc_loss, gen_loss=gen_loss)


def _round_inputs(rng, K, P, A, n=8, d=3):
    """Non-iid per-agent batches so local runs diverge."""
    x = (jax.random.normal(rng, (K, P, A, n, d))
         + jnp.arange(P * A, dtype=jnp.float32).reshape(P, A)[None, :, :, None, None])
    seeds = jax.random.randint(jax.random.fold_in(rng, 7), (K, P, A), 0,
                               2 ** 31 - 1).astype(jnp.uint32)
    return {"x": x}, seeds


def _fed(strategy=None, K=4, grid=(2, 2), **cfg_kw):
    return FedGAN(quad_task(),
                  FedGANConfig(agent_grid=grid, sync_interval=K,
                               strategy=strategy, **cfg_kw),
                  opt_g=SGD(), opt_d=SGD(),
                  scales=equal_timescale(constant(0.05)))


def _run_round(fed, rng=1, K=4, n_rounds=1):
    P, A = fed.cfg.agent_grid
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    for r in range(n_rounds):
        batches, seeds = _round_inputs(jax.random.key(rng + r), K, P, A)
        state, metrics = round_fn(state, batches, seeds)
    return state, metrics


def _gen_synced(state, p0=(0, 0), p1=(-1, -1), atol=0.0):
    th = state["params"]["gen"]["theta"]
    return bool(jnp.allclose(th[p0], th[p1], atol=atol))


# ---------------------------------------------------------------------------
# parity: every legacy mode string == its strategy, bit for bit
# ---------------------------------------------------------------------------

LEGACY_PAIRS = [
    ("fedgan", dict(mode="fedgan"), FedAvgSync()),
    ("distributed", dict(mode="distributed"), PerStepGradAvg()),
    ("local_only", dict(mode="local_only"), LocalOnly()),
    ("hierarchical", dict(mode="hierarchical", intra_interval=2),
     Hierarchical(intra_interval=2)),
    ("fedgan_bf16", dict(mode="fedgan", sync_dtype=jnp.bfloat16),
     FedAvgSync(sync_dtype=jnp.bfloat16)),
    ("fedgan_opt", dict(mode="fedgan", average_opt_state=True),
     FedAvgSync(average_opt_state=True)),
]


@pytest.mark.parametrize("name,legacy_kw,strategy",
                         LEGACY_PAIRS, ids=[p[0] for p in LEGACY_PAIRS])
def test_legacy_mode_parity_bit_identical(name, legacy_kw, strategy):
    """Same seed, two rounds: the deprecated mode string and its strategy
    must produce byte-identical training trajectories."""
    outs = []
    for kw in (legacy_kw, dict(strategy=strategy)):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            fed = _fed(**kw)
            state, _ = _run_round(fed, n_rounds=2)
            outs.append(state)
    for a, b in zip(jax.tree_util.tree_leaves(outs[0]),
                    jax.tree_util.tree_leaves(outs[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mode_shim_warns_and_resolves():
    cfg = FedGANConfig(mode="hierarchical", sync_interval=4, intra_interval=2)
    with pytest.warns(DeprecationWarning):
        strat = cfg.resolve_strategy()
    assert isinstance(strat, Hierarchical) and strat.intra_interval == 2
    # the strategy path is warning-free
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        FedGANConfig(strategy=FedAvgSync()).resolve_strategy()
        FedGANConfig().resolve_strategy()  # default is FedAvgSync, no warning


def test_strategy_conflicts_with_legacy_fields():
    """Mixing strategy= with the deprecated knobs must fail loudly, not
    silently drop the knob."""
    for kw in (dict(mode="fedgan"), dict(sync_dtype=jnp.bfloat16),
               dict(intra_interval=2), dict(average_opt_state=True)):
        with pytest.raises(ValueError, match="conflicts"):
            FedGANConfig(strategy=FedAvgSync(), **kw).resolve_strategy()


def test_registry_and_unknowns():
    assert isinstance(get_strategy("ps_fedgan"), PartialSharing)
    with pytest.raises(ValueError):
        get_strategy("nonsense")
    with pytest.raises(ValueError):
        strategy_from_mode("nonsense")
    with pytest.raises(ValueError):
        FedGANConfig(mode="nonsense").validate()


def test_strategy_validate_rejects_bad_configs():
    with pytest.raises(ValueError):
        Hierarchical(intra_interval=3).validate(FedGANConfig(sync_interval=4))
    with pytest.raises(ValueError):
        Hierarchical().validate(FedGANConfig(sync_interval=4))
    with pytest.raises(ValueError):
        SubsampledFedAvg(fraction=0.0).validate(FedGANConfig())
    with pytest.raises(ValueError):
        AdaptiveK(sync_every=0).validate(FedGANConfig())
    with pytest.raises(ValueError):
        FedAvgSync(subtrees=("nonsense",)).validate(FedGANConfig())


# ---------------------------------------------------------------------------
# PartialSharing: what-to-sync selection
# ---------------------------------------------------------------------------


def test_partial_sharing_syncs_gen_only():
    fed = _fed(PartialSharing())
    state, _ = _run_round(fed)
    th = state["params"]["gen"]["theta"]
    w = state["params"]["disc"]["w"]
    assert bool(jnp.allclose(th[0, 0], th[-1, -1], atol=1e-6))
    assert not bool(jnp.allclose(w[0, 0], w[-1, -1], atol=1e-6))


def test_partial_sharing_bytes_half_of_full():
    """quad_task has equal-size G and D -> gen-only sync is exactly half."""
    fed = _fed(FedAvgSync())
    state = fed.init_state(jax.random.key(0))
    params = fed.agent_params(state)
    full = FedAvgSync().bytes_per_round(fed.cfg, params)
    partial = PartialSharing().bytes_per_round(fed.cfg, params)
    assert partial * 2 == full
    acct = fed.comm_bytes_per_round(state)
    assert acct["strategy_bytes_per_round"] == full
    assert acct["per_agent_per_round"]["fedgan"] == full


# ---------------------------------------------------------------------------
# SubsampledFedAvg: participation mask folded into the weights
# ---------------------------------------------------------------------------


def test_subsampled_participants_average_others_keep_local():
    K, grid = 4, (1, 4)
    strat = SubsampledFedAvg(fraction=0.5)
    fed_sub = _fed(strat, K=K, grid=grid)
    fed_loc = _fed(LocalOnly(), K=K, grid=grid)
    sub, _ = _run_round(fed_sub, K=K)
    loc, _ = _run_round(fed_loc, K=K)

    mask = np.asarray(strat.participation_mask(fed_sub, {"step": jnp.int32(K)}))
    assert mask.sum() == 2  # ceil(0.5 * 4)

    # expected: weighted average of the PRE-sync (local-only) params over
    # the participants, applied to participants only
    w = np.asarray(fed_sub._w()) * mask
    w = w / w.sum()
    pre = np.asarray(loc["params"]["gen"]["theta"])
    avg = np.einsum("pa,pa...->...", w, pre)
    post = np.asarray(sub["params"]["gen"]["theta"])
    for p in range(mask.shape[0]):
        for a in range(mask.shape[1]):
            want = avg if mask[p, a] else pre[p, a]
            np.testing.assert_allclose(post[p, a], want, rtol=1e-6, atol=1e-7)


def test_subsampled_bytes_scale_with_participation():
    fed = _fed(FedAvgSync(), grid=(1, 4))
    params = fed.agent_params(fed.init_state(jax.random.key(0)))
    full = FedAvgSync().bytes_per_round(fed.cfg, params)
    half = SubsampledFedAvg(fraction=0.5).bytes_per_round(fed.cfg, params)
    assert half == full // 2


def test_subsampled_mask_seed_shim_warns_and_matches_schedule():
    """The old ``mask_seed=`` knob is a deprecation shim over the shared
    ParticipationSchedule: it must warn loudly and produce the bit-exact
    trajectory of ``schedule=ParticipationSchedule(seed=...)``."""
    from repro.core import strategies as strategies_mod
    from repro.core.participation import ParticipationSchedule
    strategies_mod._MASK_SEED_WARNED = False   # re-arm the warn-once latch
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = SubsampledFedAvg(fraction=0.5, mask_seed=42)
    assert any(issubclass(w.category, DeprecationWarning)
               and "mask_seed" in str(w.message) for w in rec)
    assert legacy.resolve_schedule() == ParticipationSchedule(seed=42)

    new = SubsampledFedAvg(fraction=0.5,
                           schedule=ParticipationSchedule(seed=42))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old_state, _ = _run_round(_fed(legacy, grid=(1, 4)), n_rounds=3)
    new_state, _ = _run_round(_fed(new, grid=(1, 4)), n_rounds=3)
    for a, b in zip(jax.tree_util.tree_leaves(old_state),
                    jax.tree_util.tree_leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_subsampled_mask_seed_warns_exactly_once():
    """Sweep configs construct hundreds of strategy instances; the shim
    warns on the first one and stays silent after — a per-instance
    warning would drown the log without adding information."""
    from repro.core import strategies as strategies_mod
    strategies_mod._MASK_SEED_WARNED = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        SubsampledFedAvg(fraction=0.5, mask_seed=42)
        SubsampledFedAvg(fraction=0.5, mask_seed=43)
        SubsampledFedAvg(fraction=0.25, mask_seed=42)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "mask_seed" in str(w.message)]
    assert len(dep) == 1
    # schedule-only construction never trips the latch
    strategies_mod._MASK_SEED_WARNED = False
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        from repro.core.participation import ParticipationSchedule
        SubsampledFedAvg(fraction=0.5,
                         schedule=ParticipationSchedule(seed=42))
    assert not any(issubclass(w.category, DeprecationWarning) for w in rec)


def test_subsampled_mask_seed_and_schedule_conflict():
    from repro.core.participation import ParticipationSchedule
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        strat = SubsampledFedAvg(mask_seed=1,
                                 schedule=ParticipationSchedule(seed=2))
        with pytest.raises(ValueError, match="competing seed streams"):
            strat.validate(FedGANConfig(agent_grid=(1, 4), sync_interval=4))


# ---------------------------------------------------------------------------
# AdaptiveK: warmup-K schedule across rounds
# ---------------------------------------------------------------------------


def test_adaptive_k_syncs_on_schedule():
    K, grid = 2, (1, 4)
    fed = _fed(AdaptiveK(warmup_rounds=1, sync_every=2), K=K, grid=grid)
    state = fed.init_state(jax.random.key(0))
    round_fn = jax.jit(fed.round)
    synced = []
    for r in range(4):
        batches, seeds = _round_inputs(jax.random.key(10 + r), K, *grid)
        state, _ = round_fn(state, batches, seeds)
        synced.append(_gen_synced(state, (0, 0), (0, -1), atol=1e-7))
    # r0 warmup sync; r1 skipped; r2 sync; r3 skipped
    assert synced == [True, False, True, False]


def test_adaptive_k_bytes_amortised():
    fed = _fed(FedAvgSync())
    params = fed.agent_params(fed.init_state(jax.random.key(0)))
    full = FedAvgSync().bytes_per_round(fed.cfg, params)
    assert AdaptiveK(sync_every=2).bytes_per_round(fed.cfg, params) == full // 2


# ---------------------------------------------------------------------------
# accounting coherence across strategies
# ---------------------------------------------------------------------------


def test_bytes_accounting_relations():
    K = 4
    fed = _fed(FedAvgSync(), K=K)
    cfg = fed.cfg
    params = fed.agent_params(fed.init_state(jax.random.key(0)))
    full = FedAvgSync().bytes_per_round(cfg, params)
    assert PerStepGradAvg().bytes_per_round(cfg, params) == full * K
    assert LocalOnly().bytes_per_round(cfg, params) == 0
    assert FedAvgSync(sync_dtype=jnp.bfloat16).bytes_per_round(cfg, params) \
        == full // 2  # f32 master, bf16 wire
    n_segs = K // 2
    assert Hierarchical(intra_interval=2).bytes_per_round(cfg, params) \
        == full * (1 + n_segs)
    # the intra-pod tier always moves the whole params tree at storage
    # dtype — compression applies only to the cross-pod round sync
    assert Hierarchical(intra_interval=2, sync_dtype=jnp.bfloat16) \
        .bytes_per_round(cfg, params) == full // 2 + n_segs * full
    # opt-state averaging moves the Adam moments too (SGD state is empty,
    # so build the count from the tree directly)
    opt = fed.agent_opt_state(fed.init_state(jax.random.key(0)))
    from repro.dist import collectives
    extra = collectives.sync_bytes(opt["opt_g"]) + collectives.sync_bytes(opt["opt_d"])
    assert FedAvgSync(average_opt_state=True).bytes_per_round(cfg, params, opt=opt) \
        == full + 2 * extra


def test_opt_state_sync_preserves_adam_count():
    """average_opt_state must not average integer leaves: the Adam step
    count would truncate to zero under float weights, resetting bias
    correction every round."""
    from repro.optim import Adam
    fed = FedGAN(quad_task(),
                 FedGANConfig(agent_grid=(1, 4), sync_interval=4,
                              strategy=FedAvgSync(average_opt_state=True)),
                 opt_g=Adam(), opt_d=Adam(),
                 scales=equal_timescale(constant(0.05)))
    state, _ = _run_round(fed, K=4)
    assert int(state["opt_g"]["count"][0, 0]) == 4
    assert int(state["opt_d"]["count"][0, 3]) == 4
    # the float moments DID sync
    mu = state["opt_g"]["mu"]["theta"]
    np.testing.assert_allclose(np.asarray(mu[0, 0]), np.asarray(mu[0, 3]),
                               rtol=1e-6)


def test_round_metrics_shape_unchanged_by_strategy():
    for strat in (FedAvgSync(), PerStepGradAvg(), LocalOnly(),
                  Hierarchical(intra_interval=2), PartialSharing(),
                  SubsampledFedAvg(fraction=0.5),
                  AdaptiveK(warmup_rounds=1, sync_every=2)):
        fed = _fed(strat)
        _, metrics = _run_round(fed)
        assert metrics["d_loss"].shape == (4,)
        assert np.isfinite(np.asarray(metrics["d_loss"])).all(), strat.name
